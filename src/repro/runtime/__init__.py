"""repro.runtime — the session layer: one engine owning pool, plan,
cache, and train steps.

* :class:`~repro.runtime.spec.RunSpec` — a typed, JSON-round-trippable
  description of one run (the trainer flags are a veneer over it).
* :class:`~repro.runtime.session.EdgeSession` — the run engine: device
  pool, Plan resolution, mesh, activation cache (+ prefetch), and the
  four compiled step variants behind one ``step(batch)`` dispatch.
* :class:`~repro.runtime.runner.EpochRunner` — the epoch loop as a
  generator of :class:`~repro.runtime.session.StepEvent` /
  :class:`~repro.runtime.runner.EpochReport` records, with observability
  attached as :class:`~repro.runtime.runner.RunHooks` callbacks
  (:class:`~repro.runtime.runner.ConsoleHook` reproduces the CLI line).

Importing this package touches no JAX device state: a session forces
the host device count (CPU pool emulation) inside ``open()``, before
its first backend-touching import — so build specs and sessions freely
at module scope, but open them before any other JAX backend use.
"""

from repro.runtime.runner import ConsoleHook, EpochReport, EpochRunner, RunHooks
from repro.runtime.session import EdgeSession, StepEvent
from repro.runtime.spec import RunSpec, RunSpecError

__all__ = [
    "ConsoleHook",
    "EdgeSession",
    "EpochReport",
    "EpochRunner",
    "RunHooks",
    "RunSpec",
    "RunSpecError",
    "StepEvent",
]
