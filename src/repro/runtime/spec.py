"""RunSpec — the typed, serializable description of one fine-tuning run.

The trainer CLI's flag soup, the examples' hand-rolled constant blocks,
and the benchmarks' ad-hoc wiring all collapse into this one dataclass:
a :class:`RunSpec` is the single source of truth an
:class:`~repro.runtime.session.EdgeSession` executes. It is pure Python
(safe to build, validate, and JSON-round-trip before any JAX backend
initialisation — the session relies on that to size the device pool
first), and every field mirrors one trainer flag (docs/CLI.md).

    spec = RunSpec(arch="internlm2-1.8b", reduced=True, dp=2, stages=2)
    spec.validate()                # layout errors before any compute
    RunSpec.from_json(spec.to_json()) == spec
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Optional

INIT_METHODS = ("pruning", "random")
KERNEL_IMPLS = ("ref", "pallas")
QUANT_BITS = (4, 8)


class RunSpecError(ValueError):
    """An invalid or inconsistent RunSpec (bad field value, impossible
    batch/mesh layout, plan/arch mismatch)."""


@dataclass(frozen=True)
class RunSpec:
    """One run of the paper's workflow (Fig. 4), as data.

    Defaults match the trainer CLI's defaults exactly; ``use_cache``
    inverts the CLI's ``--no-cache``. ``plan`` is ``None`` (CLI-pinned
    dp×stages), ``"auto"`` (Alg. 1 selects stages/boundaries/micro), or
    a path to a JSON saved with ``save_plan`` (replay).
    """

    # model / workload
    arch: str = "internlm2-1.8b"
    reduced: bool = False
    epochs: int = 3
    steps_per_epoch: int = 8
    batch: int = 4
    seq: int = 32
    seed: int = 0
    # adapter + backbone treatment
    r: int = 8
    init: str = "pruning"
    quant: Optional[int] = None
    lr: float = 3e-3
    # activation cache
    use_cache: bool = True
    cache_dir: Optional[str] = None
    cache_compress: str = "f32"
    cache_budget_mb: int = 4096
    # parallelism / planning
    dp: int = 1
    stages: int = 1
    micro: Optional[int] = None
    plan: Optional[str] = None
    pool: Optional[int] = None
    save_plan: Optional[str] = None
    calibrate: bool = False
    # compute path for BOTH the epoch-1 frozen forward (OpSet dispatch:
    # quantized matmuls, Pallas flash attention, storage-form taps) and
    # the cached-epoch step ("ref" = dense jnp oracle)
    kernels: str = "ref"
    # outputs
    ckpt: Optional[str] = None

    # -- derived ------------------------------------------------------------

    @property
    def plan_mode(self) -> bool:
        return self.plan is not None

    @property
    def total_devices(self) -> int:
        """CLI-pinned mesh size (the plan may override dp×stages)."""
        return self.dp * self.stages

    def arch_config(self):
        """The effective :class:`~repro.configs.base.ArchConfig`
        (``reduced`` applied). Pure Python — no JAX state touched."""
        from repro.configs import get_arch

        cfg = get_arch(self.arch)
        return cfg.reduced() if self.reduced else cfg

    def default_micro(self) -> Optional[int]:
        """The micro-batch count when the spec pins one statically:
        ``micro`` if set, else the stage count when distributed, else the
        4-micro planning-report default. ``None`` in plan mode with no
        override (the plan supplies or sweeps it)."""
        if self.micro is not None:
            return self.micro
        if self.plan_mode:
            return None
        return self.stages if self.total_devices > 1 else 4

    # -- validation ---------------------------------------------------------

    def validate(self) -> "RunSpec":
        """Raise :class:`RunSpecError` on any statically-checkable
        inconsistency: enum fields, batch divisibility, mesh layout,
        period/stage compatibility. Plan-file-dependent checks (pool ≥
        saved plan's stages, plan/arch period match) run when the
        session resolves the plan. Returns self for chaining."""
        def bad(msg):
            raise RunSpecError(msg)

        for name in ("epochs", "steps_per_epoch", "batch", "seq", "r",
                     "dp", "stages", "cache_budget_mb"):
            if getattr(self, name) < 1:
                bad(f"{name} must be >= 1, got {getattr(self, name)}")
        if self.init not in INIT_METHODS:
            bad(f"init must be one of {INIT_METHODS}, got {self.init!r}")
        if self.kernels not in KERNEL_IMPLS:
            bad(f"kernels must be one of {KERNEL_IMPLS}, got {self.kernels!r}")
        if self.quant is not None and self.quant not in QUANT_BITS:
            bad(f"quant must be one of {QUANT_BITS} or None, got {self.quant!r}")
        from repro.core.activation_cache import COMPRESS_POLICIES

        if self.cache_compress not in COMPRESS_POLICIES:
            bad(f"cache_compress must be one of {COMPRESS_POLICIES}, "
                f"got {self.cache_compress!r}")
        if self.micro is not None:
            if self.micro < 1:
                bad(f"micro must be >= 1, got {self.micro}")
            if self.batch % self.micro:
                bad(f"batch {self.batch} must be divisible by micro={self.micro}")
        if self.pool is not None and self.pool < 1:
            bad(f"pool must be >= 1, got {self.pool}")
        if self.plan_mode and self.plan != "auto":
            # a saved plan is pure JSON (no JAX state) — load it here so
            # pool-vs-stages inconsistencies surface before any compute
            from repro.core.planner import Plan

            try:
                saved = Plan.load(self.plan)
            except (OSError, ValueError, KeyError) as e:
                bad(f"cannot load plan file {self.plan!r}: {e}")
            if self.pool is not None and self.pool < saved.n_stages:
                bad(f"pool {self.pool} is smaller than the saved plan's "
                    f"{saved.n_stages} stages; pass pool >= "
                    f"{saved.n_stages} or replan with plan='auto'")
        if not self.plan_mode and self.total_devices > 1:
            n_micro = self.default_micro()
            if self.batch % n_micro:
                bad(f"batch {self.batch} must be divisible by the "
                    f"{n_micro} micro-batches")
            if (self.batch // n_micro) % self.dp:
                bad(f"micro-batch size {self.batch // n_micro} must be "
                    f"divisible by dp={self.dp}")
            cfg = self.arch_config()
            if cfg.n_periods % self.stages:
                bad(f"stages {self.stages} must divide n_periods="
                    f"{cfg.n_periods} of {cfg.name} (or use plan='auto' "
                    f"for uneven boundaries)")
        return self

    def replace(self, **changes) -> "RunSpec":
        """A modified copy, re-validated — how the fleet CLI stamps
        per-job seeds, cache dirs, and checkpoint paths onto one base
        spec (``RunSpec`` is frozen)."""
        return dataclasses.replace(self, **changes).validate()

    # -- (de)serialisation --------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "RunSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise RunSpecError(f"unknown RunSpec field(s): {unknown}")
        return cls(**d)

    def to_json(self, indent: Optional[int] = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "RunSpec":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    @classmethod
    def load(cls, path: str) -> "RunSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    @classmethod
    def from_args(cls, ns) -> "RunSpec":
        """Build from the trainer CLI's parsed ``argparse`` namespace —
        the flags are a thin veneer over this constructor (docs/CLI.md)."""
        return cls(
            arch=ns.arch, reduced=ns.reduced, epochs=ns.epochs,
            steps_per_epoch=ns.steps_per_epoch, batch=ns.batch, seq=ns.seq,
            seed=ns.seed, r=ns.r, init=ns.init, quant=ns.quant, lr=ns.lr,
            use_cache=not ns.no_cache, cache_dir=ns.cache_dir,
            cache_compress=ns.cache_compress,
            cache_budget_mb=ns.cache_budget_mb, dp=ns.dp, stages=ns.stages,
            micro=ns.micro, plan=ns.plan, pool=ns.pool,
            save_plan=ns.save_plan, calibrate=ns.calibrate,
            kernels=ns.kernels, ckpt=ns.ckpt,
        )
