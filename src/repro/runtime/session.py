"""EdgeSession — one engine owning pool, plan, cache, and train steps.

The paper's orchestrator (Alg. 1 plans a device pool; epoch 1 runs
hybrid DP×PP; cached epochs drop to pure DP) as a programmable object
instead of a CLI script. An :class:`EdgeSession` takes a validated
:class:`~repro.runtime.spec.RunSpec` and owns the whole run lifecycle:

* **device pool** — forcing the host device count *before* the first
  JAX backend initialisation stays a documented pre-backend hook:
  ``open()`` resolves the pool size (plan file, ``pool``, dp×stages)
  and calls :func:`repro.compat.force_host_device_count` before any
  backend-touching import runs. Construct the session (and its spec)
  before initialising a JAX backend, or bring your own devices.
* **plan** — resolves ``spec.plan`` (``"auto"`` runs Alg. 1 and sweeps
  the micro count; a path replays a saved plan; ``None`` pins the mesh
  to dp×stages and keeps the planner as an offline report), derives the
  executable :class:`~repro.core.planner.StagePartition`, and builds
  the mesh via :mod:`repro.launch.mesh`.
* **cache** — opens the (optionally persistent) activation cache with
  the shared :func:`~repro.core.activation_cache.manifest_for` identity
  and runs each fully-resident epoch through a
  :class:`~repro.core.activation_cache.CachePrefetcher` (used as a
  context manager — an exception mid-epoch joins the worker thread).
* **steps** — compiles the four step variants (``pac_train_step``,
  ``pipeline_pac_train_step``, ``pac_cached_train_step``,
  ``dp_cached_train_step``) behind one :meth:`step` dispatch, including
  the lazily-built cached step (its sharding/shard_map wrapper needs
  the first cached batch's tree structure).

Typical use (the 10-line quickstart)::

    from repro.runtime import RunSpec, EdgeSession

    spec = RunSpec(arch="internlm2-1.8b", reduced=True, epochs=3)
    reports = EdgeSession(spec).run()          # list of EpochReport

or step-by-step::

    with EdgeSession(spec) as s:
        for report in EpochRunner(s).epochs():
            ...
        s.finish()            # checkpoint + cache manifest

Observability attaches as hooks (:class:`~repro.runtime.runner.RunHooks`)
instead of prints; pass ``log=print`` for the CLI's informational lines.
"""

from __future__ import annotations

import contextlib
import functools
from dataclasses import dataclass

import numpy as np

from repro import compat
from repro.runtime.spec import RunSpec, RunSpecError


@dataclass
class StepEvent:
    """One training step, as seen by hooks and the runner."""

    epoch: int
    index: int
    loss: float
    cache_hit: bool
    mode: str          # "full" | "cached" | "hybrid dp2xpp2" | ...
    wall_s: float


class EdgeSession:
    """The run engine. ``open()``/``close()`` (or ``with``) bracket the
    heavyweight state; :meth:`step` is the single dispatch the epoch
    loop calls; :meth:`finish` writes the run's durable outputs
    (checkpoint, cache manifest)."""

    def __init__(self, spec: RunSpec, *, log=None):
        spec.validate()
        self.spec = spec
        self._log = log if log is not None else (lambda *a: None)
        self._opened = False
        self._finished = False
        self._prefetch = None
        self._saved_plan = None
        # populated by open():
        self.cfg = None
        self.plan = None
        self.partition = None
        self.mesh = None
        self.backbone = None      # the (possibly quantized) frozen tree
        self.adapter = None
        self.opt = None
        self.corpus = None
        self.pipe = None
        self.cache = None
        self.warm = False
        self.meta = None
        self.n_micro = None
        self.exec_dp = spec.dp
        self.exec_stages = spec.stages
        self.distributed = False

    # -- lifecycle ----------------------------------------------------------

    def __enter__(self) -> "EdgeSession":
        return self.open()

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def _resolve_pool(self) -> int:
        """Pre-backend: size the device pool (and force fake host devices
        on CPU) before JAX locks the device count. Pure Python — a saved
        plan is loaded as JSON only."""
        spec = self.spec
        pool = spec.pool or max(spec.total_devices, 4)
        if spec.plan_mode and spec.plan != "auto":
            from repro.core.planner import Plan

            self._saved_plan = Plan.load(spec.plan)
            if spec.pool is not None and spec.pool < self._saved_plan.n_stages:
                raise RunSpecError(
                    f"pool {spec.pool} is smaller than the saved plan's "
                    f"{self._saved_plan.n_stages} stages; pass pool >= "
                    f"{self._saved_plan.n_stages} or replan with plan='auto'")
            # size the replay pool from the plan's own stage count before
            # the device-count knob locks
            pool = max(pool, self._saved_plan.n_stages)
        if spec.plan_mode:
            # the plan decides dp×stages later, but the fake-device count
            # must precede the first backend initialisation — force the
            # whole pool (the mesh uses its first dp·stages devices)
            compat.force_host_device_count(pool)
        elif spec.total_devices > 1:
            compat.force_host_device_count(spec.total_devices)
        return pool

    def _build_plan(self, pool: int, planner_mb: int, n_micro: int, max_stages):
        """One construction site for both the executed plan and the
        offline report: period-granular costs (analytic or
        HLO-calibrated) through Alg. 1."""
        from repro.core.planner import HybridParallelismPlanner, JETSON_NANO_H
        from repro.launch.costs import resolve_cost_model

        spec = self.spec
        cost_model = resolve_cost_model(
            spec.calibrate, micro_batch=max(1, spec.batch // n_micro),
            quant_bits=spec.quant)
        return HybridParallelismPlanner(
            cost_model.period_costs(self.cfg, "pac", seq_len=spec.seq),
            [JETSON_NANO_H] * pool, planner_mb, n_micro,
        ).plan(max_stages=max_stages)

    def open(self) -> "EdgeSession":
        if self._opened:
            return self
        spec = self.spec
        pool = self._resolve_pool()

        import jax

        from repro.core import steps
        from repro.core.activation_cache import (
            ActivationCache,
            manifest_for,
            open_persistent,
        )
        from repro.core.init_methods import pruning_init
        from repro.core.parallel_adapters import init_adapter
        from repro.core.quantization import quantize_tree, tree_storage_bytes
        from repro.data import DataPipeline, SyntheticPersonalCorpus
        from repro.launch.mesh import make_edge_mesh, make_plan_mesh
        from repro.models import backbone as bb
        from repro.optim import adamw_init

        log = self._log
        cfg = self.cfg = spec.arch_config()
        log(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
            f"active≈{cfg.active_param_count()/1e6:.1f}M")

        # ---- plan resolution: the Plan is the runtime contract ----------
        partition = None
        exec_dp, exec_stages = spec.dp, spec.stages
        total = spec.total_devices
        n_micro = spec.default_micro()
        if spec.plan_mode:
            n_micro = spec.micro or (
                self._saved_plan.micro_batches if self._saved_plan else None)
            if n_micro is not None and spec.batch % n_micro:
                raise RunSpecError(
                    f"batch {spec.batch} must be divisible by the plan's "
                    f"{n_micro} micro-batches (override with micro=)")
            if spec.plan == "auto":
                smax = min(pool, cfg.n_periods)
                if n_micro is None:
                    # the plan selects the micro count too: σ-optimal
                    # latency over the batch's divisors
                    cands = [m for m in range(1, spec.batch + 1)
                             if spec.batch % m == 0]
                    n_micro, plan = min(
                        ((m, self._build_plan(pool, spec.batch // m, m, smax))
                         for m in cands),
                        key=lambda t: t[1].minibatch_latency)
                else:
                    plan = self._build_plan(pool, spec.batch // n_micro,
                                            n_micro, smax)
            else:
                if spec.calibrate:
                    log("note: --calibrate has no effect when replaying a "
                        "saved plan; re-run with --plan auto to replan")
                plan = self._saved_plan
            mb = spec.batch // n_micro
            partition = plan.stage_partition()
            if partition.n_periods != cfg.n_periods:
                raise RunSpecError(
                    f"plan partitions {partition.n_periods} periods but "
                    f"{cfg.name} has {cfg.n_periods} — replan for this arch")
            exec_stages = partition.n_stages
            # widest replica count the pool and the batch layout support
            exec_dp = max(1, pool // exec_stages)
            while exec_dp > 1 and (spec.batch // n_micro) % exec_dp:
                exec_dp -= 1
            log("plan: " + plan.describe())
            for s, split in enumerate(partition.samples_per_device):
                if sum(split) != mb:
                    log(f"note: stage {s} was planned for {sum(split)} "
                        f"samples per micro-batch, executing {mb}")
            total = exec_dp * exec_stages
            self.plan = plan
        distributed = total > 1
        if distributed:
            if partition is None and cfg.n_periods % exec_stages:
                raise RunSpecError(
                    f"stages {exec_stages} must divide n_periods={cfg.n_periods}")
            # fail fast on an impossible batch layout, before any compute
            DataPipeline.dp_microbatches(
                {"tokens": np.zeros((spec.batch, spec.seq), np.int32)},
                n_micro, exec_dp)
        self.partition = partition
        self.n_micro = n_micro
        self.exec_dp, self.exec_stages = exec_dp, exec_stages
        self.distributed = distributed

        # ---- model: backbone (frozen, maybe quantized) + adapter --------
        bp = bb.init_backbone(jax.random.PRNGKey(spec.seed), cfg)
        if spec.quant:
            bq = quantize_tree(bp, bits=spec.quant)
            log(f"backbone quantized INT{spec.quant}: "
                f"{tree_storage_bytes(bp)/2**20:.1f} MB → "
                f"{tree_storage_bytes(bq)/2**20:.1f} MB")
        else:
            bq = bp
        self.backbone = bq
        if spec.init == "pruning":
            self.adapter = pruning_init(
                jax.random.PRNGKey(spec.seed + 1), bp, cfg, r=spec.r)
        else:
            self.adapter = init_adapter(
                jax.random.PRNGKey(spec.seed + 1), cfg, r=spec.r)
        n_train = sum(x.size for x in jax.tree.leaves(self.adapter))
        log(f"trainable (adapter) params: {n_train/1e6:.2f}M "
            f"({n_train/cfg.param_count():.2%} of backbone)")
        self.opt = adamw_init(self.adapter)

        if not spec.plan_mode:
            # offline planning report (paper Step 3-4): the plan is
            # computed for the executed micro-batch count at period
            # granularity; the stage count is pinned to the mesh shape
            # and the planner's σ-optimum is reported against it.
            # (plan= makes this plan the execution contract instead.)
            plan = self._build_plan(pool, spec.batch, n_micro,
                                    exec_stages if distributed else None)
            log("edge-pool plan: " + plan.describe().splitlines()[0])
            if distributed and plan.n_stages != exec_stages:
                log(f"note: planner's σ-optimal stage count is "
                    f"{plan.n_stages}; executing --stages {exec_stages} "
                    f"(pass --plan auto to execute the σ-optimum)")
            self.plan = plan
        if spec.save_plan:
            log(f"plan saved: {self.plan.save(spec.save_plan)}")

        # ---- mesh -------------------------------------------------------
        if distributed:
            if spec.plan_mode:
                self.mesh = make_plan_mesh(partition, dp=exec_dp)
                ragged = "" if partition.is_uniform else (
                    f", ragged periods {partition.periods_per_stage}")
                log(f"mesh: plan-driven dp={exec_dp}×pp={exec_stages} on "
                    f"{total} devices, {n_micro} micro-batches{ragged}")
            else:
                self.mesh = make_edge_mesh(exec_dp, exec_stages)
                log(f"mesh: hybrid dp={exec_dp}×pp={exec_stages} on "
                    f"{total} devices, {n_micro} micro-batches")

        # ---- data + activation cache ------------------------------------
        n_seq = spec.steps_per_epoch * spec.batch
        self.corpus = SyntheticPersonalCorpus(
            cfg.vocab, spec.seq + 1, n_seq, seed=spec.seed)
        self.pipe = DataPipeline(
            self.corpus, global_batch=spec.batch, shuffle=True, seed=spec.seed)
        cache_budget = spec.cache_budget_mb << 20
        if spec.cache_dir and spec.use_cache:
            self.meta = manifest_for(
                cfg, reduced=spec.reduced, seq_len=spec.seq,
                quant_bits=spec.quant, backbone=bq,
                corpus_tokens=self.corpus.tokens)
            self.cache, self.warm = open_persistent(
                spec.cache_dir, self.meta, budget_bytes=cache_budget,
                compress=spec.cache_compress)
            if self.warm:
                log(f"activation cache: warm manifest at {spec.cache_dir} "
                    f"({len(self.cache)} seqs, {spec.cache_compress}) — "
                    f"cached epochs skip the backbone forward entirely")
        else:
            self.cache = ActivationCache(
                budget_bytes=cache_budget, compress=spec.cache_compress)

        # ---- the four step variants behind one dispatch -----------------
        use_pallas = spec.kernels == "pallas"
        self._use_pallas = use_pallas
        self._steps_mod = steps
        # Under pallas, epoch-1 taps are quantized at the tap site into
        # the cache's storage form (no f32 HBM round-trip); put_batch
        # then adopts them without recompressing.
        tap_policy = spec.cache_compress if use_pallas else "f32"
        if distributed:
            # epoch-1: staged backbone forward over `stage` + dp AllReduce
            self._step1 = jax.jit(functools.partial(
                steps.pipeline_pac_train_step, cfg=cfg, mesh=self.mesh,
                n_micro=n_micro, r=spec.r, lr=spec.lr, partition=partition,
                kernel_impl=spec.kernels, tap_policy=tap_policy))
            # built on first cached batch (needs its tree structure)
            self._stepN = None
        else:
            self._step1 = jax.jit(functools.partial(
                steps.pac_train_step, cfg=cfg, r=spec.r, lr=spec.lr,
                kernel_impl=spec.kernels, tap_policy=tap_policy))
            # donate (adapter, opt) — the cached step returns them
            # updated, so the old buffers are reused in place every step
            self._stepN = jax.jit(
                functools.partial(steps.pac_cached_train_step, cfg=cfg,
                                  r=spec.r, lr=spec.lr,
                                  kernel_impl=spec.kernels),
                donate_argnums=(1, 2))
        self._opened = True
        return self

    def close(self) -> None:
        """Release per-run state: join any live prefetcher and (for a
        non-persistent cache) drop the entries + spill files. Does NOT
        write outputs — that is :meth:`finish`, which only a completed
        run should call."""
        if self._prefetch is not None:  # defensive: epoch_scope owns it
            self._prefetch.close()
            self._prefetch = None
        if self.cache is not None and not (self.spec.cache_dir and self.spec.use_cache):
            self.cache.clear()
        self._opened = False

    # -- the step dispatch ---------------------------------------------------

    @contextlib.contextmanager
    def epoch_scope(self, epoch: int):
        """Bracket one epoch's prefetcher lifecycle. When the whole
        epoch is cache-resident this arms a
        :class:`~repro.core.activation_cache.CachePrefetcher` (a
        background thread decompresses/loads batch k+1 — and starts its
        host→device copy — while step k runs) *as a context manager*,
        so an exception mid-epoch joins the worker thread and drains
        its queue instead of leaking a daemon holding device buffers.
        Yields True iff the epoch trains straight from the cache."""
        pf = None
        if self.spec.use_cache:
            from repro.core.activation_cache import CachePrefetcher

            order = self.pipe.epoch_order(epoch)
            if order and self.cache.covers(np.concatenate(order), with_final=True):
                pf = CachePrefetcher(
                    self.cache, order, to_device=not self.distributed,
                    dtype=None, compressed=self._use_pallas)
        if pf is None:
            yield False
            return
        with pf:
            self._prefetch = pf
            try:
                yield True
            finally:
                self._prefetch = None

    def _next_hit(self, ids):
        if self._prefetch is not None:
            return next(self._prefetch)
        if not self.spec.use_cache:
            return None
        return self.cache.get_batch(ids, with_final=True, dtype=None,
                                    compressed=self._use_pallas)

    def _build_cached_step(self, cached):
        """Epoch≥2 distributed: *pure* DP over the mesh. Lazy — the
        sharding (GSPMD) / shard_map (Pallas) wrapper needs the cached
        batch's concrete tree structure."""
        import jax

        from repro.launch import sharding as shard

        spec, steps = self.spec, self._steps_mod
        if self._use_pallas:
            # GSPMD cannot repartition pallas_call — the DP twin
            # shard_maps the fused step over the pool
            return jax.jit(
                functools.partial(
                    steps.dp_cached_train_step, cfg=self.cfg,
                    mesh=self.mesh, r=spec.r, lr=spec.lr,
                    kernel_impl="pallas",
                    batch_axes=shard.cached_batch_axes(cached, self.mesh)),
                donate_argnums=(1, 2))
        return jax.jit(
            functools.partial(steps.pac_cached_train_step, cfg=self.cfg,
                              r=spec.r, lr=spec.lr),
            in_shardings=shard.cached_step_shardings(
                self.backbone, self.adapter, self.opt, cached, self.mesh),
            donate_argnums=(1, 2))

    def step(self, batch: dict, *, epoch: int = 0, index: int = 0) -> StepEvent:
        """Run one training step: cache lookup (or prefetcher pull) →
        forward step on miss / cached step on hit → cache fill. Mutates
        the session's adapter/opt state and returns a :class:`StepEvent`.

        ``batch`` is one :meth:`DataPipeline.epoch` item (``seq_ids``
        is consumed here)."""
        import time

        import jax
        import jax.numpy as jnp

        if not self._opened:
            raise RuntimeError("EdgeSession.step() before open() — use "
                               "`with EdgeSession(spec) as s:` or s.open()")
        t0 = time.perf_counter()
        ids = batch.pop("seq_ids")
        hit = self._next_hit(ids)
        if hit is None:
            loss, self.adapter, self.opt, (b0, taps, bf) = self._step1(
                self.backbone, self.adapter, self.opt, batch)
            if self.spec.use_cache:
                # orig_last: storage-form (pallas) taps are padded to the
                # quant block on the last axis; d_model is the true width
                self.cache.put_batch(ids, b0, taps, bf,
                                     orig_last=self.cfg.d_model)
            cache_hit = False
        else:
            b0, taps, bf = (jax.tree.map(jnp.asarray, h) for h in hit)
            cached = {"b0": b0, "taps": taps, "b_final": bf,
                      "labels": batch["labels"]}
            if self._stepN is None:
                self._stepN = self._build_cached_step(cached)
            loss, self.adapter, self.opt = self._stepN(
                self.backbone, self.adapter, self.opt, cached)
            cache_hit = True
        loss = float(loss)
        return StepEvent(
            epoch=epoch, index=index, loss=loss, cache_hit=cache_hit,
            mode=self.mode(cache_hit), wall_s=time.perf_counter() - t0)

    def mode(self, cache_hit: bool) -> str:
        """The run-mode label the trainer has always reported."""
        if cache_hit:
            return "cached pure-dp" if self.distributed else "cached"
        if self.distributed:
            kind = "plan-driven" if self.spec.plan_mode else "hybrid"
            return f"{kind} dp{self.exec_dp}xpp{self.exec_stages}"
        return "full"

    # -- fleet seams: preemption snapshots + elastic resharding ---------------

    def snapshot(self, extra: dict = None) -> dict:
        """The job's preemptible state: adapter + optimizer (the backbone
        is frozen and the activation cache is reproducible/persistent, so
        neither belongs in a snapshot). ``extra`` lets a caller ride its
        own cursor (epoch/step index) along. The tree round-trips through
        :func:`repro.checkpoint.save_checkpoint` bit-exactly — the
        preempt-then-resume test pins that."""
        if not self._opened:
            raise RuntimeError("snapshot() needs an open()ed session")
        snap = {"adapter": self.adapter, "opt": self.opt,
                "config": self.cfg.name}
        if extra:
            snap["extra"] = dict(extra)
        return snap

    def restore(self, snap: dict) -> dict:
        """Adopt a :meth:`snapshot`. Returns the snapshot's ``extra``."""
        if not self._opened:
            raise RuntimeError("restore() needs an open()ed session")
        if snap.get("config") != self.cfg.name:
            raise RunSpecError(
                f"snapshot is for arch {snap.get('config')!r}, "
                f"session runs {self.cfg.name!r}")
        self.adapter = snap["adapter"]
        self.opt = snap["opt"]
        return snap.get("extra", {})

    def save_snapshot(self, path: str, extra: dict = None) -> str:
        """Checkpointed preemption: :meth:`snapshot` to disk (msgpack,
        atomic) so a preempted job survives its process."""
        from repro.checkpoint import save_checkpoint

        save_checkpoint(path, self.snapshot(extra))
        return path

    def restore_snapshot(self, path: str) -> dict:
        from repro.checkpoint import load_checkpoint

        return self.restore(load_checkpoint(path))

    def reshard(self, dp: int, devices=None) -> None:
        """Elastic DP for a *distributed* session's cached epochs: rebuild
        the (dp, stage) mesh at a new replica width over ``devices``
        (default: the session's current device pool) and drop the
        lazily-compiled cached step so the next cached batch recompiles
        against the new layout. Legal between steps of a cached epoch —
        pure-DP state is just (adapter, opt), both device-agnostic.
        Single-device fleet jobs reshard through
        :class:`repro.fleet.ElasticDpRunner` instead (chunk-level,
        bit-identical numerics); this seam serves mesh-resident runs,
        where shard_map reduction order may shift float sums at the last
        bit. The epoch-1 step keeps the old mesh — reshard only once the
        cache covers the epoch."""
        if not self._opened:
            raise RuntimeError("reshard() needs an open()ed session")
        if not self.distributed:
            raise RunSpecError(
                "reshard() applies to multi-device sessions; single-device "
                "jobs reshard via repro.fleet.ElasticDpRunner")
        from repro.launch.mesh import make_edge_mesh

        dp = int(dp)
        if dp < 1:
            raise RunSpecError(f"dp must be >= 1, got {dp}")
        self.mesh = make_edge_mesh(dp, self.exec_stages, devices)
        self.exec_dp = dp
        self._stepN = None   # rebuilt for the new mesh on the next hit

    # -- outputs --------------------------------------------------------------

    def finish(self) -> None:
        """Write the run's durable outputs: the adapter checkpoint
        (``spec.ckpt``) and — for a persistent cache — the manifest that
        lets the next run resume warm with zero backbone forwards."""
        if self._finished:
            return
        spec, log = self.spec, self._log
        if spec.ckpt:
            from repro.checkpoint import save_checkpoint

            n = save_checkpoint(
                spec.ckpt, {"adapter": self.adapter, "config": self.cfg.name})
            log(f"checkpoint: {spec.ckpt} ({n/2**20:.1f} MB)")
        if self.meta is not None:
            path = self.cache.save_manifest(self.meta)
            log(f"cache manifest: {path} ({len(self.cache)} seqs, "
                f"{spec.cache_compress})")
        self._finished = True

    def serving_engine(self, adapters=None, **kw):
        """Hand the session's artifacts to the serving layer: a
        :class:`~repro.serve.ServeEngine` over this session's (quantized)
        frozen backbone and, by default, the adapter it just trained
        (registered as ``"local"``). Pass ``adapters={name: tree, ...}``
        to serve a different bank — e.g. side networks pulled from peer
        devices' checkpoints. Engine knobs (``kv_policy``, ``page_size``,
        ``max_len``, ``max_batch``, ...) pass through; ``r`` and
        ``kernel_impl`` default to the run's spec."""
        from repro.serve import ServeEngine

        if self.backbone is None:
            raise RunSpecError("serving_engine() needs an open()ed session")
        if adapters is None:
            adapters = {"local": self.adapter}
        kw.setdefault("r", self.spec.r)
        kw.setdefault("kernel_impl", self.spec.kernels)
        return ServeEngine(self.backbone, self.cfg, adapters, **kw)

    def run(self, hooks=()) -> list:
        """The whole lifecycle in one call: open → every epoch through
        an :class:`~repro.runtime.runner.EpochRunner` → finish → close.
        Returns the list of :class:`~repro.runtime.runner.EpochReport`."""
        from repro.runtime.runner import EpochRunner

        with self:
            reports = EpochRunner(self, hooks=hooks).run()
            self.finish()
        return reports
