"""EpochRunner — structured epoch loop over an EdgeSession.

The trainer's epoch loop as a generator of typed records instead of a
wall of prints: each epoch yields its :class:`StepEvent`s (loss, step
time, cache hit, mode) and closes with an :class:`EpochReport`.
Observability — and the future fleet scheduler — attach through the
:class:`RunHooks` interface as callbacks; :class:`ConsoleHook` is the
hook that reproduces the trainer CLI's classic ``epoch N: loss=...``
line byte-for-byte.

    runner = EpochRunner(session, hooks=[ConsoleHook()])
    reports = runner.run()                   # all spec.epochs
    # or stream:
    for rec in runner.events():              # StepEvent | EpochReport
        ...
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Iterator, List, Union

from repro.runtime.session import EdgeSession, StepEvent


@dataclass
class EpochReport:
    """One epoch's outcome (the CLI's per-epoch summary line, as data)."""

    epoch: int
    losses: List[float] = field(default_factory=list)
    time_s: float = 0.0
    used_cache: bool = False
    mode: str = "full"
    steps: int = 0

    @property
    def mean_loss(self) -> float:
        return float(sum(self.losses) / max(1, len(self.losses)))


class RunHooks:
    """Observer interface for a run — subclass and override what you
    need (all methods are no-ops). Hooks receive the live session, so a
    scheduler hook can inspect the cache, mesh, or adapter state."""

    def on_epoch_start(self, session: EdgeSession, epoch: int) -> None:
        pass

    def on_step(self, session: EdgeSession, event: StepEvent) -> None:
        pass

    def on_epoch_end(self, session: EdgeSession, report: EpochReport) -> None:
        pass

    # -- fleet lifecycle (repro.fleet) — no-ops for plain runs ----------------

    def on_reshard(self, session: EdgeSession, members: List[str]) -> None:
        """Pool membership changed under a running job: the job now
        executes on ``members`` (fleet member names, in placement
        order)."""

    def on_preempt(self, session: EdgeSession, resumed: bool) -> None:
        """The scheduler snapshotted this job off its devices
        (``resumed=False``) or brought it back (``resumed=True``)."""


class ConsoleHook(RunHooks):
    """The trainer CLI's per-epoch summary line, unchanged:

    ``epoch 0: loss=4.1234 time=1.2s (full) cache[8 seqs, 3 MB, f32]``
    """

    def __init__(self, print_fn=print):
        self._print = print_fn

    def on_epoch_end(self, session: EdgeSession, report: EpochReport) -> None:
        cache = session.cache
        self._print(
            f"epoch {report.epoch}: loss={report.mean_loss:.4f} "
            f"time={report.time_s:.1f}s ({report.mode}) "
            f"cache[{len(cache)} seqs, {cache.nbytes/2**20:.0f} MB, "
            f"{session.spec.cache_compress}]")


class EpochRunner:
    """Drives ``spec.epochs`` epochs of an opened :class:`EdgeSession`.

    The epoch's prefetcher lifecycle is bracketed by
    ``session.epoch_scope`` (the prefetcher is a context manager), so an
    exception mid-epoch can't leak the prefetch worker thread.
    """

    def __init__(self, session: EdgeSession, hooks=()):
        self.session = session
        self.hooks = list(hooks)

    # -- streaming ----------------------------------------------------------

    def run_epoch(self, epoch: int) -> Iterator[Union[StepEvent, EpochReport]]:
        """Yield every :class:`StepEvent` of ``epoch``, then its
        :class:`EpochReport` (always the final record)."""
        s = self.session
        for h in self.hooks:
            h.on_epoch_start(s, epoch)
        report = EpochReport(epoch=epoch)
        t0 = time.perf_counter()
        # epoch_scope arms the prefetcher (when the epoch is fully
        # cache-resident) as a context manager: an exception mid-epoch
        # joins the worker thread instead of leaking it
        with s.epoch_scope(epoch):
            for i, batch in enumerate(s.pipe.epoch(epoch)):
                event = s.step(batch, epoch=epoch, index=i)
                report.losses.append(event.loss)
                report.used_cache = report.used_cache or event.cache_hit
                report.steps += 1
                for h in self.hooks:
                    h.on_step(s, event)
                yield event
        report.time_s = time.perf_counter() - t0
        report.mode = s.mode(report.used_cache)
        for h in self.hooks:
            h.on_epoch_end(s, report)
        yield report

    def events(self) -> Iterator[Union[StepEvent, EpochReport]]:
        """All epochs, streamed: StepEvents interleaved with one
        EpochReport per epoch."""
        for epoch in range(self.session.spec.epochs):
            yield from self.run_epoch(epoch)

    # -- collecting ---------------------------------------------------------

    def epochs(self) -> Iterator[EpochReport]:
        """One EpochReport per epoch (StepEvents consumed internally —
        hooks still fire per step)."""
        for rec in self.events():
            if isinstance(rec, EpochReport):
                yield rec

    def run(self) -> List[EpochReport]:
        return list(self.epochs())
