"""JAX version-compatibility layer.

The reproduction must run on whatever JAX the edge device ships (the
portability constraint of arXiv 2406.03777 / 2311.14030): API surfaces
that moved or changed signature across JAX releases are feature-detected
*once* here and exposed as a single stable interface. Nothing outside
this module may import ``AxisType``, pass version-gated ``make_mesh``
kwargs, or import ``shard_map`` from its (moving) home.

Supported surface (tested on JAX 0.4.30–0.4.x; forward-compatible paths
for 0.5+/0.6+ are exercised opportunistically by feature detection):

* ``jax_version()`` / ``jax_at_least(version)`` — version guards.
* ``make_mesh(shape, axes)`` — ``jax.make_mesh`` with ``axis_types``
  when the installed JAX has :class:`AxisType`, without it on 0.4.x, and
  a manual ``Mesh(mesh_utils.create_device_mesh(...))`` on versions that
  predate ``jax.make_mesh`` entirely.
* ``abstract_mesh(shape, axes)`` — :class:`AbstractMesh` across its two
  constructor signatures (pairs-tuple on 0.4.x, split args later).
* ``shard_map(f, mesh, in_specs, out_specs, check_rep=...)`` — resolves
  ``jax.shard_map`` vs ``jax.experimental.shard_map.shard_map`` and maps
  the replication-check kwarg (``check_rep`` → ``check_vma`` rename).
* ``tree_map`` / ``tree_leaves`` / ``tree_structure`` /
  ``tree_map_with_path`` — ``jax.tree`` on versions that have it,
  ``jax.tree_util`` otherwise.
* ``ambient_mesh()`` — the mesh from an enclosing ``with mesh:`` /
  ``set_mesh`` context, across the abstract-mesh and thread-resources
  eras.
* ``force_host_device_count(n)`` — the ``XLA_FLAGS`` dance for faked
  host devices (must run before the first backend initialisation).
* ``enable_compilation_cache(dir)`` — persistent compile cache knobs.
"""

from __future__ import annotations

import functools
import inspect
import os
import re
from typing import Optional, Sequence, Union

import jax

__all__ = [
    "jax_version",
    "jax_at_least",
    "make_mesh",
    "abstract_mesh",
    "shard_map",
    "tree_map",
    "tree_leaves",
    "tree_structure",
    "tree_map_with_path",
    "ambient_mesh",
    "force_host_device_count",
    "default_cache_dir",
    "enable_compilation_cache",
]


# ---------------------------------------------------------------------------
# Version guards
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def jax_version() -> tuple:
    """Installed JAX version as a comparable int tuple (dev tags dropped)."""
    parts = []
    for p in jax.__version__.split("."):
        m = re.match(r"\d+", p)
        if not m:
            break
        parts.append(int(m.group()))
    return tuple(parts) if parts else (0,)


def jax_at_least(version: Union[str, Sequence[int]]) -> bool:
    """True iff the installed JAX is >= ``version`` ("0.5", (0, 5, 0), ...)."""
    if isinstance(version, str):
        want = tuple(int(x) for x in version.split("."))
    else:
        want = tuple(int(x) for x in version)
    return jax_version() >= want


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------


def make_mesh(shape, axes, *, devices=None):
    """Device mesh with ``Auto`` axis semantics on every installed JAX.

    Newest JAX: ``jax.make_mesh(..., axis_types=(AxisType.Auto, ...))``;
    0.4.35–0.4.x: ``jax.make_mesh`` without ``axis_types`` (Auto is the
    only behaviour); older: explicit ``Mesh`` over ``create_device_mesh``.
    """
    shape, axes = tuple(shape), tuple(axes)
    maker = getattr(jax, "make_mesh", None)
    if maker is not None:
        axis_type = getattr(jax.sharding, "AxisType", None)
        if axis_type is not None:
            try:
                return maker(
                    shape, axes, devices=devices,
                    axis_types=(axis_type.Auto,) * len(axes),
                )
            except TypeError:  # has AxisType but an older make_mesh
                pass
        return maker(shape, axes, devices=devices)
    from jax.experimental import mesh_utils

    devs = mesh_utils.create_device_mesh(shape, devices=devices)
    return jax.sharding.Mesh(devs, axes)


def abstract_mesh(shape, axes):
    """:class:`jax.sharding.AbstractMesh` across constructor signatures."""
    from jax.sharding import AbstractMesh

    shape, axes = tuple(shape), tuple(axes)
    try:
        return AbstractMesh(shape, axes)  # 0.5+ split signature
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))  # 0.4.x pairs tuple


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn  # moved in 0.6
    try:
        params = frozenset(inspect.signature(fn).parameters)
    except (TypeError, ValueError):  # C-accelerated / no signature
        params = frozenset()
    return fn, params


def shard_map(f, mesh, in_specs, out_specs, check_rep: Optional[bool] = None):
    """``shard_map`` with the replication check spelled one way.

    ``check_rep`` (old spelling) maps onto ``check_vma`` on JAX versions
    that renamed it; ``None`` keeps the installed default.
    """
    fn, params = _resolve_shard_map()
    kwargs = {}
    if check_rep is not None:
        if "check_vma" in params:
            kwargs["check_vma"] = check_rep
        elif "check_rep" in params:
            kwargs["check_rep"] = check_rep
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


# ---------------------------------------------------------------------------
# Pytree helpers (jax.tree arrived in 0.4.25)
# ---------------------------------------------------------------------------

if hasattr(jax, "tree") and hasattr(jax.tree, "map"):
    tree_map = jax.tree.map
    tree_leaves = jax.tree.leaves
    tree_structure = jax.tree.structure
else:  # pragma: no cover - exercised only on very old JAX
    tree_map = jax.tree_util.tree_map
    tree_leaves = jax.tree_util.tree_leaves
    tree_structure = jax.tree_util.tree_structure

tree_map_with_path = jax.tree_util.tree_map_with_path


# ---------------------------------------------------------------------------
# Ambient mesh discovery
# ---------------------------------------------------------------------------


def ambient_mesh():
    """The mesh from the enclosing ``with mesh:`` / ``set_mesh`` context.

    Tries the modern abstract-mesh context first (``set_mesh`` era), then
    the thread-resources physical mesh (``with mesh:`` era). ``None``
    when no mesh is active — callers treat that as the single-device
    CPU-test regime.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        try:
            m = getter()
            if m is not None and not m.empty:
                return m
        except Exception:
            pass
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


# ---------------------------------------------------------------------------
# Process-level knobs
# ---------------------------------------------------------------------------


def force_host_device_count(n: int) -> None:
    """Fake ``n`` host-platform devices (dry runs / subprocess tests).

    Must be called before the first JAX backend initialisation — the
    device count locks when the backend comes up, not at ``import jax``.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "", flags)
    os.environ["XLA_FLAGS"] = (
        flags + f" --xla_force_host_platform_device_count={n}"
    ).strip()


def default_cache_dir() -> str:
    """The repo-wide compile-cache location (one policy for the test
    harness, the benchmark runner, and CI's actions/cache path).

    A user-set ``JAX_COMPILATION_CACHE_DIR`` is honored so the config
    update in :func:`enable_compilation_cache` never diverges from the
    env var that subprocesses inherit.
    """
    return (
        os.environ.get("REPRO_JAX_CACHE_DIR")
        or os.environ.get("JAX_COMPILATION_CACHE_DIR")
        or os.path.join(os.path.expanduser("~"), ".cache", "repro_jax_cache")
    )


def enable_compilation_cache(cache_dir: Optional[str] = None) -> bool:
    """Point JAX's persistent compilation cache at ``cache_dir``
    (default: :func:`default_cache_dir`).

    Thresholds are dropped to zero so even the tiny CPU-test programs
    cache (the default min-compile-time gate skips them). Returns False
    when the installed JAX predates the config knobs.
    """
    if cache_dir is None:
        cache_dir = default_cache_dir()
    try:
        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
    except Exception:
        return False
    for knob, val in (
        ("jax_persistent_cache_min_compile_time_secs", 0.0),
        ("jax_persistent_cache_min_entry_size_bytes", -1),
    ):
        try:
            jax.config.update(knob, val)
        except Exception:
            pass
    return True
