"""Training / serving step functions.

These are the units that get ``jax.jit``-ed with mesh shardings — one per
fine-tuning technique (the paper's comparison set) plus the serving paths:

* ``pac_train_step``          — PAC+ epoch-1: frozen (possibly quantized)
                                 backbone forward + side-network update.
* ``pipeline_pac_train_step`` — PAC+ epoch-1 on a 2-D (dp, stage) mesh:
                                 staged backbone forward (1F1B) + dp
                                 AllReduce of adapter grads.
* ``pac_cached_train_step``   — PAC+ epoch≥2: adapter-only, from cache.
* ``full_train_step``         — full fine-tuning baseline.
* ``lora_train_step``         — LoRA baseline (backprop through backbone).
* ``houlsby_train_step``      — serial Adapters baseline.
* ``prefill_step``            — forward over a full prompt (inference).
* ``decode_step``             — one token against a KV/state cache.
* ``pac_decode_step``         — decode through backbone + fine-tuned side
                                 network (serving a personalised model).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import peft
from repro.core.opset import get_opset
from repro.core.parallel_adapters import (
    adapter_decode,
    adapter_forward,
    init_adapter_cache,
    pac_logits,
)
from repro.models.backbone import (
    backbone_decode,
    backbone_forward,
    backbone_logits,
    cross_entropy,
    embed_inputs,
    logits_from_hidden,
)
from repro.optim import adamw_update, clip_by_global_norm

# ---------------------------------------------------------------------------
# PAC+ steps
# ---------------------------------------------------------------------------


def pac_loss_fn(adapter_params, backbone_params, cfg, batch, r: int = 8):
    b_final, taps, x, positions = backbone_forward(
        backbone_params, cfg, batch, collect_taps=True, return_inputs=True
    )
    # the gradient "highway": nothing upstream of the taps is differentiated
    x, b_final, taps = jax.lax.stop_gradient((x, b_final, taps))
    logits = pac_logits(backbone_params, adapter_params, cfg, x, taps, b_final, positions, r)
    return cross_entropy(logits, batch["labels"])


def pac_train_step(
    backbone_params, adapter_params, opt_state, batch, *, cfg, r: int = 8, lr=1e-3,
    clip=1.0, kernel_impl: str = "ref", tap_policy: str = "f32", interpret=None,
):
    """Epoch-1 PAC+ step.

    ``kernel_impl`` selects the frozen-path OpSet: ``"ref"`` (default) is
    the dense jnp oracle, bit-identical to the historical step;
    ``"pallas"`` runs the frozen forward on still-quantized weights
    (quant_matmul / Pallas flash attention) and emits the activation
    triple through ``emit_tap`` — with ``tap_policy`` = the cache's
    compress policy it leaves the step already in storage form, and the
    adapter loss consumes it via the fused cached-step kernels (the
    frozen path is stop-gradient'd, so no VJP is needed through Pallas).

    Returns (loss, adapter_params', opt_state', (b0, taps, b_final))."""
    if kernel_impl == "ref":
        b_final, taps, x, positions = backbone_forward(
            backbone_params, cfg, batch, collect_taps=True, return_inputs=True
        )
        x, b_final, taps = jax.lax.stop_gradient((x, b_final, taps))

        def loss_fn(ap):
            logits = pac_logits(backbone_params, ap, cfg, x, taps, b_final, positions, r)
            return cross_entropy(logits, batch["labels"])

        loss, grads = jax.value_and_grad(loss_fn)(adapter_params)
        grads, _ = clip_by_global_norm(grads, clip)
        adapter_params, opt_state = adamw_update(adapter_params, grads, opt_state, lr=lr)
        return loss, adapter_params, opt_state, (x, taps, b_final)

    from repro.kernels.cached_step import cached_loss_parts

    ops = get_opset(kernel_impl, tap_policy, interpret)
    b_final, taps, x, positions = backbone_forward(
        backbone_params, cfg, batch, collect_taps=True, return_inputs=True, ops=ops
    )
    b0_s, bf_s = ops.emit_tap(x), ops.emit_tap(b_final)
    b0_s, taps, bf_s = jax.lax.stop_gradient((b0_s, taps, bf_s))
    cached = {"b0": b0_s, "taps": taps, "b_final": bf_s, "labels": batch["labels"]}

    def loss_fn(ap):
        num, den = cached_loss_parts(
            backbone_params, ap, cfg, cached, positions, r,
            impl=kernel_impl, interpret=interpret,
        )
        return num / jnp.maximum(den, 1)

    loss, grads = jax.value_and_grad(loss_fn)(adapter_params)
    grads, _ = clip_by_global_norm(grads, clip)
    adapter_params, opt_state = adamw_update(adapter_params, grads, opt_state, lr=lr)
    return loss, adapter_params, opt_state, (b0_s, taps, bf_s)


def _cached_positions(cached_batch, cfg):
    if "positions" in cached_batch:
        return cached_batch["positions"]
    B, S = cached_batch["labels"].shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(positions, (3, B, S))
    return positions


def pac_cached_train_step(
    backbone_params, adapter_params, opt_state, cached_batch, *, cfg, r: int = 8,
    lr=1e-3, clip=1.0, kernel_impl: str = "ref", interpret=None,
):
    """Epoch≥2 PAC+ step: backbone forward replaced by the activation cache.

    cached_batch: {"b0": (B,S,d), "taps": (n_p,B,S,d), "b_final": (B,S,d),
                   "labels": (B,S), optional "positions"}. Each activation
    may arrive in its *storage* form — an f32/bf16 array, or the int8
    ``{"q", "scale"}`` payload the cache hands out with
    ``get_batch(compressed=True)`` — and is decompressed inside the step
    (on-device), never eagerly on the host.

    ``kernel_impl`` selects the compute path (`repro.kernels.cached_step`):
    ``"ref"`` (default) is the dense jnp oracle — upcast to f32, full
    (B,S,vocab) logits; ``"pallas"`` fuses the per-period dequant ×
    down-projection × λ-mix in VMEM and streams the LM-head cross-entropy
    blockwise, so neither the f32 taps nor the logits tensor are ever
    fully resident. ``interpret=None`` auto-selects the Pallas
    interpreter off-TPU (CI). Both paths produce matching losses/grads to
    f32 tolerance (tests/test_cached_step.py).

    Only the LM head / final norm of ``backbone_params`` is read — the rest
    of the backbone can be released from memory (paper §IV-B memory win).
    Jit with ``donate_argnums=(1, 2)`` to reuse the adapter/optimizer
    buffers in place (they are returned updated).
    """
    from repro.kernels.cached_step import cached_loss_parts

    positions = _cached_positions(cached_batch, cfg)

    def loss_fn(ap):
        num, den = cached_loss_parts(
            backbone_params, ap, cfg, cached_batch, positions, r,
            impl=kernel_impl, interpret=interpret,
        )
        return num / jnp.maximum(den, 1)

    loss, grads = jax.value_and_grad(loss_fn)(adapter_params)
    grads, _ = clip_by_global_norm(grads, clip)
    adapter_params, opt_state = adamw_update(adapter_params, grads, opt_state, lr=lr)
    return loss, adapter_params, opt_state


def dp_cached_train_step(
    backbone_params, adapter_params, opt_state, cached_batch, *, cfg, mesh,
    batch_axes, r: int = 8, lr=1e-3, clip=1.0, kernel_impl: str = "pallas",
    interpret=None,
):
    """Epoch≥2 cached step, data-parallel over ``batch_axes`` of ``mesh``
    via an explicit shard_map — the DP twin of :func:`pac_cached_train_step`
    for the Pallas path (whose ``pallas_call``s GSPMD cannot repartition;
    the ref path can instead be jitted with
    ``launch.sharding.cached_step_shardings``).

    Per shard: local (num, den) CE parts from the fused loss, psum'd over
    ``batch_axes`` before the division (exact global mean); adapter grads
    pmean'd (the psum's transpose re-sums the replicated cotangent, so the
    mean removes the axes× factor — same argument as the pipeline step).
    The update is replicated. ``batch_axes`` must shard the batch dim of
    every cached entry (use ``launch.sharding.cached_batch_axes``).
    """
    from repro.kernels.cached_step import cached_loss_parts
    from repro.launch.sharding import batch_specs

    axes = tuple(batch_axes)

    def spmd(ap, bp, cached):
        positions = _cached_positions(cached, cfg)

        def loss_fn(a):
            num, den = cached_loss_parts(
                bp, a, cfg, cached, positions, r,
                impl=kernel_impl, interpret=interpret,
            )
            num = jax.lax.psum(num, axes)
            den = jax.lax.psum(den, axes)
            return num / jnp.maximum(den, 1)

        loss, grads = jax.value_and_grad(loss_fn)(ap)
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, axes), grads)
        return loss, grads

    # shard_seq=False: the psums above reduce over batch_axes only, so a
    # `model`-axis sequence split of the entries would silently drop
    # every other shard's tokens from the loss
    cspecs = batch_specs(cached_batch, mesh, batch_axes=axes, shard_seq=False)
    fn = shard_map(
        spmd,
        mesh=mesh,
        in_specs=(P(), P(), cspecs),
        out_specs=(P(), P()),
        check_rep=False,
    )
    loss, grads = fn(adapter_params, backbone_params, cached_batch)
    grads, _ = clip_by_global_norm(grads, clip)
    adapter_params, opt_state = adamw_update(adapter_params, grads, opt_state, lr=lr)
    return loss, adapter_params, opt_state


# ---------------------------------------------------------------------------
# Hybrid DP×PP PAC+ step (paper Fig. 10/11 — the epoch-1 edge-pool regime)
# ---------------------------------------------------------------------------


def _backbone_stage_fn(cfg, masked: bool = False, ops=None):
    """One pipeline stage of the frozen backbone: scan this stage's periods,
    emitting every period's hidden state (the PAC+ taps) through
    ``ops.emit_tap`` (identity when no OpSet is given — the ref path).

    ``masked=True`` is the ragged-partition variant: the stage params are
    ``{"blocks": padded_slab, "mask": (max_pp,)}`` (see
    ``stack_stages_ragged``) and periods whose mask is False run as
    identity — they are the zero-padding that equalizes slab shapes across
    uneven stages, and both their carry and their tap slot are discarded.
    """
    from repro.models.backbone import apply_block

    emit = ops.emit_tap if ops is not None else (lambda h: h)

    def run_period(bs, hh, positions):
        for i, spec in enumerate(cfg.pattern):
            hh = apply_block(bs[i], hh, cfg, spec, positions, ops=ops)
        return hh

    def _positions(h):
        lead = (3,) if cfg.rope == "mrope" else ()
        return jnp.broadcast_to(
            jnp.arange(h.shape[1], dtype=jnp.int32), lead + h.shape[:2]
        )

    if masked:

        def stage_fn(local, h):
            positions = _positions(h)

            def period_fn(carry, xs):
                bs, m = xs
                hh = jnp.where(m, run_period(bs, carry, positions), carry)
                return hh, emit(hh)

            return jax.lax.scan(
                period_fn, h, (tuple(local["blocks"]), local["mask"])
            )

        return stage_fn

    def stage_fn(block_slice, h):
        positions = _positions(h)

        def period_fn(carry, bs):
            hh = run_period(bs, carry, positions)
            return hh, emit(hh)

        return jax.lax.scan(period_fn, h, tuple(block_slice))

    return stage_fn


def pipeline_pac_loss_and_grads(
    backbone_params, adapter_params, batch, *, cfg, mesh, n_micro,
    r: int = 8, dp_axis: str = "dp", stage_axis: str = "stage",
    partition=None, kernel_impl: str = "ref", tap_policy: str = "f32",
    interpret=None,
):
    """Distributed epoch-1 forward+grads: staged backbone forward over the
    ``stage`` mesh axis (1F1B micro-batching via :func:`pipeline_apply`),
    adapter loss/grads data-parallel over ``dp`` with an explicit psum
    (the paper's per-minibatch AllReduce of the *trainable* params only).

    ``partition`` (a :class:`~repro.core.planner.StagePartition`) makes the
    planner's Plan the execution contract: its period boundaries choose
    what each stage runs. A *uniform* partition reduces to exactly the
    even-split path (bit-for-bit — same stage function, same stacking); a
    *ragged* one pads each stage's parameter slab to the max
    periods-per-stage, runs the padding as masked identity periods, and
    re-assembles the taps in true layer order from the uneven boundaries.

    ``kernel_impl="pallas"`` runs every stage's frozen forward on the
    pallas OpSet (still-quantized weights in quant_matmul, Pallas flash
    attention) and emits taps in ``tap_policy`` storage form — each stage's
    tap leaves ``pipeline_apply`` as a pytree (int8 payload + scales under
    the int8 policy), and the adapter loss consumes it through the fused
    cached-step kernels.

    Returns (loss, adapter_grads, (b0, taps, b_final)) — the activation
    triple is what the cache captures; all are global (dp-sharded) arrays
    (or storage-form pytrees under a pallas tap policy).
    """
    from repro.core.pipeline import pipeline_apply, stack_stages, stack_stages_ragged
    from repro.models.backbone import cross_entropy_parts

    from repro.data import DataPipeline

    n_stages = mesh.shape[stage_axis]
    dp = mesh.shape[dp_axis] if dp_axis in mesh.axis_names else 1
    if partition is not None:
        if partition.n_stages != n_stages:
            raise ValueError(
                f"plan has {partition.n_stages} stages but the mesh's "
                f"{stage_axis!r} axis has {n_stages}"
            )
        if partition.n_periods != cfg.n_periods:
            raise ValueError(
                f"plan partitions {partition.n_periods} periods but "
                f"{cfg.name} has {cfg.n_periods}"
            )
        if partition.is_uniform:
            partition = None  # identical to the even split — take that path
    if partition is None and cfg.n_periods % n_stages:
        raise ValueError(
            f"{cfg.n_periods} periods not divisible by {n_stages} pipeline stages"
        )
    if "positions" in batch:
        # _backbone_stage_fn rebuilds implicit arange positions per stage;
        # silently running custom positions through it would cache wrong
        # activations for every later epoch
        raise NotImplementedError(
            "pipeline_pac_train_step supports implicit (arange) positions only"
        )

    ops = None if kernel_impl == "ref" else get_opset(kernel_impl, tap_policy, interpret)
    x, positions = embed_inputs(backbone_params, cfg, batch, ops=ops)
    B = x.shape[0]
    # staged backbone forward: (B,S,d) → micro-batched → 1F1B pipeline
    # (dp_microbatches owns the layout contract + divisibility checks)
    x_micro = DataPipeline.dp_microbatches({"x": x}, n_micro, dp)["x"]
    if partition is None:
        stage_params = stack_stages(backbone_params["blocks"], n_stages)
        stage_fn = _backbone_stage_fn(cfg, ops=ops)
        pps = None
    else:  # ragged plan: padded slabs + per-stage active-period masks
        stage_params = {
            "blocks": stack_stages_ragged(
                backbone_params["blocks"], partition.boundaries
            ),
            "mask": jnp.asarray(partition.masks(), dtype=bool),
        }
        stage_fn = _backbone_stage_fn(cfg, masked=True, ops=ops)
        pps = partition.periods_per_stage
    b_final_micro, taps_micro = pipeline_apply(
        stage_fn, stage_params, x_micro, mesh,
        axis=stage_axis, batch_axis=dp_axis if dp > 1 else None,
        collect_taps=True, periods_per_stage=pps,
    )
    b_final = b_final_micro.reshape((B,) + b_final_micro.shape[2:])
    # (n_micro, n_p, mb, S, ·) → (n_p, B, S, ·) — micro-major sample order
    # (tree-mapped: a storage-form tap is a pytree of payload + scales)
    taps = jax.tree.map(lambda t: jnp.moveaxis(t, 1, 0), taps_micro)
    taps = jax.tree.map(lambda t: t.reshape(t.shape[:1] + (B,) + t.shape[3:]), taps)
    b0 = x if ops is None else ops.emit_tap(x)
    b_final = b_final if ops is None else ops.emit_tap(b_final)
    b0, taps, b_final = jax.lax.stop_gradient((b0, taps, b_final))

    # adapter loss + grads, dp-sharded batch, explicit AllReduce
    def spmd_grads(ap, head, b0_l, taps_l, bf_l, labels_l, pos_l):
        def loss_fn(a):
            if ops is None:
                logits = pac_logits(head, a, cfg, b0_l, taps_l, bf_l, pos_l, r)
                num, den = cross_entropy_parts(logits, labels_l)
            else:
                from repro.kernels.cached_step import cached_loss_parts

                cached = {"b0": b0_l, "taps": taps_l, "b_final": bf_l,
                          "labels": labels_l}
                num, den = cached_loss_parts(
                    head, a, cfg, cached, pos_l, r,
                    impl=kernel_impl, interpret=interpret,
                )
            if dp > 1:  # global mean: psum parts, not pmean of local means
                num = jax.lax.psum(num, dp_axis)
                den = jax.lax.psum(den, dp_axis)
            return num / jnp.maximum(den, 1)

        loss, grads = jax.value_and_grad(loss_fn)(ap)
        if dp > 1:
            # AllReduce completes the global gradient (trainable params
            # only — tiny). pmean, not psum: the transpose of the psum in
            # loss_fn already re-sums the replicated cotangent over dp, so
            # each shard's grad carries a dp× factor that the mean removes.
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, dp_axis), grads)
        return loss, grads

    bspec = P(dp_axis) if dp > 1 else P()
    tspec = P(None, dp_axis) if dp > 1 else P()
    pspec = (P(None, dp_axis) if positions.ndim == 3 else P(dp_axis)) if dp > 1 else P()
    fn = shard_map(
        spmd_grads,
        mesh=mesh,
        in_specs=(P(), P(), bspec, tspec, bspec, bspec, pspec),
        out_specs=(P(), P()),
        check_rep=False,
    )
    loss, grads = fn(
        adapter_params, backbone_params, b0, taps, b_final, batch["labels"], positions
    )
    return loss, grads, (b0, taps, b_final)


def pipeline_pac_train_step(
    backbone_params, adapter_params, opt_state, batch, *, cfg, mesh, n_micro,
    r: int = 8, lr=1e-3, clip=1.0, dp_axis: str = "dp", stage_axis: str = "stage",
    partition=None, kernel_impl: str = "ref", tap_policy: str = "f32",
    interpret=None,
):
    """Epoch-1 PAC+ step on a 2-D ``(dp, stage)`` mesh — the distributed
    twin of :func:`pac_train_step` (same signature plus mesh/n_micro).

    Backbone forward runs staged over ``stage`` with 1F1B micro-batching
    (optionally along a planner ``partition`` — see
    :func:`pipeline_pac_loss_and_grads`); adapter grads are AllReduced
    across ``dp``; the update itself is replicated (identical on every
    device after the AllReduce). Returns
    (loss, adapter_params', opt_state', (b0, taps, b_final)).
    """
    loss, grads, acts = pipeline_pac_loss_and_grads(
        backbone_params, adapter_params, batch, cfg=cfg, mesh=mesh,
        n_micro=n_micro, r=r, dp_axis=dp_axis, stage_axis=stage_axis,
        partition=partition, kernel_impl=kernel_impl, tap_policy=tap_policy,
        interpret=interpret,
    )
    grads, _ = clip_by_global_norm(grads, clip)
    adapter_params, opt_state = adamw_update(adapter_params, grads, opt_state, lr=lr)
    return loss, adapter_params, opt_state, acts


# ---------------------------------------------------------------------------
# Baseline fine-tuning steps
# ---------------------------------------------------------------------------


def full_train_step(params, opt_state, batch, *, cfg, lr=1e-4, clip=1.0):
    def loss_fn(p):
        return cross_entropy(backbone_logits(p, cfg, batch), batch["labels"])

    loss, grads = jax.value_and_grad(loss_fn)(params)
    grads, _ = clip_by_global_norm(grads, clip)
    params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
    return loss, params, opt_state


def lora_train_step(backbone_params, lora_params, opt_state, batch, *, cfg, lr=1e-3, clip=1.0):
    def loss_fn(lp):
        return cross_entropy(peft.lora_logits(backbone_params, lp, cfg, batch), batch["labels"])

    loss, grads = jax.value_and_grad(loss_fn)(lora_params)
    grads, _ = clip_by_global_norm(grads, clip)
    lora_params, opt_state = adamw_update(lora_params, grads, opt_state, lr=lr)
    return loss, lora_params, opt_state


def houlsby_train_step(backbone_params, ad_params, opt_state, batch, *, cfg, lr=1e-3, clip=1.0):
    def loss_fn(ap):
        return cross_entropy(peft.houlsby_logits(backbone_params, ap, cfg, batch), batch["labels"])

    loss, grads = jax.value_and_grad(loss_fn)(ad_params)
    grads, _ = clip_by_global_norm(grads, clip)
    ad_params, opt_state = adamw_update(ad_params, grads, opt_state, lr=lr)
    return loss, ad_params, opt_state


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def prefill_step(params, batch, *, cfg, kernel_impl: str = "ref", interpret=None):
    """Full-prompt forward (inference-prefill). Returns last-position logits."""
    ops = None if kernel_impl == "ref" else get_opset(kernel_impl, interpret=interpret)
    h, _ = backbone_forward(params, cfg, batch, ops=ops)
    return logits_from_hidden(params, cfg, h)[:, -1:, :]


def decode_step(params, token_batch, cache, pos, *, cfg, kernel_impl: str = "ref",
                interpret=None):
    """One-token decode against the cache. Returns (logits, cache')."""
    ops = None if kernel_impl == "ref" else get_opset(kernel_impl, interpret=interpret)
    return backbone_decode(params, cfg, token_batch, cache, pos, ops=ops)


def pac_decode_step(
    backbone_params, adapter_params, token_batch, cache, adapter_cache, pos, *, cfg,
    r: int = 8, kernel_impl: str = "ref", interpret=None,
):
    """Serve the personalised model: backbone decode + side-network decode.

    The frozen backbone decode dispatches through the ``kernel_impl``
    OpSet (quantized projections under ``"pallas"``); the side network
    and LM head stay on the ref ops — they are the trainable/fp math."""
    from repro.models.backbone import _REF_OPS, apply_block_decode

    ops = _REF_OPS if kernel_impl == "ref" else get_opset(kernel_impl, interpret=interpret)
    if "embeds" in token_batch:
        x = token_batch["embeds"]
    else:
        x = ops.embed_lookup(backbone_params["embed"], token_batch["tokens"])

    def period_fn(carry, xs):
        block_slice, cache_slice = xs
        h = carry
        new_caches = []
        for i, spec in enumerate(cfg.pattern):
            h, nc = apply_block_decode(block_slice[i], h, cfg, spec, cache_slice[i], pos, ops=ops)
            new_caches.append(nc)
        return h, (tuple(new_caches), h)

    b_final, (new_cache, taps_t) = jax.lax.scan(
        period_fn, x, (tuple(backbone_params["blocks"]), tuple(cache))
    )
    side, new_acache = adapter_decode(
        adapter_params, cfg, x, taps_t, adapter_cache, pos, r
    )
    logits = logits_from_hidden(backbone_params, cfg, b_final + side)
    return logits, list(new_cache), new_acache
