"""Training / serving step functions.

These are the units that get ``jax.jit``-ed with mesh shardings — one per
fine-tuning technique (the paper's comparison set) plus the serving paths:

* ``pac_train_step``          — PAC+ epoch-1: frozen (possibly quantized)
                                 backbone forward + side-network update.
* ``pac_cached_train_step``   — PAC+ epoch≥2: adapter-only, from cache.
* ``full_train_step``         — full fine-tuning baseline.
* ``lora_train_step``         — LoRA baseline (backprop through backbone).
* ``houlsby_train_step``      — serial Adapters baseline.
* ``prefill_step``            — forward over a full prompt (inference).
* ``decode_step``             — one token against a KV/state cache.
* ``pac_decode_step``         — decode through backbone + fine-tuned side
                                 network (serving a personalised model).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import peft
from repro.core.parallel_adapters import (
    adapter_decode,
    adapter_forward,
    init_adapter_cache,
    pac_logits,
)
from repro.models.backbone import (
    backbone_decode,
    backbone_forward,
    backbone_logits,
    cross_entropy,
    embed_inputs,
    logits_from_hidden,
)
from repro.optim import adamw_update, clip_by_global_norm

# ---------------------------------------------------------------------------
# PAC+ steps
# ---------------------------------------------------------------------------


def pac_loss_fn(adapter_params, backbone_params, cfg, batch, r: int = 8):
    x, positions = embed_inputs(backbone_params, cfg, batch)
    b_final, taps = backbone_forward(backbone_params, cfg, batch, collect_taps=True)
    # the gradient "highway": nothing upstream of the taps is differentiated
    x, b_final, taps = jax.lax.stop_gradient((x, b_final, taps))
    logits = pac_logits(backbone_params, adapter_params, cfg, x, taps, b_final, positions, r)
    return cross_entropy(logits, batch["labels"])


def pac_train_step(
    backbone_params, adapter_params, opt_state, batch, *, cfg, r: int = 8, lr=1e-3, clip=1.0
):
    """Epoch-1 PAC+ step. Returns (loss, adapter_params', opt_state', (b0, taps))."""
    x, positions = embed_inputs(backbone_params, cfg, batch)
    b_final, taps = backbone_forward(backbone_params, cfg, batch, collect_taps=True)
    x, b_final, taps = jax.lax.stop_gradient((x, b_final, taps))

    def loss_fn(ap):
        logits = pac_logits(backbone_params, ap, cfg, x, taps, b_final, positions, r)
        return cross_entropy(logits, batch["labels"])

    loss, grads = jax.value_and_grad(loss_fn)(adapter_params)
    grads, _ = clip_by_global_norm(grads, clip)
    adapter_params, opt_state = adamw_update(adapter_params, grads, opt_state, lr=lr)
    return loss, adapter_params, opt_state, (x, taps, b_final)


def pac_cached_train_step(
    backbone_params, adapter_params, opt_state, cached_batch, *, cfg, r: int = 8, lr=1e-3, clip=1.0
):
    """Epoch≥2 PAC+ step: backbone forward replaced by the activation cache.

    cached_batch: {"b0": (B,S,d), "taps": (n_p,B,S,d), "b_final": (B,S,d),
                   "labels": (B,S), optional "positions"}.
    Only the LM head / final norm of ``backbone_params`` is read — the rest
    of the backbone can be released from memory (paper §IV-B memory win).
    """
    b0, taps, b_final = cached_batch["b0"], cached_batch["taps"], cached_batch["b_final"]
    B, S = b0.shape[:2]
    if "positions" in cached_batch:
        positions = cached_batch["positions"]
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if cfg.rope == "mrope":
            positions = jnp.broadcast_to(positions, (3, B, S))

    def loss_fn(ap):
        logits = pac_logits(backbone_params, ap, cfg, b0, taps, b_final, positions, r)
        return cross_entropy(logits, cached_batch["labels"])

    loss, grads = jax.value_and_grad(loss_fn)(adapter_params)
    grads, _ = clip_by_global_norm(grads, clip)
    adapter_params, opt_state = adamw_update(adapter_params, grads, opt_state, lr=lr)
    return loss, adapter_params, opt_state


# ---------------------------------------------------------------------------
# Baseline fine-tuning steps
# ---------------------------------------------------------------------------


def full_train_step(params, opt_state, batch, *, cfg, lr=1e-4, clip=1.0):
    def loss_fn(p):
        return cross_entropy(backbone_logits(p, cfg, batch), batch["labels"])

    loss, grads = jax.value_and_grad(loss_fn)(params)
    grads, _ = clip_by_global_norm(grads, clip)
    params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
    return loss, params, opt_state


def lora_train_step(backbone_params, lora_params, opt_state, batch, *, cfg, lr=1e-3, clip=1.0):
    def loss_fn(lp):
        return cross_entropy(peft.lora_logits(backbone_params, lp, cfg, batch), batch["labels"])

    loss, grads = jax.value_and_grad(loss_fn)(lora_params)
    grads, _ = clip_by_global_norm(grads, clip)
    lora_params, opt_state = adamw_update(lora_params, grads, opt_state, lr=lr)
    return loss, lora_params, opt_state


def houlsby_train_step(backbone_params, ad_params, opt_state, batch, *, cfg, lr=1e-3, clip=1.0):
    def loss_fn(ap):
        return cross_entropy(peft.houlsby_logits(backbone_params, ap, cfg, batch), batch["labels"])

    loss, grads = jax.value_and_grad(loss_fn)(ad_params)
    grads, _ = clip_by_global_norm(grads, clip)
    ad_params, opt_state = adamw_update(ad_params, grads, opt_state, lr=lr)
    return loss, ad_params, opt_state


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def prefill_step(params, batch, *, cfg):
    """Full-prompt forward (inference-prefill). Returns last-position logits."""
    logits = backbone_logits(params, cfg, batch)
    return logits[:, -1:, :]


def decode_step(params, token_batch, cache, pos, *, cfg):
    """One-token decode against the cache. Returns (logits, cache')."""
    return backbone_decode(params, cfg, token_batch, cache, pos)


def pac_decode_step(
    backbone_params, adapter_params, token_batch, cache, adapter_cache, pos, *, cfg, r: int = 8
):
    """Serve the personalised model: backbone decode + side-network decode."""
    from repro.core.quantization import maybe_dequantize_tree
    from repro.models.backbone import apply_block_decode
    from repro.models.layers import rms_norm

    if "embeds" in token_batch:
        x = token_batch["embeds"]
    else:
        embed = maybe_dequantize_tree(backbone_params["embed"])
        x = jnp.take(embed, token_batch["tokens"], axis=0)

    def period_fn(carry, xs):
        block_slice, cache_slice = xs
        h = carry
        new_caches = []
        for i, spec in enumerate(cfg.pattern):
            h, nc = apply_block_decode(block_slice[i], h, cfg, spec, cache_slice[i], pos)
            new_caches.append(nc)
        return h, (tuple(new_caches), h)

    b_final, (new_cache, taps_t) = jax.lax.scan(
        period_fn, x, (tuple(backbone_params["blocks"]), tuple(cache))
    )
    side, new_acache = adapter_decode(
        adapter_params, cfg, x, taps_t, adapter_cache, pos, r
    )
    logits = logits_from_hidden(backbone_params, cfg, b_final + side)
    return logits, list(new_cache), new_acache
