"""Weight initialization for Parallel Adapters (paper §IV-C).

Two initialisers beyond random Gaussian / zero:

* **Structural pruning** — the adapter inherits the backbone's top-norm
  channels (Torch-Pruning's norm criterion, re-implemented in JAX):
  per-matrix row/col selection by L2 importance, with W_down initialised
  to the channel-selection matrix so the side network starts as a pruned
  functional copy of the backbone.
* **Knowledge distillation** — the side network is trained (on public
  calibration data; no private user data, so the paper runs this in the
  cloud) to reproduce the frozen backbone's next-token distribution from
  its taps.

Both keep ``W_up`` zero so the PAC+ model's initial output equals the
pre-trained backbone exactly — the smooth-start property the paper
derives from LoRA's B=0 init.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.parallel_adapters import adapter_config, init_adapter
from repro.core.quantization import QTensor, maybe_dequantize_tree


# ---------------------------------------------------------------------------
# Norm-based structural pruning
# ---------------------------------------------------------------------------


def _l2(w, axis):
    return jnp.sqrt(jnp.sum(jnp.square(w), axis=axis))


def _topk_idx(importance: jax.Array, k: int) -> jax.Array:
    """Indices of the top-k channels, sorted ascending (stable layout)."""
    k = min(k, importance.shape[0])
    idx = jnp.argsort(-importance)[:k]
    return jnp.sort(idx)


def _dense(x):
    return maybe_dequantize_tree(x)


def channel_importance(backbone_params, cfg) -> jax.Array:
    """L2 importance of each d_model channel (norm criterion)."""
    emb = _dense(backbone_params["embed"])
    imp = _l2(emb, axis=0)
    for pos in backbone_params["blocks"]:
        mixer = pos["mixer"]
        for name in ("wq", "wz", "in_proj"):
            if name in mixer:
                w = _dense(mixer[name])  # (n_p, d, out)
                imp = imp + _l2(w, axis=(0, 2))
                break
    return imp


def _prune_rows_cols(w, row_idx=None, col_idx=None):
    w = _dense(w)
    if row_idx is not None:
        w = jnp.take(w, row_idx, axis=-2)
    if col_idx is not None:
        w = jnp.take(w, col_idx, axis=-1)
    return w


def _prune_heads(w, keep_d, n_heads, hd, n_heads_a, hd_a, transpose=False):
    """(n_p, d, H*hd) -> (n_p, d_a, H_a*hd_a) by head/width norm selection."""
    w = _dense(w)
    if transpose:
        w = jnp.swapaxes(w, -1, -2)  # (n_p, d, H*hd)
    n_p, d, _ = w.shape
    w = w.reshape(n_p, d, n_heads, hd)
    head_imp = _l2(w, axis=(0, 1, 3))
    heads = _topk_idx(head_imp, min(n_heads_a, n_heads))
    w = jnp.take(w, heads, axis=2)
    if n_heads_a > n_heads:  # adapter wider than source: zero-pad heads
        w = jnp.pad(w, ((0, 0), (0, 0), (0, n_heads_a - n_heads), (0, 0)))
    dim_imp = _l2(w, axis=(0, 1, 2))
    dims = _topk_idx(dim_imp, min(hd_a, hd))
    w = jnp.take(w, dims, axis=3)
    if hd_a > hd:  # adapter head_dim wider than source: zero-pad (smooth start)
        w = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, hd_a - hd)))
    w = jnp.take(w, keep_d, axis=1).reshape(n_p, keep_d.shape[0], n_heads_a * hd_a)
    if transpose:
        w = jnp.swapaxes(w, -1, -2)
    return w


def pruning_init(rng, backbone_params, cfg, r: int = 8, dtype=jnp.float32) -> dict:
    """Adapter params initialised from the backbone's top-norm channels."""
    acfg = adapter_config(cfg, r)
    params = init_adapter(rng, cfg, r, dtype)  # layout template
    d_a = acfg.d_model
    imp = channel_importance(backbone_params, cfg)
    keep_d = _topk_idx(imp, d_a)

    # W_down := channel-selection matrices (b_i -> its top-norm channels)
    sel = jnp.zeros((cfg.d_model, d_a), dtype).at[keep_d, jnp.arange(d_a)].set(1.0)
    params["downs"] = jnp.broadcast_to(sel, params["downs"].shape)
    params["up"] = jnp.zeros_like(params["up"])  # smooth start (§IV-C)

    for pos_i, spec in enumerate(cfg.pattern):
        src, dst = backbone_params["blocks"][pos_i], params["blocks"][pos_i]
        dst["ln1"] = jnp.take(_dense(src["ln1"]), keep_d, axis=-1)
        if "ln2" in dst and "ln2" in src:
            dst["ln2"] = jnp.take(_dense(src["ln2"]), keep_d, axis=-1)
        sm, dm = src["mixer"], dst["mixer"]
        if spec.kind == "attn" or spec.kind == "mlstm":
            H, hd = cfg.n_heads, cfg.hd
            Ha, hda = acfg.n_heads, acfg.hd
            for nm in ("wq", "wk", "wv"):
                n_src = cfg.n_kv_heads if (spec.kind == "attn" and nm in ("wk", "wv")) else H
                n_dst = acfg.n_kv_heads if (spec.kind == "attn" and nm in ("wk", "wv")) else Ha
                dm[nm] = _prune_heads(sm[nm], keep_d, n_src, hd, n_dst, hda)
            dm["wo"] = _prune_heads(sm["wo"], keep_d, H, hd, Ha, hda, transpose=True)
            if spec.kind == "mlstm":
                dm["ogate"] = _prune_heads(sm["ogate"], keep_d, H, hd, Ha, hda)
                gate_heads = _topk_idx(
                    _l2(_dense(sm["wi"]), axis=(0, 1)), Ha
                )
                dm["wi"] = _prune_rows_cols(sm["wi"], keep_d, gate_heads)
                dm["wf"] = _prune_rows_cols(sm["wf"], keep_d, gate_heads)
                dm["f_bias"] = jnp.take(_dense(sm["f_bias"]), gate_heads, axis=-1)
        elif spec.kind == "slstm":
            dd = acfg.d_model
            for nm in ("wz", "wi", "wf", "wog", "wo"):
                dm[nm] = _prune_rows_cols(sm[nm], keep_d, keep_d)
            dm["f_bias"] = jnp.take(_dense(sm["f_bias"]), keep_d, axis=-1)
            # block-diagonal recurrences: select matching head blocks
            Ha = acfg.n_heads
            hda = dd // Ha
            for nm in ("rz", "ri", "rf"):
                w = _dense(sm[nm])  # (n_p, H, hd, hd)
                w = w[:, :Ha, :hda, :hda]
                dm[nm] = w
        elif spec.kind == "mamba":
            di_imp = _l2(_dense(sm["in_proj"]), axis=(0, 1))
            di_a = acfg.d_inner
            keep_x = _topk_idx(di_imp[: cfg.d_inner], di_a)
            keep_z = _topk_idx(di_imp[cfg.d_inner :], di_a) + cfg.d_inner
            keep_xz = jnp.concatenate([keep_x, keep_z])
            dm["in_proj"] = _prune_rows_cols(sm["in_proj"], keep_d, keep_xz)
            dm["conv_w"] = jnp.take(_dense(sm["conv_w"]), keep_x, axis=-1)
            dm["conv_b"] = jnp.take(_dense(sm["conv_b"]), keep_x, axis=-1)
            ds = acfg.ssm_d_state
            bc = _prune_rows_cols(sm["w_bc"], keep_x)
            dm["w_bc"] = jnp.concatenate(
                [bc[..., :ds], bc[..., cfg.ssm_d_state : cfg.ssm_d_state + ds]], axis=-1
            )
            rk = dm["w_dt1"].shape[-1]
            dm["w_dt1"] = _prune_rows_cols(sm["w_dt1"], keep_x)[..., :rk]
            dm["w_dt2"] = _prune_rows_cols(sm["w_dt2"], None, keep_x)[..., :rk, :]
            dm["dt_bias"] = jnp.take(_dense(sm["dt_bias"]), keep_x, axis=-1)
            dm["a_log"] = jnp.take(_dense(sm["a_log"]), keep_x, axis=-2)[..., :ds]
            dm["d_skip"] = jnp.take(_dense(sm["d_skip"]), keep_x, axis=-1)
            dm["out_proj"] = _prune_rows_cols(sm["out_proj"], keep_x, keep_d)
        # FFN
        if "ffn" in dst:
            if spec.moe and cfg.moe is not None:
                # average the experts, then prune — the adapter's dense FFN
                # inherits the expert ensemble's dominant channels
                wi = jnp.mean(_dense(src["ffn"]["wi"]), axis=1)  # (n_p, d, de)
                wg = jnp.mean(_dense(src["ffn"]["wg"]), axis=1)
                wo = jnp.mean(_dense(src["ffn"]["wo"]), axis=1)
            else:
                wi, wg, wo = (_dense(src["ffn"][n]) for n in ("wi", "wg", "wo"))
            ff_imp = _l2(wi, axis=(0, 1))
            keep_ff = _topk_idx(ff_imp, dst["ffn"]["wi"].shape[-1])
            dst["ffn"]["wi"] = _prune_rows_cols(wi, keep_d, keep_ff)
            dst["ffn"]["wg"] = _prune_rows_cols(wg, keep_d, keep_ff)
            dst["ffn"]["wo"] = _prune_rows_cols(wo, keep_ff, keep_d)
    return params


# ---------------------------------------------------------------------------
# Knowledge-distillation init
# ---------------------------------------------------------------------------


def distillation_init(
    rng,
    backbone_params,
    cfg,
    calib_batches,
    r: int = 8,
    steps: int = 50,
    lr: float = 1e-3,
    from_pruning: bool = True,
) -> dict:
    """Train the side network to mimic the frozen backbone's predictions.

    calib_batches: iterable of {"tokens": (B,S)} public-data batches.
    The student's logits come from the adapter path *alone*
    (`lm_head(W_up a_L)` vs teacher `lm_head(b_final)`), so after
    distillation the side network is a functional mini-replica — the
    paper's "smaller student model" (Hsieh et al. toolkit analogue).
    """
    from repro.core.parallel_adapters import adapter_forward
    from repro.models.backbone import backbone_forward, embed_inputs, logits_from_hidden
    from repro.optim import adamw_init, adamw_update

    if from_pruning:
        adapter = pruning_init(rng, backbone_params, cfg, r)
    else:
        adapter = init_adapter(rng, cfg, r)
    # distillation needs a non-zero output path; break the W_up symmetry
    k_up = jax.random.fold_in(rng, 17)
    adapter["up"] = (
        jax.random.normal(k_up, adapter["up"].shape) * adapter["up"].shape[0] ** -0.5
    ).astype(adapter["up"].dtype)

    def kl_loss(aparams, batch):
        x, positions = embed_inputs(backbone_params, cfg, batch)
        b_final, taps = backbone_forward(backbone_params, cfg, batch, collect_taps=True)
        b_final, taps, x = jax.lax.stop_gradient((b_final, taps, x))
        side = adapter_forward(aparams, cfg, x, taps, positions, r)
        s_logits = logits_from_hidden(backbone_params, cfg, side)
        t_logits = logits_from_hidden(backbone_params, cfg, b_final)
        t = jax.nn.softmax(t_logits.astype(jnp.float32), axis=-1)
        ls = jax.nn.log_softmax(s_logits.astype(jnp.float32), axis=-1)
        return -jnp.mean(jnp.sum(t * ls, axis=-1))

    opt = adamw_init(adapter)
    step_fn = jax.jit(
        lambda ap, op, b, i: _distill_step(kl_loss, ap, op, b, i, lr)
    )
    batches = list(calib_batches)
    for i in range(steps):
        adapter, opt = step_fn(adapter, opt, batches[i % len(batches)], jnp.int32(i))
    return adapter


def _distill_step(loss_fn, aparams, opt, batch, i, lr):
    from repro.optim import adamw_update

    grads = jax.grad(loss_fn)(aparams, batch)
    return adamw_update(aparams, grads, opt, lr=lr)
