"""Parallel Adapters — the paper's core fine-tuning technique (§IV-A).

A lightweight *side network* (hidden width ``d/r``, r=8 by default) runs
in parallel with the frozen backbone. Adapter block *i* consumes

    input_i = λ_i · W_down_i(b_i)  +  (1 − λ_i) · a_{i−1}

where ``b_i`` is the backbone's post-period-i activation (a "tap") and
``a_{i−1}`` the previous adapter output; λ_i is learnable, initialised to
0.5 (paper Fig. 6). The final adapter state is projected back up with
``W_up`` and summed with the backbone's final hidden state (side-tuning),
then fed through the *frozen* LM head.

Because no trainable parameter lives inside the backbone, the backward
pass never touches it: gradients flow only through the ~(1/r²)-sized side
network. Combined with the activation cache
(`repro.core.activation_cache`) the backbone forward is also skipped from
epoch 2 on.

The side network mirrors the backbone *family* (attention blocks for
transformers, mLSTM blocks for xLSTM, Mamba blocks for Jamba …) at the
reduced width — the paper's "lightweight version of the backbone" — with
two deliberate deviations recorded in DESIGN.md §Arch-applicability:
MoE layers become dense FFNs, and taps are taken at pattern-period
granularity (== per layer for un-patterned archs).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.backbone import apply_block, init_block, logits_from_hidden
from repro.models.layers import rms_norm


# ---------------------------------------------------------------------------
# Adapter (side-network) config derivation
# ---------------------------------------------------------------------------


def adapter_config(cfg, r: int = 8):
    """The paper's 'lightweight version of the backbone': every width /r."""
    d_a = max(8, cfg.d_model // r)
    n_heads = max(1, cfg.n_heads // r)
    # keep the GQA grouping ratio where possible
    ratio = max(1, cfg.n_heads // cfg.n_kv_heads)
    n_kv = max(1, n_heads // ratio)
    n_heads = max(n_heads, n_kv)
    hd = max(4, (d_a // n_heads) // 2 * 2)  # RoPE needs an even head_dim
    d_a = hd * n_heads  # keep divisible
    # MoE layers in the backbone become dense FFNs in the adapter
    pattern = tuple(dataclasses.replace(s, moe=False) for s in cfg.pattern)
    d_ff = cfg.d_ff
    if any(s.moe for s in cfg.pattern) and cfg.moe is not None:
        d_ff = cfg.moe.d_expert * cfg.moe.top_k
    return dataclasses.replace(
        cfg,
        name=cfg.name + f"-adapter-r{r}",
        d_model=d_a,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=hd,
        d_ff=max(16, d_ff // r) if d_ff else 0,
        pattern=pattern,
        moe=None,
        mlstm_chunk=cfg.mlstm_chunk,
    )


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_adapter(rng, cfg, r: int = 8, dtype=jnp.float32) -> dict:
    """Random (Gaussian) init. See `repro.core.init_methods` for the
    pruning/distillation initialisers the paper recommends."""
    acfg = adapter_config(cfg, r)
    n_p = cfg.n_periods
    d, d_a = cfg.d_model, acfg.d_model
    k_down, k_blocks, k_up = jax.random.split(rng, 3)

    blocks = []
    for i, spec in enumerate(acfg.pattern):
        rngs = jax.random.split(jax.random.fold_in(k_blocks, i), n_p)
        blocks.append(jax.vmap(lambda rr, s=spec: init_block(rr, acfg, s, dtype))(rngs))

    downs = (
        jax.random.normal(k_down, (n_p + 1, d, d_a)) * d ** -0.5
    ).astype(dtype)
    return {
        "downs": downs,  # [0] embeds b_0; [1..n_p] per-period taps
        "lambda": jnp.full((n_p,), 0.5, jnp.float32),
        "blocks": blocks,
        "up": (jax.random.normal(k_up, (d_a, d)) * d_a ** -0.5).astype(dtype),
        "out_norm": jnp.zeros((d_a,), dtype),
    }


def abstract_adapter(cfg, r: int = 8, dtype=jnp.float32):
    return jax.eval_shape(lambda: init_adapter(jax.random.PRNGKey(0), cfg, r, dtype))


def adapter_param_count(cfg, r: int = 8) -> int:
    params = abstract_adapter(cfg, r)
    return sum(int(jnp.prod(jnp.array(l.shape))) for l in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def adapter_forward(
    adapter_params: dict,
    cfg,
    b0: jax.Array,
    taps: jax.Array,
    positions: jax.Array,
    r: int = 8,
) -> jax.Array:
    """Run the side network.

    b0:   (B, S, d)       backbone embedding output
    taps: (n_p, B, S, d)  backbone activations after each period
    Returns the final adapter hidden state upsampled to d: (B, S, d).
    """
    acfg = adapter_config(cfg, r)
    downs = adapter_params["downs"]
    # λ is stored unconstrained in [0,1] at init (0.5); clamp softly.
    lambdas = jnp.clip(adapter_params["lambda"], 0.0, 1.0)

    a = b0 @ downs[0]  # (B, S, d_a)

    def period_fn(carry, xs):
        a_prev = carry
        block_slice, down_i, lam_i, b_i = xs
        # cast back to the stream dtype: λ is f32, which would upcast a
        # bf16 carry and break the scan's carry-type invariant
        mixed = lam_i * (b_i @ down_i) + (1.0 - lam_i) * a_prev
        h = mixed.astype(a_prev.dtype)
        for j, spec in enumerate(acfg.pattern):
            h = apply_block(block_slice[j], h, acfg, spec, positions)
        return h, None

    a, _ = jax.lax.scan(
        period_fn,
        a,
        (tuple(adapter_params["blocks"]), downs[1:], lambdas, taps),
    )
    a = rms_norm(a, adapter_params["out_norm"], acfg.norm_eps)
    return a @ adapter_params["up"]


def pac_logits(backbone_params, adapter_params, cfg, b0, taps, b_final, positions, r: int = 8):
    """Side-tuning combine: adapter output + backbone final hidden → frozen head."""
    side = adapter_forward(adapter_params, cfg, b0, taps, positions, r)
    return logits_from_hidden(backbone_params, cfg, b_final + side)


# ---------------------------------------------------------------------------
# Decode-time adapter (serving a fine-tuned model)
# ---------------------------------------------------------------------------


def init_adapter_cache(cfg, B: int, max_len: int, r: int = 8, dtype=jnp.float32):
    from repro.models.backbone import init_cache

    return init_cache(adapter_config(cfg, r), B, max_len, dtype)


def adapter_decode(
    adapter_params, cfg, b0_t, taps_t, cache, pos, r: int = 8
):
    """One-token adapter step. b0_t: (B,1,d); taps_t: (n_p,B,1,d)."""
    from repro.models.backbone import apply_block_decode

    acfg = adapter_config(cfg, r)
    downs = adapter_params["downs"]
    lambdas = jnp.clip(adapter_params["lambda"], 0.0, 1.0)
    a = b0_t @ downs[0]

    def period_fn(carry, xs):
        a_prev = carry
        block_slice, cache_slice, down_i, lam_i, b_i = xs
        # cast like the train path (adapter_forward): λ is f32, which would
        # upcast a bf16 carry and break the scan's carry-type invariant
        mixed = lam_i * (b_i @ down_i) + (1.0 - lam_i) * a_prev
        h = mixed.astype(a_prev.dtype)
        new_caches = []
        for j, spec in enumerate(acfg.pattern):
            h, nc = apply_block_decode(block_slice[j], h, acfg, spec, cache_slice[j], pos)
            new_caches.append(nc)
        return h, tuple(new_caches)

    a, new_cache = jax.lax.scan(
        period_fn,
        a,
        (tuple(adapter_params["blocks"]), tuple(cache), downs[1:], lambdas, taps_t),
    )
    a = rms_norm(a, adapter_params["out_norm"], acfg.norm_eps)
    return a @ adapter_params["up"], list(new_cache)


# ---------------------------------------------------------------------------
# Multi-adapter serving (one decode batch, one adapter per request)
# ---------------------------------------------------------------------------


def stack_adapters(adapters):
    """Stack per-user adapter trees into one bank with a leading user
    axis — the resident form the multi-tenant engine gathers from."""
    adapters = list(adapters)
    if not adapters:
        raise ValueError("need at least one adapter")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *adapters)


def gather_adapters(bank, user_idx):
    """Per-request adapter stacks: bank leaves (U, ...) gathered down to
    (B, ...) by ``user_idx`` (B,) int32 — duplicates are fine."""
    return jax.tree.map(lambda t: t[user_idx], bank)


def batched_adapter_decode(adapter_batch, cfg, b0_t, taps_t, cache, lengths, r: int = 8):
    """One adapter step for B requests with B *different* adapters and
    per-request write positions (continuous batching is ragged).

    adapter_batch: adapter tree with a leading request axis (B, ...) —
    see :func:`gather_adapters`; b0_t: (B,1,d); taps_t: (n_p,B,1,d);
    cache: adapter cache with request axis 1 — leaves (n_p, B, L, ...);
    lengths: (B,) int32 per-request write index. Returns
    (side (B,1,d), new_cache) — row b is exactly
    :func:`adapter_decode` of request b alone (the λ-mix, blocks and
    cache update vmap over the request axis unchanged)."""

    def lane(ap, b0, taps, cache_1, pos):
        cache_1 = jax.tree.map(lambda t: t[:, None], cache_1)
        side, nc = adapter_decode(ap, cfg, b0[None], taps[:, None], cache_1, pos, r)
        return side[0], jax.tree.map(lambda t: t[:, 0], nc)

    return jax.vmap(lane, in_axes=(0, 0, 1, 1, 0), out_axes=(0, 1))(
        adapter_batch, b0_t, taps_t, cache, lengths
    )


def adapter_prefill(
    adapter_params, cfg, b0, taps, positions, max_len: int, r: int = 8
):
    """Side-network prefill: one batched forward over the prompt that
    also captures the adapter's KV caches (the decode-ready state) —
    the serving twin of :func:`adapter_forward`.

    b0: (B,S,d); taps: (n_p,B,S,d); positions: (B,S) or (3,B,S).
    Returns (side (B,S,d), caches) where ``caches`` has the
    :func:`init_adapter_cache` layout (leaves (n_p, B, max_len, ...))
    with the first S slots holding the prompt KV. Attention-pattern
    adapters only — SSM side networks have no forward-final-state API
    and take the engine's stepwise prefill path instead."""
    acfg = adapter_config(cfg, r)
    if any(s.kind != "attn" for s in acfg.pattern):
        raise ValueError(
            "adapter_prefill supports attention-pattern adapters only; "
            f"got {tuple(s.kind for s in acfg.pattern)}"
        )
    S = b0.shape[1]
    if S > max_len:
        raise ValueError(f"prompt length {S} exceeds max_len {max_len}")
    downs = adapter_params["downs"]
    lambdas = jnp.clip(adapter_params["lambda"], 0.0, 1.0)
    a = b0 @ downs[0]

    def period_fn(carry, xs):
        a_prev = carry
        block_slice, down_i, lam_i, b_i = xs
        mixed = lam_i * (b_i @ down_i) + (1.0 - lam_i) * a_prev
        h = mixed.astype(a_prev.dtype)
        kvs = []
        for j, spec in enumerate(acfg.pattern):
            h, kv = apply_block(
                block_slice[j], h, acfg, spec, positions, return_kv=True
            )
            kvs.append(kv)
        return h, tuple(kvs)

    a, kvs = jax.lax.scan(
        period_fn, a, (tuple(adapter_params["blocks"]), downs[1:], lambdas, taps)
    )
    a = rms_norm(a, adapter_params["out_norm"], acfg.norm_eps)
    side = a @ adapter_params["up"]
    caches = []
    for k, v in kvs:  # each (n_p, B, S, Hkv_a, hd_a)
        pad = ((0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0))
        caches.append({"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)})
    return side, caches


def batched_adapter_prefill(
    adapter_batch, cfg, b0, taps, positions, max_len: int, r: int = 8
):
    """Per-request-adapter prefill: :func:`adapter_prefill` vmapped over
    a leading request axis of the adapter tree. Same shapes as
    :func:`adapter_prefill` plus the (B, ...) adapter_batch."""
    pos_axis = positions.ndim - 2  # 0 for (B,S), 1 for mrope (3,B,S)

    def lane(ap, b0_1, taps_1, pos_1):
        pos_1 = jnp.expand_dims(pos_1, pos_axis)
        side, caches = adapter_prefill(
            ap, cfg, b0_1[None], taps_1[:, None], pos_1, max_len, r
        )
        return side[0], jax.tree.map(lambda t: t[:, 0], caches)

    return jax.vmap(lane, in_axes=(0, 0, 1, pos_axis), out_axes=(0, 1))(
        adapter_batch, b0, taps, positions
    )
