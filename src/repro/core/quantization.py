"""Block-wise absmax quantization of the frozen LLM backbone (paper §IV-D).

Implements the paper's Eq. (1)/(2): weights are stored in a low-bit
integer format (INT8, or packed INT4) with one f32 scale per contiguous
block of ``block`` elements along the **last** axis — keeping the original
dimension structure so GSPMD sharding rules written for the f32 parameter
apply unchanged to the quantized storage.

The storage/compute split follows the paper's Fig. 8 (and QLoRA): storage
dtype INT8/INT4, compute dtype f32/bf16 — ``dequantize`` happens at use,
layer-by-layer inside the backbone scan so at most one layer's worth of
f32 weights is live at a time. On TPU the fused Pallas kernel
(`repro.kernels.quant_matmul`) performs dequantisation in VMEM so HBM
traffic stays at the integer byte-width — the memory-roofline payoff of
the technique.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import compat


@jax.tree_util.register_pytree_node_class
class QTensor:
    """Block-quantized tensor: int storage + per-block scales.

    q:      int8 array; for bits=4, two nibbles packed per byte along the
            last axis (shape[..., padded_last/2]).
    scale:  f32 array (..., n_blocks) — absmax-derived, one per block.
    """

    def __init__(self, q, scale, bits: int, block: int, orig_last: int):
        self.q = q
        self.scale = scale
        self.bits = bits
        self.block = block
        self.orig_last = orig_last

    # -- pytree protocol --
    def tree_flatten(self):
        return (self.q, self.scale), (self.bits, self.block, self.orig_last)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def shape(self):
        return self.q.shape[:-1] + (self.orig_last,)

    @property
    def dtype(self):  # storage dtype
        return self.q.dtype

    @property
    def nbytes(self) -> int:
        return self.q.size * 1 + self.scale.size * 4

    def __repr__(self):
        return f"QTensor(int{self.bits}, shape={self.shape}, block={self.block})"


def _qmax(bits: int) -> int:
    return {8: 127, 4: 7}[bits]


def quantize(x: jax.Array, bits: int = 8, block: int = 128) -> QTensor:
    """Block-wise absmax quantization along the last axis (paper Eq. 1)."""
    if bits not in (8, 4):
        raise ValueError(f"bits must be 8 or 4, got {bits}")
    orig_last = x.shape[-1]
    block = min(block, orig_last)
    if bits == 4 and block % 2:
        block += 1  # nibble packing needs an even padded length
    nb = -(-orig_last // block)
    pad = nb * block - orig_last
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = x.reshape(x.shape[:-1] + (nb, block)).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xb), axis=-1)  # (..., nb)
    qmax = _qmax(bits)
    scale = absmax / qmax  # dequant multiplier; 0 where block is all-zero
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    q = jnp.clip(jnp.round(xb * inv[..., None]), -qmax, qmax).astype(jnp.int8)
    q = q.reshape(x.shape[:-1] + (nb * block,))
    if bits == 4:
        lo = q[..., 0::2] & 0xF
        hi = (q[..., 1::2] & 0xF) << 4
        q = (lo | hi).astype(jnp.int8)
    return QTensor(q, scale, bits, block, orig_last)


def dequantize(t: QTensor, dtype=jnp.float32) -> jax.Array:
    """Paper Eq. (2): elementwise q * scale, unpad, cast to compute dtype."""
    q = t.q
    if t.bits == 4:
        lo = (q.astype(jnp.int32) & 0xF)
        lo = jnp.where(lo >= 8, lo - 16, lo)  # sign-extend nibble
        hi = (q.astype(jnp.int32) >> 4) & 0xF
        hi = jnp.where(hi >= 8, hi - 16, hi)
        q = jnp.stack([lo, hi], axis=-1).reshape(q.shape[:-1] + (q.shape[-1] * 2,))
    padded_last = q.shape[-1]
    nb = padded_last // t.block
    xb = q.reshape(q.shape[:-1] + (nb, t.block)).astype(jnp.float32)
    x = (xb * t.scale[..., None]).reshape(q.shape[:-1] + (padded_last,))
    return x[..., : t.orig_last].astype(dtype)


# ---------------------------------------------------------------------------
# Tree helpers — quantize a whole backbone, dequantize lazily at use
# ---------------------------------------------------------------------------


def _is_qtensor(x: Any) -> bool:
    return isinstance(x, QTensor)


# leaves whose pytree path has a component containing one of these
# substrings stay full-precision no matter their size: MoE routers are
# quantization-sensitive — a few
# mis-rounded logits flip top-k expert assignments outright, a much larger
# error than any dense matmul suffers (cf. QLoRA keeping norms in f32)
QUANT_SKIP_NAMES = ("router",)


def _path_names(path) -> list:
    names = []
    for k in path:
        for attr in ("key", "name", "idx"):
            if hasattr(k, attr):
                names.append(str(getattr(k, attr)))
                break
    return names


def quantize_tree(
    tree, bits: int = 8, block: int = 128, min_size: int = 4096,
    skip_names=QUANT_SKIP_NAMES,
):
    """Quantize every large weight leaf; leave small/1-D leaves untouched.

    Leaves whose path has a component *containing* any ``skip_names``
    substring keep their dtype — by default anything router-like
    ("router", "moe_router", "router_w", ...; see QUANT_SKIP_NAMES)."""
    if isinstance(skip_names, str):  # a bare string is one name, not chars
        skip_names = (skip_names,)
    skip_names = tuple(skip_names)

    def f(path, x):
        if any(s in n for n in _path_names(path) for s in skip_names):
            return x
        if isinstance(x, jax.Array) and x.ndim >= 2 and x.size >= min_size:
            return quantize(x, bits, block)
        return x

    return compat.tree_map_with_path(f, tree)


def maybe_dequantize_tree(tree, dtype=jnp.float32):
    """Identity on plain arrays; dequantizes any QTensor leaves."""
    if _is_qtensor(tree):
        return dequantize(tree, dtype)
    return jax.tree.map(
        lambda x: dequantize(x, dtype) if _is_qtensor(x) else x, tree, is_leaf=_is_qtensor
    )


def tree_storage_bytes(tree) -> int:
    """Total storage bytes (int bytes for QTensors, array bytes otherwise)."""
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=_is_qtensor):
        if _is_qtensor(leaf):
            total += leaf.nbytes
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total
