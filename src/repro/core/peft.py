"""Baseline PEFT techniques the paper compares against (§II, §VI):

* **Full fine-tuning** — every backbone parameter trainable.
* **LoRA** (Hu et al.) — low-rank ΔW = B·A on W_q and W_v, A Gaussian,
  B zero (the initialisation PAC+'s §IV-C analysis starts from).
* **Adapters** (Houlsby et al.) — bottleneck MLP inserted after each
  layer's FFN, residual around it.

Both LoRA and Adapters keep trainable structures *inside* the backbone,
so gradients must backpropagate through the whole model — the
inefficiency PAC+ removes. We implement them faithfully to reproduce the
paper's FLOPs/memory/time comparison tables.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import maybe_dequantize_tree
from repro.models.backbone import embed_inputs, logits_from_hidden
from repro.models.layers import (
    attention_forward,
    mlp_forward,
    rms_norm,
)
from repro.models.moe import moe_forward
from repro.models import ssm

# ---------------------------------------------------------------------------
# LoRA
# ---------------------------------------------------------------------------

LORA_TARGETS = ("wq", "wv")  # paper follows Hu et al.: q and v projections


def init_lora(rng, cfg, rank: int = 8, dtype=jnp.float32) -> dict:
    """One (A, B) pair per attention-ish layer position, stacked over periods."""
    n_p = cfg.n_periods
    d = cfg.d_model
    out = []
    for i, spec in enumerate(cfg.pattern):
        k = jax.random.fold_in(rng, i)
        if spec.kind == "attn":
            dq = cfg.n_heads * cfg.hd
            dkv = cfg.n_kv_heads * cfg.hd
        elif spec.kind in ("mlstm", "slstm"):
            dq = dkv = cfg.n_heads * cfg.hd if spec.kind == "mlstm" else d
        else:  # mamba: adapt the in/out projections
            dq, dkv = 2 * cfg.d_inner, d
        ka, kb = jax.random.split(k)
        out.append(
            {
                "a_q": (jax.random.normal(ka, (n_p, d, rank)) * d ** -0.5).astype(dtype),
                "b_q": jnp.zeros((n_p, rank, dq), dtype),
                "a_v": (jax.random.normal(kb, (n_p, d, rank)) * d ** -0.5).astype(dtype),
                "b_v": jnp.zeros((n_p, rank, dkv), dtype),
            }
        )
    return {"layers": out, "alpha": jnp.float32(2.0 * rank)}


def lora_delta(lp, x, which: str, rank_scale):
    a, b = lp[f"a_{which}"], lp[f"b_{which}"]
    return ((x @ a) @ b) * rank_scale


def apply_block_lora(p, lp, x, cfg, spec, positions, rank_scale):
    """Block forward with LoRA deltas on the q/v-ish projections."""
    from repro.core import psharding

    # same §Perf-iter-2 treatment as the shared apply_block: gather the
    # layer's weight slice (TP-only) so backward doesn't all-reduce
    # activations over `data` (missing this cost LoRA 6× the collective
    # volume of full FT on the production mesh — measured)
    p = psharding.gather_for_compute(p)
    p = maybe_dequantize_tree(p)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.kind == "attn":
        mixer = dict(p["mixer"])
        # materialised-ΔW variant keeps the flash path untouched
        mixer["wq"] = mixer["wq"] + (lp["a_q"] @ lp["b_q"]) * rank_scale
        mixer["wv"] = mixer["wv"] + (lp["a_v"] @ lp["b_v"]) * rank_scale
        mix = attention_forward(mixer, h, cfg, spec, positions)
    elif spec.kind == "mamba":
        mixer = dict(p["mixer"])
        mixer["in_proj"] = mixer["in_proj"] + (lp["a_q"] @ lp["b_q"]) * rank_scale
        mix = ssm.mamba_forward(mixer, h, cfg)
    elif spec.kind == "mlstm":
        mixer = dict(p["mixer"])
        mixer["wq"] = mixer["wq"] + (lp["a_q"] @ lp["b_q"]) * rank_scale
        mixer["wv"] = mixer["wv"] + (lp["a_v"] @ lp["b_v"]) * rank_scale
        mix = ssm.mlstm_forward(mixer, h, cfg)
    else:  # slstm
        mixer = dict(p["mixer"])
        mixer["wz"] = mixer["wz"] + (lp["a_q"] @ lp["b_q"]) * rank_scale
        mix = ssm.slstm_forward(mixer, h, cfg)
    x = x + mix
    if "ffn" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.moe and cfg.moe is not None:
            x = x + moe_forward(p["ffn"], h, cfg.moe)
        else:
            x = x + mlp_forward(p["ffn"], h)
    return x


def lora_logits(backbone_params, lora_params, cfg, batch):
    x, positions = embed_inputs(backbone_params, cfg, batch)
    rank = lora_params["layers"][0]["a_q"].shape[-1]
    rank_scale = lora_params["alpha"] / rank

    def period_fn(carry, xs):
        h = carry
        block_slice, lora_slice = xs
        for i, spec in enumerate(cfg.pattern):
            h = apply_block_lora(block_slice[i], lora_slice[i], h, cfg, spec, positions, rank_scale)
        return h, None

    x, _ = jax.lax.scan(
        period_fn, x, (tuple(backbone_params["blocks"]), tuple(lora_params["layers"]))
    )
    return logits_from_hidden(backbone_params, cfg, x)


# ---------------------------------------------------------------------------
# Houlsby Adapters (serial bottleneck inside the backbone)
# ---------------------------------------------------------------------------


def init_houlsby(rng, cfg, bottleneck: int = 64, dtype=jnp.float32) -> dict:
    n_p = cfg.n_periods
    d = cfg.d_model
    out = []
    for i in range(len(cfg.pattern)):
        k1, k2 = jax.random.split(jax.random.fold_in(rng, i))
        out.append(
            {
                "down": (jax.random.normal(k1, (n_p, d, bottleneck)) * d ** -0.5).astype(dtype),
                "up": jnp.zeros((n_p, bottleneck, d), dtype),  # zero-init = identity start
                "ln": jnp.zeros((n_p, d), dtype),
            }
        )
    return {"layers": out}


def houlsby_logits(backbone_params, adapters, cfg, batch):
    from repro.models.backbone import apply_block

    x, positions = embed_inputs(backbone_params, cfg, batch)

    def period_fn(carry, xs):
        h = carry
        block_slice, ad_slice = xs
        for i, spec in enumerate(cfg.pattern):
            h = apply_block(block_slice[i], h, cfg, spec, positions)
            a = rms_norm(h, ad_slice[i]["ln"], cfg.norm_eps)
            h = h + jax.nn.gelu(a @ ad_slice[i]["down"]) @ ad_slice[i]["up"]
        return h, None

    x, _ = jax.lax.scan(
        period_fn, x, (tuple(backbone_params["blocks"]), tuple(adapters["layers"]))
    )
    return logits_from_hidden(backbone_params, cfg, x)


def peft_param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params) if hasattr(x, "size"))
