"""Hybrid data+pipeline parallel runtime (paper §V-A) in JAX.

Three layers:

1. **Schedule** — ``build_1f1b_schedule`` emits the paper's
   one-forward-one-backward micro-batch order (Fig. 10b); validated for
   legality (dependencies, at-most-one-in-flight-per-device) in tests.
2. **Simulator** — ``simulate_plan`` replays a
   :class:`~repro.core.planner.Plan` through a discrete-event model
   (compute, inter-stage links, AllReduce) and returns the per-minibatch
   timeline; this is what the Fig. 12/16 benchmarks sweep.
3. **Runtime** — ``pipeline_apply`` runs a *real* SPMD pipeline over a
   ``stage`` mesh axis with ``shard_map`` + ``ppermute`` (GPipe-style
   rotation, autodiff straight through the collective). Since PR 2 this
   is the **trainer's execution path**, not a test-only artifact:
   ``repro.launch.train --dp N --stages S`` runs epoch-1 PAC+ through it
   on a 2-D ``(dp, stage)`` mesh (``repro.core.steps
   .pipeline_pac_train_step``), with each stage emitting its periods'
   taps for the activation cache and the adapter, then drops to pure
   data parallelism from epoch 2 (paper Fig. 10/11). Micro-batch
   gradient accumulation ≡ the paper's per-stage gradient aggregation;
   AllReduce of adapter grads over ``dp`` is the (tiny) trailing
   collective.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

# ---------------------------------------------------------------------------
# 1F1B schedule
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Op:
    stage: int
    micro: int
    kind: str  # "F" | "B"


def build_1f1b_schedule(n_stages: int, n_micro: int) -> List[List[Op]]:
    """Per-stage op order for 1F1B (PipeDream-flush). Returns ops[stage] lists."""
    out: List[List[Op]] = []
    for s in range(n_stages):
        warmup = min(n_stages - s - 1, n_micro)
        ops: List[Op] = [Op(s, m, "F") for m in range(warmup)]
        f, b = warmup, 0
        while b < n_micro:
            if f < n_micro:
                ops.append(Op(s, f, "F"))
                f += 1
            ops.append(Op(s, b, "B"))
            b += 1
        # dedupe while preserving order (warmup overlap)
        seen = set()
        ops = [o for o in ops if not ((o.kind, o.micro) in seen or seen.add((o.kind, o.micro)))]
        out.append(ops)
    return out


def validate_schedule(sched: List[List[Op]], n_micro: int) -> None:
    """Raises if the schedule violates pipeline dependencies."""
    n_stages = len(sched)
    for s, ops in enumerate(sched):
        fs = [o.micro for o in ops if o.kind == "F"]
        bs = [o.micro for o in ops if o.kind == "B"]
        assert fs == sorted(fs) and len(fs) == n_micro, f"stage {s}: bad F order"
        assert bs == sorted(bs) and len(bs) == n_micro, f"stage {s}: bad B order"
        # 1F1B memory bound: in-flight microbatches ≤ n_stages - s
        inflight = 0
        for o in ops:
            inflight += 1 if o.kind == "F" else -1
            assert inflight <= n_stages - s, f"stage {s}: {inflight} in flight"


# ---------------------------------------------------------------------------
# Discrete-event simulator
# ---------------------------------------------------------------------------


def simulate_plan(plan, comm_bytes_per_stage: Optional[Sequence[float]] = None) -> dict:
    """Replay 1F1B through the plan's stage times; returns timing breakdown."""
    S, M = plan.n_stages, plan.micro_batches
    sched = build_1f1b_schedule(S, M)
    # per-stage fwd/bwd split as recorded from LayerCost by the planner's
    # _phase_latencies; hand-built stages without recorded times fall back
    # to the historical tf:tb = 1:2 approximation
    tf, tb = [], []
    for st in plan.stages:
        if getattr(st, "fwd_time", 0.0) or getattr(st, "bwd_time", 0.0):
            tf.append(st.fwd_time)
            tb.append(st.bwd_time)
        else:
            tf.append(st.stage_time / 3.0)
            tb.append(2.0 * st.stage_time / 3.0)
    if comm_bytes_per_stage is None:
        comm = [0.0] * S
    else:
        comm = [
            b / min(d.bandwidth for d in st.devices)
            for b, st in zip(comm_bytes_per_stage, plan.stages)
        ]
    f_done = {}
    b_done = {}
    dev_free = [0.0] * S
    idx = [0] * S
    remaining = sum(len(x) for x in sched)
    while remaining:
        progressed = False
        for s in range(S):
            if idx[s] >= len(sched[s]):
                continue
            op = sched[s][idx[s]]
            if op.kind == "F":
                ready = 0.0 if s == 0 else f_done.get((s - 1, op.micro), None)
                if ready is None:
                    continue
                start = max(dev_free[s], ready + (comm[s - 1] if s else 0.0))
                f_done[(s, op.micro)] = start + tf[s]
                dev_free[s] = start + tf[s]
            else:
                ready = f_done.get((s, op.micro))
                up = 0.0 if s == S - 1 else b_done.get((s + 1, op.micro), None)
                if up is None or ready is None:
                    continue
                start = max(dev_free[s], ready, up + (comm[s] if s < S - 1 else 0.0))
                b_done[(s, op.micro)] = start + tb[s]
                dev_free[s] = start + tb[s]
            idx[s] += 1
            remaining -= 1
            progressed = True
        assert progressed, "schedule deadlock"
    total = max(b_done.values())
    busy = sum(M * (tf[s] + tb[s]) for s in range(S))
    return {
        "minibatch_time": total,
        "bubble_fraction": 1.0 - busy / (total * S),
        "per_stage_busy": [M * (tf[s] + tb[s]) for s in range(S)],
    }


# ---------------------------------------------------------------------------
# Real SPMD pipeline over a `stage` mesh axis
# ---------------------------------------------------------------------------


def stack_stages(blocks, n_stages: int):
    """Re-chunk period-stacked block params (n_p, ...) → (n_stages, n_p/s, ...)."""

    def f(x):
        n_p = x.shape[0]
        assert n_p % n_stages == 0, f"{n_p} periods not divisible by {n_stages} stages"
        return x.reshape((n_stages, n_p // n_stages) + x.shape[1:])

    return jax.tree.map(f, blocks)


def stack_stages_ragged(blocks, boundaries: Sequence[int]):
    """Uneven re-chunk: stage ``s`` owns periods ``[boundaries[s],
    boundaries[s+1])``; every stage's slab is zero-padded to the max
    periods-per-stage so the leaves stay rectangular —
    (n_stages, max_pp, ...). Padded slots must be masked to identity by
    the stage function (see the partition's ``masks()``)."""
    counts = [b - a for a, b in zip(boundaries, boundaries[1:])]
    assert counts and min(counts) >= 1, f"bad boundaries {boundaries}"
    max_pp = max(counts)

    def f(x):
        n_p = x.shape[0]
        assert n_p == boundaries[-1], (
            f"{n_p} periods but boundaries end at {boundaries[-1]}"
        )
        slabs = []
        for a, b in zip(boundaries, boundaries[1:]):
            s = x[a:b]
            if b - a < max_pp:
                pad = jnp.zeros((max_pp - (b - a),) + x.shape[1:], x.dtype)
                s = jnp.concatenate([s, pad], axis=0)
            slabs.append(s)
        return jnp.stack(slabs)

    return jax.tree.map(f, blocks)


def pipeline_apply(
    stage_fn: Callable,
    stage_params,
    x_micro: jax.Array,
    mesh: Mesh,
    axis: str = "stage",
    batch_axis: Optional[str] = None,
    collect_taps: bool = False,
    periods_per_stage: Optional[Sequence[int]] = None,
):
    """GPipe-style rotation: run ``stage_fn`` over pipelined micro-batches.

    stage_fn(params_slice, h) -> h' — one stage's compute (same shape
    in/out). With ``collect_taps=True`` it must instead return
    ``(h', taps)`` where ``taps`` is an array — or any pytree of arrays,
    e.g. the int8 ``{"q", "scale"}`` storage form a pallas OpSet emits —
    whose every leaf has shape (periods_per_stage, mb, ...): the stage's
    intermediate activations, e.g. the post-period hidden states PAC+'s
    adapter consumes.

    stage_params: leaves with leading dim n_stages (sharded over ``axis``).
    x_micro: (n_micro, mb, ...) micro-batched input. When ``batch_axis``
    names a second mesh axis, dim 1 (the micro-batch) is sharded over it
    — hybrid data×pipeline parallelism on a 2-D ``(dp, stage)`` mesh.

    Returns the (n_micro, mb, ...) outputs of the LAST stage, or with
    ``collect_taps`` a pair ``(outs, taps)`` where ``taps`` mirrors the
    stage-tap pytree with every leaf (n_micro, n_periods_total, mb, ...)
    assembled across stages in layer order (stage s owns periods
    [s·pp, (s+1)·pp)).

    ``periods_per_stage`` declares a *ragged* partition (a planner
    :class:`~repro.core.planner.StagePartition` executed for real): every
    stage's tap buffer is padded to max(periods_per_stage) — build the
    params with :func:`stack_stages_ragged` and mask padded periods to
    identity inside ``stage_fn`` — and the taps are assembled in true
    layer order from the uneven boundaries, dropping the padding slots.

    Differentiable: ``ppermute``'s transpose is the reverse permutation, so
    ``jax.grad`` through this function implements the backward pipeline.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    T = n_micro + n_stages - 1

    def spmd(params, xs):
        idx = jax.lax.axis_index(axis)
        state = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)
        local_params = jax.tree.map(lambda p: p[0], params)
        taps_buf = None

        def step(carry, t):
            state, outs, taps_buf = carry
            inject = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(idx == 0, xs[inject], state)
            if collect_taps:
                y, taps = stage_fn(local_params, x_in)
            else:
                y = stage_fn(local_params, x_in)
            # this stage processes micro-batch m = t - idx at time t
            m = t - idx
            if collect_taps:
                slot_m = jnp.clip(m, 0, n_micro - 1)
                valid = jnp.logical_and(m >= 0, m < n_micro)
                taps_buf = jax.tree.map(
                    lambda buf, tp: jnp.where(
                        valid, jax.lax.dynamic_update_index_in_dim(buf, tp, slot_m, 0), buf
                    ),
                    taps_buf, taps,
                )
            # collect finished micro-batches on the last stage
            out_t = t - (n_stages - 1)
            slot = jnp.clip(out_t, 0, n_micro - 1)
            write = jnp.logical_and(idx == n_stages - 1, out_t >= 0)
            updated = jax.lax.dynamic_update_index_in_dim(outs, y, slot, 0)
            outs = jnp.where(write, updated, outs)
            # rotate activations forward one stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            state = jax.lax.ppermute(y, axis, perm)
            return (state, outs, taps_buf), None

        if collect_taps:
            # probe the per-stage tap shape without committing compute
            # (a pytree of ShapeDtypeStructs — storage-form taps are dicts)
            tap_shape = jax.eval_shape(stage_fn, local_params, xs[0])[1]
            taps_buf = jax.tree.map(
                lambda t: jnp.zeros((n_micro,) + t.shape, t.dtype), tap_shape
            )
        (state, outs, taps_buf), _ = jax.lax.scan(
            step, (state, outs, taps_buf), jnp.arange(T)
        )
        # replicate the last stage's buffer everywhere (psum of masked copies —
        # a broadcast; ppermute cannot fan out one source to all)
        outs = jax.lax.psum(jnp.where(idx == n_stages - 1, outs, 0.0), axis)
        if collect_taps:
            # (1, n_micro, pp, mb, ...) sharded over `axis` on the new
            # leading dim → global (n_stages, n_micro, pp, mb, ...)
            return outs, jax.tree.map(lambda t: t[None], taps_buf)
        return outs

    b = batch_axis
    x_spec = P(None, b) if b else P()
    if collect_taps:
        # the tap *structure* (not shapes) decides the out_specs pytree:
        # every leaf — bare array or {"q","scale"} storage form — carries
        # (stage, micro, pp, mb, ...), so one spec shape fits all leaves
        tap_struct = jax.eval_shape(
            stage_fn, jax.tree.map(lambda p: p[0], stage_params), x_micro[0]
        )[1]
        leaf_spec = P(axis, None, None, b) if b else P(axis)
        out_specs = (x_spec, jax.tree.map(lambda _: leaf_spec, tap_struct))
    else:
        out_specs = x_spec
    fn = shard_map(
        spmd,
        mesh=mesh,
        in_specs=(P(axis), x_spec),
        out_specs=out_specs,
        check_rep=False,
    )
    if not collect_taps:
        return fn(stage_params, x_micro)
    outs, taps = fn(stage_params, x_micro)
    # (n_stages, n_micro, pp, mb, ...) → (n_micro, n_periods, mb, ...);
    # stage-major period order == layer order (stack_stages is contiguous)
    taps = jax.tree.map(lambda t: jnp.moveaxis(t, 0, 1), taps)
    if periods_per_stage is not None and len(set(periods_per_stage)) > 1:
        # ragged partition: keep each stage's first pp_s (active) periods,
        # concatenated in stage order == true layer order
        assert len(periods_per_stage) == n_stages, (periods_per_stage, n_stages)
        taps = jax.tree.map(
            lambda t: jnp.concatenate(
                [t[:, s, :pp] for s, pp in enumerate(periods_per_stage)], axis=1
            ),
            taps,
        )
    else:
        taps = jax.tree.map(
            lambda t: t.reshape((t.shape[0], t.shape[1] * t.shape[2]) + t.shape[3:]),
            taps,
        )
    return outs, taps


def pipeline_grads(
    loss_fn: Callable,
    trainable,
    frozen,
    batch_micro,
    mesh: Mesh,
    axis: str = "stage",
):
    """value_and_grad of a micro-batched pipelined loss.

    loss_fn(trainable, frozen, batch_micro, mesh) -> scalar mean loss.
    Provided for symmetry; gradient accumulation across micro-batches is
    what AllReduce-per-minibatch in the paper amounts to.
    """
    return jax.value_and_grad(lambda tp: loss_fn(tp, frozen, batch_micro, mesh))(trainable)
