"""Heterogeneity-aware hybrid-parallelism planner (paper §V-A, Alg. 1).

Faithful implementation of the paper's two nested dynamic programs:

* **Eq. (4)** ``H_{x→y}(b, G_n)`` — optimal dispatch of ``b`` samples of a
  micro-batch across a device group running stage layers ``x..y`` in data
  parallel, minimising the slowest device under per-device memory budgets
  (OOM ⇒ +inf).
* **Eq. (3)** ``W(0→y, D_n, s)`` — optimally balanced partition of layers
  ``0..y`` over the first ``n`` devices into ``s`` pipeline stages.
* **Eqs. (5)–(7)** — stage-count selection σ from the beginning /
  execution / ending phase latencies of the 1F1B schedule, including
  AllReduce of the *trainable* parameters only (tiny for PAC+, the whole
  model for the full-FT baselines — exactly the asymmetry the paper
  exploits).

The planner is offline and hardware-agnostic: it consumes per-layer
``LayerCost`` records (analytic FLOPs/bytes here; measured times on a
real testbed) and ``DeviceProfile``s. Used by the edge-regime pipeline
runtime (`repro.core.pipeline`), the paper-table benchmarks, and the
scalability/heterogeneity studies (Figs. 12, 16, 17).
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from functools import lru_cache
from typing import List, Optional, Sequence, Tuple

INF = float("inf")


# ---------------------------------------------------------------------------
# Inputs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DeviceProfile:
    """An edge device. Paper Table IV uses Jetson Nano/TX2 at two power modes."""

    name: str
    flops: float  # effective FLOP/s
    memory_bytes: float  # budget u_d
    bandwidth: float = 125e6  # bytes/s to its peers (1000 Mbps LAN default)

    def t(self, flops: float) -> float:
        return flops / self.flops


# paper Table IV (effective sustained FLOP/s, not peak)
JETSON_NANO_H = DeviceProfile("nano-h", 235e9, 4 * 2 ** 30)
JETSON_NANO_L = DeviceProfile("nano-l", 160e9, 4 * 2 ** 30)
JETSON_TX2_H = DeviceProfile("tx2-h", 665e9, 8 * 2 ** 30)
JETSON_TX2_L = DeviceProfile("tx2-l", 435e9, 8 * 2 ** 30)


@dataclass(frozen=True)
class LayerCost:
    """Per-layer workload, per sample (analytic or measured)."""

    fwd_flops: float
    bwd_flops: float
    param_bytes: float
    trainable_bytes: float  # params that need grads + AllReduce
    act_bytes: float  # output activation bytes per sample (inter-stage comm)
    resident_act_bytes: float  # activations that must stay live for bwd, per sample


def model_layer_costs(cfg, technique: str = "pac", dtype_bytes: int = 4, seq_len: int = 128, quant_bits: Optional[int] = None) -> List[LayerCost]:
    """Analytic per-layer costs for a backbone + fine-tuning technique.

    technique ∈ {"pac", "pac_cached", "lora", "adapters", "full"}.
    Mirrors the paper's Fig. 3 / Table I accounting: LoRA/Adapters still
    pay a full backward through the backbone (~2× fwd FLOPs); PAC+ pays
    backward only on the (1/r²-sized) side network; the cached variant
    drops the backbone forward too.
    """
    from repro.core.parallel_adapters import adapter_config

    d, s = cfg.d_model, seq_len
    specs = cfg.layer_specs()
    acfg = adapter_config(cfg)
    w_bytes = dtype_bytes if quant_bits is None else quant_bits / 8.0
    costs: List[LayerCost] = []
    for spec in specs:
        # params
        p_attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd + cfg.n_heads * cfg.hd * d
        if spec.kind != "attn":
            p_attn = 4 * d * cfg.n_heads * cfg.hd + 2 * d * cfg.n_heads  # ssm-ish
        if spec.moe and cfg.moe is not None:
            p_ffn = cfg.moe.n_experts * 3 * d * cfg.moe.d_expert
            p_ffn_active = cfg.moe.top_k * 3 * d * cfg.moe.d_expert
        elif spec.ffn and cfg.d_ff:
            p_ffn = p_ffn_active = 3 * d * cfg.d_ff
        else:
            p_ffn = p_ffn_active = 0
        p_total = p_attn + p_ffn
        p_active = p_attn + p_ffn_active
        # FLOPs (per sample of seq_len s): 2·params_active·s + attention quadratic
        f_fwd = 2.0 * p_active * s
        if spec.kind == "attn":
            win = min(spec.window or s, s)
            f_fwd += 4.0 * s * win * cfg.n_heads * cfg.hd
        f_bwd = 2.0 * f_fwd
        # adapter-side costs for PAC+
        a_p = (
            d * acfg.d_model  # W_down
            + acfg.d_model * (acfg.n_heads + 2 * acfg.n_kv_heads) * acfg.hd
            + acfg.n_heads * acfg.hd * acfg.d_model
            + (3 * acfg.d_model * acfg.d_ff if acfg.d_ff else 0)
        )
        a_fwd = 2.0 * a_p * s
        a_bwd = 2.0 * a_fwd
        act = s * d * dtype_bytes
        if technique == "full":
            # resident-for-backward per block ≈ q,k,v,o (4) + attn probs/
            # softmax (~4 at s≈128) + ffn input/mid/gate (~5 in d units) +
            # norms (calibrated to Table I: T5-Large b16 s128 -> 5.3 GB)
            costs.append(
                LayerCost(f_fwd, f_bwd, p_total * dtype_bytes, p_total * dtype_bytes, act,
                          14 * act if spec.kind == "attn" else 9 * act)
            )
        elif technique in ("lora", "adapters"):
            # frozen weights skip the weight-grad matmuls but still need the
            # activation-grad pass — the paper's "only ~49% backward
            # reduction" (Fig. 13a): bwd ≈ 1× fwd instead of 2× fwd.
            # Resident acts ≈ 0.8× of full (paper: 4.0-4.3 vs 5.3 GB) —
            # weight-grad inputs can be dropped, everything else stays.
            extra = 2 * d * 8 * s * 2  # bottleneck/low-rank FLOPs (rank≈8)
            costs.append(
                LayerCost(f_fwd + extra, f_fwd + 3 * extra, p_total * w_bytes,
                          (2 * d * 8) * dtype_bytes, act,
                          12 * act if spec.kind == "attn" else 8 * act)
            )
        elif technique == "pac":
            costs.append(
                LayerCost(f_fwd + a_fwd, a_bwd, p_total * w_bytes + a_p * dtype_bytes,
                          a_p * dtype_bytes, act, 2 * act // max(1, cfg.d_model // acfg.d_model))
            )
        elif technique == "pac_cached":
            costs.append(
                LayerCost(a_fwd, a_bwd, a_p * dtype_bytes, a_p * dtype_bytes,
                          s * acfg.d_model * dtype_bytes,
                          2 * s * acfg.d_model * dtype_bytes)
            )
        else:
            raise ValueError(technique)
    return costs


def aggregate_periods(costs: Sequence[LayerCost], layers_per_period: int) -> List[LayerCost]:
    """Collapse per-layer costs to per-*period* costs (the runtime's unit).

    The backbone stacks parameters over periods and scans whole periods, so
    an executable plan must cut on period boundaries. FLOPs and memory sum
    over the period's layers; inter-stage activation bytes are the *last*
    layer's output (the only tensor that crosses a period boundary).
    """
    if layers_per_period < 1 or len(costs) % layers_per_period:
        raise ValueError(
            f"{len(costs)} layer costs not divisible into periods of {layers_per_period}"
        )
    out: List[LayerCost] = []
    for i in range(0, len(costs), layers_per_period):
        chunk = costs[i : i + layers_per_period]
        out.append(
            LayerCost(
                fwd_flops=sum(c.fwd_flops for c in chunk),
                bwd_flops=sum(c.bwd_flops for c in chunk),
                param_bytes=sum(c.param_bytes for c in chunk),
                trainable_bytes=sum(c.trainable_bytes for c in chunk),
                act_bytes=chunk[-1].act_bytes,
                resident_act_bytes=sum(c.resident_act_bytes for c in chunk),
            )
        )
    return out


def period_costs(cfg, technique: str = "pac", dtype_bytes: int = 4, seq_len: int = 128, quant_bits: Optional[int] = None) -> List[LayerCost]:
    """Per-period costs for ``cfg`` — what a runtime-executable plan consumes
    (one planner "layer" == one backbone period)."""
    return aggregate_periods(
        model_layer_costs(cfg, technique, dtype_bytes, seq_len, quant_bits), cfg.period
    )


# ---------------------------------------------------------------------------
# Plan data model
# ---------------------------------------------------------------------------


@dataclass
class Stage:
    layer_start: int  # inclusive
    layer_end: int  # inclusive
    devices: Tuple[DeviceProfile, ...]
    samples_per_device: Tuple[int, ...]  # micro-batch split
    stage_time: float  # max over devices of fwd+bwd for its share
    # recorded from LayerCost by _phase_latencies (fwd_time + bwd_time ==
    # stage_time); 0.0 on hand-built stages — simulate_plan falls back to
    # its historical 1:2 approximation then
    fwd_time: float = 0.0
    bwd_time: float = 0.0


@dataclass(frozen=True)
class StagePartition:
    """Runtime-facing view of a :class:`Plan`: the executable contract.

    ``boundaries`` are cumulative *period* indices — stage ``s`` owns
    periods ``[boundaries[s], boundaries[s+1])``. ``masks`` pads every
    stage to ``max_periods`` (the padded slots run as identity periods in
    the SPMD pipeline); ``samples_per_device`` is the planner's Eq. (4)
    dispatch per stage, carried so the runtime/report layer can consume
    and validate it against the executed micro-batch size.
    """

    boundaries: Tuple[int, ...]  # len n_stages + 1, boundaries[0] == 0
    samples_per_device: Tuple[Tuple[int, ...], ...]
    n_micro: int

    def __post_init__(self):
        b = self.boundaries
        if len(b) < 2 or b[0] != 0 or any(y <= x for x, y in zip(b, b[1:])):
            raise ValueError(f"bad stage boundaries {b}")
        if len(self.samples_per_device) != self.n_stages:
            raise ValueError(
                f"{len(self.samples_per_device)} sample splits for {self.n_stages} stages"
            )

    @property
    def n_stages(self) -> int:
        return len(self.boundaries) - 1

    @property
    def n_periods(self) -> int:
        return self.boundaries[-1]

    @property
    def periods_per_stage(self) -> Tuple[int, ...]:
        return tuple(y - x for x, y in zip(self.boundaries, self.boundaries[1:]))

    @property
    def max_periods(self) -> int:
        return max(self.periods_per_stage)

    @property
    def is_uniform(self) -> bool:
        pps = self.periods_per_stage
        return all(p == pps[0] for p in pps)

    def masks(self) -> Tuple[Tuple[bool, ...], ...]:
        """(n_stages, max_periods) active-period masks (False == padding)."""
        m = self.max_periods
        return tuple(
            tuple(i < pp for i in range(m)) for pp in self.periods_per_stage
        )


@dataclass
class Plan:
    stages: List[Stage]
    n_stages: int
    micro_batches: int
    latency_begin: float
    latency_exec: float
    latency_end: float

    @property
    def minibatch_latency(self) -> float:
        return self.latency_begin + self.latency_exec + self.latency_end

    def describe(self) -> str:
        out = [f"{self.n_stages} stages, minibatch latency {self.minibatch_latency:.3f}s"]
        for i, st in enumerate(self.stages):
            devs = ",".join(d.name for d in st.devices)
            out.append(
                f"  stage {i}: layers [{st.layer_start}..{st.layer_end}] on {{{devs}}} "
                f"split={st.samples_per_device} time={st.stage_time * 1e3:.1f}ms"
            )
        return "\n".join(out)

    # -- executable artifact -------------------------------------------------
    def stage_partition(self, layers_per_period: int = 1) -> StagePartition:
        """Derive the runtime contract. The plan's layer indices convert to
        period indices; every stage boundary must fall on a period boundary
        (guaranteed when the planner was fed :func:`period_costs`)."""
        bounds = [0]
        for i, st in enumerate(self.stages):
            if st.layer_start != (self.stages[i - 1].layer_end + 1 if i else 0):
                raise ValueError("plan stages are not contiguous")
            end = st.layer_end + 1
            if end % layers_per_period:
                raise ValueError(
                    f"stage {i} ends at layer {st.layer_end}, not a period "
                    f"boundary (period = {layers_per_period} layers); plan at "
                    f"period granularity (planner.period_costs) to execute"
                )
            bounds.append(end // layers_per_period)
        return StagePartition(
            boundaries=tuple(bounds),
            samples_per_device=tuple(tuple(st.samples_per_device) for st in self.stages),
            n_micro=self.micro_batches,
        )

    # -- JSON round-trip (save once, replay on the pool) ---------------------
    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(
            {
                "version": 1,
                "n_stages": self.n_stages,
                "micro_batches": self.micro_batches,
                "latency_begin": self.latency_begin,
                "latency_exec": self.latency_exec,
                "latency_end": self.latency_end,
                "stages": [
                    {
                        "layer_start": st.layer_start,
                        "layer_end": st.layer_end,
                        "devices": [
                            {
                                "name": d.name,
                                "flops": d.flops,
                                "memory_bytes": d.memory_bytes,
                                "bandwidth": d.bandwidth,
                            }
                            for d in st.devices
                        ],
                        "samples_per_device": list(st.samples_per_device),
                        "stage_time": st.stage_time,
                        "fwd_time": st.fwd_time,
                        "bwd_time": st.bwd_time,
                    }
                    for st in self.stages
                ],
            },
            indent=indent,
        )

    @classmethod
    def from_json(cls, text: str) -> "Plan":
        d = json.loads(text)
        if d.get("version") != 1:
            raise ValueError(f"unsupported plan version {d.get('version')!r}")
        stages = [
            Stage(
                layer_start=s["layer_start"],
                layer_end=s["layer_end"],
                devices=tuple(DeviceProfile(**dev) for dev in s["devices"]),
                samples_per_device=tuple(s["samples_per_device"]),
                stage_time=s["stage_time"],
                fwd_time=s.get("fwd_time", 0.0),
                bwd_time=s.get("bwd_time", 0.0),
            )
            for s in d["stages"]
        ]
        return cls(
            stages=stages,
            n_stages=d["n_stages"],
            micro_batches=d["micro_batches"],
            latency_begin=d["latency_begin"],
            latency_exec=d["latency_exec"],
            latency_end=d["latency_end"],
        )

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    @classmethod
    def load(cls, path: str) -> "Plan":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# The planner
# ---------------------------------------------------------------------------


class HybridParallelismPlanner:
    """Paper Alg. 1. ``plan()`` returns the σ-optimal configuration."""

    def __init__(
        self,
        layer_costs: Sequence[LayerCost],
        devices: Sequence[DeviceProfile],
        micro_batch_size: int = 4,
        n_micro_batches: int = 4,
        heterogeneity_aware: bool = True,
    ):
        self.costs = list(layer_costs)
        self.devices = list(devices)
        self.B = micro_batch_size
        self.M = n_micro_batches
        self.L = len(self.costs)
        self.het = heterogeneity_aware
        self._h_cache: dict = {}
        self._w_cache: dict = {}
        # the device subset the current plan() call may use, as absolute
        # indices into self.devices — plan(available=...) re-plans after a
        # pool-membership change without rebuilding the planner, and the
        # Eq. (4) memo (keyed on absolute-index groups) carries over
        self._avail: Tuple[int, ...] = tuple(range(len(self.devices)))

    # -- Eq. (4): sample dispatch inside one stage --------------------------
    def _device_time(self, d: DeviceProfile, x: int, y: int, b: int) -> float:
        """fwd+bwd time + OOM check for b samples of layers x..y on d."""
        if b == 0:
            return 0.0
        fl = sum(c.fwd_flops + c.bwd_flops for c in self.costs[x : y + 1]) * b
        mem = sum(c.param_bytes + 2 * c.trainable_bytes for c in self.costs[x : y + 1])
        mem += sum(c.resident_act_bytes for c in self.costs[x : y + 1]) * b * self.M
        if mem > d.memory_bytes:
            return INF
        return d.t(fl)

    def stage_dispatch(self, x: int, y: int, group: Tuple[int, ...], b: int):
        """Returns (H_{x→y}(b, G), split) via the Eq. (4) DP."""
        if not self.het:
            # heterogeneity-oblivious (PAC, the older conference version):
            # equal split regardless of device speed
            per = [b // len(group)] * len(group)
            for i in range(b % len(group)):
                per[i] += 1
            t = max(self._device_time(self.devices[g], x, y, p) for g, p in zip(group, per))
            return t, tuple(per)
        key = (x, y, group, b)
        if key in self._h_cache:
            return self._h_cache[key]
        if len(group) == 1:
            t = self._device_time(self.devices[group[0]], x, y, b)
            self._h_cache[key] = (t, (b,))
            return self._h_cache[key]
        best, best_split = INF, None
        rest = group[:-1]
        last = self.devices[group[-1]]
        for i in range(b + 1):
            t_last = self._device_time(last, x, y, i)
            if t_last == INF:
                continue  # larger i only worse
            t_rest, split_rest = self.stage_dispatch(x, y, rest, b - i)
            t = max(t_rest, t_last)
            if t < best:
                best, best_split = t, split_rest + (i,)
        self._h_cache[key] = (best, best_split if best_split else tuple([0] * len(group)))
        return self._h_cache[key]

    # -- Eq. (3): balanced pipeline partition --------------------------------
    def _w(self, y: int, n: int, s: int):
        """W(0→y, first n of the available devices, s stages):
        (slowest-stage time, config list). Groups are tuples of absolute
        device indices, so the Eq. (4) memo survives ``available=``
        subset changes."""
        key = (y, n, s, self._avail)
        if key in self._w_cache:
            return self._w_cache[key]
        if s == 1:
            group = self._avail[:n]
            t, split = self.stage_dispatch(0, y, group, self.B)
            cfgs = [(0, y, group, split)]
            self._w_cache[key] = (t, cfgs)
            return self._w_cache[key]
        best, best_cfg = INF, None
        for q in range(s - 2, y):  # at least s-1 layers before the last stage
            for m in range(1, n - (s - 1) + 1):
                group = self._avail[n - m : n]
                t_stage, split = self.stage_dispatch(q + 1, y, group, self.B)
                if t_stage >= best:
                    continue
                t_prev, cfg_prev = self._w(q, n - m, s - 1)
                t = max(t_prev, t_stage)
                if t < best:
                    best = t
                    best_cfg = cfg_prev + [(q + 1, y, group, split)]
        self._w_cache[key] = (best, best_cfg)
        return self._w_cache[key]

    # -- Eqs. (5)-(7): stage-count selection ---------------------------------
    def _phase_latencies(self, cfgs) -> Tuple[float, float, float, List[Stage]]:
        s = len(cfgs)
        stages: List[Stage] = []
        e = []  # (e_f, e_b) per stage
        c_f, c_b, ar = [], [], []
        for x, y, group, split in cfgs:
            devs = tuple(self.devices[g] for g in group)
            tf = max(
                (d.t(sum(c.fwd_flops for c in self.costs[x : y + 1]) * b) if b else 0.0)
                for d, b in zip(devs, split)
            )
            tb = max(
                (d.t(sum(c.bwd_flops for c in self.costs[x : y + 1]) * b) if b else 0.0)
                for d, b in zip(devs, split)
            )
            e.append((tf, tb))
            bw = min(d.bandwidth for d in devs)
            act = self.costs[y].act_bytes * self.B
            c_f.append(act / bw)
            c_b.append(act / bw)
            train_bytes = sum(c.trainable_bytes for c in self.costs[x : y + 1])
            # ring AllReduce within the group
            k = len(devs)
            ar.append(2.0 * train_bytes * (k - 1) / (k * bw) if k > 1 else 0.0)
            stages.append(Stage(x, y, devs, split, tf + tb, fwd_time=tf, bwd_time=tb))
        # Eq. (5)
        L_b = sum(e[i][0] + c_f[i] for i in range(s - 1))
        L_e = self.M * (e[-1][0] + e[-1][1])
        # Eq. (6)
        L_n = max(
            ar[i] + sum(e[j][1] + c_b[j] for j in range(i, s - 1))
            for i in range(s)
        )
        return L_b, L_e, L_n, stages

    def plan(self, max_stages: Optional[int] = None,
             available: Optional[Sequence[int]] = None) -> Plan:
        """σ-optimal plan over the pool — or, with ``available=`` (absolute
        device indices), over a surviving subset: the fleet scheduler's
        incremental re-plan after a device is lost or joins. Eq. (4)
        dispatch results are memoized on absolute-index groups, so
        re-planning a subset reuses every group the two pools share."""
        if available is None:
            self._avail = tuple(range(len(self.devices)))
        else:
            avail = tuple(int(i) for i in available)
            if len(set(avail)) != len(avail):
                raise ValueError(f"available has duplicates: {avail}")
            bad = [i for i in avail if i < 0 or i >= len(self.devices)]
            if bad or not avail:
                raise ValueError(
                    f"available must be non-empty indices into the "
                    f"{len(self.devices)}-device pool, got {avail}")
            self._avail = avail
        n = len(self._avail)
        best: Optional[Plan] = None
        smax = min(self.L, n, max_stages or n)
        for s in range(1, smax + 1):
            t, cfgs = self._w(self.L - 1, n, s)
            if cfgs is None or t == INF:
                continue
            L_b, L_e, L_n, stages = self._phase_latencies(cfgs)
            plan = Plan(stages, s, self.M, L_b, L_e, L_n)
            if best is None or plan.minibatch_latency < best.minibatch_latency:
                best = plan
        if best is None:
            raise RuntimeError(
                "no feasible plan: aggregate device memory cannot hold the model"
            )
        return best


# ---------------------------------------------------------------------------
# Baseline planners for the paper's comparisons
# ---------------------------------------------------------------------------


def plan_pure_dp(layer_costs, devices, micro_batch_size, n_micro_batches) -> Optional[Plan]:
    """EDDL-style pure data parallelism (every device hosts the full model)."""
    p = HybridParallelismPlanner(layer_costs, devices, micro_batch_size, n_micro_batches)
    group = tuple(range(len(devices)))
    t, split = p.stage_dispatch(0, p.L - 1, group, micro_batch_size)
    if t == INF:
        return None
    L_b, L_e, L_n, stages = p._phase_latencies([(0, p.L - 1, group, split)])
    return Plan(stages, 1, n_micro_batches, L_b, L_e, L_n)


def plan_pure_pp(layer_costs, devices, micro_batch_size, n_micro_batches) -> Optional[Plan]:
    """Eco-FL-style straight pipeline: one stage per device."""
    p = HybridParallelismPlanner(layer_costs, devices, micro_batch_size, n_micro_batches)
    n = len(devices)
    t, cfgs = p._w(p.L - 1, n, n)
    if cfgs is None or t == INF:
        return None
    L_b, L_e, L_n, stages = p._phase_latencies(cfgs)
    return Plan(stages, n, n_micro_batches, L_b, L_e, L_n)


def brute_force_plan(layer_costs, devices, micro_batch_size, n_micro_batches, max_stages=None):
    """Exponential-search reference for planner-optimality tests (small inputs)."""
    import itertools

    p = HybridParallelismPlanner(layer_costs, devices, micro_batch_size, n_micro_batches)
    L, n = p.L, len(devices)
    best = None
    smax = min(L, n, max_stages or n)
    for s in range(1, smax + 1):
        # all layer cut points and all contiguous device groupings
        for cuts in itertools.combinations(range(L - 1), s - 1):
            bounds = [(a + 1, b) for a, b in zip((-1,) + cuts, cuts + (L - 1,))]
            for dev_cuts in itertools.combinations(range(1, n), s - 1):
                dbounds = [(a, b) for a, b in zip((0,) + dev_cuts, dev_cuts + (n,))]
                cfgs = []
                ok = True
                for (x, y), (da, db) in zip(bounds, dbounds):
                    group = tuple(range(da, db))
                    t, split = p.stage_dispatch(x, y, group, micro_batch_size)
                    if t == INF:
                        ok = False
                        break
                    cfgs.append((x, y, group, split))
                if not ok:
                    continue
                L_b, L_e, L_n, stages = p._phase_latencies(cfgs)
                plan = Plan(stages, s, n_micro_batches, L_b, L_e, L_n)
                if best is None or plan.minibatch_latency < best.minibatch_latency:
                    best = plan
    return best
