"""Parameter sharding rules (logical axes) + in-graph sharding constraints.

Kept dependency-free (core) so both the launch layer (building
in/out_shardings) and the model code (in-scan-body constraints) share one
rule table.

``gather_for_compute`` is §Perf iteration 2: with FSDP weights (d_model
sharded over ``data``), GSPMD may lower ``x @ W`` as a partial dot +
all-reduce of the *activations* over the data axis — for train_4k that
moved 115 GB/device/step (measured, EXPERIMENTS.md). Constraining the
per-layer weight slice to be replicated over ``data`` (sharded only over
``model``) inside the scan body forces the classic FSDP all-gather of
the *weights* instead (~0.3 GB/layer), a ~16× collective reduction.
Decode keeps weights sharded (weights-stationary: at batch·1 tokens the
activation all-reduce is the cheap side).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro import compat

FSDP = "fsdp"
TP = "tp"
# Fallback tensor-parallel axis: gets `model` only if every TP dim in the
# same leaf failed its divisibility guard (grok: 8 experts on a 16-way
# model axis -> shard d_ff inside the experts instead of replicating
# the whole expert compute 16x).
TP_ALT = "tp_alt"


def path_names(path) -> list:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        elif isinstance(k, SequenceKey):
            out.append(f"[{k.idx}]")
        else:
            out.append(str(k))
    return out


def logical_for_param(names: list, ndim: int) -> Tuple[Optional[str], ...]:
    """Logical axes per dim for a parameter leaf at `names` path."""
    name = names[-1]
    parent = next((n for n in reversed(names[:-1]) if not n.startswith("[")), "")
    none = (None,) * ndim

    if name == "embed":
        return (TP, FSDP)
    if name == "lm_head":
        return (FSDP, TP)
    if name == "up" and ndim == 2:  # adapter up-projection (d_a, d)
        return (TP, FSDP)
    if name == "downs":  # (n_p+1, d, d_a)
        return (None, FSDP, TP)
    if name == "router":
        return (None, FSDP, None)[:ndim] if ndim >= 2 else none
    if parent == "ffn":
        if ndim == 4:  # MoE experts (n_p, E, d, f) / (n_p, E, f, d)
            if name in ("wi", "wg"):
                return (None, TP, FSDP, TP_ALT)
            if name == "wo":
                return (None, TP, TP_ALT, FSDP)
        if ndim == 3:
            if name in ("wi", "wg"):
                return (None, FSDP, TP)
            if name == "wo":
                return (None, TP, FSDP)
        return none
    if parent in ("mixer", ""):
        table = {
            "wq": (None, FSDP, TP),
            "wk": (None, FSDP, TP),
            "wv": (None, FSDP, TP),
            "ogate": (None, FSDP, TP),
            "wz": (None, FSDP, TP),
            "wog": (None, FSDP, TP),
            "wi": (None, FSDP, TP),
            "wf": (None, FSDP, TP),
            "wo": (None, TP, FSDP),
            "in_proj": (None, FSDP, TP),
            "out_proj": (None, TP, FSDP),
            "conv_w": (None, None, TP),
            "conv_b": (None, TP),
            "w_bc": (None, TP, None),
            "w_dt1": (None, TP, None),
            "w_dt2": (None, None, TP),
            "dt_bias": (None, TP),
            "d_skip": (None, TP),
            "a_log": (None, TP, None),
        }
        spec = table.get(name)
        if spec is not None and len(spec) == ndim:
            return spec
    if name in ("a_q", "a_v"):
        return (None, FSDP, None)
    if name in ("b_q", "b_v"):
        return (None, None, TP)
    if name == "down" and ndim == 3:
        return (None, FSDP, TP)
    if name == "up" and ndim == 3:
        return (None, TP, FSDP)
    return none


def resolve(logical, shape, mesh) -> P:
    """Logical → mesh axes with divisibility guards."""
    from repro.launch.mesh import data_axes

    dp = data_axes(mesh)
    # first pass: did any TP dim take the model axis?
    tp_taken = any(
        ax == TP and "model" in mesh.axis_names and dim % mesh.shape["model"] == 0
        for dim, ax in zip(shape, logical)
    )
    out = []
    for dim, ax in zip(shape, logical):
        if ax is None:
            out.append(None)
        elif ax == FSDP:
            total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
            if dp and dim % total == 0:
                out.append(dp if len(dp) > 1 else dp[0])
            elif "data" in mesh.axis_names and dim % mesh.shape["data"] == 0:
                out.append("data")
            else:
                out.append(None)
        elif ax == TP or (ax == TP_ALT and not tp_taken):
            if "model" in mesh.axis_names and dim % mesh.shape["model"] == 0:
                out.append("model")
            else:
                out.append(None)
        else:
            out.append(None)
    return P(*out)


def ambient_mesh():
    """The mesh from the enclosing ``with mesh:`` / set_mesh context."""
    return compat.ambient_mesh()


def _mesh_has_model_axis() -> bool:
    mesh = ambient_mesh()
    return mesh is not None and "model" in mesh.axis_names


def constrain_hidden(x, mesh=None):
    """Pin a (B, S, d) residual-stream tensor between blocks.

    §Perf iterations 3+4: unconstrained, GSPMD re-shards the hidden state
    ~5×/layer (measured 292 GB-weighted collectives on
    internlm2×train_4k). Iteration 3 pinned x replicated-over-model
    (Megatron TP: one all-reduce per matmul chain) — collectives dropped
    3.4× but the stacked taps then lived model-replicated inside the scan
    (64 GB temp). Iteration 4 shards the *sequence* dim over `model`
    between blocks (Megatron sequence parallelism): same collective
    volume (all-gather S before the mixer, reduce-scatter after), but the
    resident stream and taps are 16× smaller. No-op outside a
    `model`-axis mesh or when dims don't divide.
    """
    if mesh is None:
        mesh = ambient_mesh()
        if mesh is None or "model" not in mesh.axis_names:
            return x
    dp = [a for a in ("pod", "data") if a in mesh.axis_names]
    total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if not dp or x.shape[0] % total != 0:
        return x
    b_spec = tuple(dp) if len(dp) > 1 else dp[0]
    seq_spec = None
    if x.ndim >= 3 and x.shape[1] % mesh.shape["model"] == 0:
        seq_spec = "model"
    spec = P(b_spec, seq_spec, *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, spec)


def n_data_shards(mesh=None) -> int:
    """Total size of the batch-ish mesh axes (pod×data); 1 without a mesh."""
    if mesh is None:
        mesh = ambient_mesh()
    if mesh is None:
        return 1
    dp = [a for a in ("pod", "data") if a in mesh.axis_names]
    return int(np.prod([mesh.shape[a] for a in dp])) if dp else 1


def constrain_spec(x, axes, mesh=None):
    """with_sharding_constraint with logical axes + divisibility guards.

    ``axes``: per-dim entries of None | "batch" (pod+data) | "model".
    No-op outside a mesh with a `model` axis (CPU tests), and any dim that
    does not divide its axis size falls back to None.
    """
    if mesh is None:
        mesh = ambient_mesh()
        if mesh is None or "model" not in mesh.axis_names:
            return x
    dp = [a for a in ("pod", "data") if a in mesh.axis_names]
    out = []
    for dim, ax in zip(x.shape, axes):
        if ax == "batch":
            total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
            if dp and dim % total == 0:
                out.append(tuple(dp) if len(dp) > 1 else dp[0])
            else:
                out.append(None)
        elif ax == "model" and dim % mesh.shape["model"] == 0:
            out.append("model")
        else:
            out.append(None)
    if all(a is None for a in out):
        # an all-None constraint is *explicit replication* — it forces an
        # immediate all-reduce of any partial-sum producer (measured on
        # grok, where E=8 fails the model-axis guard: the (G,E,C,d)
        # combine input got AR'd pre-scatter at 4× the post-scatter size)
        return x
    return jax.lax.with_sharding_constraint(x, P(*out))


def gather_for_compute(block_params, mesh=None):
    """Constrain a (single-layer) param slice to TP-only sharding.

    Called inside the backbone scan body after dequantisation: the FSDP
    dim becomes replicated → GSPMD must all-gather the weight slice once
    per layer (classic FSDP), instead of all-reducing activations.
    No-op outside a mesh with a `model` axis (CPU tests).
    """
    if mesh is None:
        mesh = ambient_mesh()
        if mesh is None or "model" not in mesh.axis_names:
            return block_params

    def constrain(path, leaf):
        if not hasattr(leaf, "ndim"):
            return leaf
        names = path_names(path)
        # QTensor children (q/scale) add a flatten-index tail to the path;
        # strip it so the rule lookup sees the parameter name. Gathering
        # the *quantized* payload (int8) instead of the dequantized f32
        # quarters the FSDP all-gather traffic (§Perf kimi iter H).
        while names and (names[-1].startswith("[") or not names[-1].isidentifier()):
            names = names[:-1]
        if not names:
            return leaf
        # Inside the scan body every leaf lost its leading (n_period) dim;
        # the rule table is keyed to *stacked* shapes. Look up the stacked
        # logical and drop the scan dim (§Perf-hillclimb kimi iter A: the
        # ndim-of-slice lookup mis-bucketed MoE (E,d,f) slices into the
        # dense-stacked rule, replicating experts over `model`).
        logical = logical_for_param(names, leaf.ndim + 1)[1:]
        if len(logical) != leaf.ndim:
            logical = logical_for_param(names, leaf.ndim)
        logical = tuple(ax if ax in (TP, TP_ALT) else None for ax in logical)
        spec = resolve(logical, leaf.shape, mesh)
        return jax.lax.with_sharding_constraint(leaf, spec)

    return compat.tree_map_with_path(constrain, block_params)
