"""OpSet — the dispatch seam between the model math and its kernels.

The frozen-backbone forward (epoch 1, prefill, decode) is built from a
handful of primitive ops: the dense matmuls of the QKV/MLP projections,
the attention core, the embedding gather, the norms/rope, and — for
PAC+ — the *tap emission* that hands each period's hidden state to the
activation cache. An :class:`OpSet` bundles one implementation of each
primitive behind a tiny object, and the model layer
(:mod:`repro.models.backbone` / :mod:`repro.models.layers`) calls only
the OpSet — it never imports :mod:`repro.kernels` (CI greps for this),
so every kernel variant plugs in here and nowhere else.

Two implementations ship:

* ``ref`` — the dense jnp oracle. ``prepare_block`` dequantizes the
  whole block up front (the historical dequantize-then-dense idiom) and
  every op is plain jnp, so the forward is **bit-identical** to the
  pre-OpSet model code and stays differentiable (the PAC+ adapter runs
  its own blocks through the same ``apply_block`` with this OpSet).
* ``pallas`` — the storage-width fast path (paper §IV-D on TPU).
  INT8/INT4 block weights stay *quantized*: the projections run the
  fused in-VMEM-dequant :func:`repro.kernels.quant_matmul.quant_matmul`
  (HBM weight traffic at integer width), attention runs the Pallas
  flash kernel, the embedding gathers int8 rows and dequantizes only
  the gathered (B,S) slice, and ``emit_tap`` quantizes each tap at the
  tap site into the activation cache's storage form (``tap_policy`` =
  the cache's compress policy) — no f32 HBM round-trip between the
  backbone forward and the cache. Forward-only: the PAC+ steps
  ``stop_gradient`` the frozen path, so no VJP is needed (the trainable
  adapter side keeps the ``ref`` ops).

Off-TPU the pallas OpSet runs the kernels in interpreter mode
(``interpret=None`` auto-selects, exactly like
:mod:`repro.kernels.cached_step`) — bit-accurate, slow; the CI path.

The registry is the extension point ROADMAP items 1/2/4 plug into:
``register_opset("paged", ...)`` etc. without touching the model code.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax.numpy as jnp

from repro.core.quantization import QTensor, dequantize, maybe_dequantize_tree, quantize

# quantization block of emitted int8 taps — must match the activation
# cache's block (activation_cache._INT8_BLOCK) so tap-site quantization
# is bit-identical to cache-side compression
TAP_BLOCK = 128

TAP_POLICIES = ("f32", "bf16", "int8")


def _pad_axis(x, axis: int, pad: int):
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


class OpSet:
    """One implementation of the backbone's primitive ops.

    Subclasses override the compute-bearing ops (``matmul``,
    ``attention``, ``embed_lookup``, ``prepare_block``, ``emit_tap``);
    the norm/rope passthroughs below are shared — no variant has a
    reason to change their numerics, but they route through the OpSet so
    a future variant (e.g. a fused-norm kernel) can.
    """

    name: str = "abstract"
    tap_policy: str = "f32"

    # -- block parameter preparation ------------------------------------

    def prepare_block(self, p, spec):
        """Make one block's (gathered) params consumable by this OpSet's
        ops. Called once per block inside ``apply_block``."""
        raise NotImplementedError

    # -- compute ops ----------------------------------------------------

    def matmul(self, x, w):
        """``x @ w`` where ``w`` is a plain array or a :class:`QTensor`."""
        raise NotImplementedError

    def attention(self, q, k, v, cfg, spec, block_k: int = 1024):
        """Causal (train/prefill) attention core. q: (B,S,H,hd);
        k, v: (B,S,Hkv,hd), rope applied. Returns (B,S,H·hd)."""
        raise NotImplementedError

    def paged_attention(self, q, k_pages, v_pages, k_scale, v_scale,
                        block_tables, lengths, cfg, spec):
        """Paged-KV decode attention (the serving engine's core).
        q: (B, Hkv, n_rep, hd) grouped post-rope new-token query;
        pages: (n_pages, page, Hkv, hd) int8/f32/bf16 pool (+ scales
        for int8, else None); block_tables: (B, max_pages) int32;
        lengths: (B,) int32. Returns (B, Hkv, n_rep, hd) f32."""
        raise NotImplementedError

    def embed_lookup(self, embed, tokens):
        """Token embedding gather; ``embed`` may be a QTensor."""
        raise NotImplementedError

    def emit_tap(self, h):
        """A PAC+ tap leaving the backbone forward, in the form the
        activation cache stores (identity for the f32 policy)."""
        raise NotImplementedError

    # -- shared passthroughs (norms / rope) -----------------------------

    def rms_norm(self, x, weight, eps: float = 1e-6):
        from repro.models.layers import rms_norm

        return rms_norm(x, weight, eps)

    def apply_rope(self, x, positions, theta: float = 10_000.0):
        from repro.models.layers import apply_rope

        return apply_rope(x, positions, theta)

    def apply_mrope(self, x, positions, theta: float = 1_000_000.0):
        from repro.models.layers import apply_mrope

        return apply_mrope(x, positions, theta)


class RefOpSet(OpSet):
    """The dense jnp oracle — bit-identical to the pre-OpSet model code."""

    name = "ref"

    def __init__(self, tap_policy: str = "f32", interpret=None):
        # taps leave the ref forward in f32 regardless of the cache
        # policy: compression stays the cache's job on this path
        self.tap_policy = "f32"
        self.interpret = None

    def prepare_block(self, p, spec):
        return maybe_dequantize_tree(p)

    def matmul(self, x, w):
        if isinstance(w, QTensor):
            w = dequantize(w)
        return x @ w

    def attention(self, q, k, v, cfg, spec, block_k: int = 1024):
        from repro.models.layers import ref_attention_core

        return ref_attention_core(q, k, v, cfg, spec, block_k)

    def paged_attention(self, q, k_pages, v_pages, k_scale, v_scale,
                        block_tables, lengths, cfg, spec):
        from repro.kernels.ref import paged_attention_ref

        return paged_attention_ref(
            q, k_pages, v_pages, block_tables, lengths,
            k_scale=k_scale, v_scale=v_scale, window=spec.window,
            attn_softcap=cfg.attn_softcap,
        )

    def embed_lookup(self, embed, tokens):
        return jnp.take(maybe_dequantize_tree(embed), tokens, axis=0)

    def emit_tap(self, h):
        return h


class PallasOpSet(OpSet):
    """Storage-width frozen-path ops: quantized matmuls, Pallas flash
    attention, taps quantized at the tap site. Forward-only (the PAC+
    steps stop-gradient the frozen path); plain-array weights fall back
    to dense jnp — the kernels buy nothing on an unquantized backbone.
    """

    name = "pallas"

    def __init__(self, tap_policy: str = "f32", interpret=None):
        if tap_policy not in TAP_POLICIES:
            raise ValueError(
                f"tap_policy must be one of {TAP_POLICIES}, got {tap_policy!r}")
        from repro.kernels.cached_step import _auto_interpret

        self.tap_policy = tap_policy
        self.interpret = _auto_interpret(interpret)

    def prepare_block(self, p, spec):
        """Keep the matmul weights quantized — only the leaves with no
        quantized kernel (norm gains; SSM mixers and MoE experts, whose
        scans/einsums are documented dense fallbacks) are dequantized."""
        out = {"ln1": maybe_dequantize_tree(p["ln1"])}
        if spec.kind == "attn":
            out["mixer"] = p["mixer"]  # wq/wk/wv/wo feed quant_matmul
        else:
            out["mixer"] = maybe_dequantize_tree(p["mixer"])
        if "ffn" in p:
            out["ln2"] = maybe_dequantize_tree(p["ln2"])
            if spec.moe:
                out["ffn"] = maybe_dequantize_tree(p["ffn"])
            else:
                out["ffn"] = p["ffn"]  # wi/wg/wo feed quant_matmul
        return out

    def matmul(self, x, w):
        if not isinstance(w, QTensor):
            return x @ w
        from repro.kernels.quant_matmul import quant_matmul

        lead, K = x.shape[:-1], x.shape[-1]
        x2 = x.reshape(-1, K)
        M = x2.shape[0]
        # pad-and-slice ragged M/K to the kernel's clamped block
        # multiples (bm=128, bk=256); N = n_blocks·128 is always aligned
        if M > 128:
            x2 = _pad_axis(x2, 0, -M % 128)
        q, scale = w.q, w.scale
        if K > 256:
            pad = -K % 256
            x2 = _pad_axis(x2, 1, pad)
            q = _pad_axis(q, 0, pad)
            scale = _pad_axis(scale, 0, pad)
        out = quant_matmul(x2, q, scale, bits=w.bits, interpret=self.interpret)
        return out[:M, : w.orig_last].reshape(lead + (w.orig_last,))

    def attention(self, q, k, v, cfg, spec, block_k: int = 1024):
        from repro.kernels.flash_attention import flash_attention_tpu
        from repro.models.layers import _repeat_kv

        B, S, _, hd = q.shape
        H = cfg.n_heads
        # the Pallas kernel derives positions from its grid ids, so it
        # needs the standard repeated-KV layout (the ref OpSet's
        # grouped-head fold would misnumber the query rows)
        k = _repeat_kv(k, H // cfg.n_kv_heads)
        v = _repeat_kv(v, H // cfg.n_kv_heads)

        def fold(t):
            return t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

        q3, k3, v3 = fold(q), fold(k), fold(v)
        Sp = S if S <= 256 else -(-S // 256) * 256
        if Sp != S:
            # pad-and-slice: padded KV rows sit at positions >= S, which
            # the causal mask excludes for every real query row
            q3, k3, v3 = (_pad_axis(t, 1, Sp - S) for t in (q3, k3, v3))
        o = flash_attention_tpu(
            q3, k3, v3, causal=True, window=spec.window,
            attn_softcap=cfg.attn_softcap, interpret=self.interpret,
        )[:, :S]
        return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3).reshape(B, S, H * hd)

    def paged_attention(self, q, k_pages, v_pages, k_scale, v_scale,
                        block_tables, lengths, cfg, spec):
        from repro.kernels.paged_attention import paged_attention

        return paged_attention(
            q, k_pages, v_pages, block_tables, lengths,
            k_scale=k_scale, v_scale=v_scale, window=spec.window,
            attn_softcap=cfg.attn_softcap, interpret=self.interpret,
        )

    def embed_lookup(self, embed, tokens):
        if not isinstance(embed, QTensor):
            return jnp.take(embed, tokens, axis=0)
        # gather at storage width: int8 payload rows + scale rows, then
        # dequantize only the gathered (B,S) slice — never the full
        # (vocab, d) f32 table
        q = jnp.take(embed.q, tokens, axis=0)
        scale = jnp.take(embed.scale, tokens, axis=0)
        return dequantize(QTensor(q, scale, embed.bits, embed.block, embed.orig_last))

    def emit_tap(self, h):
        if self.tap_policy == "f32":
            return h
        if self.tap_policy == "bf16":
            return h.astype(jnp.bfloat16)
        qt = quantize(h.astype(jnp.float32), bits=8, block=TAP_BLOCK)
        return {"q": qt.q, "scale": qt.scale}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY = {"ref": RefOpSet, "pallas": PallasOpSet}


def register_opset(name: str, factory) -> None:
    """Register an OpSet factory (``factory(tap_policy=, interpret=)``)
    under ``name`` — the plug-in point for future op variants (paged
    decode, MoE/SSM kernels) that must not touch the model code."""
    _REGISTRY[name] = factory


@functools.lru_cache(maxsize=None)
def _cached(name: str, tap_policy: str, interpret):
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown OpSet {name!r}; registered: {sorted(_REGISTRY)}")
    return factory(tap_policy=tap_policy, interpret=interpret)


def get_opset(name, tap_policy: str = "f32",
              interpret: Optional[bool] = None) -> OpSet:
    """Resolve an OpSet by name (``"ref"``/``"pallas"``/registered).
    Instances are cached per (name, tap_policy, interpret) — they are
    stateless dispatch objects, resolved inside traced code from the
    jit-hashable string the steps carry."""
    if isinstance(name, OpSet):
        return name
    return _cached(name, tap_policy, interpret)
