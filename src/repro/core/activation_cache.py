"""Activation cache for Parallel Adapters (paper §IV-B, §V-B) — v2.

Because the backbone is frozen, the taps ``b_0..b_L`` and the final
hidden state ``b_final`` are invariant per input sequence. During epoch 1
the cache captures them; from epoch 2 on the backbone forward is skipped
entirely and the adapter trains straight from the cache (pure data
parallelism — paper Fig. 11).

v2 extends the byte-budgeted RAM/disk store of v1 with the three pieces
that turn it from a demo into the deployable subsystem the paper costs
out in §V-B:

* **Compressed entries** — a ``compress=`` policy (``"f32"``, ``"bf16"``,
  ``"int8"``) applied at ``put`` time. ``bf16`` halves storage with a
  ≤2⁻⁸ relative error; ``int8`` is the same block-wise absmax scheme the
  backbone weights use (:mod:`repro.core.quantization`, paper §IV-D /
  QLoRA), ~3.9× smaller than f32 including scales. The byte budget and
  all eviction/spill accounting operate on *compressed* bytes.
* **Async prefetch** — :class:`CachePrefetcher` runs a background thread
  over the epoch's known batch order (``DataPipeline.epoch_order``),
  decompressing/loading the *next* batches while the current train step
  runs, with the host→device transfer started early (double-buffered via
  a bounded queue).
* **Compressed handoff** — ``get``/``get_batch``/``CachePrefetcher``
  accept ``compressed=True`` and hand entries to the training step in
  their *storage* form (int8 payload + scales as ``{"q", "scale"}``
  dicts, bf16 arrays) instead of eagerly decompressing:
  ``repro.kernels.cached_step`` then dequantises tile-wise in VMEM, so
  the host→device transfer and HBM reads stay at storage width
  (``--kernels pallas``).
* **Cross-run persistence** — ``save_manifest``/``open_persistent``
  record and validate a manifest (corpus + backbone fingerprints,
  compression policy) next to the spill files, so a re-run against the
  same ``--cache-dir`` starts with a warm cache and performs **zero**
  backbone forwards. A mismatching manifest invalidates loudly and
  discards the stale entries.

Storage cost is ``(n_periods + 2) · S · d`` values per sequence with
``b_final`` folded in (the paper's ``s × h × l`` analysis, +1 for the
final hidden state). Spills are ``.npz`` shards (the paper reloads per
micro-batch from embedded flash); each archive handle is closed after
the read.
"""

from __future__ import annotations

import json
import os
import queue
import sys
import threading
from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.core.quantization import QTensor, dequantize, quantize

COMPRESS_POLICIES = ("f32", "bf16", "int8")
_INT8_BLOCK = 128
MANIFEST_NAME = "manifest.json"
MANIFEST_VERSION = 2


def cache_bytes_per_sequence(
    cfg, seq_len: int, dtype_bytes: float = 4, with_final: bool = False
) -> int:
    """Paper §V-B storage analysis: s·h·(l+1) values per sequence.

    ``with_final=True`` adds the ``b_final`` plane that v2 entries fold
    in (s·h·(l+2)) — what ``--cache-budget-mb`` sizing should use; pass
    ``policy_bytes_per_value(policy)`` as ``dtype_bytes`` for compressed
    entries."""
    planes = cfg.n_periods + (2 if with_final else 1)
    return int(planes * seq_len * cfg.d_model * dtype_bytes)


def policy_bytes_per_value(policy: str, block: int = _INT8_BLOCK) -> float:
    """Stored bytes per cached value under each compression policy
    (int8 includes the per-block f32 scale amortised over the block)."""
    return {"f32": 4.0, "bf16": 2.0, "int8": 1.0 + 4.0 / block}[policy]


# ---------------------------------------------------------------------------
# Compressed tensors / cache entries
# ---------------------------------------------------------------------------


@dataclass
class _CTensor:
    """One compressed host tensor + enough metadata to invert it.

    f32:  data float32, scale None
    bf16: data ml_dtypes.bfloat16 (stored as uint16 inside npz shards)
    int8: data int8 payload, scale f32 per-block absmax/127
          (exactly ``quantization.quantize(bits=8, block=_INT8_BLOCK)``)
    """

    policy: str
    data: np.ndarray
    scale: Optional[np.ndarray]
    orig_last: int
    block: int = 0

    @property
    def nbytes(self) -> int:
        return self.data.nbytes + (0 if self.scale is None else self.scale.nbytes)


def _compress(x, policy: str, own: bool = False, orig_last: Optional[int] = None) -> _CTensor:
    """``own=True`` guarantees the payload owns its buffer: a same-dtype
    conversion is a no-copy view, and an entry holding a view of e.g. one
    row of a (B,S,d) batch array would pin the whole batch in RAM — the
    byte budget would no longer bound real memory.

    ``x`` may already BE storage form: an int8 ``{"q", "scale"}`` dict as
    emitted at the tap site by the pallas OpSet (``emit_tap``). It is
    adopted as-is — no recompress, no f32 round-trip — provided the
    policy is int8 and ``orig_last`` names the unpadded feature width."""
    if isinstance(x, dict):
        if policy != "int8":
            raise ValueError(
                f"storage-form (q/scale) tap requires the int8 policy, got {policy!r}"
            )
        q = np.asarray(x["q"])
        scale = np.asarray(x["scale"])
        last = q.shape[-1] if orig_last is None else orig_last
        return _CTensor("int8", q, scale, last, q.shape[-1] // scale.shape[-1])
    x = np.asarray(x)
    if policy in ("f32", "bf16"):
        target = np.float32 if policy == "f32" else ml_dtypes.bfloat16
        data = np.asarray(x, target)
        if own and (data is x or data.base is not None):
            data = data.copy()
        return _CTensor(policy, data, None, x.shape[-1])
    if policy == "int8":
        qt = quantize(jnp.asarray(x, jnp.float32), bits=8, block=_INT8_BLOCK)
        return _CTensor(
            "int8", np.asarray(qt.q), np.asarray(qt.scale), qt.orig_last, qt.block
        )
    raise ValueError(f"compress must be one of {COMPRESS_POLICIES}, got {policy!r}")


def _ct_index(ct: _CTensor, idx) -> _CTensor:
    """Slice one sequence out of a batch-compressed tensor. Copies, so the
    per-sequence entry owns its bytes instead of pinning the batch array.
    Valid because compression is independent along the last axis (blocks
    never straddle the sliced leading axes)."""
    return _CTensor(
        ct.policy,
        ct.data[idx].copy(),
        None if ct.scale is None else ct.scale[idx].copy(),
        ct.orig_last,
        ct.block,
    )


def _decompress(ct: _CTensor, dtype=np.float32) -> np.ndarray:
    """dtype=None returns the storage dtype where it is a real float type
    (bf16 entries ship compressed to the device; the train step upcasts).

    int8 entries dequantize on the host to f32 here — their H2D transfer
    is full-width. To keep the transfer at integer width instead, read
    with ``compressed=True`` (:meth:`ActivationCache.get_batch`): the
    raw ``{"q", "scale"}`` payload then reaches the jitted step and
    `repro.kernels.cached_step` dequantizes it in VMEM."""
    if ct.policy in ("f32", "bf16"):
        return ct.data if dtype is None else np.asarray(ct.data, dtype)
    qt = QTensor(jnp.asarray(ct.data), jnp.asarray(ct.scale), 8, ct.block, ct.orig_last)
    out = np.asarray(dequantize(qt))
    return out if dtype is None else np.asarray(out, dtype)


def _raw_part(ct: _CTensor):
    """Storage-form view for the jitted step: f32/bf16 entries are their
    payload array; int8 entries are the ``{"q", "scale"}`` dict that
    ``kernels.cached_step`` consumes (dequantised in VMEM, so both the
    host→device transfer and HBM reads stay at integer width)."""
    if ct.policy == "int8":
        return {"q": ct.data, "scale": ct.scale}
    return ct.data


def _stack_parts(parts, axis: int):
    """Stack per-sequence storage-form parts (arrays or q/scale dicts)."""
    if isinstance(parts[0], dict):
        return {k: np.stack([p[k] for p in parts], axis=axis) for k in parts[0]}
    return np.stack(parts, axis=axis)


@dataclass
class CacheEntry:
    """One sequence's cached activations: (b0, taps[, b_final])."""

    b0: _CTensor
    taps: _CTensor
    b_final: Optional[_CTensor] = None

    @property
    def nbytes(self) -> int:
        n = self.b0.nbytes + self.taps.nbytes
        return n + (0 if self.b_final is None else self.b_final.nbytes)

    def parts(self) -> Iterable[Tuple[str, _CTensor]]:
        yield "b0", self.b0
        yield "taps", self.taps
        if self.b_final is not None:
            yield "bf", self.b_final


def _entry_to_npz(entry: CacheEntry) -> Dict[str, np.ndarray]:
    meta = {}
    arrays: Dict[str, np.ndarray] = {}
    for name, ct in entry.parts():
        meta[name] = {"policy": ct.policy, "orig_last": ct.orig_last, "block": ct.block}
        arrays[name] = ct.data.view(np.uint16) if ct.policy == "bf16" else ct.data
        if ct.scale is not None:
            arrays[name + "_scale"] = ct.scale
    arrays["meta"] = np.array(json.dumps(meta))
    return arrays


def _entry_from_npz(z) -> CacheEntry:
    meta = json.loads(str(z["meta"]))

    def part(name: str) -> _CTensor:
        m = meta[name]
        data = z[name]
        if m["policy"] == "bf16":
            data = data.view(ml_dtypes.bfloat16)
        scale = z[name + "_scale"] if name + "_scale" in z.files else None
        return _CTensor(m["policy"], data, scale, m["orig_last"], m["block"])

    return CacheEntry(part("b0"), part("taps"), part("bf") if "bf" in meta else None)


# ---------------------------------------------------------------------------
# The cache manager
# ---------------------------------------------------------------------------


@dataclass
class ActivationCache:
    """Keyed store of backbone taps.

    Keys are sequence ids (ints). Values are (b0, taps[, b_final]) with
    shapes (S, d), (n_periods, S, d) and (S, d) — stored per-sequence so
    epochs can re-batch/shuffle freely, exactly like the paper's
    redistribution step. Entries are compressed per ``compress`` at put
    time; the byte budget covers compressed bytes. All mutating paths
    hold a lock so :class:`CachePrefetcher` can read from its own thread.
    """

    budget_bytes: int = 2 << 30
    spill_dir: Optional[str] = None
    compress: str = "f32"
    _ram: Dict[int, CacheEntry] = field(default_factory=dict)
    _disk: Dict[int, str] = field(default_factory=dict)
    _final_absent: Set[int] = field(default_factory=set)
    _ram_bytes: int = 0
    hits: int = 0
    misses: int = 0
    _lock: threading.RLock = field(default_factory=threading.RLock, repr=False)

    def __post_init__(self):
        if self.compress not in COMPRESS_POLICIES:
            raise ValueError(
                f"compress must be one of {COMPRESS_POLICIES}, got {self.compress!r}"
            )

    def __contains__(self, key: int) -> bool:
        return key in self._ram or key in self._disk

    def __len__(self) -> int:
        # a promoted entry keeps its (clean) disk copy — count keys once
        return len(self._ram.keys() | self._disk.keys())

    @property
    def nbytes(self) -> int:
        return self._ram_bytes

    def keys(self) -> Set[int]:
        return self._ram.keys() | self._disk.keys()

    def covers(self, keys, with_final: bool = False) -> bool:
        """True when every key is resident (RAM or disk) — the gate for
        running an epoch through the prefetcher instead of the forward."""
        with self._lock:
            return all(
                int(k) in self and not (with_final and int(k) in self._final_absent)
                for k in keys
            )

    # -- writes ------------------------------------------------------------

    def put(self, key: int, b0, taps, b_final=None) -> None:
        entry = CacheEntry(
            _compress(b0, self.compress, own=True),
            _compress(taps, self.compress, own=True),
            None if b_final is None else _compress(b_final, self.compress, own=True),
        )
        with self._lock:
            self._put_entry(key, entry)

    def _put_entry(self, key: int, entry: CacheEntry) -> None:
        size = entry.nbytes
        if entry.b_final is None:
            self._final_absent.add(key)
        else:
            self._final_absent.discard(key)
        # re-putting an existing key replaces it: retire the old entry's
        # bytes first, or the budget check double-counts and triggers
        # spurious evictions/spills
        if key in self._ram:
            old = self._ram.pop(key)
            self._ram_bytes -= old.nbytes
        if size > self.budget_bytes:
            # the entry alone exceeds the whole budget — don't flush the
            # hot working set making room that can't suffice: disk is its
            # home, or without a spill_dir it is dropped (one sequence
            # re-forwards later, instead of the whole RAM set)
            if self.spill_dir:
                self._spill(key, entry)
            return
        # LRU eviction: the *oldest* RAM entries move to disk, the new
        # entry stays RAM-resident — so under budget pressure the hot
        # (recently written/read) working set keeps serving from RAM
        # instead of freezing the earliest sequences there and routing
        # all later traffic through npz round-trips. Without a spill_dir
        # evicted entries are dropped (paper clears the cache
        # post-training; a mid-training drop means a re-forward later).
        self._evict_until(self.budget_bytes - size)
        if key in self._disk:  # new *data* for the key — the spill is stale
            path = self._disk.pop(key)
            try:
                os.remove(path)
            except OSError:
                pass
        self._ram[key] = entry
        self._ram_bytes += size

    def _evict_until(self, target_bytes: int) -> None:
        """Evict oldest RAM entries until ``_ram_bytes <= target_bytes``.
        A victim with a clean disk copy (promoted earlier) is dropped for
        free; otherwise it is spilled (or dropped without a spill_dir)."""
        while self._ram and self._ram_bytes > target_bytes:
            k, entry = next(iter(self._ram.items()))
            self._ram_bytes -= entry.nbytes
            del self._ram[k]
            if self.spill_dir and k not in self._disk:
                self._spill(k, entry)

    def _spill(self, key: int, entry: CacheEntry) -> None:
        os.makedirs(self.spill_dir, exist_ok=True)
        path = os.path.join(self.spill_dir, f"act_{key}.npz")
        np.savez(path, **_entry_to_npz(entry))
        self._disk[key] = path

    def flush(self) -> None:
        """Write every RAM entry without a clean disk copy to spill_dir —
        the persistence barrier before ``save_manifest``."""
        if not self.spill_dir:
            raise ValueError("flush() requires a spill_dir")
        with self._lock:
            for k, entry in self._ram.items():
                if k not in self._disk:
                    self._spill(k, entry)

    # -- reads -------------------------------------------------------------

    def _get_entry(self, key: int, need_final: bool) -> Optional[CacheEntry]:
        with self._lock:
            if need_final and key in self._final_absent:
                # present but incomplete for this request — the caller
                # re-forwards and re-puts with b_final (replacing the entry)
                self.misses += 1
                return None
            if key in self._ram:
                self.hits += 1
                # refresh recency so eviction order tracks access, not just
                # insertion (dicts iterate in insertion order)
                entry = self._ram.pop(key)
                self._ram[key] = entry
                return entry
            if key in self._disk:
                self.hits += 1
                # npz archives cannot be mmapped; close the zip handle rather
                # than leaking one file descriptor per disk hit
                with np.load(self._disk[key]) as z:
                    entry = _entry_from_npz(z)
                # promote the hit into RAM, *keeping* the npz as a clean copy:
                # evicting a promoted entry later is then free (no rewrite), so
                # the cyclic epoch sweep of a corpus larger than the budget
                # costs one read per miss — never a write per read
                size = entry.nbytes
                if size <= self.budget_bytes:
                    self._evict_until(self.budget_bytes - size)
                    self._ram[key] = entry
                    self._ram_bytes += size
                return entry
            self.misses += 1
            return None

    def get(self, key: int, with_final: bool = False, dtype=np.float32,
            compressed: bool = False):
        """Decompressed (b0, taps) — or (b0, taps, b_final) with
        ``with_final``; None on miss (including an entry stored without
        b_final when b_final is requested). ``dtype=None`` keeps bf16
        payloads compressed for the device transfer. ``compressed=True``
        skips host-side decompression entirely and returns each part in
        its storage form (int8 entries as ``{"q", "scale"}`` dicts) for
        a step that dequantizes on-device (``--kernels pallas``)."""
        entry = self._get_entry(int(key), need_final=with_final)
        if entry is None:
            return None
        parts = [entry.b0, entry.taps] + ([entry.b_final] if with_final else [])
        if compressed:
            return tuple(_raw_part(ct) for ct in parts)
        return tuple(_decompress(ct, dtype) for ct in parts)

    def put_batch(self, keys, b0, taps, b_final=None,
                  orig_last: Optional[int] = None) -> None:
        """b0: (B,S,d); taps: (n_p,B,S,d); b_final: (B,S,d) — device
        arrays from epoch 1 (one device→host gather each, not B). Each
        may instead arrive already in storage form — the int8
        ``{"q", "scale"}`` dict a pallas OpSet emits at the tap site —
        and is adopted without recompression (``orig_last`` = the
        unpadded feature width, d).

        Compression runs once on the whole batch array and per-sequence
        entries are sliced (with copies) out of the result — block-wise
        quantization along the last axis makes the payloads bit-identical
        to per-sequence compression at 1/B the dispatch overhead."""
        cb0 = _compress(b0, self.compress, orig_last=orig_last)
        ctaps = _compress(taps, self.compress, orig_last=orig_last)
        cbf = None if b_final is None else _compress(b_final, self.compress, orig_last=orig_last)
        for i, k in enumerate(keys):
            entry = CacheEntry(
                _ct_index(cb0, i),
                _ct_index(ctaps, (slice(None), i)),
                None if cbf is None else _ct_index(cbf, i),
            )
            with self._lock:
                self._put_entry(int(k), entry)

    def get_batch(self, keys, with_final: bool = False, dtype=np.float32,
                  compressed: bool = False):
        """Reassemble a training batch from cached sequences.

        ``compressed=True`` hands back storage-form parts (see
        :meth:`get`): the int8 policy yields ``{"q": (B,S,·) int8,
        "scale": (B,S,·) f32}`` dicts instead of dequantized arrays —
        the payload ``repro.kernels.cached_step`` dequantizes in VMEM."""
        items = [
            self.get(int(k), with_final=with_final, dtype=dtype,
                     compressed=compressed)
            for k in keys
        ]
        if any(it is None for it in items):
            return None
        b0 = _stack_parts([it[0] for it in items], axis=0)  # (B,S,d)
        taps = _stack_parts([it[1] for it in items], axis=1)  # (n_p,B,S,d)
        if not with_final:
            return b0, taps
        bf = _stack_parts([it[2] for it in items], axis=0)  # (B,S,d)
        return b0, taps, bf

    def clear(self) -> None:
        with self._lock:
            for path in self._disk.values():
                try:
                    os.remove(path)
                except OSError:
                    pass
            self._ram.clear()
            self._disk.clear()
            self._final_absent.clear()
            self._ram_bytes = 0

    # -- cross-run persistence ---------------------------------------------

    def save_manifest(self, meta: dict) -> str:
        """Flush all entries to spill_dir and write the manifest that lets
        a later run resume warm (``open_persistent``). ``meta`` is the
        caller's identity record — corpus/backbone fingerprints,
        compression policy knobs — compared verbatim on reopen."""
        self.flush()
        with self._lock:
            entries = {
                str(k): {
                    "file": os.path.basename(self._disk[k]),
                    "has_final": k not in self._final_absent,
                }
                for k in sorted(self.keys())
            }
            manifest = {
                "version": MANIFEST_VERSION,
                "compress": self.compress,
                "meta": meta,
                "entries": entries,
            }
            path = os.path.join(self.spill_dir, MANIFEST_NAME)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(manifest, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
            return path


def manifest_for(cfg, *, reduced, seq_len, quant_bits, backbone,
                 corpus_tokens) -> dict:
    """The cache-manifest identity dict, shared by every persistent-cache
    consumer (the trainer/session and the persistent-cache docs demo).

    Any change to the backbone weights (seed, quantization), the corpus
    contents, or the shapes changes a fingerprint here and invalidates
    the cache on reopen — ``open_persistent`` compares this dict
    verbatim against the stored manifest's ``meta``."""
    from repro.checkpoint import tree_fingerprint

    return {
        "arch": cfg.name,
        "reduced": bool(reduced),
        "seq": int(seq_len),
        "quant": int(quant_bits or 0),
        "backbone": tree_fingerprint(backbone),
        "corpus": tree_fingerprint(corpus_tokens),
    }


def _invalidate(cache_dir: str, reason: str) -> None:
    print(
        f"ACTIVATION CACHE INVALIDATED at {cache_dir}: {reason} — discarding "
        f"cached entries; epoch 1 will re-run the backbone forward",
        file=sys.stderr,
    )
    for name in os.listdir(cache_dir):
        if name == MANIFEST_NAME or (name.startswith("act_") and name.endswith(".npz")):
            try:
                os.remove(os.path.join(cache_dir, name))
            except OSError:
                pass


def open_persistent(
    cache_dir: str,
    meta: dict,
    *,
    budget_bytes: int = 2 << 30,
    compress: str = "f32",
) -> Tuple[ActivationCache, bool]:
    """Open (or create) a persistent cache at ``cache_dir``.

    Returns ``(cache, warm)``. ``warm`` is True iff a manifest exists and
    validates against ``meta`` + ``compress`` with every entry file
    present — the cache's disk index is then pre-populated and an epoch
    over the manifest's keys performs zero backbone forwards. Any
    mismatch invalidates loudly (stderr) and removes the stale entries.
    """
    cache = ActivationCache(
        budget_bytes=budget_bytes, spill_dir=cache_dir, compress=compress
    )
    path = os.path.join(cache_dir, MANIFEST_NAME)
    if not os.path.exists(path):
        return cache, False
    try:
        with open(path) as f:
            m = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        _invalidate(cache_dir, f"unreadable manifest ({e})")
        return cache, False
    if m.get("version") != MANIFEST_VERSION:
        _invalidate(cache_dir, f"manifest version {m.get('version')} != {MANIFEST_VERSION}")
        return cache, False
    if m.get("compress") != compress:
        _invalidate(
            cache_dir, f"compression policy changed ({m.get('compress')} -> {compress})"
        )
        return cache, False
    if m.get("meta") != meta:
        changed = sorted(
            k
            for k in set(m.get("meta", {})) | set(meta)
            if m.get("meta", {}).get(k) != meta.get(k)
        )
        _invalidate(cache_dir, f"meta mismatch on {changed}")
        return cache, False
    entries = m.get("entries", {})
    files = {k: os.path.join(cache_dir, v["file"]) for k, v in entries.items()}
    missing = [k for k, p in files.items() if not os.path.exists(p)]
    if missing:
        _invalidate(cache_dir, f"{len(missing)} entry file(s) missing")
        return cache, False
    for k, v in entries.items():
        cache._disk[int(k)] = files[k]
        if not v.get("has_final", False):
            cache._final_absent.add(int(k))
    return cache, True


# ---------------------------------------------------------------------------
# Async prefetch
# ---------------------------------------------------------------------------


class CachePrefetcher:
    """Background loader for cached epochs (paper Fig. 11's pure-DP phase).

    Iterates the epoch's known batch order (``DataPipeline.epoch_order``)
    on a daemon thread, so npz reads and dequantisation of batch *k+1*
    overlap train step *k*. With ``to_device=True`` the worker also calls
    ``jax.device_put``, starting the host→device copy early; the bounded
    queue (``depth``, default 2) double-buffers: one batch in flight
    while one is being consumed, and the thread blocks rather than
    loading the whole epoch ahead.

    Yields one ``(b0, taps[, b_final])`` tuple per key-batch, in order —
    or ``None`` for a batch with a missing key (the consumer falls back
    to the forward path). With ``compressed=True`` each part is yielded
    in its *storage* form (int8 entries as ``{"q", "scale"}`` dicts) so
    the device transfer stays at integer width and the Pallas cached
    step dequantizes in VMEM. While a prefetcher is draining, the owning
    thread must not mutate the cache except via ``put`` (both sides take
    the cache lock).

    A prefetcher is a context manager: ``with CachePrefetcher(...) as
    pf:`` guarantees deterministic shutdown on exit — including an
    exception mid-epoch — via :meth:`close` (signal the worker to stop,
    drain the queue so a blocked ``put`` unblocks, join the thread). A
    leaked worker would otherwise keep device buffers alive through its
    queued ``device_put`` results until process exit.
    """

    _DONE = object()

    def __init__(
        self,
        cache: ActivationCache,
        key_batches: Sequence[np.ndarray],
        *,
        with_final: bool = True,
        depth: int = 2,
        to_device: bool = True,
        dtype=np.float32,
        compressed: bool = False,
    ):
        self._cache = cache
        self._key_batches = list(key_batches)
        self._with_final = with_final
        self._to_device = to_device
        self._dtype = dtype
        self._compressed = compressed
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._done = False    # consumer saw the _DONE sentinel
        self._closed = False  # close() ran — iteration must fail fast
        self._thread = threading.Thread(
            target=self._worker, name="activation-cache-prefetch", daemon=True
        )
        self._thread.start()

    def _worker(self) -> None:
        try:
            for keys in self._key_batches:
                if self._stop.is_set():
                    break
                got = self._cache.get_batch(
                    keys, with_final=self._with_final, dtype=self._dtype,
                    compressed=self._compressed,
                )
                if got is not None and self._to_device:
                    # device_put handles the storage-form pytrees too
                    # ({"q","scale"} dicts ship at integer width)
                    got = tuple(jax.device_put(g) for g in got)
                self._q.put(got)
        except BaseException as e:  # surfaced on the consumer side
            self._err = e
        finally:
            self._q.put(self._DONE)

    def __iter__(self):
        return self

    def __next__(self):
        if self._closed:
            # after close() the queue is drained and the worker is gone —
            # a blocking get() here would hang forever. Elastic resharding
            # (repro.fleet) closes mid-epoch and re-opens over the
            # remaining order; a stale iterator must fail loudly instead.
            raise RuntimeError(
                "CachePrefetcher iterated after close(); open a new "
                "prefetcher over the remaining key batches")
        item = self._q.get()
        if item is self._DONE:
            self._done = True
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def __enter__(self) -> "CachePrefetcher":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def close(self) -> None:
        """Deterministic shutdown: signal the worker to stop, drain the
        queue until its ``_DONE`` sentinel (unblocking a worker stuck on
        a full queue), and join the thread. Idempotent; safe mid-epoch
        (early exit / exception) and after normal exhaustion. Unlike
        iteration, a worker error is swallowed here — close() is for
        unwinding, not for results."""
        self._closed = True
        self._stop.set()
        while not self._done:
            try:
                item = self._q.get(timeout=60)
            except queue.Empty:  # worker wedged — join below, best effort
                break
            if item is self._DONE:
                self._done = True
        self._thread.join(timeout=30)
