"""Activation cache for Parallel Adapters (paper §IV-B, §V-B).

Because the backbone is frozen, the taps ``b_0..b_L`` are invariant per
input sequence. During epoch 1 the cache captures them; from epoch 2 on
the backbone forward is skipped entirely and the adapter trains straight
from the cache (pure data parallelism — paper Fig. 11).

Storage cost is ``(n_periods + 1) · S · d`` values per sequence (paper's
``s × h × l`` analysis). The manager enforces a byte budget and spills to
disk (the paper reloads per micro-batch from embedded flash; here we
reload ``.npz`` shards, closing each archive handle after the read).
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def cache_bytes_per_sequence(cfg, seq_len: int, dtype_bytes: int = 4) -> int:
    """Paper §V-B storage analysis: s·h·(l+1) values per sequence."""
    return (cfg.n_periods + 1) * seq_len * cfg.d_model * dtype_bytes


@dataclass
class ActivationCache:
    """Keyed store of backbone taps.

    Keys are sequence ids (ints). Values are (b0, taps) with shapes
    (S, d) and (n_periods, S, d) — stored per-sequence so epochs can
    re-batch/shuffle freely, exactly like the paper's redistribution step.
    """

    budget_bytes: int = 2 << 30
    spill_dir: Optional[str] = None
    dtype: np.dtype = np.float32
    _ram: Dict[int, Tuple[np.ndarray, np.ndarray]] = field(default_factory=dict)
    _disk: Dict[int, str] = field(default_factory=dict)
    _ram_bytes: int = 0
    hits: int = 0
    misses: int = 0

    def __contains__(self, key: int) -> bool:
        return key in self._ram or key in self._disk

    def __len__(self) -> int:
        # a promoted entry keeps its (clean) disk copy — count keys once
        return len(self._ram.keys() | self._disk.keys())

    @property
    def nbytes(self) -> int:
        return self._ram_bytes

    def put(self, key: int, b0: np.ndarray, taps: np.ndarray) -> None:
        b0 = np.asarray(b0, self.dtype)
        taps = np.asarray(taps, self.dtype)
        size = b0.nbytes + taps.nbytes
        # re-putting an existing key replaces it: retire the old entry's
        # bytes first, or the budget check double-counts and triggers
        # spurious evictions/spills
        if key in self._ram:
            a, b = self._ram.pop(key)
            self._ram_bytes -= a.nbytes + b.nbytes
        if size > self.budget_bytes:
            # the entry alone exceeds the whole budget — don't flush the
            # hot working set making room that can't suffice: disk is its
            # home, or without a spill_dir it is dropped (one sequence
            # re-forwards later, instead of the whole RAM set)
            if self.spill_dir:
                self._spill(key, b0, taps)
            return
        # LRU eviction: the *oldest* RAM entries move to disk, the new
        # entry stays RAM-resident — so under budget pressure the hot
        # (recently written/read) working set keeps serving from RAM
        # instead of freezing the earliest sequences there and routing
        # all later traffic through npz round-trips. Without a spill_dir
        # evicted entries are dropped (paper clears the cache
        # post-training; a mid-training drop means a re-forward later).
        self._evict_until(self.budget_bytes - size)
        if key in self._disk:  # new *data* for the key — the spill is stale
            path = self._disk.pop(key)
            try:
                os.remove(path)
            except OSError:
                pass
        self._ram[key] = (b0, taps)
        self._ram_bytes += size

    def _evict_until(self, target_bytes: int) -> None:
        """Evict oldest RAM entries until ``_ram_bytes <= target_bytes``.
        A victim with a clean disk copy (promoted earlier) is dropped for
        free; otherwise it is spilled (or dropped without a spill_dir)."""
        while self._ram and self._ram_bytes > target_bytes:
            k, (a, b) = next(iter(self._ram.items()))
            self._ram_bytes -= a.nbytes + b.nbytes
            del self._ram[k]
            if self.spill_dir and k not in self._disk:
                self._spill(k, a, b)

    def _spill(self, key: int, b0: np.ndarray, taps: np.ndarray) -> None:
        os.makedirs(self.spill_dir, exist_ok=True)
        path = os.path.join(self.spill_dir, f"act_{key}.npz")
        np.savez(path, b0=b0, taps=taps)
        self._disk[key] = path

    def get(self, key: int) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        if key in self._ram:
            self.hits += 1
            # refresh recency so eviction order tracks access, not just
            # insertion (dicts iterate in insertion order)
            entry = self._ram.pop(key)
            self._ram[key] = entry
            return entry
        if key in self._disk:
            self.hits += 1
            # npz archives cannot be mmapped; close the zip handle rather
            # than leaking one file descriptor per disk hit
            with np.load(self._disk[key]) as z:
                b0, taps = z["b0"], z["taps"]
            # promote the hit into RAM, *keeping* the npz as a clean copy:
            # evicting a promoted entry later is then free (no rewrite), so
            # the cyclic epoch sweep of a corpus larger than the budget
            # costs one read per miss — never a write per read
            size = b0.nbytes + taps.nbytes
            if size <= self.budget_bytes:
                self._evict_until(self.budget_bytes - size)
                self._ram[key] = (b0, taps)
                self._ram_bytes += size
            return b0, taps
        self.misses += 1
        return None

    def put_batch(self, keys, b0: jax.Array, taps: jax.Array) -> None:
        """b0: (B,S,d); taps: (n_p,B,S,d) — device arrays from epoch 1."""
        b0 = np.asarray(b0)
        taps = np.asarray(taps)
        for i, k in enumerate(keys):
            self.put(int(k), b0[i], taps[:, i])

    def get_batch(self, keys) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Reassemble a training batch from cached sequences."""
        items = [self.get(int(k)) for k in keys]
        if any(it is None for it in items):
            return None
        b0 = np.stack([it[0] for it in items], axis=0)  # (B,S,d)
        taps = np.stack([it[1] for it in items], axis=1)  # (n_p,B,S,d)
        return b0, taps

    def clear(self) -> None:
        for path in self._disk.values():
            try:
                os.remove(path)
            except OSError:
                pass
        self._ram.clear()
        self._disk.clear()
        self._ram_bytes = 0
