"""Data pipeline for personal-LLM fine-tuning.

The paper's setting is a *small personal corpus* (GLUE-scale: hundreds to
a few thousand sequences) iterated for multiple epochs — which is exactly
what makes the activation cache pay off. We provide:

* ``SyntheticPersonalCorpus`` — a deterministic synthetic corpus with a
  learnable structure (Zipf-ish unigram mixture per "intent" class, with
  class-dependent transition rules) so fine-tuning quality benchmarks
  (paper Table VI analogue) have a real signal to fit.
* ``glue_like_task`` — sequence-classification-style corpora mirroring
  MRPC/STS-B/SST-2/QNLI sizes.
* ``DataPipeline`` — epoch shuffling, microbatching, global-batch
  sharding helpers (keyed by stable sequence ids — the activation-cache
  keys).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass
class SyntheticPersonalCorpus:
    """Deterministic synthetic next-token corpus with class structure."""

    vocab: int
    seq_len: int
    n_sequences: int
    n_classes: int = 4
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # class-conditional bigram tables (sparse, peaked)
        self._start = rng.integers(0, self.vocab, size=self.n_classes)
        self._shift = rng.integers(1, max(2, self.vocab // 2), size=self.n_classes)
        self._noise = 0.1
        self._rng = rng
        toks = np.empty((self.n_sequences, self.seq_len), np.int32)
        cls = np.arange(self.n_sequences) % self.n_classes
        for i in range(self.n_sequences):
            c = cls[i]
            t = np.empty(self.seq_len, np.int32)
            t[0] = (self._start[c] + i) % self.vocab
            for j in range(1, self.seq_len):
                if rng.random() < self._noise:
                    t[j] = rng.integers(0, self.vocab)
                else:
                    t[j] = (t[j - 1] + self._shift[c]) % self.vocab
            toks[i] = t
        self.tokens = toks
        self.classes = cls.astype(np.int32)

    def __len__(self) -> int:
        return self.n_sequences

    def batch(self, ids: np.ndarray) -> dict:
        toks = self.tokens[ids]
        return {
            "seq_ids": ids.astype(np.int32),
            "tokens": toks[:, :-1].copy(),
            "labels": toks[:, 1:].copy(),
        }


# paper's GLUE subsets (approximate train sizes)
_GLUE_SIZES = {"mrpc": 3_668, "stsb": 5_749, "sst2": 67_349, "qnli": 104_743}


def glue_like_task(name: str, vocab: int, seq_len: int, scale: float = 1.0, seed: int = 0):
    name = name.lower().replace("-", "")
    n = max(8, int(_GLUE_SIZES[name] * scale))
    return SyntheticPersonalCorpus(vocab, seq_len, n, n_classes=4, seed=seed)


@dataclass
class DataPipeline:
    corpus: SyntheticPersonalCorpus
    global_batch: int
    shuffle: bool = True
    seed: int = 0
    drop_remainder: bool = True

    def _order(self, epoch_idx: int) -> np.ndarray:
        n = len(self.corpus)
        order = np.arange(n)
        if self.shuffle:
            np.random.default_rng(self.seed + epoch_idx).shuffle(order)
        end = n - (n % self.global_batch) if self.drop_remainder else n
        return order[:end]

    def epoch(self, epoch_idx: int) -> Iterator[dict]:
        order = self._order(epoch_idx)
        for i in range(0, len(order), self.global_batch):
            yield self.corpus.batch(order[i : i + self.global_batch])

    def epoch_order(self, epoch_idx: int) -> list:
        """Per-batch sequence-id arrays for ``epoch_idx``, without
        materializing token batches — the known batch order that feeds
        the activation cache's :class:`~repro.core.activation_cache.
        CachePrefetcher` (ids here are exactly the ``seq_ids`` the
        matching :meth:`epoch` iteration yields, in the same order)."""
        order = self._order(epoch_idx)
        return [
            order[i : i + self.global_batch].astype(np.int32)
            for i in range(0, len(order), self.global_batch)
        ]

    def steps_per_epoch(self) -> int:
        return len(self.corpus) // self.global_batch

    @staticmethod
    def microbatches(batch: dict, n_micro: int) -> dict:
        """(B, ...) -> (n_micro, B/n_micro, ...) for pipelined execution."""

        def f(x):
            b = x.shape[0]
            assert b % n_micro == 0, f"batch {b} not divisible by {n_micro} micro-batches"
            return x.reshape((n_micro, b // n_micro) + x.shape[1:])

        return {k: f(v) for k, v in batch.items()}

    @staticmethod
    def dp_microbatches(batch: dict, n_micro: int, dp: int = 1) -> dict:
        """Micro-batch layout for the hybrid DP×PP trainer.

        (B, ...) -> (n_micro, mb, ...) with mb = B/n_micro, where dim 1
        is contiguous-chunk shardable over ``dp`` ranks: dp rank ``r`` of
        micro ``m`` owns original samples
        ``[m·mb + r·mb/dp, m·mb + (r+1)·mb/dp)`` — the layout
        ``pipeline_apply(batch_axis="dp")`` shards, and the order the
        activation-cache keys follow. Raises (not asserts) on
        indivisibility so CLI misconfiguration fails with a clear
        message before any compute.
        """
        B = next(iter(batch.values())).shape[0]
        if n_micro < 1 or dp < 1:
            raise ValueError(f"n_micro={n_micro} and dp={dp} must be >= 1")
        if B % (n_micro * dp):
            raise ValueError(
                f"global batch {B} must be divisible by n_micro×dp = "
                f"{n_micro}×{dp}; adjust --batch/--micro/--dp"
            )
        return DataPipeline.microbatches(batch, n_micro)
