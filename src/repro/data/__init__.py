from repro.data.pipeline import (  # noqa: F401
    DataPipeline,
    SyntheticPersonalCorpus,
    glue_like_task,
)
