"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel has a pure-jnp oracle in :mod:`repro.kernels.ref` and is
allclose-pinned to it in ``tests/test_kernels.py`` /
``tests/test_cached_step.py``. Shared conventions:

* **interpret escape hatch** — every kernel takes ``interpret=``; pass
  ``True`` off-TPU (CI does, everywhere) to run the kernel body through
  the Pallas interpreter: bit-accurate, not fast. The ``ops``/
  ``cached_step`` wrappers auto-select on ``jax.default_backend()``.
* **ragged shapes** — public entry points either pad-and-slice
  non-divisible dims (``adapter_fuse``, everything in ``cached_step``)
  or clamp block sizes and assert divisibility (``quant_matmul``,
  ``flash_attention`` — their callers control the shapes); each
  docstring says which.
* **dtypes** — inputs may be f32/bf16 (plus int8 payloads where
  documented); the MXU accumulates in f32
  (``preferred_element_type``) and outputs cast back at the epilogue.

Modules:

* ``cached_step`` — the epoch≥2 hot path: fused dequant×adapter λ-mix
  + blockwise LM-head cross-entropy, with custom VJPs (this is what
  ``--kernels pallas`` runs).
* ``quant_matmul`` — ``x @ dequant(Wq)`` for INT8/INT4 block-absmax
  weights (paper §IV-D).
* ``adapter_fuse`` — single λ-mix combine for f32 taps.
* ``flash_attention`` — causal/windowed/soft-capped attention.
* ``ops`` — jit'd public wrappers with CPU (ref) fallbacks.
* ``ref`` — the jnp oracles.
"""
