"""jit'd public wrappers for the Pallas kernels.

On TPU these call the real kernels; on CPU the kernels would run in
``interpret=True`` mode (bit-accurate, not fast), so by default the CPU
path routes to the jnp reference implementations instead — kernels are
an opt-in perf feature inside the big jnp model code, where XLA fusion
is already adequate. Pass ``force_kernel=True`` to exercise the Pallas
body anyway (what the kernel tests do).

The *cached-epoch training* kernels (fused dequant×adapter λ-mix and
blockwise LM-head CE, with custom VJPs) live in
:mod:`repro.kernels.cached_step` and are selected by the trainer's
``--kernels pallas`` switch rather than wrapped here.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor
from repro.kernels import ref
from repro.kernels.adapter_fuse import adapter_fuse as _adapter_fuse_kernel
from repro.kernels.flash_attention import flash_attention_tpu as _flash_kernel
from repro.kernels.quant_matmul import quant_matmul as _quant_matmul_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quant_matmul(x: jax.Array, w: QTensor, *, force_kernel: bool = False) -> jax.Array:
    """x @ dequant(w) with fused in-VMEM dequantisation."""
    if w.block != 128:
        return x @ ref.quant_matmul_ref(jnp.eye(1), w.q, w.scale)  # pragma: no cover
    if _on_tpu() or force_kernel:
        return _quant_matmul_kernel(
            x, w.q, w.scale, bits=w.bits, interpret=not _on_tpu()
        )[..., : w.orig_last]
    return ref.quant_matmul_ref(x, w.q, w.scale, w.bits)[..., : w.orig_last]


def adapter_fuse(b, w_down, a, lam, *, force_kernel: bool = False):
    """λ·(b@W_down) + (1−λ)·a, fused."""
    T2 = b.shape[:-1]
    b2 = b.reshape(-1, b.shape[-1])
    a2 = a.reshape(-1, a.shape[-1])
    if _on_tpu() or force_kernel:
        out = _adapter_fuse_kernel(b2, w_down, a2, lam, interpret=not _on_tpu())
    else:
        out = ref.adapter_fuse_ref(b2, w_down, a2, lam)
    return out.reshape(*T2, -1)


def flash_attention(
    q, k, v, *, causal=True, window: Optional[int] = None,
    attn_softcap: Optional[float] = None, force_kernel: bool = False,
):
    """(B,H,S,hd) attention via the TPU kernel (or the jnp oracle on CPU)."""
    B, H, S, hd = q.shape
    q3 = q.reshape(B * H, S, hd)
    k3 = k.reshape(B * H, -1, hd)
    v3 = v.reshape(B * H, -1, hd)
    if _on_tpu() or force_kernel:
        out = _flash_kernel(
            q3, k3, v3, causal=causal, window=window, attn_softcap=attn_softcap,
            interpret=not _on_tpu(),
        )
    else:
        out = ref.flash_attention_ref(
            q3, k3, v3, causal=causal, window=window, attn_softcap=attn_softcap
        )
    return out.reshape(B, H, S, hd)
