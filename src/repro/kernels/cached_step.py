"""Pallas TPU kernels: the cached-epoch (epoch ≥ 2) training hot path.

From epoch 2 on, the activation cache replaces every backbone forward
(paper §IV-B) and ``pac_cached_train_step`` becomes the dominant
per-step cost of a fine-tuning run. Its two heavy pieces are fused here:

* :func:`dq_adapter_mix` — the per-period tap consumption
  ``out = λ · (dequant(b) @ W_down) + (1 − λ) · a``
  where ``b`` is a cache entry in its *storage* form: f32, bf16, or the
  int8 block-absmax format of :mod:`repro.core.quantization`
  (``{"q": int8, "scale": f32}``). Dequantisation happens on the
  (bt, bk) tile **in VMEM**, so HBM (and host→device) traffic for the
  taps stays at the storage byte-width — the tap never materialises as
  an f32 (T, d) array. A custom VJP keeps that true in the backward
  pass too: ``dW_down = λ · dequant(b)ᵀ @ g`` re-dequantises tile-wise
  in a second kernel; the residual saved between the passes is the
  (T, d/r) down-projection, 1/r of the tap's size.

* :func:`lmhead_ce` — blockwise softmax-cross-entropy over the frozen
  LM head. The (T, vocab) logits tensor is never fully resident:
  an online-softmax sweep over vocab tiles tracks the running max /
  sum-exp / label logit (flash-attention style), and the backward pass
  recomputes each logits tile to form ``dh = (softmax − onehot) @ Wᵀ``.
  Only the (T,) per-token NLL and log-sum-exp are materialised.

:func:`cached_loss_parts` composes them into the full cached-epoch
PAC+ loss — ``impl="ref"`` is the pure-jnp numerics oracle (exactly the
pre-kernel math: upcast to f32, dense matmuls, full logits), and
``impl="pallas"`` the fused path. ``repro.core.steps.
pac_cached_train_step(kernel_impl=...)`` is the consumer.

Shape/dtype contract (every public op):

* Ragged shapes are zero-padded up to block multiples and sliced back
  (the PR 3 pad-and-slice idiom) — any (T, d, d_a, vocab) works.
* Block sizes are clamped to the array dims, so tiny CI shapes run the
  same code path as production shapes.
* ``interpret=None`` auto-selects: compiled on TPU, interpreter mode
  everywhere else (CPU/CI) — bit-accurate, not fast. Pass
  ``interpret=True``/``False`` to force.
* Compute is f32 on the MXU regardless of storage dtype
  (``preferred_element_type=jnp.float32``); outputs cast back to the
  carry/param dtype at the epilogue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantization import QTensor, dequantize


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _pad_to(x, axis: int, target: int):
    if x.shape[axis] == target:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - x.shape[axis])
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------
# Cache-entry storage form
# ---------------------------------------------------------------------------
#
# A cached activation reaches the jitted step either as a plain array
# (f32 / bf16 policies) or, under the int8 policy, as a small dict
# {"q": int8 (..., d_pad), "scale": f32 (..., n_blocks)} — exactly the
# QTensor payload+scales of core.quantization, kept as a dict so the
# batch stays an ordinary pytree for jit/sharding. d_pad = n_blocks ·
# block ≥ d; the pad region quantises to zero so it contributes nothing
# to any contraction.


def is_quantized_entry(x) -> bool:
    """True for the int8 ``{"q", "scale"}`` storage form."""
    return isinstance(x, dict) and "q" in x


def entry_block(x) -> int:
    """Quantization block size of an int8 entry (from its shapes)."""
    return x["q"].shape[-1] // x["scale"].shape[-1]


def entry_to_f32(x, orig_last: int) -> jax.Array:
    """Storage form → f32 array (the eager/ref decompression)."""
    if is_quantized_entry(x):
        qt = QTensor(x["q"], x["scale"], 8, entry_block(x), orig_last)
        return dequantize(qt, jnp.float32)
    return x.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Fused dequant × down-projection × λ-mix
# ---------------------------------------------------------------------------


def _mix_fwd_kernel(q_ref, s_ref, w_ref, a_ref, lam_ref, o_ref, bw_ref,
                    acc_ref, *, n_k: int, qblock: int):
    """One (bt, bj) output tile; K innermost. s_ref is None for float
    storage (the tile is just upcast); int8 tiles dequantise in VMEM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if s_ref is None:
        x = q_ref[...].astype(jnp.float32)
    else:
        q = q_ref[...]
        s = s_ref[...]
        bt_, bk_ = q.shape
        x = (
            q.astype(jnp.float32).reshape(bt_, bk_ // qblock, qblock)
            * s[..., None]
        ).reshape(bt_, bk_)
    acc_ref[...] += jax.lax.dot_general(
        x, w_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _done():
        bw = acc_ref[...]
        bw_ref[...] = bw
        lam = lam_ref[0]
        o_ref[...] = (
            lam * bw + (1.0 - lam) * a_ref[...].astype(jnp.float32)
        ).astype(o_ref.dtype)


def _mix_fwd_impl(q, scale, w, a, lam, bt, bj, bk, interpret):
    """Returns (out (T, da) in a.dtype, bw (T, da) f32)."""
    T, d_store = q.shape
    da = w.shape[1]
    if scale is not None:
        qblock = d_store // scale.shape[1]
        bk = max(qblock, (min(bk, d_store) // qblock) * qblock)
    else:
        qblock = 0
        bk = min(bk, d_store)
    bt, bj = min(bt, T), min(bj, da)
    Tp = -(-T // bt) * bt
    dap = -(-da // bj) * bj
    Kp = -(-d_store // bk) * bk
    q = _pad_to(_pad_to(q, 0, Tp), 1, Kp)
    # w rows beyond its own d (int8 stores d_pad ≥ d) and up to Kp are
    # zero — matching the zero q/scale padding, they contribute nothing
    w = _pad_to(_pad_to(w, 0, Kp), 1, dap)
    a = _pad_to(_pad_to(a, 0, Tp), 1, dap)
    n_k = Kp // bk
    in_specs = [pl.BlockSpec((bt, bk), lambda i, j, k: (i, k))]
    args = [q]
    if scale is not None:
        scale = _pad_to(_pad_to(scale, 0, Tp), 1, Kp // qblock)
        in_specs.append(
            pl.BlockSpec((bt, bk // qblock), lambda i, j, k: (i, k))
        )
        args.append(scale)
    in_specs += [
        pl.BlockSpec((bk, bj), lambda i, j, k: (k, j)),
        pl.BlockSpec((bt, bj), lambda i, j, k: (i, j)),
        pl.BlockSpec(memory_space=pltpu.SMEM),
    ]
    args += [w, a, jnp.asarray(lam, jnp.float32).reshape(1)]

    kernel = functools.partial(_mix_fwd_kernel, n_k=n_k, qblock=qblock)
    if scale is None:  # drop the s_ref slot entirely
        kernel = functools.partial(
            lambda q_ref, w_ref, a_ref, lam_ref, o_ref, bw_ref, acc_ref, f:
            f(q_ref, None, w_ref, a_ref, lam_ref, o_ref, bw_ref, acc_ref),
            f=kernel,
        )
    out, bw = pl.pallas_call(
        kernel,
        grid=(Tp // bt, dap // bj, n_k),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((bt, bj), lambda i, j, k: (i, j)),
            pl.BlockSpec((bt, bj), lambda i, j, k: (i, j)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((Tp, dap), a.dtype),
            jax.ShapeDtypeStruct((Tp, dap), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((bt, bj), jnp.float32)],
        interpret=interpret,
    )(*args)
    return out[:T, :da], bw[:T, :da]


def _mix_dw_kernel(q_ref, s_ref, g_ref, lam_ref, dw_ref, acc_ref,
                   *, n_k: int, qblock: int):
    """dW tile (bi, bj) = λ · Σ_T dequant(b)ᵀ @ g — T innermost."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if s_ref is None:
        x = q_ref[...].astype(jnp.float32)
    else:
        q = q_ref[...]
        s = s_ref[...]
        bt_, bi_ = q.shape
        x = (
            q.astype(jnp.float32).reshape(bt_, bi_ // qblock, qblock)
            * s[..., None]
        ).reshape(bt_, bi_)
    acc_ref[...] += jax.lax.dot_general(
        x, g_ref[...].astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _done():
        dw_ref[...] = (lam_ref[0] * acc_ref[...]).astype(dw_ref.dtype)


def _mix_dw_impl(q, scale, g, lam, d_out, out_dtype, bi, bj, bkt, interpret):
    """Backward weight grad: (d_out, da) = λ · dequant(b)[:, :d_out]ᵀ @ g."""
    T, d_store = q.shape
    da = g.shape[1]
    if scale is not None:
        qblock = d_store // scale.shape[1]
        bi = max(qblock, (min(bi, d_store) // qblock) * qblock)
    else:
        qblock = 0
        bi = min(bi, d_store)
    bj, bkt = min(bj, da), min(bkt, T)
    Dp = -(-d_store // bi) * bi
    dap = -(-da // bj) * bj
    Tp = -(-T // bkt) * bkt
    q = _pad_to(_pad_to(q, 0, Tp), 1, Dp)
    g = _pad_to(_pad_to(g, 0, Tp), 1, dap)
    n_k = Tp // bkt
    in_specs = [pl.BlockSpec((bkt, bi), lambda i, j, k: (k, i))]
    args = [q]
    if scale is not None:
        scale = _pad_to(_pad_to(scale, 0, Tp), 1, Dp // qblock)
        in_specs.append(
            pl.BlockSpec((bkt, bi // qblock), lambda i, j, k: (k, i))
        )
        args.append(scale)
    in_specs += [
        pl.BlockSpec((bkt, bj), lambda i, j, k: (k, j)),
        pl.BlockSpec(memory_space=pltpu.SMEM),
    ]
    args += [g, jnp.asarray(lam, jnp.float32).reshape(1)]

    kernel = functools.partial(_mix_dw_kernel, n_k=n_k, qblock=qblock)
    if scale is None:
        kernel = functools.partial(
            lambda q_ref, g_ref, lam_ref, dw_ref, acc_ref, f:
            f(q_ref, None, g_ref, lam_ref, dw_ref, acc_ref),
            f=kernel,
        )
    dw = pl.pallas_call(
        kernel,
        grid=(Dp // bi, dap // bj, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Dp, dap), out_dtype),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
    )(*args)
    return dw[:d_out, :da]


def _zero_cotangent(x):
    """Zero (co)tangent matching a primal's tangent type: float0 for
    integer storage, a same-dtype zeros array (DCE'd by XLA) for floats."""
    if jnp.issubdtype(x.dtype, jnp.floating):
        return jnp.zeros_like(x)
    return np.zeros(x.shape, jax.dtypes.float0)


@functools.lru_cache(maxsize=None)
def _mix_op(bt: int, bj: int, bk: int, interpret: bool):
    """custom-VJP fused mix op, cached per static configuration.

    Differentiable in (w, a, lam) only — the cache entry (q, scale) is a
    frozen activation and receives a zero/float0 cotangent. Residuals:
    the storage-form entry itself plus the (T, da) f32 down-projection
    ``bw`` — never the dequantised (T, d) tap.
    """

    @jax.custom_vjp
    def op(q, scale, w, a, lam):
        out, _ = _mix_fwd_impl(q, scale, w, a, lam, bt, bj, bk, interpret)
        return out

    def fwd(q, scale, w, a, lam):
        out, bw = _mix_fwd_impl(q, scale, w, a, lam, bt, bj, bk, interpret)
        return out, (q, scale, bw, a, lam, w)

    def bwd(res, g):
        q, scale, bw, a, lam, w = res
        dw = _mix_dw_impl(
            q, scale, g, lam, w.shape[0], w.dtype, 256, bj, 256, interpret
        )
        g32 = g.astype(jnp.float32)
        lam32 = jnp.asarray(lam, jnp.float32)
        da_cot = ((1.0 - lam32) * g32).astype(a.dtype)
        dlam = jnp.sum(g32 * (bw - a.astype(jnp.float32)))
        dlam = dlam.astype(jnp.asarray(lam).dtype).reshape(jnp.shape(lam))
        dscale = None if scale is None else jnp.zeros_like(scale)
        return _zero_cotangent(q), dscale, dw, da_cot, dlam

    op.defvjp(fwd, bwd)
    return op


def dq_adapter_mix(b, w_down, a, lam, *, bt: int = 256, bj: int = 128,
                   bk: int = 512, interpret=None) -> jax.Array:
    """Fused ``λ · (dequant(b) @ w_down) + (1 − λ) · a``.

    b:      cache entry, (..., d)-shaped — an f32/bf16 array or the int8
            ``{"q": (..., d_pad) int8, "scale": (..., nb) f32}`` form.
            Dequantisation runs tile-wise in VMEM; b is treated as a
            constant (zero cotangent) — it is a frozen activation.
    w_down: (d, d_a) float. Rows are zero-extended to the entry's
            padded width, so d need not match d_pad.
    a:      (..., d_a) previous adapter state; out has a's dtype/shape
            (matching the reference's ``mixed.astype(carry.dtype)``).
    lam:    scalar λ (traced; differentiable).
    bt/bj/bk: block sizes over (tokens, d_a, contraction d) — clamped
            to the dims and (for int8) aligned down to the quantization
            block, then every dim is zero-padded to its block multiple
            and the result sliced back (ragged shapes welcome).
    interpret: None → compiled on TPU, interpreter elsewhere (CI).
    """
    interpret = _auto_interpret(interpret)
    if is_quantized_entry(b):
        q, scale = b["q"], b["scale"]
    else:
        q, scale = b, None
    lead = a.shape[:-1]
    q2 = q.reshape(-1, q.shape[-1])
    s2 = None if scale is None else scale.reshape(-1, scale.shape[-1])
    a2 = a.reshape(-1, a.shape[-1])
    out = _mix_op(bt, bj, bk, interpret)(q2, s2, w_down, a2, lam)
    return out.reshape(*lead, -1)


# ---------------------------------------------------------------------------
# Blockwise softmax-cross-entropy over the LM head
# ---------------------------------------------------------------------------


_NEG = -1e30  # mask value for vocab padding


def _ce_fwd_kernel(h_ref, w_ref, lab_ref, nll_ref, lse_ref,
                   m_ref, l_ref, ll_ref, *, n_v: int, bv: int, V: int,
                   softcap):
    """Online softmax over vocab tiles: running max m, sum-exp l, and
    the label logit ll; the (bt, bv) logits tile lives only in VMEM."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        ll_ref[...] = jnp.zeros_like(ll_ref)

    logits = jax.lax.dot_general(
        h_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    bt_ = logits.shape[0]
    col = k * bv + jax.lax.broadcasted_iota(jnp.int32, (bt_, bv), 1)
    logits = jnp.where(col < V, logits, _NEG)
    lab = lab_ref[...]  # (bt, 1) int32
    ll_ref[...] += jnp.sum(
        jnp.where(col == lab, logits, 0.0), axis=1, keepdims=True
    )
    bm = jnp.max(logits, axis=1, keepdims=True)
    new_m = jnp.maximum(m_ref[...], bm)
    l_ref[...] = l_ref[...] * jnp.exp(m_ref[...] - new_m) + jnp.sum(
        jnp.exp(logits - new_m), axis=1, keepdims=True
    )
    m_ref[...] = new_m

    @pl.when(k == n_v - 1)
    def _done():
        lse = m_ref[...] + jnp.log(l_ref[...])
        lse_ref[...] = lse
        nll_ref[...] = lse - ll_ref[...]


def _ce_bwd_kernel(h_ref, w_ref, lab_ref, lse_ref, g_ref, dh_ref,
                   acc_ref, *, n_v: int, bv: int, V: int, softcap):
    """dh tile = dnll · Σ_vocab-tiles (softmax − onehot) @ Wᵀ, with each
    logits tile recomputed in VMEM (never materialised in HBM)."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = jax.lax.dot_general(
        h_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    if softcap is not None:
        t = jnp.tanh(z / softcap)
        logits = softcap * t
        dfac = 1.0 - t * t  # d(softcap(z))/dz
    else:
        logits = z
        dfac = None
    bt_ = logits.shape[0]
    col = k * bv + jax.lax.broadcasted_iota(jnp.int32, (bt_, bv), 1)
    valid = col < V
    p = jnp.where(valid, jnp.exp(logits - lse_ref[...]), 0.0)
    p = p - jnp.where(col == lab_ref[...], 1.0, 0.0)
    if dfac is not None:
        p = p * dfac
    acc_ref[...] += jax.lax.dot_general(
        p, w_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_v - 1)
    def _done():
        dh_ref[...] = (acc_ref[...] * g_ref[...]).astype(dh_ref.dtype)


def _ce_pad(h, labels, bt):
    T, d = h.shape
    Tp = -(-T // bt) * bt
    return _pad_to(h, 0, Tp), _pad_to(labels.reshape(-1, 1), 0, Tp), Tp


def _ce_fwd_impl(h, w, labels, softcap, bt, bv, interpret):
    T, d = h.shape
    V = w.shape[1]
    bt, bv = min(bt, T), min(bv, V)
    hp, lab, Tp = _ce_pad(h, labels, bt)
    Vp = -(-V // bv) * bv
    wp = _pad_to(w, 1, Vp)
    n_v = Vp // bv
    nll, lse = pl.pallas_call(
        functools.partial(
            _ce_fwd_kernel, n_v=n_v, bv=bv, V=V, softcap=softcap
        ),
        grid=(Tp // bt, n_v),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, k: (i, 0)),
            pl.BlockSpec((d, bv), lambda i, k: (0, k)),
            pl.BlockSpec((bt, 1), lambda i, k: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bt, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, k: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((Tp, 1), jnp.float32),
            jax.ShapeDtypeStruct((Tp, 1), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
        ],
        interpret=interpret,
    )(hp, wp, lab)
    return nll[:T, 0], lse[:T, 0]


def _ce_bwd_impl(h, w, labels, lse, g, softcap, bt, bv, interpret):
    T, d = h.shape
    V = w.shape[1]
    bt, bv = min(bt, T), min(bv, V)
    hp, lab, Tp = _ce_pad(h, labels, bt)
    lsep = _pad_to(lse.reshape(-1, 1), 0, Tp)
    gp = _pad_to(g.astype(jnp.float32).reshape(-1, 1), 0, Tp)
    Vp = -(-V // bv) * bv
    wp = _pad_to(w, 1, Vp)
    n_v = Vp // bv
    dh = pl.pallas_call(
        functools.partial(
            _ce_bwd_kernel, n_v=n_v, bv=bv, V=V, softcap=softcap
        ),
        grid=(Tp // bt, n_v),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, k: (i, 0)),
            pl.BlockSpec((d, bv), lambda i, k: (0, k)),
            pl.BlockSpec((bt, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, d), h.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), jnp.float32)],
        interpret=interpret,
    )(hp, wp, lab, lsep, gp)
    return dh[:T]


@functools.lru_cache(maxsize=None)
def _ce_op(softcap, bt: int, bv: int, interpret: bool):
    """custom-VJP blockwise CE, cached per static configuration.
    Differentiable in h only (the head is frozen in PAC+)."""

    @jax.custom_vjp
    def op(h, w, labels):
        nll, _ = _ce_fwd_impl(h, w, labels, softcap, bt, bv, interpret)
        return nll

    def fwd(h, w, labels):
        nll, lse = _ce_fwd_impl(h, w, labels, softcap, bt, bv, interpret)
        return nll, (h, w, labels, lse)

    def bwd(res, g):
        h, w, labels, lse = res
        dh = _ce_bwd_impl(h, w, labels, lse, g, softcap, bt, bv, interpret)
        # the head is frozen — its zero cotangent is DCE'd by XLA
        return dh, jnp.zeros_like(w), _zero_cotangent(labels)

    op.defvjp(fwd, bwd)
    return op


def lmhead_ce(h, w, labels, *, softcap=None, bt: int = 128, bv: int = 512,
              interpret=None) -> jax.Array:
    """Per-token NLL of ``softmax(softcap(h @ w))`` without materialising
    the (T, vocab) logits.

    h:      (T, d) hidden states (post final-norm). Differentiable.
    w:      (d, V) frozen LM head (f32/bf16; dequantise QTensors first).
    labels: (T,) int32 target ids in [0, V) — clamp ignored positions to
            0 and mask their NLL outside (the masking is differentiable
            jnp, so ``d nll`` arrives pre-scaled by mask/denominator).
    softcap: optional tanh logit soft-cap (Gemma-style), applied inside
            the kernel in both passes.
    bt/bv:  token/vocab block sizes, clamped and zero-padded as needed;
            vocab padding columns are masked to −1e30 before the online
            max. Returns f32 (T,).
    """
    interpret = _auto_interpret(interpret)
    cap = None if softcap is None else float(softcap)
    return _ce_op(cap, bt, bv, interpret)(
        h, w, labels.astype(jnp.int32)
    )


# ---------------------------------------------------------------------------
# The composed cached-epoch loss (ref oracle + fused path)
# ---------------------------------------------------------------------------


def ref_cached_loss_parts(backbone_params, adapter_params, cfg, cached,
                          positions, r: int = 8):
    """Numerics oracle: eager f32 decompression + dense jnp math —
    bit-identical to the pre-kernel ``pac_cached_train_step`` body."""
    from repro.core.parallel_adapters import pac_logits
    from repro.models.backbone import cross_entropy_parts

    b0, taps, b_final = (
        entry_to_f32(cached[k], cfg.d_model)
        for k in ("b0", "taps", "b_final")
    )
    logits = pac_logits(
        backbone_params, adapter_params, cfg, b0, taps, b_final, positions, r
    )
    return cross_entropy_parts(logits, cached["labels"])


def fused_cached_loss_parts(backbone_params, adapter_params, cfg, cached,
                            positions, r: int = 8, interpret=None):
    """The Pallas fast path: storage-form entries feed
    :func:`dq_adapter_mix` per period (in-VMEM dequant, λ-mix fused) and
    the head runs through :func:`lmhead_ce` (blockwise CE). Everything
    else — the d/r-wide adapter blocks, norms, the up projection — is
    jnp at 1/r² the backbone's cost.
    """
    from repro.core.parallel_adapters import adapter_config
    from repro.core.quantization import maybe_dequantize_tree
    from repro.models.backbone import apply_block, head_weight
    from repro.models.layers import rms_norm

    labels = cached["labels"]
    B, S = labels.shape
    d = cfg.d_model
    acfg = adapter_config(cfg, r)
    da = acfg.d_model
    downs = adapter_params["downs"]
    lambdas = jnp.clip(adapter_params["lambda"], 0.0, 1.0)

    # b0 embedding-side projection: the same fused op with λ=1 (no mix)
    a = dq_adapter_mix(
        cached["b0"], downs[0], jnp.zeros((B, S, da), jnp.float32),
        jnp.float32(1.0), interpret=interpret,
    )

    def period_fn(carry, xs):
        a_prev = carry
        block_slice, down_i, lam_i, b_i = xs
        mixed = dq_adapter_mix(
            b_i, down_i, a_prev, lam_i, interpret=interpret
        )
        h = mixed.astype(a_prev.dtype)
        for j, spec in enumerate(acfg.pattern):
            h = apply_block(block_slice[j], h, acfg, spec, positions)
        return h, None

    a, _ = jax.lax.scan(
        period_fn,
        a,
        (tuple(adapter_params["blocks"]), downs[1:], lambdas,
         cached["taps"]),
    )
    a = rms_norm(a, adapter_params["out_norm"], acfg.norm_eps)
    side = a @ adapter_params["up"]

    # b_final is one (B, S, d) plane consumed elementwise — its
    # decompression is the storage-width H2D transfer plus one cheap
    # on-device dequant (no matmul to fuse into)
    h = entry_to_f32(cached["b_final"], d) + side
    p_norm = maybe_dequantize_tree(backbone_params["final_norm"])
    h = rms_norm(h, p_norm, cfg.norm_eps)
    w_head = head_weight(backbone_params, cfg)

    mask = labels != -100
    lab = jnp.where(mask, labels, 0)
    nll = lmhead_ce(
        h.reshape(B * S, d), w_head, lab.reshape(B * S),
        softcap=cfg.logit_softcap, interpret=interpret,
    ).reshape(B, S)
    return jnp.sum(nll * mask), jnp.sum(mask)


def cached_loss_parts(backbone_params, adapter_params, cfg, cached,
                      positions, r: int = 8, *, impl: str = "ref",
                      interpret=None):
    """(summed NLL, valid-token count) of the cached-epoch PAC+ loss.

    ``cached``: {"b0", "taps", "b_final"} in storage form (arrays or
    int8 {"q","scale"} dicts) + "labels". ``impl="ref"`` is the jnp
    oracle, ``impl="pallas"`` the fused kernels; both accept all three
    storage forms, so the oracle also validates the compressed handoff.
    """
    if impl == "ref":
        return ref_cached_loss_parts(
            backbone_params, adapter_params, cfg, cached, positions, r
        )
    if impl == "pallas":
        return fused_cached_loss_parts(
            backbone_params, adapter_params, cfg, cached, positions, r,
            interpret=interpret,
        )
    raise ValueError(f"kernel_impl must be 'ref' or 'pallas', got {impl!r}")
