"""Pallas TPU kernel: blocked online-softmax (flash) attention.

Variants needed by the zoo: causal, sliding-window (gemma2 local layers,
the ``sw8k`` long-context serving mode), and attention-logit softcap
(gemma2/grok). Numerics mirror `repro.models.layers.flash_attention`
(the jnp oracle used as ``ref``).

Grid: (B·H, Sq/bq, Sk/bk) with K innermost; VMEM scratch carries the
online-softmax state (acc, m, l) across K steps; the final K step
normalises and writes the output tile. Causal/window masking is computed
from block-relative iota so out-of-range blocks contribute nothing (a
perf TODO in DESIGN.md notes block skipping via a restricted grid).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: Optional[int], cap: Optional[float],
    bq: int, bk: int, n_k: int,
):
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    qpos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kstep * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kstep == n_k - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "attn_softcap", "bq", "bk", "interpret")
)
def flash_attention_tpu(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Blocked online-softmax attention → (BH, Sq, hd) in q.dtype.

    q, k, v: (BH, S, hd) f32/bf16 — batch·heads flattened, GQA repeat
    already applied. ``causal``/``window``/``attn_softcap`` select the
    masking/softcap variants (gemma2 local layers, grok softcap).

    Block sizes ``bq/bk`` tile (Sq, Sk); they are clamped to the dims
    and then **asserted** to divide them (no pad-and-slice here — the
    serving shapes are powers of two; ``ops.flash_attention`` is the
    auto-selecting wrapper). Softmax state is carried in f32 VMEM
    scratch across K steps. ``interpret=True`` runs the Pallas
    interpreter off-TPU (bit-accurate, slow — the CI path).
    """
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    bq, bk = min(bq, Sq), min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    n_k = Sk // bk
    scale = hd ** -0.5

    return pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            cap=attn_softcap, bq=bq, bk=bk, n_k=n_k,
        ),
        grid=(BH, Sq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
