"""Pallas TPU kernel: paged-KV decode attention (the serving engine's core).

One decode step attends the new token's query against a KV cache that
lives in fixed-size *pages* scattered through a global pool
(`repro.serve.paging`). The kernel walks each request's page table via
scalar prefetch — ``PrefetchScalarGridSpec`` hands the (B, max_pages)
block table and the (B,) lengths to every ``index_map``, so the K/V
``BlockSpec`` for grid step (b, j) DMAs page ``block_tables[b, j]``
straight from the pool; the f32 page is never materialised in HBM.
INT8 pages are dequantized element-wise in VMEM (payload + per-(token,
kv-head) absmax scales), exactly like `quant_matmul` does for weights.

Grid: (B, max_pages) with pages innermost; VMEM scratch carries the
online-softmax state (acc, m, l) across pages; the final page step
normalises and writes the (Hkv, n_rep, hd) output block. Queries are
grouped GQA-style — head g·n_rep+r reads KV head g — so the repeated-KV
layout is never built.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cached_step import _auto_interpret

_NEG_INF = -1e30


def _kernel(
    bt_ref, len_ref,  # scalar-prefetch: (B, max_pages) int32, (B,) int32
    q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, window: Optional[int], cap: Optional[float],
    page: int, n_pages_walked: int, quantized: bool,
):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (Hkv, n_rep, hd)
    k = k_ref[0].astype(jnp.float32)  # (page, Hkv, hd)
    v = v_ref[0].astype(jnp.float32)
    if quantized:  # in-VMEM dequant: int8 payload × per-(token, head) scale
        k = k * ks_ref[0].astype(jnp.float32)[..., None]
        v = v * vs_ref[0].astype(jnp.float32)[..., None]

    # scores per KV head batch: (Hkv, n_rep, page)
    s = jax.lax.dot_general(
        q, k, (((2,), (2,)), ((0,), (1,))), preferred_element_type=jnp.float32
    ) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    pos = len_ref[b]  # the new token's position: kpos <= pos attends it
    shape = s.shape
    kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, shape, 2)
    valid = kpos <= pos
    if window is not None:
        valid &= kpos > pos - window
    s = jnp.where(valid, s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    # a fully-masked page leaves m_new at -inf → exp(0)=1 rows; zero them
    p = jnp.where(valid, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(  # (Hkv, n_rep, hd)
        p, v, (((2,), (0,)), ((0,), (1,))), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv

    @pl.when(j == n_pages_walked - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = acc_ref[...] / l[..., None]


@functools.partial(
    jax.jit, static_argnames=("window", "attn_softcap", "interpret")
)
def _paged_attention_call(
    q, k_pages, v_pages, k_scale, v_scale, block_tables, lengths,
    window, attn_softcap, interpret,
):
    B, hkv, n_rep, hd = q.shape
    page = k_pages.shape[1]
    max_pages = block_tables.shape[1]
    # supported envelope: one page of K/V plus the per-batch-row q/acc
    # blocks must fit VMEM (palint budgets the estimate off these bounds)
    assert page <= 64 and hkv <= 16 and n_rep <= 32 and hd <= 256, (
        f"paged attention geometry out of envelope: page={page} "
        f"hkv={hkv} n_rep={n_rep} hd={hd}")
    quantized = k_scale is not None
    scale = hd ** -0.5

    kernel = functools.partial(
        _kernel, scale=scale, window=window, cap=attn_softcap,
        page=page, n_pages_walked=max_pages, quantized=quantized,
    )
    page_spec = pl.BlockSpec(
        (1, page, hkv, hd), lambda b, j, bt, ln: (bt[b, j], 0, 0, 0))
    row_spec = pl.BlockSpec(
        (1, hkv, n_rep, hd), lambda b, j, bt, ln: (b, 0, 0, 0))
    in_specs = [row_spec, page_spec, page_spec]
    operands = [q, k_pages, v_pages]
    if quantized:
        scale_spec = pl.BlockSpec(
            (1, page, hkv), lambda b, j, bt, ln: (bt[b, j], 0, 0))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    else:
        # the kernel signature is fixed; feed (1,1,1) dummies the
        # non-quantized variant never reads
        dummy = jnp.zeros((1, 1, 1), jnp.float32)
        in_specs += [
            pl.BlockSpec((1, 1, 1), lambda b, j, bt, ln: (0, 0, 0))] * 2
        operands += [dummy, dummy]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=in_specs,
        out_specs=row_spec,
        scratch_shapes=[
            pltpu.VMEM((hkv, n_rep, hd), jnp.float32),
            pltpu.VMEM((hkv, n_rep), jnp.float32),
            pltpu.VMEM((hkv, n_rep), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, hkv, n_rep, hd), jnp.float32),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), *operands)


def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Paged decode attention → (B, Hkv, n_rep, hd) f32.

    q: (B, Hkv, n_rep, hd) post-rope new-token query (grouped GQA
    layout); k/v_pages: (n_pages, page, Hkv, hd) pool — int8 payload
    with ``k_scale``/``v_scale`` (n_pages, page, Hkv), or plain
    f32/bf16; block_tables: (B, max_pages) int32 (page id 0 is the null
    page — masked rows may point anywhere); lengths: (B,) int32, the
    index the new token was written at (``kpos <= lengths[b]`` attends).

    Oracle: :func:`repro.kernels.ref.paged_attention_ref`.
    ``interpret=None`` auto-selects the Pallas interpreter off-TPU.
    """
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale and v_scale must be passed together")
    return _paged_attention_call(
        q, k_pages, v_pages, k_scale, v_scale, block_tables, lengths,
        window, attn_softcap, _auto_interpret(interpret),
    )
