"""Pallas TPU kernel: fused adapter combine (paper Fig. 6 glue).

Computes ``out = λ · (b @ W_down) + (1 − λ) · a`` in one pass: the
down-projection matmul accumulates in VMEM and the λ-mix epilogue is
applied on the final K step, so the (T × d/r) intermediate never makes an
HBM round-trip. This op runs once per layer per step in the PAC+ forward
(and its transpose pattern in the adapter backward), so on a
bandwidth-bound chip the saved traffic is ``2 · T · d/r · 4B`` per layer.

Grid: (T/bt, da/bj, d/bk) over block-padded dims (ragged shapes are
zero-padded and sliced), K innermost with an f32 accumulator scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(b_ref, w_ref, a_ref, lam_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        b_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _done():
        lam = lam_ref[0]
        o_ref[...] = (
            lam * acc_ref[...] + (1.0 - lam) * a_ref[...].astype(jnp.float32)
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "bj", "bk", "interpret"))
def adapter_fuse(
    b: jax.Array,
    w_down: jax.Array,
    a: jax.Array,
    lam: jax.Array,
    *,
    bt: int = 256,
    bj: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused ``λ · (b @ w_down) + (1 − λ) · a`` → (T, da), in b.dtype.

    b: (T, d) f32/bf16; w_down: (d, da) f32/bf16; a: (T, da); lam: ()
    f32 (SMEM scalar). Block sizes ``bt/bj/bk`` tile (T, da, d); each is
    clamped to its dim, then every dim is zero-padded up to its block
    multiple and the result sliced back — ragged shapes (e.g. --seq 100)
    are fine. Accumulation is f32 on the MXU regardless of input dtype.
    ``interpret=True`` runs the Pallas interpreter (CPU/CI; bit-accurate,
    slow). For *compressed* taps and the custom-VJP training path use
    :func:`repro.kernels.cached_step.dq_adapter_mix` instead.
    """
    T, d = b.shape
    da = w_down.shape[1]
    bt, bj, bk = min(bt, T), min(bj, da), min(bk, d)
    # ragged shapes (e.g. --seq 100): pad every dim up to its block
    # multiple. Zero K-padding contributes nothing to the accumulator;
    # the padded rows/cols see the λ-mix epilogue over zeros, and the
    # final slice masks them out of the result.
    Tp, dap, dp = -(-T // bt) * bt, -(-da // bj) * bj, -(-d // bk) * bk
    padded = (Tp, dap, dp) != (T, da, d)
    if padded:
        b = jnp.pad(b, ((0, Tp - T), (0, dp - d)))
        w_down = jnp.pad(w_down, ((0, dp - d), (0, dap - da)))
        a = jnp.pad(a, ((0, Tp - T), (0, dap - da)))
    n_k = dp // bk
    lam = jnp.asarray(lam, jnp.float32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(Tp // bt, dap // bj, n_k),
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bj), lambda i, j, k: (k, j)),
            pl.BlockSpec((bt, bj), lambda i, j, k: (i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bt, bj), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, dap), b.dtype),
        scratch_shapes=[pltpu.VMEM((bt, bj), jnp.float32)],
        interpret=interpret,
    )(b, w_down, a, lam)
    return out[:T, :da] if padded else out
