"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor, dequantize


def quant_matmul_ref(x: jax.Array, q: jax.Array, scale: jax.Array, bits: int = 8) -> jax.Array:
    """Dequantize-then-matmul (the paper's Fig. 8 two-step path)."""
    orig_last = scale.shape[-1] * 128
    t = QTensor(q, scale, bits, 128, orig_last)
    return x @ dequantize(t, jnp.float32).astype(x.dtype)


def adapter_fuse_ref(b: jax.Array, w_down: jax.Array, a: jax.Array, lam) -> jax.Array:
    return (lam * (b @ w_down) + (1.0 - lam) * a).astype(b.dtype)


def dq_adapter_mix_ref(b, w_down: jax.Array, a: jax.Array, lam, orig_last: int) -> jax.Array:
    """Eager twin of `cached_step.dq_adapter_mix`: decompress the cache
    entry to f32, dense matmul, λ-mix; result in a.dtype."""
    from repro.kernels.cached_step import entry_to_f32

    x = entry_to_f32(b, orig_last)
    lam = jnp.asarray(lam, jnp.float32)
    out = lam * (x @ w_down.astype(jnp.float32)) + (1.0 - lam) * a.astype(jnp.float32)
    return out.astype(a.dtype)


def lmhead_ce_ref(h: jax.Array, w: jax.Array, labels: jax.Array, softcap=None) -> jax.Array:
    """Full-logits per-token NLL (the (T, V) tensor this oracle
    materialises is exactly what `cached_step.lmhead_ce` avoids)."""
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=1)[:, 0]


def paged_attention_ref(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
) -> jax.Array:
    """Paged decode attention oracle: gather-then-dense.

    q: (B, Hkv, n_rep, hd) post-rope query of the new token;
    k/v_pages: (n_pages, page, Hkv, hd) — int8 payload with
    ``[kv]_scale`` (n_pages, page, Hkv) scales, or plain f32/bf16;
    block_tables: (B, max_pages) int32 page ids (0 = the null page);
    lengths: (B,) int32 — tokens already resident, i.e. the index the
    new token was written at (it is attended: ``kpos <= lengths[b]``).
    Returns (B, Hkv, n_rep, hd) f32.

    Dequantizes element-wise *before* the dot — the same order as the
    in-VMEM dequant of ``kernels.paged_attention`` (the linear-cache
    ``attention_decode_quant`` folds scales after the einsum instead,
    so int8 parity against it is per-policy tolerance, not bitwise).
    """
    B = q.shape[0]
    page = k_pages.shape[1]
    hd = q.shape[-1]
    k = k_pages[block_tables].astype(jnp.float32)  # (B, maxp, page, Hkv, hd)
    v = v_pages[block_tables].astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale[block_tables].astype(jnp.float32)[..., None]
    if v_scale is not None:
        v = v * v_scale[block_tables].astype(jnp.float32)[..., None]
    max_pages = block_tables.shape[1]
    S = max_pages * page
    k = k.reshape(B, S, k.shape[-2], hd)
    v = v.reshape(B, S, v.shape[-2], hd)
    s = jnp.einsum("bgrd,bsgd->bgrs", q.astype(jnp.float32), k) * (hd ** -0.5)
    if attn_softcap is not None:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    kpos = jnp.arange(S)
    valid = kpos[None, :] <= lengths[:, None]
    if window is not None:
        valid &= kpos[None, :] > (lengths[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgrs,bsgd->bgrd", w, v)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
) -> jax.Array:
    """Naive quadratic attention. q,k,v: (BH, S, hd)."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (hd ** -0.5)
    if attn_softcap is not None:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
