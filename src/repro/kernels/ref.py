"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor, dequantize


def quant_matmul_ref(x: jax.Array, q: jax.Array, scale: jax.Array, bits: int = 8) -> jax.Array:
    """Dequantize-then-matmul (the paper's Fig. 8 two-step path)."""
    orig_last = scale.shape[-1] * 128
    t = QTensor(q, scale, bits, 128, orig_last)
    return x @ dequantize(t, jnp.float32).astype(x.dtype)


def adapter_fuse_ref(b: jax.Array, w_down: jax.Array, a: jax.Array, lam) -> jax.Array:
    return (lam * (b @ w_down) + (1.0 - lam) * a).astype(b.dtype)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
) -> jax.Array:
    """Naive quadratic attention. q,k,v: (BH, S, hd)."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (hd ** -0.5)
    if attn_softcap is not None:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
