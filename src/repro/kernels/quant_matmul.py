"""Pallas TPU kernel: block-dequant INT8/INT4 matmul (paper §IV-D on TPU).

Computes ``y = x @ dequant(Wq)`` where ``Wq`` is stored INT8 (or packed
INT4) with per-(row, 128-col-block) absmax scales — the storage format of
`repro.core.quantization`. The dequantisation happens on the (bk, bn)
weight tile **in VMEM**, so HBM traffic for the weights is the integer
byte-width; the MXU accumulates in f32. This is the TPU-native rethink of
the paper's (bitsandbytes-style) dequant-then-GEMM: on a
bandwidth-limited chip the fused version moves 4×/8× fewer weight bytes,
which is exactly the term the memory roofline charges.

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary") with an f32 VMEM
accumulator scratch; block shapes default to MXU-aligned (128, 128, 256).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

QBLOCK = 128  # quantization block size along N (matches core.quantization)


def _kernel(x_ref, q_ref, s_ref, o_ref, acc_ref, *, bits: int, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # (bm, bk) f32
    q = q_ref[...]  # (bk, bn) int8  |  (bk, bn//2) packed int4
    s = s_ref[...]  # (bk, bn // QBLOCK) f32
    if bits == 4:
        qi = q.astype(jnp.int32)
        lo = qi & 0xF
        lo = jnp.where(lo >= 8, lo - 16, lo)
        hi = (qi >> 4) & 0xF
        hi = jnp.where(hi >= 8, hi - 16, hi)
        q = jnp.stack([lo, hi], axis=-1).reshape(q.shape[0], q.shape[1] * 2)
    bk, bn = q.shape
    w = q.astype(jnp.float32).reshape(bk, bn // QBLOCK, QBLOCK) * s[:, :, None]
    w = w.reshape(bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "bk", "interpret"))
def quant_matmul(
    x: jax.Array,
    q: jax.Array,
    scale: jax.Array,
    *,
    bits: int = 8,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """``x @ dequant(q, scale)`` with in-VMEM dequantisation → (M, N).

    x: (M, K) f32/bf16; q: (K, N) int8 or (K, N//2) packed int4 nibbles;
    scale: (K, N // QBLOCK) f32 per-(row, 128-col-block) absmax scales —
    the exact storage format of ``core.quantization.quantize(block=128)``.
    Returns (M, N) in x.dtype; MXU accumulation is f32.

    Block-size constraints (asserted, *not* padded — the weight shapes
    are static and callers align them): ``bn % QBLOCK == 0`` so a weight
    tile covers whole quantization blocks, and after clamping to the
    dims, ``bm | M``, ``bn | N``, ``bk | K``. ``interpret=True`` runs
    the Pallas interpreter off-TPU (CI path; see ``ops.quant_matmul``
    for the auto-selecting wrapper that also slices padding off N).
    """
    M, K = x.shape
    N = scale.shape[1] * QBLOCK
    assert bn % QBLOCK == 0, "bn must cover whole quantization blocks"
    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    pack = 2 if bits == 4 else 1

    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn // pack), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn // QBLOCK), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, q, scale)
