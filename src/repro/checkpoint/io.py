"""Checkpointing: pytree <-> disk with msgpack framing.

Handles plain arrays and :class:`~repro.core.quantization.QTensor` leaves
(the quantized backbone checkpoints exactly at its storage bit-width —
the on-disk artifact is as small as the in-memory footprint, which is the
paper's deployment story for edge flash).
"""

from __future__ import annotations

import hashlib
import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.core.quantization import QTensor

_SENTINEL_Q = "__qtensor__"
_SENTINEL_A = "__array__"


def _encode(tree: Any):
    if isinstance(tree, QTensor):
        return {
            _SENTINEL_Q: True,
            "q": _encode(np.asarray(tree.q)),
            "scale": _encode(np.asarray(tree.scale)),
            "bits": tree.bits,
            "block": tree.block,
            "orig_last": tree.orig_last,
        }
    if isinstance(tree, (jax.Array, np.ndarray)):
        arr = np.asarray(tree)
        return {
            _SENTINEL_A: True,
            "dtype": arr.dtype.str,
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    if isinstance(tree, dict):
        return {k: _encode(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return {"__list__": [_encode(v) for v in tree], "__tuple__": isinstance(tree, tuple)}
    if isinstance(tree, (int, float, str, bool)) or tree is None:
        return {"__scalar__": tree}
    raise TypeError(f"cannot checkpoint leaf of type {type(tree)}")


def _decode(obj: Any):
    if isinstance(obj, dict):
        if obj.get(_SENTINEL_Q):
            return QTensor(
                _decode(obj["q"]), _decode(obj["scale"]), obj["bits"], obj["block"], obj["orig_last"]
            )
        if obj.get(_SENTINEL_A):
            arr = np.frombuffer(obj["data"], dtype=np.dtype(obj["dtype"])).reshape(obj["shape"])
            return jnp.asarray(arr)
        if "__list__" in obj:
            items = [_decode(v) for v in obj["__list__"]]
            return tuple(items) if obj.get("__tuple__") else items
        if "__scalar__" in obj:
            return obj["__scalar__"]
        return {k: _decode(v) for k, v in obj.items()}
    return obj


def save_checkpoint(path: str, tree: Any) -> int:
    """Write atomically; returns bytes written."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    payload = msgpack.packb(_encode(tree), use_bin_type=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(payload)
    os.replace(tmp, path)
    return len(payload)


def load_checkpoint(path: str) -> Any:
    with open(path, "rb") as f:
        return _decode(msgpack.unpackb(f.read(), raw=False, strict_map_key=False))


def tree_fingerprint(tree: Any) -> str:
    """Stable 16-hex digest of a pytree's exact contents.

    Reuses the checkpoint encoder, so anything checkpointable (plain
    arrays, QTensor leaves, nested containers, scalars) can be
    fingerprinted. The activation-cache manifest uses this to detect a
    changed backbone or corpus across runs — any bit flip in any leaf,
    or any structural change, yields a different digest.

    Hashing is streamed leaf-by-leaf (treedef first), so no full-model
    serialization buffer is ever materialized — on edge targets the
    transient 1×-model-size allocation of a single packb would be the
    difference between launching and OOMing.
    """
    h = hashlib.sha256()
    leaves, treedef = jax.tree_util.tree_flatten(
        tree, is_leaf=lambda x: isinstance(x, QTensor)
    )
    h.update(repr(treedef).encode())
    for leaf in leaves:
        h.update(msgpack.packb(_encode(leaf), use_bin_type=True))
    return h.hexdigest()[:16]
