from repro.checkpoint.io import (  # noqa: F401
    load_checkpoint,
    save_checkpoint,
    tree_fingerprint,
)
