"""Pool events: the pluggable failure/arrival source.

The scheduler consumes membership changes through one seam —
:class:`PoolEvents.poll(tick)` — so the deterministic CI harness
(:class:`ScriptedEvents` over a :class:`FaultPlan`) and a real
deployment's monitor are interchangeable. A :class:`FaultPlan` is a
list of :class:`FleetEvent` records pinned to scheduler *tick* indices
(step boundaries — the only points the runtime can react anyway, since
a jitted step is atomic), JSON round-trippable for replay, and
generatable from a seed for property tests.

Event kinds:

``join``    device (re)joins the pool
``leave``   graceful departure — removed immediately
``kill``    abrupt loss — the device stops heartbeating and is only
            *detected* when the pool's heartbeat timeout elapses
``slow``    straggler: the device's speed factor drops to ``factor``
            (1.0 restores full speed; feeds the planner's deweighting)
``submit``  a job named ``job`` arrives in the scheduler queue
"""

from __future__ import annotations

import json
import random
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Protocol, Sequence

EVENT_KINDS = ("join", "leave", "kill", "slow", "submit")


@dataclass(frozen=True)
class FleetEvent:
    """One scripted pool/queue change at a step boundary."""

    tick: int
    kind: str
    device: Optional[str] = None
    job: Optional[str] = None
    factor: float = 1.0  # "slow" only: new speed multiplier

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"kind must be one of {EVENT_KINDS}, got {self.kind!r}")
        if self.kind == "submit":
            if self.job is None:
                raise ValueError("submit events need job=")
        elif self.device is None:
            raise ValueError(f"{self.kind} events need device=")
        if self.kind == "slow" and self.factor <= 0:
            raise ValueError(f"slow factor must be > 0, got {self.factor}")


class PoolEvents(Protocol):
    """Anything that feeds membership/arrival changes to the scheduler."""

    def poll(self, tick: int) -> List[FleetEvent]:
        ...


@dataclass
class FaultPlan:
    """An ordered, replayable event script."""

    events: List[FleetEvent]

    def __post_init__(self):
        self.events = sorted(self.events, key=lambda e: (e.tick, EVENT_KINDS.index(e.kind), e.device or "", e.job or ""))

    @property
    def last_tick(self) -> int:
        return max((e.tick for e in self.events), default=-1)

    def at(self, tick: int) -> List[FleetEvent]:
        return [e for e in self.events if e.tick == tick]

    # -- JSON round-trip -----------------------------------------------------

    def to_json(self, indent: Optional[int] = 1) -> str:
        return json.dumps(
            {"version": 1, "events": [asdict(e) for e in self.events]},
            indent=indent, sort_keys=True,
        )

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        d = json.loads(text)
        if d.get("version") != 1:
            raise ValueError(f"unsupported fault-plan version {d.get('version')!r}")
        return cls([FleetEvent(**e) for e in d["events"]])

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- seeded generation (property tests) ----------------------------------

    @classmethod
    def random(
        cls,
        seed: int,
        devices: Sequence[str],
        *,
        n_events: int = 8,
        max_tick: int = 16,
        jobs: Sequence[str] = (),
    ) -> "FaultPlan":
        """Deterministic pseudo-random plan over ``devices`` (and optional
        job submissions): the same seed always yields the same script, so
        a failing property-test example stays failing while it is fixed."""
        rng = random.Random(seed)
        kinds = ["join", "leave", "kill", "slow"] + (["submit"] if jobs else [])
        events: List[FleetEvent] = []
        for _ in range(n_events):
            kind = rng.choice(kinds)
            tick = rng.randrange(max_tick)
            if kind == "submit":
                events.append(FleetEvent(tick, "submit", job=rng.choice(list(jobs))))
            elif kind == "slow":
                events.append(FleetEvent(
                    tick, "slow", device=rng.choice(list(devices)),
                    factor=rng.choice([0.25, 0.5, 1.0])))
            else:
                events.append(FleetEvent(tick, kind, device=rng.choice(list(devices))))
        return cls(events)


class ScriptedEvents:
    """A :class:`FaultPlan` as a :class:`PoolEvents` source. Each tick is
    delivered at most once (polling the same tick twice returns [])."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._delivered: Dict[int, bool] = {}

    def poll(self, tick: int) -> List[FleetEvent]:
        if self._delivered.get(tick):
            return []
        self._delivered[tick] = True
        return self.plan.at(tick)

    @property
    def exhausted(self) -> bool:
        return all(
            self._delivered.get(e.tick, False) for e in self.plan.events
        )
