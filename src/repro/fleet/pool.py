"""DevicePool — fleet membership, health, and the member→device map.

The pool is the scheduler's single source of truth about *who is
available right now*. Each member pairs a planner
:class:`~repro.core.planner.DeviceProfile` with liveness state:

* **heartbeats** — a healthy member heartbeats every tick; a *killed*
  device silently stops, and :meth:`check_timeouts` reports it lost only
  once ``heartbeat_timeout`` of (simulated) time has passed — the
  detection latency a real fleet pays, made deterministic by
  :class:`~repro.fleet.clock.SimClock`.
* **speed factors** — a straggler keeps its membership but its
  ``effective_profile`` scales FLOP/s down, so the planner's Eq. (4)
  dispatch automatically deweights it at the next re-plan.
* **device slots** — members map to JAX devices by a stable slot index
  assigned at join time (on CPU, ``compat.force_host_device_count`` fake
  devices). ``capacity`` bounds concurrent members; slots are recycled
  so a fleet can see more joins than it has slots over its lifetime.
  With ``bind_devices=False`` members stay logical (single-device
  tests and the in-process docs demo).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.planner import DeviceProfile, JETSON_NANO_H
from repro.fleet.clock import Clock, SimClock


@dataclass
class DeviceMember:
    """One fleet device: planner profile + liveness."""

    name: str
    profile: DeviceProfile = JETSON_NANO_H
    speed: float = 1.0
    slot: int = -1             # index into jax.devices(); -1 = unbound
    last_heartbeat: float = 0.0

    def effective_profile(self) -> DeviceProfile:
        """The profile the planner prices: FLOP/s scaled by the current
        straggler factor (memory/bandwidth unchanged)."""
        if self.speed == 1.0:
            return self.profile
        return dataclasses.replace(
            self.profile,
            name=f"{self.profile.name}*{self.speed:g}",
            flops=self.profile.flops * self.speed,
        )


class DevicePool:
    """Mutable fleet membership with heartbeat-based failure detection."""

    def __init__(
        self,
        members: Sequence[DeviceMember] = (),
        *,
        clock: Optional[Clock] = None,
        heartbeat_timeout: float = 2.0,
        capacity: Optional[int] = None,
        bind_devices: bool = False,
    ):
        self.clock = clock if clock is not None else SimClock()
        self.heartbeat_timeout = float(heartbeat_timeout)
        self.bind_devices = bind_devices
        self._members: Dict[str, DeviceMember] = {}
        self._dead: set = set()       # killed, waiting for timeout detection
        self._free_slots: List[int] = []
        self._next_slot = 0
        self.capacity = capacity
        self.generation = 0           # bumped on every membership change
        for m in members:
            self.add(m)

    # -- membership ---------------------------------------------------------

    def __contains__(self, name: str) -> bool:
        return name in self._members

    def __len__(self) -> int:
        return len(self._members)

    def member(self, name: str) -> DeviceMember:
        return self._members[name]

    def alive(self) -> List[str]:
        """Member names in stable (join) order. Includes killed-but-not-
        yet-detected devices — exactly what a real scheduler sees."""
        return list(self._members)

    def add(self, member: DeviceMember) -> DeviceMember:
        if member.name in self._members:
            raise ValueError(f"device {member.name!r} already in the pool")
        if self.capacity is not None and len(self._members) >= self.capacity:
            raise ValueError(
                f"pool at capacity {self.capacity}; {member.name!r} cannot join")
        if self.bind_devices and member.slot < 0:
            if self._free_slots:
                member.slot = self._free_slots.pop()
            else:
                member.slot = self._next_slot
                self._next_slot += 1
        member.last_heartbeat = self.clock.now()
        self._members[member.name] = member
        self._dead.discard(member.name)
        self.generation += 1
        return member

    def remove(self, name: str) -> DeviceMember:
        """Graceful leave (or post-detection eviction)."""
        m = self._members.pop(name)
        self._dead.discard(name)
        if m.slot >= 0:
            self._free_slots.append(m.slot)
        self.generation += 1
        return m

    # -- health -------------------------------------------------------------

    def heartbeat(self, name: str) -> None:
        self._members[name].last_heartbeat = self.clock.now()

    def heartbeat_all(self) -> None:
        """One simulation tick's worth of heartbeats — every member that
        has not been killed reports in."""
        now = self.clock.now()
        for name, m in self._members.items():
            if name not in self._dead:
                m.last_heartbeat = now

    def kill(self, name: str) -> None:
        """Abrupt loss: the device stops heartbeating but stays a member
        until :meth:`check_timeouts` detects it."""
        if name not in self._members:
            raise KeyError(name)
        self._dead.add(name)

    def mark_slow(self, name: str, factor: float) -> None:
        if factor <= 0:
            raise ValueError(f"speed factor must be > 0, got {factor}")
        self._members[name].speed = float(factor)
        self.generation += 1

    def check_timeouts(self) -> List[str]:
        """Evict every member whose last heartbeat is older than the
        timeout; returns the names detected lost (in join order)."""
        now = self.clock.now()
        lost = [
            name for name, m in self._members.items()
            if now - m.last_heartbeat > self.heartbeat_timeout
        ]
        for name in lost:
            self.remove(name)
        return lost

    # -- planner / runtime views --------------------------------------------

    def profiles(self, names: Optional[Sequence[str]] = None) -> List[DeviceProfile]:
        """Speed-scaled profiles for the planner's placement pricing."""
        names = self.alive() if names is None else list(names)
        return [self._members[n].effective_profile() for n in names]

    def jax_device(self, name: str):
        """The JAX device a member's work runs on. Unbound members (and
        pools built with ``bind_devices=False``) share the default
        device — the single-process test/demo mode."""
        import jax

        slot = self._members[name].slot
        devices = jax.devices()
        if slot < 0 or slot >= len(devices):
            return devices[0]
        return devices[slot]
