"""FleetScheduler — the job queue over a shared, flaky device pool.

One tick = one step boundary (the atom of scheduling: a jitted step
cannot be interrupted). Each tick the scheduler

1. polls its :class:`~repro.fleet.events.PoolEvents` source and applies
   join/leave/slow/kill/submit to the pool and queue,
2. heartbeats the healthy members and evicts heartbeat-timeout losses
   (a killed device is detected ``ceil(timeout/dt)`` ticks later —
   deterministic under :class:`~repro.fleet.clock.SimClock`),
3. reconciles placements with the surviving membership (a job whose
   placement shrank keeps running on the survivors — the elastic runner
   makes that numerically invisible; a job that lost *every* device goes
   back to the queue head, state intact),
4. preempts any job that exhausted its ``quantum`` while others wait
   (checkpointed: :meth:`~repro.fleet.job.SessionJob.pause` snapshots
   adapter+optimizer+cursor, to disk when ``snapshot_dir`` is set) —
   FIFO admission + quantum expiry bound every job's wait, so a full
   pool never starves the queue,
5. places queued jobs onto the fastest free members — chunk shares
   priced by the paper's Eq. (4) dispatch over speed-scaled profiles
   (``job.plan_shares``), so stragglers are deweighted by the same
   planner that sized the pool — and grows running jobs onto idle
   devices when nobody waits,
6. runs one step of every placed job, in placement order.

Everything observable lands in a :class:`TickRecord`; :meth:`run` loops
until queue+pool are quiescent and the event script is exhausted.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.fleet.elastic import assign_chunks
from repro.fleet.events import FleetEvent, PoolEvents
from repro.fleet.pool import DeviceMember, DevicePool


@dataclass(frozen=True)
class Placement:
    """One job's current device subset and its Eq. (4) chunk shares."""

    job: str
    devices: Tuple[str, ...]
    shares: Tuple[int, ...]
    since_tick: int        # when these devices were granted (quantum base)


@dataclass
class TickRecord:
    """Everything that happened in one scheduler tick."""

    tick: int
    events: List[FleetEvent] = field(default_factory=list)
    lost: List[str] = field(default_factory=list)
    preempted: List[str] = field(default_factory=list)
    placements: Dict[str, Tuple[str, ...]] = field(default_factory=dict)
    shares: Dict[str, Tuple[int, ...]] = field(default_factory=dict)
    steps: Dict[str, float] = field(default_factory=dict)   # job -> loss
    queued: List[str] = field(default_factory=list)


@dataclass
class FleetReport:
    """The whole simulation, tick by tick."""

    ticks: List[TickRecord] = field(default_factory=list)
    rejected: List[str] = field(default_factory=list)

    @property
    def n_ticks(self) -> int:
        return len(self.ticks)

    def job_steps(self, name: str) -> int:
        return sum(1 for t in self.ticks if name in t.steps)

    def first_step_tick(self, name: str) -> Optional[int]:
        for t in self.ticks:
            if name in t.steps:
                return t.tick
        return None

    def losses(self, name: str) -> List[float]:
        return [t.steps[name] for t in self.ticks if name in t.steps]


class FleetScheduler:
    """Admission, placement, elastic re-planning, and preemption."""

    def __init__(self, pool: DevicePool, *, events: Optional[PoolEvents] = None,
                 quantum: Optional[int] = None, tick_dt: float = 1.0,
                 snapshot_dir: Optional[str] = None, max_ticks: int = 10_000,
                 log=None):
        if quantum is not None and quantum < 1:
            raise ValueError(f"quantum must be >= 1 tick, got {quantum}")
        self.pool = pool
        self.events = events
        self.quantum = quantum
        self.tick_dt = float(tick_dt)
        self.snapshot_dir = snapshot_dir
        self.max_ticks = max_ticks
        self._log = log if log is not None else (lambda *a: None)
        self.jobs: Dict[str, object] = {}
        self._queue: List[str] = []
        self._running: Dict[str, Placement] = {}
        self._snaps: Dict[str, object] = {}    # preempted jobs' snapshots
        self._tick = 0
        self._priced_gen = -1
        self.report = FleetReport()

    # -- admission ------------------------------------------------------------

    def register(self, job) -> None:
        """Make a job known (so a FaultPlan ``submit`` event can queue it
        by name) without queueing it yet."""
        self.jobs[job.name] = job

    def submit(self, job=None, name: Optional[str] = None) -> bool:
        """Admit a job (by object or registered name). Returns False —
        and marks the job ``rejected`` — when the pool can *never* place
        it (min_devices exceeds pool capacity)."""
        if job is not None:
            self.jobs[job.name] = job
            name = job.name
        job = self.jobs[name]
        cap = self.pool.capacity
        if cap is not None and job.min_devices > cap:
            job.state = "rejected"
            self.report.rejected.append(name)
            self._log(f"[fleet] {name}: rejected "
                      f"(needs {job.min_devices} devices, pool capacity {cap})")
            return False
        if name not in self._queue and name not in self._running:
            self._queue.append(name)
            job.state = "queued"
        return True

    # -- event application ----------------------------------------------------

    def _apply(self, e: FleetEvent) -> None:
        pool = self.pool
        if e.kind == "join":
            if e.device not in pool:
                try:
                    pool.add(DeviceMember(e.device))
                except ValueError:          # at capacity
                    self._log(f"[fleet] join {e.device} dropped: pool full")
        elif e.kind == "leave":
            if e.device in pool:
                pool.remove(e.device)
        elif e.kind == "kill":
            if e.device in pool:
                pool.kill(e.device)
        elif e.kind == "slow":
            if e.device in pool:
                pool.mark_slow(e.device, e.factor)
        elif e.kind == "submit":
            self.submit(name=e.job)

    # -- placement ------------------------------------------------------------

    def _fastest(self, names: List[str], want: int) -> Tuple[str, ...]:
        ranked = sorted(
            names,
            key=lambda n: -self.pool.member(n).effective_profile().flops)
        return tuple(ranked[:want])

    def _shares(self, job, devices: Tuple[str, ...]) -> Tuple[int, ...]:
        shares = job.plan_shares(self.pool.profiles(devices))
        if shares is None:
            shares = assign_chunks(
                job.n_chunks, len(devices),
                [self.pool.member(d).speed for d in devices])
        return tuple(int(s) for s in shares)

    def _place(self, name: str, devices: Tuple[str, ...]) -> None:
        job = self.jobs[name]
        self._running[name] = Placement(
            name, devices, self._shares(job, devices), self._tick)
        job.state = "running"
        self._log(f"[fleet] t{self._tick} place {name} on "
                  f"{','.join(devices)} shares="
                  f"{list(self._running[name].shares)}")

    def _free(self) -> List[str]:
        used = {d for pl in self._running.values() for d in pl.devices}
        return [m for m in self.pool.alive() if m not in used]

    def _reconcile(self) -> None:
        """Membership or speed changed: shrink placements to survivors
        (re-pricing shares) and requeue jobs that lost everything."""
        if self.pool.generation == self._priced_gen:
            return
        members = set(self.pool.alive())
        for name, pl in list(self._running.items()):
            kept = tuple(d for d in pl.devices if d in members)
            job = self.jobs[name]
            if not kept:
                del self._running[name]
                self._queue.insert(0, name)   # head: it lost its turn to a fault
                job.state = "queued"
                self._log(f"[fleet] t{self._tick} {name}: all devices lost, requeued")
            else:
                # survivors keep running; always re-price — a speed change
                # (straggler) moves shares even when membership didn't
                self._running[name] = Placement(
                    name, kept, self._shares(job, kept), pl.since_tick)
        self._priced_gen = self.pool.generation

    def _maybe_preempt(self, rec: TickRecord) -> None:
        if self.quantum is None or not self._queue:
            return
        for name, pl in list(self._running.items()):
            if self._tick - pl.since_tick >= self.quantum:
                job = self.jobs[name]
                self._snaps[name] = job.pause(self.snapshot_dir)
                del self._running[name]
                self._queue.append(name)
                rec.preempted.append(name)
                self._log(f"[fleet] t{self._tick} preempt {name} "
                          f"(quantum {self.quantum})")

    def _schedule(self) -> None:
        if self._queue and self._running:
            # elastic shrink: running jobs give back devices above their
            # fair share so arrivals run concurrently instead of waiting
            # out the head (placements keep their fastest members)
            total = len(self.pool.alive())
            fair = max(1, total // (len(self._running) + len(self._queue)))
            for name, pl in list(self._running.items()):
                keep_n = max(fair, self.jobs[name].min_devices)
                if len(pl.devices) > keep_n:
                    kept = pl.devices[:keep_n]
                    self._running[name] = Placement(
                        name, kept, self._shares(self.jobs[name], kept),
                        pl.since_tick)
        free = self._free()
        while self._queue and free:
            name = self._queue[0]
            job = self.jobs[name]
            # fair split of the free pool across the whole queue — nobody
            # waits behind a head that grabbed everything
            want = min(job.max_devices,
                       max(job.min_devices, len(free) // len(self._queue)))
            want = min(want, len(free))
            if want < job.min_devices:
                break        # FIFO: the head waits, nobody bypasses it
            self._queue.pop(0)
            if name in self._snaps:
                job.resume(self._snaps.pop(name))
            devices = self._fastest(free, want)
            self._place(name, devices)
            free = [m for m in free if m not in set(devices)]
        # idle capacity + empty queue: grow running jobs (elastic DP up)
        if free and not self._queue:
            for name, pl in list(self._running.items()):
                job = self.jobs[name]
                room = job.max_devices - len(pl.devices)
                if room <= 0 or not free:
                    continue
                extra = self._fastest(free, min(room, len(free)))
                devices = pl.devices + extra
                self._running[name] = Placement(
                    name, devices, self._shares(job, devices), pl.since_tick)
                free = [m for m in free if m not in set(extra)]

    # -- the loop -------------------------------------------------------------

    @property
    def tick_index(self) -> int:
        return self._tick

    @property
    def quiescent(self) -> bool:
        """Nothing queued or running, and no future scripted events."""
        exhausted = (self.events is None
                     or getattr(self.events, "exhausted", True))
        return not self._queue and not self._running and exhausted

    def tick(self) -> TickRecord:
        """One step boundary: events → health → reconcile → preempt →
        schedule → one step per placed job."""
        rec = TickRecord(tick=self._tick)
        if self.events is not None:
            rec.events = self.events.poll(self._tick)
            for e in rec.events:
                self._apply(e)
        self.pool.heartbeat_all()
        rec.lost = self.pool.check_timeouts()
        self._reconcile()
        self._maybe_preempt(rec)
        self._schedule()
        for name in list(self._running):
            job, pl = self.jobs[name], self._running[name]
            placement = [
                (d, self.pool.jax_device(d) if self.pool.bind_devices else None, s)
                for d, s in zip(pl.devices, pl.shares)]
            rec.placements[name] = pl.devices
            rec.shares[name] = pl.shares
            event = job.run_step(placement)
            rec.steps[name] = event.loss
            if job.done:
                del self._running[name]
                self._log(f"[fleet] t{self._tick} {name}: done "
                          f"(final loss {event.loss:.4f})")
        rec.queued = list(self._queue)
        self.report.ticks.append(rec)
        advance = getattr(self.pool.clock, "advance", None)
        if advance is not None:
            advance(self.tick_dt)       # SimClock: virtual time, per tick
        self._tick += 1
        return rec

    def run(self, max_ticks: Optional[int] = None) -> FleetReport:
        """Tick until quiescent (or the tick budget runs out — queued
        jobs then simply stay queued; the property tests re-run after
        restoring capacity)."""
        limit = self.max_ticks if max_ticks is None else max_ticks
        for _ in range(limit):
            self.tick()
            if self.quiescent:
                break
        return self.report
