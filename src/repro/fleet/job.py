"""SessionJob — one fine-tuning job, driven step-by-step by the scheduler.

Wraps a single-device :class:`~repro.runtime.EdgeSession` (the fleet
distributes *chunks*, not the mesh — see
:class:`~repro.fleet.elastic.ElasticDpRunner`) and owns the job's
cursor: which epoch/step it is on, which member set it last ran on, and
the live :class:`~repro.core.activation_cache.CachePrefetcher`. The
scheduler pokes exactly three verbs:

* :meth:`run_step` — advance one training step under a placement.
  Epoch-1 (capture) steps run through ``session.step`` on the job's
  home device; cache-resident steps run elastically across the
  placement's members. A placement change closes + re-arms the
  prefetcher over the *remaining* epoch order and reshards the runner.
* :meth:`pause` — checkpointed preemption: snapshot adapter+optimizer
  (+ cursor) via the session's snapshot seam — to disk when the
  scheduler has a ``snapshot_dir``, so the state survives the process.
* :meth:`resume` — adopt a snapshot and rebuild the cursor; the epoch
  order is recomputed (it is a pure function of spec.seed and the epoch
  index), so resuming replays the exact remaining batches.

``plan_shares`` prices a placement's chunk split with the paper's
Eq. (4) dispatch (``plan_pure_dp`` over ``pac_cached`` period costs on
the members' speed-scaled profiles) — stragglers are deweighted by the
same math that sized the original pool.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.session import EdgeSession, StepEvent
from repro.runtime.spec import RunSpec, RunSpecError


class SessionJob:
    """One queued/running fine-tuning job on the fleet."""

    min_devices = 1

    def __init__(self, name: str, spec: RunSpec, *, chunk: int = 1,
                 hooks=(), log=None):
        if spec.total_devices != 1 or spec.plan_mode:
            raise RunSpecError(
                "fleet jobs run single-device sessions (dp=1, stages=1, no "
                "plan) — the fleet distributes cached-epoch chunks, not the "
                "mesh")
        if spec.batch % chunk:
            raise RunSpecError(
                f"batch {spec.batch} must be divisible by chunk={chunk}")
        self.name = name
        self.spec = spec
        self.chunk = chunk
        self.hooks = list(hooks)
        self.session = EdgeSession(spec, log=log)
        self.state = "queued"     # queued|running|preempted|done|rejected
        self.events: List[StepEvent] = []
        self.forward_steps = 0    # epoch-1 backbone forwards (capture)
        self.cached_steps = 0     # elastic cache-resident steps
        self.reshards = 0         # placement changes while running
        self._elastic = None
        self._epoch = 0
        self._index = 0
        self._order = None        # this epoch's remaining batch-id arrays
        self._pf = None
        self._members_sig: Optional[Tuple[str, ...]] = None
        self._costs = None

    # -- sizing (the scheduler's admission/pricing view) ----------------------

    @property
    def n_chunks(self) -> int:
        return self.spec.batch // self.chunk

    @property
    def max_devices(self) -> int:
        """A member below chunk granularity would idle — never spread one
        batch across more devices than it has chunks."""
        return self.n_chunks

    @property
    def done(self) -> bool:
        return self._epoch >= self.spec.epochs

    @property
    def losses(self) -> List[float]:
        return [e.loss for e in self.events]

    def plan_shares(self, profiles) -> Optional[List[int]]:
        """Eq. (4) chunk dispatch over the placement's (speed-scaled)
        profiles. ``None`` when the planner can't place (scheduler falls
        back to speed-weighted :func:`~repro.fleet.elastic.assign_chunks`)."""
        from repro.core.planner import plan_pure_dp

        if self._costs is None:
            from repro.launch.costs import resolve_cost_model

            self._costs = resolve_cost_model(
                False, micro_batch=self.chunk, quant_bits=self.spec.quant,
            ).period_costs(self.spec.arch_config(), "pac_cached",
                           seq_len=self.spec.seq)
        plan = plan_pure_dp(self._costs, list(profiles), self.n_chunks, 1)
        if plan is None:
            return None
        return [int(s) for s in plan.stages[0].samples_per_device]

    # -- lifecycle ------------------------------------------------------------

    def open(self) -> "SessionJob":
        if self.session.cfg is None:
            from repro.fleet.elastic import ElasticDpRunner

            s = self.session.open()
            self._elastic = ElasticDpRunner(
                s.backbone, s.cfg, r=self.spec.r, lr=self.spec.lr,
                kernel_impl=self.spec.kernels, chunk=self.chunk)
        return self

    def close(self) -> None:
        self._close_prefetcher()
        if self.session.cfg is not None:
            self.session.close()

    def finish(self) -> None:
        self._close_prefetcher()
        self.session.finish()

    def _close_prefetcher(self) -> None:
        if self._pf is not None:
            self._pf.close()
            self._pf = None

    def _arm_prefetcher(self) -> None:
        """Prefetch the *remaining* epoch order — called at epoch start
        and re-called after every reshard/resume mid-epoch."""
        from repro.core.activation_cache import CachePrefetcher

        s = self.session
        rest = self._order[self._index:]
        if (self.spec.use_cache and rest
                and s.cache.covers(np.concatenate(rest), with_final=True)):
            self._pf = CachePrefetcher(
                s.cache, rest, to_device=False, dtype=None,
                compressed=self.spec.kernels == "pallas")

    # -- the one verb the scheduler calls per tick ----------------------------

    def run_step(self, placement: Sequence[Tuple[str, object, int]]) -> StepEvent:
        """Advance one step on ``placement`` (``[(member, device, share),
        ...]``, shares summing to :attr:`n_chunks`). Mutates session
        adapter/opt; returns the :class:`StepEvent`."""
        self.open()
        s = self.session
        t0 = time.perf_counter()
        if self._order is None:
            self._order = s.pipe.epoch_order(self._epoch)
            self._close_prefetcher()
            self._arm_prefetcher()

        names = tuple(n for n, _, _ in placement)
        if names != self._members_sig:
            self._elastic.reshard([(n, d) for n, d, _ in placement])
            if self._members_sig is not None:
                # a live placement changed under us: the prefetcher's
                # remaining order is still valid, but close + re-arm so the
                # worker thread never straddles a reshard (the hang the
                # prefetcher-hardening test pins)
                self.reshards += 1
                if self._pf is not None:
                    self._close_prefetcher()
                    self._arm_prefetcher()
            self._members_sig = names
            for h in self.hooks:
                h.on_reshard(s, list(names))

        ids = self._order[self._index]
        if self._pf is not None:
            hit = next(self._pf)
        elif self.spec.use_cache:
            hit = s.cache.get_batch(ids, with_final=True, dtype=None,
                                    compressed=self.spec.kernels == "pallas")
        else:
            hit = None

        if hit is None:
            # capture path: the frozen forward runs on the job's home
            # device exactly as a solo run would — byte-identical cache
            event = s.step(s.corpus.batch(ids), epoch=self._epoch,
                           index=self._index)
            event.mode = f"fleet {event.mode}"
            self.forward_steps += 1
        else:
            b0, taps, bf = hit
            cached = {"b0": b0, "taps": taps, "b_final": bf,
                      "labels": s.corpus.batch(ids)["labels"]}
            loss, s.adapter, s.opt = self._elastic.step(
                s.adapter, s.opt, cached, placement)
            event = StepEvent(
                epoch=self._epoch, index=self._index, loss=loss,
                cache_hit=True, mode=f"elastic dp{len(placement)}",
                wall_s=time.perf_counter() - t0)
            self.cached_steps += 1
        self.events.append(event)
        for h in self.hooks:
            h.on_step(s, event)

        self._index += 1
        if self._index >= len(self._order):
            self._epoch += 1
            self._index = 0
            self._order = None
            self._close_prefetcher()
        if self.done:
            self.state = "done"
            self.finish()
        return event

    # -- checkpointed preemption ----------------------------------------------

    def pause(self, snapshot_dir: Optional[str] = None):
        """Yield the devices: close the prefetcher, snapshot adapter +
        optimizer + cursor. Returns the snapshot (a path when
        ``snapshot_dir`` is given — checkpointed through
        :mod:`repro.checkpoint`, surviving the process)."""
        self._close_prefetcher()
        self._members_sig = None        # force reshard on next placement
        self.state = "preempted"
        for h in self.hooks:
            h.on_preempt(self.session, False)
        extra = {"epoch": self._epoch, "index": self._index}
        if snapshot_dir is not None:
            os.makedirs(snapshot_dir, exist_ok=True)
            return self.session.save_snapshot(
                os.path.join(snapshot_dir, f"{self.name}.ckpt"), extra)
        return self.session.snapshot(extra)

    def resume(self, snap) -> None:
        """Adopt a :meth:`pause` snapshot (dict or path). The epoch order
        is a pure function of (seed, epoch), so the remaining batches
        replay exactly; restored trees round-trip bit-exactly."""
        self.open()
        if isinstance(snap, str):
            extra = self.session.restore_snapshot(snap)
        else:
            extra = self.session.restore(snap)
        self._epoch = int(extra["epoch"])
        self._index = int(extra["index"])
        self._order = None
        self.state = "queued"
        for h in self.hooks:
            h.on_preempt(self.session, True)
