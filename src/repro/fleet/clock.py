"""Deterministic time for the fleet layer.

Failure handling is time-based (heartbeat timeouts), but CI must replay
every failure path identically — so the scheduler never reads the wall
clock directly. It reads a :class:`Clock`, and the simulation harness
hands it a :class:`SimClock` advanced a fixed ``dt`` per scheduler tick:
a device that stops heartbeating at tick *k* is detected at exactly tick
``k + ceil(timeout / dt)``, on every machine, every run.
"""

from __future__ import annotations

import time


class Clock:
    """Readable time source (seconds, monotonic)."""

    def now(self) -> float:
        raise NotImplementedError


class WallClock(Clock):
    """Real deployments: monotonic wall time."""

    def now(self) -> float:
        return time.perf_counter()


class SimClock(Clock):
    """Virtual time, advanced explicitly — the simulation default."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance time backwards (dt={dt})")
        self._t += float(dt)
        return self._t
