"""ElasticDpRunner — the elastic pure-DP cached train step.

Cached epochs are pure data parallelism over activation-cache entries
(no backbone forward), so fleet membership changes are a *resharding*
problem: move work units between devices, replay nothing. The runner
makes resharding **numerically invisible** by construction:

* The work unit is a fixed-size **chunk** of the global batch (default
  one sequence). Each chunk produces its CE parts and the gradient of
  its CE numerator — ``(num_i, den_i, ∇num_i)`` — on whichever member
  device owns it this step.
* Results are accumulated on the host **in canonical chunk order**
  (0, 1, 2, …), never in device order. Float addition is performed in
  one fixed association, so *any* assignment of chunks to *any* member
  set yields bit-identical sums — the property the kill-mid-epoch
  simulation test asserts as exact float equality.
* The adapter update then runs once:
  ``loss = Σnum/Σden``, ``grads = Σ∇num/Σden`` (the denominator is the
  token count, independent of the adapter), followed by the same
  clip + AdamW the single-device cached step uses — the identical math
  of :func:`repro.core.steps.pac_cached_train_step`, reassociated at
  chunk granularity.

Contrast with :func:`repro.core.steps.dp_cached_train_step`: the
shard_map twin is the fast path for a *fixed* mesh (one jitted psum),
but its reduction tree follows the dp layout, so growing or shrinking
the mesh perturbs float sums. The fleet runner trades one host sync per
chunk for layout-independence — on an edge fleet the chunks are whole
sequences on devices linked by LAN, so the sync is not the bottleneck,
and determinism is what makes elastic membership *testable*.

Each member holds a **backbone replica** on its device (`device_put` at
placement time — growing onto a joined device ships weights, never
recomputes activations). The adapter is re-replicated every step (it
just changed); it is 1/r²-sized, the paper's asymmetry.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def assign_chunks(n_chunks: int, n_members: int,
                  weights: Optional[Sequence[float]] = None) -> List[int]:
    """Deterministic proportional chunk counts per member.

    Largest-remainder rounding of ``n_chunks · w_i/Σw`` with ties broken
    by member order — the planner-free fallback for chunk dispatch (the
    scheduler normally prices shares with Eq. (4) via
    :meth:`~repro.fleet.job.SessionJob.plan_shares`)."""
    if n_members < 1:
        raise ValueError("need at least one member")
    w = [1.0] * n_members if weights is None else [float(x) for x in weights]
    if len(w) != n_members or any(x < 0 for x in w) or sum(w) <= 0:
        raise ValueError(f"bad weights {w} for {n_members} members")
    total = sum(w)
    raw = [n_chunks * x / total for x in w]
    counts = [int(r) for r in raw]
    order = sorted(range(n_members), key=lambda i: (-(raw[i] - counts[i]), i))
    for i in order[: n_chunks - sum(counts)]:
        counts[i] += 1
    return counts


def slice_cached(cached: dict, lo: int, hi: int) -> dict:
    """Rows ``[lo, hi)`` of a cached training batch. ``taps`` carry the
    batch on axis 1 (``(n_p, B, S, d)``); every other entry (b0,
    b_final, labels — or their storage-form ``{"q", "scale"}`` pytrees)
    on axis 0. Works on host (numpy) and device arrays alike."""
    import jax

    out = {}
    for k, v in cached.items():
        if k == "taps":
            out[k] = jax.tree.map(lambda t: t[:, lo:hi], v)
        else:
            out[k] = jax.tree.map(lambda t: t[lo:hi], v)
    return out


def _chunk_parts(backbone, adapter, chunk, *, cfg, r, kernel_impl, interpret):
    """(num, den, ∇num) for one chunk — the jitted per-device unit."""
    import jax

    from repro.core.steps import _cached_positions
    from repro.kernels.cached_step import cached_loss_parts

    positions = _cached_positions(chunk, cfg)

    def parts(ap):
        num, den = cached_loss_parts(
            backbone, ap, cfg, chunk, positions, r,
            impl=kernel_impl, interpret=interpret,
        )
        return num, den

    (num, den), grad_num = jax.value_and_grad(parts, has_aux=True)(adapter)
    return num, den, grad_num


def _apply_update(adapter, opt_state, num, den, grad_sum, *, lr, clip):
    import jax
    import jax.numpy as jnp

    from repro.optim import adamw_update, clip_by_global_norm

    den = jnp.maximum(den, 1)
    loss = num / den
    grads = jax.tree.map(lambda g: g / den, grad_sum)
    grads, _ = clip_by_global_norm(grads, clip)
    adapter, opt_state = adamw_update(adapter, grads, opt_state, lr=lr)
    return loss, adapter, opt_state


class ElasticDpRunner:
    """Layout-independent cached steps for one job over a member subset.

    ``placement`` at each step is ``[(member, device_or_None, share),
    ...]`` — shares must sum to the batch's chunk count. ``device=None``
    runs the member's chunks on the default device (single-process
    tests/demos); numerics are identical either way.
    """

    def __init__(self, backbone, cfg, *, r: int = 8, lr=3e-3, clip=1.0,
                 kernel_impl: str = "ref", interpret=None, chunk: int = 1):
        import jax

        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.backbone = backbone
        self.cfg = cfg
        self.chunk = chunk
        self._chunk_fn = jax.jit(functools.partial(
            _chunk_parts, cfg=cfg, r=r, kernel_impl=kernel_impl,
            interpret=interpret))
        self._update_fn = jax.jit(functools.partial(
            _apply_update, lr=lr, clip=clip))
        self._replicas: Dict[str, object] = {}   # member -> backbone on its device
        self.n_reshards = 0

    # -- membership ---------------------------------------------------------

    def reshard(self, members: Sequence[Tuple[str, object]]) -> None:
        """Adopt a new member set: drop replicas of departed members,
        ship the backbone to joiners' devices (weights only — the cache
        already holds every activation, so growth does zero backbone
        forwards). Call between steps; the jitted chunk fn is reused."""
        import jax

        names = {n for n, _ in members}
        for n in list(self._replicas):
            if n not in names:
                del self._replicas[n]
        for n, dev in members:
            if dev is not None and n not in self._replicas:
                self._replicas[n] = jax.device_put(self.backbone, dev)
        self.n_reshards += 1

    def members(self) -> List[str]:
        return list(self._replicas)

    # -- the step -----------------------------------------------------------

    def n_chunks(self, batch_size: int) -> int:
        if batch_size % self.chunk:
            raise ValueError(
                f"batch {batch_size} not divisible into chunks of {self.chunk}")
        return batch_size // self.chunk

    def step(self, adapter, opt_state, cached: dict,
             placement: Sequence[Tuple[str, object, int]]):
        """One elastic cached step. Returns ``(loss, adapter, opt_state)``
        — bit-identical for any placement of the same batch."""
        import jax
        import jax.numpy as jnp

        n_chunks = self.n_chunks(cached["labels"].shape[0])
        shares = [int(s) for _, _, s in placement]
        if sum(shares) != n_chunks:
            raise ValueError(
                f"placement shares {shares} must cover {n_chunks} chunks")
        owners: List[Tuple[str, object]] = []
        for (name, dev, _), s in zip(placement, shares):
            owners.extend([(name, dev)] * s)

        # one adapter transfer per member device (it changed last step)
        local_adapter: Dict[str, object] = {}
        for name, dev, s in placement:
            if s and dev is not None:
                local_adapter[name] = jax.device_put(adapter, dev)

        num = np.float32(0.0)
        den = np.float32(0.0)
        grad_sum = None
        for ci in range(n_chunks):
            name, dev = owners[ci]
            piece = slice_cached(cached, ci * self.chunk, (ci + 1) * self.chunk)
            if dev is not None:
                piece = jax.device_put(piece, dev)
            bb = self._replicas.get(name, self.backbone)
            ap = local_adapter.get(name, adapter)
            # canonical-order host accumulation: the float sums associate
            # by chunk index, never by device layout — resharding cannot
            # perturb them
            n_i, d_i, g_i = jax.device_get(self._chunk_fn(bb, ap, piece))
            num = np.float32(num + n_i)
            den = np.float32(den + d_i)
            if grad_sum is None:
                grad_sum = g_i
            else:
                grad_sum = jax.tree.map(lambda a, b: np.add(a, b), grad_sum, g_i)

        loss, adapter, opt_state = self._update_fn(
            adapter, opt_state, jnp.asarray(num), jnp.asarray(den),
            jax.tree.map(jnp.asarray, grad_sum))
        return float(loss), adapter, opt_state
