"""Fleet scheduling: many fine-tuning jobs on one shared edge pool.

The paper evaluates one job on a dedicated, reliable pool. Production
(ROADMAP item 3; the federated fine-tuning survey, arXiv 2503.12016) is
N users' jobs arriving continuously on a shared, *flaky* fleet —
heterogeneous devices that join, leave, slow down, and die without
warning. This package builds the online layer on top of the existing
planner and runtime:

* :mod:`~repro.fleet.clock` — a deterministic simulation clock, so every
  failure path replays identically in CI (no wall-clock flakiness).
* :mod:`~repro.fleet.events` — :class:`FaultPlan`: scripted
  join/leave/slow/kill/submit events at step boundaries (seedable random
  plans for property tests), behind the :class:`PoolEvents` source
  protocol.
* :mod:`~repro.fleet.pool` — :class:`DevicePool`: fleet membership,
  heartbeats (a killed device stops heartbeating and is detected after a
  deterministic timeout), straggler speed factors, and the mapping from
  member names to JAX devices.
* :mod:`~repro.fleet.elastic` — :class:`ElasticDpRunner`: the elastic
  pure-DP cached train step. Cached epochs have no backbone forward, so
  device loss is a *resharding* problem: work moves between members with
  **bit-identical** numerics under any layout (canonical-order chunk
  accumulation — the property the kill-mid-epoch test pins exactly).
* :mod:`~repro.fleet.job` — :class:`SessionJob`: one fine-tuning job
  (an :class:`~repro.runtime.EdgeSession` driven step-by-step) with
  checkpointed preemption via the session's snapshot/restore seam.
* :mod:`~repro.fleet.scheduler` — :class:`FleetScheduler`: the job
  queue. Admission, planner-priced placement onto device subsets,
  re-planning on every membership change, quantum-based preemption so a
  full pool never starves the queue.

CLI: ``python -m repro.launch.fleet --simulate`` (docs/CLI.md).
"""

from repro.fleet.clock import SimClock
from repro.fleet.events import FaultPlan, FleetEvent, PoolEvents, ScriptedEvents
from repro.fleet.pool import DeviceMember, DevicePool
from repro.fleet.elastic import ElasticDpRunner, assign_chunks, slice_cached
from repro.fleet.job import SessionJob
from repro.fleet.scheduler import FleetReport, FleetScheduler, Placement, TickRecord

__all__ = [
    "SimClock",
    "FaultPlan",
    "FleetEvent",
    "PoolEvents",
    "ScriptedEvents",
    "DeviceMember",
    "DevicePool",
    "ElasticDpRunner",
    "assign_chunks",
    "slice_cached",
    "SessionJob",
    "FleetScheduler",
    "FleetReport",
    "Placement",
    "TickRecord",
]
