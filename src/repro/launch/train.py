"""End-to-end PAC+ trainer CLI.

Runs the paper's full workflow (Fig. 4): quantize → init adapters →
plan → epoch-1 (backbone fwd + adapter update, cache capture) →
epoch≥2 (cache hit, adapter-only). CPU-runnable with --reduced.

With ``--dp``/``--stages`` the trainer executes the planner's hybrid
parallelism on a real 2-D ``(dp, stage)`` device mesh (paper Fig. 10/11):
epoch-1 stages the frozen-backbone forward over the pipeline axis with
1F1B micro-batching and AllReduces the adapter grads across ``dp``; from
epoch 2 the warm activation cache drops the run to *pure* data
parallelism. On CPU the mesh is emulated with
``compat.force_host_device_count`` (dp·stages fake host devices) — the
same path CI exercises on every PR.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --epochs 3 --steps-per-epoch 8 --batch 4 --seq 32

    # hybrid DP×PP on an emulated 4-device mesh
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --dp 2 --stages 2 --epochs 3 --batch 4 --seq 32

With ``--plan`` the planner's Plan *is* the runtime contract (paper
§V-A, Alg. 1 — the point of the system): ``--plan auto`` runs Alg. 1 at
period granularity over a ``--pool``-sized device pool, and the winning
plan selects the stage count, the (possibly uneven) per-stage layer
boundaries, and the micro-batch count; the mesh is built from the plan
and the hybrid step executes those exact boundaries (ragged stages run
padded slabs with masked identity periods). ``--plan <file.json>``
replays a plan saved earlier with ``--save-plan`` (`Plan.to_json`
round-trip). ``--calibrate`` prices one real lowered period with the
trip-count-aware HLO cost model and feeds the measured ``LayerCost``s to
the planner instead of the analytic ones.

    # plan-driven: Alg. 1 chooses stages/boundaries/micro, trainer executes it
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --plan auto --pool 4 --epochs 3 --batch 4 --seq 32

    # save once, replay on the pool
    PYTHONPATH=src python -m repro.launch.train --reduced --plan auto \
        --save-plan plan.json && \
    PYTHONPATH=src python -m repro.launch.train --reduced --plan plan.json

With ``--cache-dir`` the activation cache persists across runs: the
first run captures (compressed per ``--cache-compress``) entries and
writes a manifest fingerprinting the backbone + corpus; a second run
against the same dir validates the manifest and performs **zero**
backbone forwards — every epoch, including the first, trains straight
from the cache. Any change to the backbone (seed, quantization), the
corpus, or the compression policy invalidates the cache loudly.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --cache-dir act_cache --cache-compress int8

With ``--kernels pallas`` the cached (epoch≥2) step runs the fused
Pallas fast path (`repro.kernels.cached_step`): cache entries reach the
step in their *storage* form (int8 payload + scales, bf16) and are
dequantised in VMEM inside the fused dequant×adapter kernel, and the
LM-head cross-entropy streams over vocab blocks so the (B,S,vocab)
logits are never materialised. Off-TPU the kernels run in interpreter
mode (bit-accurate, not fast) — the default ``--kernels ref`` is the
dense jnp oracle the Pallas path is tested against.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --cache-compress int8 --kernels pallas
"""

from __future__ import annotations

import argparse
import functools
import time

import numpy as np

from repro import compat

_EPILOG = """\
Full flag reference with one runnable example per flag: docs/CLI.md.
Module→paper map and the data-flow of an epoch-1 vs cached epoch:
docs/ARCHITECTURE.md.
"""


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, epilog=_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", help="CPU-scale variant")
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--steps-per-epoch", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--r", type=int, default=8, help="adapter reduction factor")
    ap.add_argument("--quant", type=int, default=None, choices=[4, 8])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--init", default="pruning", choices=["pruning", "random"])
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--cache-dir", default=None,
                    help="persist the activation cache here; a later run against "
                         "the same dir resumes warm (zero backbone forwards)")
    ap.add_argument("--cache-compress", default="f32", choices=["f32", "bf16", "int8"],
                    help="activation-cache entry compression policy")
    ap.add_argument("--cache-budget-mb", type=int, default=4096,
                    help="RAM budget for cache entries (compressed bytes)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dp", type=int, default=1, help="data-parallel mesh axis")
    ap.add_argument("--stages", type=int, default=1, help="pipeline stages (mesh axis)")
    ap.add_argument("--micro", type=int, default=None,
                    help="micro-batches per minibatch (default: --stages; a "
                         "replayed plan's micro count with --plan <file>; "
                         "swept and selected by the planner with --plan auto)")
    ap.add_argument("--plan", default=None,
                    help="'auto' (run Alg. 1 and execute its winning plan: "
                         "stage count, layer boundaries, micro count) or a "
                         "plan JSON saved with --save-plan")
    ap.add_argument("--pool", type=int, default=None,
                    help="device-pool size for --plan auto (default: "
                         "max(dp*stages, 4); the mesh uses dp*stages <= pool)")
    ap.add_argument("--save-plan", default=None,
                    help="write the executed plan as JSON for later replay")
    ap.add_argument("--calibrate", action="store_true",
                    help="price one lowered period with the HLO cost model "
                         "and plan from measured LayerCosts")
    ap.add_argument("--kernels", default="ref", choices=["ref", "pallas"],
                    help="cached-epoch compute path: 'ref' = dense jnp "
                         "oracle; 'pallas' = fused dequant×adapter + "
                         "blockwise-CE kernels (interpret mode off-TPU), "
                         "with compressed cache entries decompressed "
                         "on-device instead of on the host")
    args = ap.parse_args()

    plan_mode = args.plan is not None
    total = args.dp * args.stages
    pool = args.pool or max(total, 4)
    saved_plan = None
    if plan_mode and args.plan != "auto":
        # a saved plan knows its stage count, and Plan.load is pure JSON
        # (no JAX state) — load it now so the replay pool is sized before
        # the device-count knob locks
        from repro.core.planner import Plan as _Plan

        saved_plan = _Plan.load(args.plan)
        if args.pool is not None and args.pool < saved_plan.n_stages:
            raise SystemExit(
                f"--pool {args.pool} is smaller than the saved plan's "
                f"{saved_plan.n_stages} stages; pass --pool >= "
                f"{saved_plan.n_stages} or replan with --plan auto")
        pool = max(pool, saved_plan.n_stages)
    if plan_mode:
        # the plan decides dp×stages later, but the fake-device count must
        # precede the first backend initialisation — force the whole pool
        # (the mesh uses its first dp·stages devices)
        compat.force_host_device_count(pool)
    elif total > 1:
        # must precede the first JAX backend initialisation: on CPU this
        # fakes dp·stages host devices so the SPMD mesh is real
        compat.force_host_device_count(total)

    import jax  # noqa: E402 — after the device-count knob
    import jax.numpy as jnp

    from repro.checkpoint import save_checkpoint, tree_fingerprint
    from repro.configs import get_arch
    from repro.core import steps
    from repro.core.activation_cache import (
        ActivationCache,
        CachePrefetcher,
        open_persistent,
    )
    from repro.core.init_methods import pruning_init
    from repro.core.parallel_adapters import init_adapter
    from repro.core.planner import HybridParallelismPlanner, JETSON_NANO_H
    from repro.core.quantization import quantize_tree, tree_storage_bytes
    from repro.data import DataPipeline, SyntheticPersonalCorpus
    from repro.launch import sharding as shard
    from repro.launch.costs import resolve_cost_model
    from repro.launch.mesh import make_edge_mesh, make_plan_mesh
    from repro.models import backbone as bb
    from repro.optim import adamw_init

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"active≈{cfg.active_param_count()/1e6:.1f}M")

    def _build_plan(planner_mb, n_micro, max_stages):
        # one construction site for both the executed plan and the report:
        # period-granular costs (analytic or HLO-calibrated) through Alg. 1
        cost_model = resolve_cost_model(
            args.calibrate, micro_batch=max(1, args.batch // n_micro),
            quant_bits=args.quant)
        return HybridParallelismPlanner(
            cost_model.period_costs(cfg, "pac", seq_len=args.seq),
            [JETSON_NANO_H] * pool, planner_mb, n_micro,
        ).plan(max_stages=max_stages)

    partition = None
    exec_dp, exec_stages = args.dp, args.stages
    if plan_mode:
        # ---- plan-driven execution: the Plan is the runtime contract ----
        n_micro = args.micro or (saved_plan.micro_batches if saved_plan else None)
        if n_micro is not None and args.batch % n_micro:
            raise SystemExit(
                f"--batch {args.batch} must be divisible by the plan's "
                f"{n_micro} micro-batches (override with --micro)")
        if args.plan == "auto":
            smax = min(pool, cfg.n_periods)
            if n_micro is None:
                # the plan selects the micro count too: σ-optimal latency
                # over the batch's divisors
                cands = [m for m in range(1, args.batch + 1) if args.batch % m == 0]
                n_micro, plan = min(
                    ((m, _build_plan(args.batch // m, m, smax)) for m in cands),
                    key=lambda t: t[1].minibatch_latency)
            else:
                plan = _build_plan(args.batch // n_micro, n_micro, smax)
        else:
            if args.calibrate:
                print("note: --calibrate has no effect when replaying a "
                      "saved plan; re-run with --plan auto to replan")
            plan = saved_plan
        mb = args.batch // n_micro
        partition = plan.stage_partition()
        if partition.n_periods != cfg.n_periods:
            raise SystemExit(
                f"plan partitions {partition.n_periods} periods but "
                f"{cfg.name} has {cfg.n_periods} — replan for this arch")
        exec_stages = partition.n_stages
        # widest replica count the pool and the batch layout support
        exec_dp = max(1, pool // exec_stages)
        while exec_dp > 1 and (args.batch // n_micro) % exec_dp:
            exec_dp -= 1
        print("plan:", plan.describe())
        for s, split in enumerate(partition.samples_per_device):
            if sum(split) != mb:
                print(f"note: stage {s} was planned for {sum(split)} samples "
                      f"per micro-batch, executing {mb}")
        total = exec_dp * exec_stages
    distributed = total > 1
    # default micro count: the plan's when plan-driven, the mesh's stage
    # count when distributed; the pre-existing 4-micro planning report otherwise
    if not plan_mode:
        n_micro = args.micro if args.micro is not None else (
            args.stages if distributed else 4)
    if distributed:
        if partition is None and cfg.n_periods % exec_stages:
            raise SystemExit(
                f"--stages {exec_stages} must divide n_periods={cfg.n_periods}")
        # fail fast on an impossible batch layout, before any compute
        DataPipeline.dp_microbatches(
            {"tokens": np.zeros((args.batch, args.seq), np.int32)}, n_micro, exec_dp)

    bp = bb.init_backbone(jax.random.PRNGKey(args.seed), cfg)
    if args.quant:
        bq = quantize_tree(bp, bits=args.quant)
        print(f"backbone quantized INT{args.quant}: "
              f"{tree_storage_bytes(bp)/2**20:.1f} MB → {tree_storage_bytes(bq)/2**20:.1f} MB")
    else:
        bq = bp
    if args.init == "pruning":
        adapter = pruning_init(jax.random.PRNGKey(args.seed + 1), bp, cfg, r=args.r)
    else:
        adapter = init_adapter(jax.random.PRNGKey(args.seed + 1), cfg, r=args.r)
    n_train = sum(x.size for x in jax.tree.leaves(adapter))
    print(f"trainable (adapter) params: {n_train/1e6:.2f}M "
          f"({n_train/cfg.param_count():.2%} of backbone)")
    opt = adamw_init(adapter)

    if not plan_mode:
        # offline planning report (paper Step 3-4): the plan is computed
        # for the executed micro-batch count at period granularity; the
        # stage count is CLI-pinned to the mesh shape and the planner's
        # σ-optimum is reported against it. (--plan makes this plan the
        # execution contract instead of a report.)
        plan = _build_plan(args.batch, n_micro,
                           args.stages if distributed else None)
        print("edge-pool plan:", plan.describe().splitlines()[0])
        if distributed and plan.n_stages != args.stages:
            print(f"note: planner's σ-optimal stage count is {plan.n_stages}; "
                  f"executing --stages {args.stages} (pass --plan auto to "
                  f"execute the σ-optimum)")
    if args.save_plan:
        print(f"plan saved: {plan.save(args.save_plan)}")

    mesh = None
    if distributed:
        if plan_mode:
            mesh = make_plan_mesh(partition, dp=exec_dp)
            ragged = "" if partition.is_uniform else (
                f", ragged periods {partition.periods_per_stage}")
            print(f"mesh: plan-driven dp={exec_dp}×pp={exec_stages} on "
                  f"{total} devices, {n_micro} micro-batches{ragged}")
        else:
            mesh = make_edge_mesh(exec_dp, exec_stages)
            print(f"mesh: hybrid dp={exec_dp}×pp={exec_stages} on "
                  f"{total} devices, {n_micro} micro-batches")

    n_seq = args.steps_per_epoch * args.batch
    corpus = SyntheticPersonalCorpus(cfg.vocab, args.seq + 1, n_seq, seed=args.seed)
    pipe = DataPipeline(corpus, global_batch=args.batch, shuffle=True, seed=args.seed)

    # activation cache v2: compressed entries (b0 + taps + b_final folded
    # into one budgeted entry), optionally persistent across runs
    cache_budget = args.cache_budget_mb << 20
    meta = None
    if args.cache_dir and not args.no_cache:
        # the manifest identity: any change to the backbone weights (seed,
        # quantization), the corpus, or the shapes invalidates the cache
        meta = {
            "arch": cfg.name,
            "reduced": bool(args.reduced),
            "seq": args.seq,
            "quant": args.quant or 0,
            "backbone": tree_fingerprint(bq),
            "corpus": tree_fingerprint(corpus.tokens),
        }
        cache, warm = open_persistent(
            args.cache_dir, meta, budget_bytes=cache_budget,
            compress=args.cache_compress)
        if warm:
            print(f"activation cache: warm manifest at {args.cache_dir} "
                  f"({len(cache)} seqs, {args.cache_compress}) — cached epochs "
                  f"skip the backbone forward entirely")
    else:
        cache = ActivationCache(budget_bytes=cache_budget,
                                compress=args.cache_compress)

    # compressed handoff: with the Pallas kernels the cache skips host-side
    # decompression — int8 entries ship as {"q", "scale"} payloads and are
    # dequantised in VMEM inside the fused cached step
    use_pallas = args.kernels == "pallas"
    step1 = jax.jit(functools.partial(steps.pac_train_step, cfg=cfg, r=args.r, lr=args.lr))
    # donate (adapter, opt) — the cached step returns them updated, so the
    # old buffers can be reused in place every step of a cached epoch
    stepN = jax.jit(
        functools.partial(steps.pac_cached_train_step, cfg=cfg, r=args.r,
                          lr=args.lr, kernel_impl=args.kernels),
        donate_argnums=(1, 2))
    if distributed:
        # epoch-1: staged backbone forward over `stage` + dp AllReduce
        step1 = jax.jit(functools.partial(
            steps.pipeline_pac_train_step, cfg=cfg, mesh=mesh, n_micro=n_micro,
            r=args.r, lr=args.lr, partition=partition))
        stepN = None  # built on first cached batch (needs its tree structure)

    for epoch in range(args.epochs):
        t0 = time.time()
        losses = []
        used_cache = False
        prefetch = None
        if not args.no_cache:
            order = pipe.epoch_order(epoch)
            if order and cache.covers(np.concatenate(order), with_final=True):
                # the whole epoch is resident: a background thread
                # decompresses/loads batch k+1 (and starts its
                # host→device copy) while step k runs
                prefetch = CachePrefetcher(
                    cache, order, to_device=not distributed, dtype=None,
                    compressed=use_pallas)
        for batch in pipe.epoch(epoch):
            ids = batch.pop("seq_ids")
            if prefetch is not None:
                hit = next(prefetch)
            elif args.no_cache:
                hit = None
            else:
                hit = cache.get_batch(ids, with_final=True, dtype=None,
                                      compressed=use_pallas)
            if hit is None:
                loss, adapter, opt, (b0, taps, bf) = step1(bq, adapter, opt, batch)
                if not args.no_cache:
                    cache.put_batch(ids, b0, taps, bf)
            else:
                used_cache = True
                b0, taps, bf = (jax.tree.map(jnp.asarray, h) for h in hit)
                cached = {
                    "b0": b0,
                    "taps": taps,
                    "b_final": bf,
                    "labels": batch["labels"],
                }
                if stepN is None:  # epoch≥2 distributed: *pure* DP over the mesh
                    if use_pallas:
                        # GSPMD cannot repartition pallas_call — the DP
                        # twin shard_maps the fused step over the pool
                        stepN = jax.jit(
                            functools.partial(
                                steps.dp_cached_train_step, cfg=cfg,
                                mesh=mesh, r=args.r, lr=args.lr,
                                kernel_impl="pallas",
                                batch_axes=shard.cached_batch_axes(
                                    cached, mesh)),
                            donate_argnums=(1, 2))
                    else:
                        stepN = jax.jit(
                            functools.partial(steps.pac_cached_train_step,
                                              cfg=cfg, r=args.r, lr=args.lr),
                            in_shardings=shard.cached_step_shardings(
                                bq, adapter, opt, cached, mesh),
                            donate_argnums=(1, 2))
                loss, adapter, opt = stepN(bq, adapter, opt, cached)
            losses.append(float(loss))
        dt = time.time() - t0
        if used_cache:
            mode = "cached pure-dp" if distributed else "cached"
        elif distributed:
            kind = "plan-driven" if plan_mode else "hybrid"
            mode = f"{kind} dp{exec_dp}xpp{exec_stages}"
        else:
            mode = "full"
        print(f"epoch {epoch}: loss={np.mean(losses):.4f} time={dt:.1f}s ({mode}) "
              f"cache[{len(cache)} seqs, {cache.nbytes/2**20:.0f} MB, "
              f"{args.cache_compress}]")

    if args.ckpt:
        n = save_checkpoint(args.ckpt, {"adapter": adapter, "config": cfg.name})
        print(f"checkpoint: {args.ckpt} ({n/2**20:.1f} MB)")
    if meta is not None:
        path = cache.save_manifest(meta)
        print(f"cache manifest: {path} ({len(cache)} seqs, {args.cache_compress})")
    else:
        cache.clear()


if __name__ == "__main__":
    main()
