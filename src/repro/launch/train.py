"""End-to-end PAC+ trainer CLI.

Runs the paper's full workflow (Fig. 4): quantize → init adapters →
plan → epoch-1 (backbone fwd + adapter update, cache capture) →
epoch≥2 (cache hit, adapter-only). CPU-runnable with --reduced.

The flags here are a thin veneer over :class:`repro.runtime.RunSpec` —
``main()`` is exactly flags → RunSpec → ``EdgeSession.run()``. All run
logic (device pool, plan resolution, mesh, cache wiring, the epoch
loop and its step dispatch) lives in :mod:`repro.runtime`; use that API
directly to embed a run programmatically (see docs/ARCHITECTURE.md,
"The runtime layer").

With ``--dp``/``--stages`` the trainer executes the planner's hybrid
parallelism on a real 2-D ``(dp, stage)`` device mesh (paper Fig. 10/11):
epoch-1 stages the frozen-backbone forward over the pipeline axis with
1F1B micro-batching and AllReduces the adapter grads across ``dp``; from
epoch 2 the warm activation cache drops the run to *pure* data
parallelism. On CPU the mesh is emulated with
``compat.force_host_device_count`` (dp·stages fake host devices) — the
same path CI exercises on every PR.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --epochs 3 --steps-per-epoch 8 --batch 4 --seq 32

    # hybrid DP×PP on an emulated 4-device mesh
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --dp 2 --stages 2 --epochs 3 --batch 4 --seq 32

With ``--plan`` the planner's Plan *is* the runtime contract (paper
§V-A, Alg. 1 — the point of the system): ``--plan auto`` runs Alg. 1 at
period granularity over a ``--pool``-sized device pool, and the winning
plan selects the stage count, the (possibly uneven) per-stage layer
boundaries, and the micro-batch count; the mesh is built from the plan
and the hybrid step executes those exact boundaries (ragged stages run
padded slabs with masked identity periods). ``--plan <file.json>``
replays a plan saved earlier with ``--save-plan`` (`Plan.to_json`
round-trip). ``--calibrate`` prices one real lowered period with the
trip-count-aware HLO cost model and feeds the measured ``LayerCost``s to
the planner instead of the analytic ones.

    # plan-driven: Alg. 1 chooses stages/boundaries/micro, trainer executes it
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --plan auto --pool 4 --epochs 3 --batch 4 --seq 32

    # save once, replay on the pool
    PYTHONPATH=src python -m repro.launch.train --reduced --plan auto \
        --save-plan plan.json && \
    PYTHONPATH=src python -m repro.launch.train --reduced --plan plan.json

With ``--cache-dir`` the activation cache persists across runs: the
first run captures (compressed per ``--cache-compress``) entries and
writes a manifest fingerprinting the backbone + corpus; a second run
against the same dir validates the manifest and performs **zero**
backbone forwards — every epoch, including the first, trains straight
from the cache. Any change to the backbone (seed, quantization), the
corpus, or the compression policy invalidates the cache loudly.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --cache-dir act_cache --cache-compress int8

With ``--kernels pallas`` the whole run leaves the dense-jnp path — the
flag selects the OpSet (`repro.core.opset`) every step dispatches
through. Epoch 1's frozen forward runs on still-quantized block params
(`quant_matmul` dequantises INT8/INT4 weights in VMEM) with Pallas flash
attention, and its taps are quantized *at the tap site* into the cache's
storage form (``--cache-compress``) — no f32 HBM round-trip before
``put_batch``. The cached (epoch≥2) step runs the fused Pallas fast path
(`repro.kernels.cached_step`): entries reach the step as int8 payload +
scales / bf16 and dequantise in VMEM, and the LM-head cross-entropy
streams over vocab blocks so the (B,S,vocab) logits are never
materialised. Off-TPU the kernels run in interpreter mode (bit-accurate,
not fast) — the default ``--kernels ref`` is the dense jnp oracle the
Pallas path is tested against.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --quant 8 --cache-compress int8 --kernels pallas
"""

from __future__ import annotations

import argparse

from repro.runtime import ConsoleHook, EdgeSession, RunSpec, RunSpecError

_EPILOG = """\
Full flag reference with one runnable example per flag: docs/CLI.md.
Module→paper map and the data-flow of an epoch-1 vs cached epoch:
docs/ARCHITECTURE.md. Programmatic API (RunSpec → EdgeSession →
EpochRunner): the "runtime layer" section of docs/ARCHITECTURE.md.
"""


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, epilog=_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", help="CPU-scale variant")
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--steps-per-epoch", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--r", type=int, default=8, help="adapter reduction factor")
    ap.add_argument("--quant", type=int, default=None, choices=[4, 8])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--init", default="pruning", choices=["pruning", "random"])
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--cache-dir", default=None,
                    help="persist the activation cache here; a later run against "
                         "the same dir resumes warm (zero backbone forwards)")
    ap.add_argument("--cache-compress", default="f32", choices=["f32", "bf16", "int8"],
                    help="activation-cache entry compression policy")
    ap.add_argument("--cache-budget-mb", type=int, default=4096,
                    help="RAM budget for cache entries (compressed bytes)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dp", type=int, default=1, help="data-parallel mesh axis")
    ap.add_argument("--stages", type=int, default=1, help="pipeline stages (mesh axis)")
    ap.add_argument("--micro", type=int, default=None,
                    help="micro-batches per minibatch (default: --stages; a "
                         "replayed plan's micro count with --plan <file>; "
                         "swept and selected by the planner with --plan auto)")
    ap.add_argument("--plan", default=None,
                    help="'auto' (run Alg. 1 and execute its winning plan: "
                         "stage count, layer boundaries, micro count) or a "
                         "plan JSON saved with --save-plan")
    ap.add_argument("--pool", type=int, default=None,
                    help="device-pool size for --plan auto (default: "
                         "max(dp*stages, 4); the mesh uses dp*stages <= pool)")
    ap.add_argument("--save-plan", default=None,
                    help="write the executed plan as JSON for later replay")
    ap.add_argument("--calibrate", action="store_true",
                    help="price one lowered period with the HLO cost model "
                         "and plan from measured LayerCosts")
    ap.add_argument("--kernels", default="ref", choices=["ref", "pallas"],
                    help="compute path for epoch 1 AND the cached epochs: "
                         "'ref' = dense jnp oracle; 'pallas' = OpSet "
                         "dispatch to quant_matmul/flash-attention on the "
                         "epoch-1 frozen forward (taps emitted in cache "
                         "storage form) plus the fused dequant×adapter + "
                         "blockwise-CE cached step (interpret mode off-TPU)")
    args = ap.parse_args()

    try:
        spec = RunSpec.from_args(args)
        EdgeSession(spec, log=print).run(hooks=(ConsoleHook(),))
    except RunSpecError as e:
        raise SystemExit(str(e))


if __name__ == "__main__":
    main()
