"""Trip-count-aware HLO cost model.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body
**once**, which silently hides ~n_layers× of the FLOPs/bytes for any
scan-over-layers model (verified in tests). Since the whole framework
leans on ``jax.lax.scan`` for compile-time sanity on 512-device meshes,
the roofline needs its own cost model. This module parses the
post-optimization (per-device, post-SPMD) HLO text and computes:

* **flops** — ``dot``s (2·|result|·|contracted|), convolutions
  (approximate), and 1 FLOP/element for elementwise fusion outputs;
* **bytes** — operand+result bytes of top-level instructions at fusion
  granularity (the XLA accounting), with two fidelity fixes: fusions that
  only ``dynamic-slice`` a parameter are charged the slice (not the whole
  buffer — critical for scans over stacked layer weights), and ``gather``
  is charged 2×result (embedding lookups don't stream the whole table);
* **collectives** — per-category bytes (output-shape based), with
  all-reduce weighted 2× for its ring cost;

…each multiplied by the enclosing ``while`` trip counts (read from
``backend_config.known_trip_count``, falling back to the loop-condition
constant).
"""

from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u2": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(")
_OPCODE_RE = re.compile(r"\s([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_DSS_RE = re.compile(r"dynamic_slice_sizes=\{([0-9,]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)


def _shapes_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape(segment: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(segment)
    if not m:
        return None
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",") if d]


@dataclass
class Instruction:
    name: str
    opcode: str
    result_segment: str  # text between '=' and opcode
    line: str

    @property
    def result_bytes(self) -> int:
        return _shapes_bytes(self.result_segment)


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> result segment


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)
    collective_count: int = 0

    def __add__(self, o: "Cost") -> "Cost":
        c = {k: self.collectives.get(k, 0) + o.collectives.get(k, 0)
             for k in set(self.collectives) | set(o.collectives)}
        return Cost(self.flops + o.flops, self.bytes + o.bytes, c,
                    self.collective_count + o.collective_count)

    def __mul__(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    {n: v * k for n, v in self.collectives.items()},
                    int(self.collective_count * k))

    @property
    def collective_bytes(self) -> float:
        """Ring-weighted total (all-reduce ×2)."""
        return sum(v * (2.0 if k == "all-reduce" else 1.0) for k, v in self.collectives.items())


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and _COMP_HEADER_RE.match(stripped):
                m = _COMP_HEADER_RE.match(stripped)
                cur = Computation(m.group(2))
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        if not stripped.startswith("%") and not stripped.startswith("ROOT"):
            continue
        body = stripped[5:].strip() if stripped.startswith("ROOT") else stripped
        if "=" not in body:
            continue
        lhs, rhs = body.split(" = ", 1)
        name = lhs.strip().lstrip("%")
        m = _OPCODE_RE.search(" " + rhs)
        if not m:
            continue
        opcode = m.group(1)
        result_segment = rhs[: m.start()]
        cur.symbols[name] = result_segment
        cur.instructions.append(Instruction(name, opcode, result_segment, body))
    return comps


_SKIP_BYTES = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast", "while",
    "conditional", "call", "after-all", "add-dependency", "copy-start", "copy-done",
    "partition-id", "replica-id", "iota",
}


def _dot_flops(inst: Instruction, comp: Computation) -> float:
    result = _first_shape(inst.result_segment)
    if result is None:
        return 0.0
    _, rdims = result
    ops = _OPERAND_RE.findall(inst.line.split("(", 1)[1])
    lhs_seg = comp.symbols.get(ops[0], "") if ops else ""
    lhs = _first_shape(lhs_seg)
    cm = _CONTRACT_RE.search(inst.line)
    contracted = 1
    if lhs and cm and cm.group(1):
        for c in cm.group(1).split(","):
            ci = int(c)
            if ci < len(lhs[1]):
                contracted *= lhs[1][ci]
    return 2.0 * math.prod(rdims) * contracted if rdims else 2.0 * contracted


def _fusion_operand_bytes(inst: Instruction, comp: Computation, comps) -> float:
    """Operand bytes for a fusion: parameters that are only dynamic-sliced
    are charged at slice size."""
    called = None
    m = _CALLS_RE.search(inst.line)
    if m:
        called = comps.get(m.group(1))
    ops = _OPERAND_RE.findall(inst.line.split("(", 1)[1])
    # map fusion parameter index -> "slice-only" bytes if applicable
    slice_bytes: Dict[int, float] = {}
    if called is not None:
        param_names = {}
        for ci in called.instructions:
            pm = re.search(r"parameter\((\d+)\)", ci.line)
            if ci.opcode == "parameter" and pm:
                param_names[ci.name] = int(pm.group(1))
        usage: Dict[int, List[str]] = {}
        for ci in called.instructions:
            if ci.opcode == "parameter":
                continue
            for ref in _OPERAND_RE.findall(ci.line.split("(", 1)[1] if "(" in ci.line else ""):
                if ref in param_names:
                    usage.setdefault(param_names[ref], []).append(ci.opcode)
        for idx, users in usage.items():
            if users and all(
                u in ("dynamic-slice", "gather", "bitcast", "reshape") for u in users
            ):
                # charge the slice/gather result, not the whole buffer
                for ci in called.instructions:
                    if ci.opcode in ("dynamic-slice", "gather"):
                        res = _first_shape(ci.result_segment)
                        if res:
                            dt, dims = res
                            slice_bytes[idx] = math.prod(dims or [1]) * _DTYPE_BYTES.get(dt, 4)
    total = 0.0
    for i, op in enumerate(ops):
        seg = comp.symbols.get(op)
        if seg is None:
            continue
        if i in slice_bytes:
            total += slice_bytes[i]
        else:
            total += _shapes_bytes(seg)
    return total


def _while_trip(inst: Instruction, comps) -> int:
    m = _TRIP_RE.search(inst.line)
    if m:
        return int(m.group(1))
    cm = _COND_RE.search(inst.line)
    if cm and cm.group(1) in comps:
        for ci in comps[cm.group(1)].instructions:
            k = re.search(r"constant\((\d+)\)", ci.line)
            if k:
                return int(k.group(1))
    return 1


def _comp_cost(comp: Computation, comps, memo) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    total = Cost()
    for inst in comp.instructions:
        op = inst.opcode
        if op == "while":
            bm = _BODY_RE.search(inst.line)
            cnd = _COND_RE.search(inst.line)
            trip = _while_trip(inst, comps)
            if bm and bm.group(1) in comps:
                total = total + _comp_cost(comps[bm.group(1)], comps, memo) * trip
            if cnd and cnd.group(1) in comps:
                total = total + _comp_cost(comps[cnd.group(1)], comps, memo) * trip
            continue
        if op in ("call", "conditional", "async-start"):
            for cname in _CALLS_RE.findall(inst.line) + re.findall(
                r"(?:branch_computations|to_apply)=\{?%?([\w.\-]+)", inst.line
            ):
                if cname in comps:
                    total = total + _comp_cost(comps[cname], comps, memo)
            continue
        if op == "fusion":
            m = _CALLS_RE.search(inst.line)
            called = comps.get(m.group(1)) if m else None
            fl = 0.0
            if called is not None:
                for ci in called.instructions:
                    if ci.opcode in ("dot", "convolution"):
                        fl += _dot_flops(ci, called)
                    elif ci.opcode not in _SKIP_BYTES:
                        res = _first_shape(ci.result_segment)
                        if res:
                            fl += math.prod(res[1] or [1])
            total.flops += fl
            # In-place dynamic-update-slice fusions (scan stacking, KV
            # cache append): XLA aliases input/output buffers, so the
            # real HBM traffic is the updated slice (read update + write
            # region), not the whole accumulator. Without this, a
            # chunked-scan backward is overcounted ~chunk× (measured 26 TB
            # phantom bytes on jamba×train_4k).
            if called is not None and called.instructions and (
                called.instructions[-1].opcode == "dynamic-update-slice"
            ):
                ops_ = _OPERAND_RE.findall(inst.line.split("(", 1)[1])
                small = sum(
                    b for b in (
                        _shapes_bytes(comp.symbols.get(o, "")) for o in ops_
                    ) if b < inst.result_bytes
                )
                total.bytes += 2.0 * small
                continue
            total.bytes += inst.result_bytes + _fusion_operand_bytes(inst, comp, comps)
            continue
        if op in COLLECTIVE_OPS or any(op == c + "-start" for c in COLLECTIVE_OPS):
            base = op.replace("-start", "")
            b = float(inst.result_bytes)
            total.collectives[base] = total.collectives.get(base, 0.0) + b
            total.collective_count += 1
            total.bytes += b
            continue
        if op in _SKIP_BYTES or op.endswith("-done"):
            continue
        if op in ("dot", "convolution"):
            total.flops += _dot_flops(inst, comp)
        elif op == "gather":
            total.bytes += 2.0 * inst.result_bytes
            continue
        else:
            res = _first_shape(inst.result_segment)
            if res:
                total.flops += math.prod(res[1] or [1])
        # bytes: operands + result
        opnds = _OPERAND_RE.findall(inst.line.split("(", 1)[1] if "(" in inst.line else "")
        total.bytes += inst.result_bytes + sum(
            _shapes_bytes(comp.symbols.get(o, "")) for o in opnds
        )
    memo[comp.name] = total
    return total


def analyze_compiled(compiled) -> Cost:
    """Price a compiled (post-SPMD, per-device) jax computation.

    The one entry point the roofline, the benchmarks, and the calibrated
    planner cost model share (see ``repro.launch.costs``)."""
    return analyze_hlo(compiled.as_text())


def analyze_hlo(text: str) -> Cost:
    comps = parse_module(text)
    entry = None
    for raw in text.splitlines():
        s = raw.strip()
        if s.startswith("ENTRY"):
            m = _COMP_HEADER_RE.match(s)
            if m:
                entry = m.group(2)
            break
    if entry is None or entry not in comps:
        # fall back: last computation
        entry = list(comps)[-1]
    return _comp_cost(comps[entry], comps, {})
