"""Three-term roofline analysis from compiled dry-run artifacts.

Per (arch × shape × mesh) cell we derive, from the *per-device* SPMD
module (so every term is already per-chip — consistent with the
assignment's "÷ chips" normalisation):

    compute    = HLO_FLOPs(per-device)        / PEAK_FLOPS_BF16
    memory     = HLO_bytes_accessed(per-dev)  / HBM_BW
    collective = Σ collective op bytes        / ICI_BW

``cost_analysis()`` provides FLOPs and bytes; collective bytes are parsed
from the optimized HLO text (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute), weighted by a ring-cost factor for
all-reduce (2×). The dominant term is the bottleneck the §Perf loop
iterates on; we also report MODEL_FLOPS = 6·N_active·D (train) or
2·N_active·D (inference) and the useful-compute ratio.
"""

from __future__ import annotations

import math
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:[0-9]+)?)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes of every collective op in the (per-device) module."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # match "<result-shape> <op-name>(" — the op defining line
        for op in _COLLECTIVES:
            # e.g.:  %all-reduce.1 = f32[128,256]{1,0} all-reduce(...)
            if f" {op}(" in stripped or f" {op}-start(" in stripped:
                m = _SHAPE_RE.findall(stripped.split("=", 1)[-1])
                if m:
                    # first shape after '=' is the result
                    dtype, dims = m[0]
                    # tuple results (e.g. all-reduce-start) list several; sum result side
                    out[op] += _shape_bytes(dtype, dims)
                    counts[op] += 1
                break
    out["_counts"] = counts  # type: ignore
    return out


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    technique: str
    note: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops_total: float
    useful_compute_ratio: float
    collective_breakdown: Dict[str, float] = field(default_factory=dict)
    memory_analysis: str = ""

    def as_dict(self) -> dict:
        return asdict(self)


def analyze(
    compiled,
    *,
    arch: str,
    shape,
    mesh,
    technique: str,
    note: str = "",
    n_active_params: float = 0.0,
    n_adapter_params: float = 0.0,
) -> RooflineTerms:
    # trip-count-aware cost model (XLA's cost_analysis counts scan bodies
    # once — see launch/hlo_cost.py); numbers are per-device (post-SPMD
    # HLO). Shared entry point with the planner's calibrated cost model
    # (launch/costs.py) and the benchmarks.
    from repro.launch.costs import price_lowered

    cost = price_lowered(compiled)
    flops = cost.flops
    byts = cost.bytes
    coll = {k: cost.collectives.get(k, 0.0) for k in _COLLECTIVES}
    counts = {"n_total": cost.collective_count}
    coll_weighted = cost.collective_bytes

    t_comp = flops / PEAK_FLOPS_BF16
    t_mem = byts / HBM_BW
    t_coll = coll_weighted / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)

    # MODEL_FLOPS, technique-aware: PAC+ pays 2·N·D backbone forward +
    # 6·N_a·D side network (no backbone backward — the paper's savings);
    # the cached variant drops the backbone forward entirely.
    n_chips = math.prod(mesh.devices.shape)
    B, S = shape.global_batch, shape.seq_len
    if shape.mode == "train":
        D = B * S
        if technique == "pac":
            mf = 2.0 * n_active_params * D + 6.0 * n_adapter_params * D
        elif technique == "pac_cached":
            mf = 6.0 * n_adapter_params * D
        else:  # full / lora / adapters: full backward through the backbone
            mf = 6.0 * n_active_params * D
    elif shape.mode == "prefill":
        mf = 2.0 * n_active_params * B * S
    else:
        mf = 2.0 * n_active_params * B  # one token per sequence
    ratio = mf / (flops * n_chips) if flops else 0.0

    try:
        mem_an = str(compiled.memory_analysis())
    except Exception as e:  # pragma: no cover
        mem_an = f"unavailable: {e}"

    return RooflineTerms(
        arch=arch,
        shape=shape.name,
        mesh="x".join(map(str, mesh.devices.shape)),
        technique=technique,
        note=note,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=coll_weighted,
        t_compute=t_comp,
        t_memory=t_mem,
        t_collective=t_coll,
        bottleneck=bottleneck,
        model_flops_total=mf,
        useful_compute_ratio=ratio,
        collective_breakdown={**coll, **{f"n_{k}": v for k, v in counts.items()}},
        memory_analysis=mem_an,
    )


def format_row(t: RooflineTerms) -> str:
    return (
        f"{t.arch:24s} {t.shape:12s} {t.mesh:8s} {t.technique:10s} {t.note:6s} "
        f"comp={t.t_compute * 1e3:9.3f}ms mem={t.t_memory * 1e3:9.3f}ms "
        f"coll={t.t_collective * 1e3:9.3f}ms -> {t.bottleneck:10s} "
        f"useful={t.useful_compute_ratio * 100:6.2f}%"
    )
