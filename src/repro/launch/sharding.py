"""Sharding rules: parameter/activation pytrees → PartitionSpecs.

Strategy (DESIGN.md §5): FSDP-style weight sharding over the ``data``
(+``pod``) axes on d_model-like dims, tensor/expert parallelism over
``model`` on head/FFN/expert/vocab dims. Rules are *divisibility-guarded*:
a mesh axis is only applied to a dim it divides evenly, so one rule set
covers every architecture and the reduced smoke configs alike.

PAC+ specifics: the frozen backbone is sharded identically whether its
leaves are f32 arrays or :class:`QTensor`s (the int payload keeps the
original dim structure; per-block scales inherit the spec with the last
dim replicated — they are tiny).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.psharding import (
    FSDP,
    TP,
    logical_for_param as _logical_for_param,
    path_names as _path_names,
    resolve as _presolve,
)
from repro.core.quantization import QTensor
from repro.launch.mesh import data_axes

DP = "dp"  # batch dim -> ("pod","data")
SEQ = "seq"  # sequence dim (decode caches) -> "model"


def param_specs(params, mesh: Mesh):
    """PartitionSpec pytree for a parameter tree (QTensor-aware)."""

    def spec_for(path, leaf):
        names = _path_names(path)
        if isinstance(leaf, QTensor):
            logical = _logical_for_param(names, leaf.q.ndim)
            q_spec = _presolve(logical, leaf.q.shape, mesh)
            # scales: same leading layout, replicated block dim
            s_logical = logical[:-1] + (None,)
            s_spec = _presolve(s_logical, leaf.scale.shape, mesh)
            return QTensor(q_spec, s_spec, leaf.bits, leaf.block, leaf.orig_last)
        return _presolve(_logical_for_param(names, leaf.ndim), leaf.shape, mesh)

    return compat.tree_map_with_path(
        spec_for, params, is_leaf=lambda x: isinstance(x, QTensor)
    )


def batch_specs(batch, mesh: Mesh, shard_batch: bool = True, batch_axes=None,
                shard_seq: bool = True):
    """Specs for a training/serving batch dict.

    ``batch_axes`` overrides the default ``data_axes(mesh)`` — e.g. the
    epoch≥2 cached phase shards over the pipeline axis too (the whole
    pool is pure-DP once the backbone no longer runs). ``shard_seq=False``
    keeps the sequence dim of cached activations replicated even on a
    ``model``-axis mesh — required by shard_map consumers that reduce
    over the batch axes only (``steps.dp_cached_train_step``)."""
    dp = tuple(batch_axes) if batch_axes is not None else data_axes(mesh)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    seq_ok = shard_seq and "model" in mesh.axis_names

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[0] if names else ""
        B_axis = dp_spec if shard_batch else None
        if name == "positions" and leaf.ndim == 3:  # mrope (3,B,S)
            return P(None, B_axis, None)
        if name in ("tokens", "labels", "positions", "seq_ids"):
            return P(*((B_axis,) + (None,) * (leaf.ndim - 1)))
        if name == "embeds":
            return P(B_axis, None, None)
        if name in ("b0", "b_final"):  # cached activations: S over `model`
            sq = "model" if (seq_ok and leaf.shape[1] % mesh.shape["model"] == 0) else None
            return P(*((B_axis, sq) + (None,) * (leaf.ndim - 2)))
        if name == "taps":
            sq = "model" if (seq_ok and leaf.shape[2] % mesh.shape["model"] == 0) else None
            return P(*((None, B_axis, sq) + (None,) * (leaf.ndim - 3)))
        return P(*((None,) * leaf.ndim))

    return compat.tree_map_with_path(spec_for, batch)


def cache_specs(cache, mesh: Mesh, B: int):
    """Decode-cache specs. Batch over data axes when divisible; the KV
    sequence dim over `model` (and over everything for B=1 long-context)."""
    dp = data_axes(mesh)
    total_dp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    shard_b = dp and B % total_dp == 0
    b_axis = (dp if len(dp) > 1 else dp[0]) if shard_b else None

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1]
        if name in ("k_scale", "v_scale"):  # (n_p, B, Smax, Hkv) - INT8 KV
            s_ax = []
            if "model" in mesh.axis_names and leaf.shape[2] % mesh.shape["model"] == 0:
                s_ax = ["model"]
            if not shard_b and dp and leaf.shape[2] % (total_dp * mesh.shape["model"]) == 0:
                s_ax = list(dp) + ["model"]
            s_spec = tuple(s_ax) if len(s_ax) > 1 else (s_ax[0] if s_ax else None)
            return P(None, b_axis, s_spec, None)
        if name in ("k", "v"):  # (n_p, B, Smax, Hkv, hd)
            s_ax = []
            if "model" in mesh.axis_names and leaf.shape[2] % mesh.shape["model"] == 0:
                s_ax = ["model"]
            if not shard_b and dp and leaf.shape[2] % (total_dp * mesh.shape["model"]) == 0:
                s_ax = list(dp) + ["model"]  # B=1: spread KV over the whole mesh
            s_spec = tuple(s_ax) if len(s_ax) > 1 else (s_ax[0] if s_ax else None)
            return P(None, b_axis, s_spec, None, None)
        if name == "h" and leaf.ndim == 4:  # mamba (n_p, B, di, ds)
            tp = "model" if leaf.shape[2] % mesh.shape["model"] == 0 else None
            return P(None, b_axis, tp, None)
        if name == "conv":  # (n_p, B, dc-1, di)
            tp = "model" if leaf.shape[3] % mesh.shape["model"] == 0 else None
            return P(None, b_axis, None, tp)
        # mlstm C/n/m, slstm c/n/h/m: batch-sharded, rest replicated
        return P(*((None, b_axis) + (None,) * (leaf.ndim - 2))) if leaf.ndim >= 2 else P(
            *((None,) * leaf.ndim)
        )

    return compat.tree_map_with_path(spec_for, cache)


def replicated(tree, mesh: Mesh):
    """NamedSharding pytree replicating every leaf of ``tree`` over ``mesh``.

    The edge trainer's adapter/optimizer state is tiny (1/r² of the
    backbone) — the paper keeps it replicated on every device and
    AllReduces grads, rather than FSDP-sharding it."""
    s = NamedSharding(mesh, P())
    return compat.tree_map(lambda _: s, tree)


def cached_batch_axes(cached_batch, mesh: Mesh) -> tuple:
    """Mesh axes the epoch≥2 cached batch shards over: the data axes,
    *plus* the pipeline ``stage`` axis when the batch divides — the
    backbone no longer runs from epoch 2, so the whole pool
    data-parallels instead of the stage devices duplicating work. The
    shared contract behind :func:`cached_step_shardings` and the
    shard_map-based ``steps.dp_cached_train_step``."""
    axes = list(data_axes(mesh))
    if "stage" in mesh.axis_names:
        B = cached_batch["labels"].shape[0]
        pool = int(np.prod([mesh.shape[a] for a in axes + ["stage"]]))
        if B % pool == 0:
            axes.append("stage")
    return tuple(axes)


def cached_step_shardings(backbone, adapter, opt_state, cached_batch, mesh: Mesh):
    """in_shardings for the epoch≥2 pure-DP cached step
    (``pac_cached_train_step(backbone, adapter, opt, cached_batch)``):
    params/optimizer replicated, the cached activation batch sharded over
    :func:`cached_batch_axes`. Handles compressed entries — an int8
    ``{"q", "scale"}`` leaf pair inherits the batch layout of the tensor
    it stores. One definition of the cached-batch sharding contract,
    shared by the trainer, benchmarks, and examples."""
    axes = list(cached_batch_axes(cached_batch, mesh))
    return (
        replicated(backbone, mesh),
        replicated(adapter, mesh),
        replicated(opt_state, mesh),
        to_named(batch_specs(cached_batch, mesh, batch_axes=axes), mesh),
    )


def to_named(tree_specs, mesh: Mesh):
    """PartitionSpec pytree → NamedSharding pytree (QTensor-aware)."""

    def f(s):
        return NamedSharding(mesh, s)

    def g(leaf):
        if isinstance(leaf, QTensor):
            return QTensor(f(leaf.q), f(leaf.scale), leaf.bits, leaf.block, leaf.orig_last)
        return f(leaf)

    return compat.tree_map(g, tree_specs, is_leaf=lambda x: isinstance(x, (P, QTensor)))
