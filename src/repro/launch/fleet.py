"""Fleet-scheduler CLI: N fine-tuning jobs on one shared, flaky pool.

Drives :class:`repro.fleet.FleetScheduler` under a deterministic failure
simulation: a pool of devices, a queue of jobs, and a
:class:`~repro.fleet.events.FaultPlan` of scripted join/leave/slow/kill/
submit events pinned to scheduler ticks (one tick = one step boundary),
all on a virtual :class:`~repro.fleet.clock.SimClock` — every run of the
same plan replays identically, on any machine.

    # two jobs, four devices, one device killed mid-run
    PYTHONPATH=src python -m repro.launch.fleet --simulate --reduced \\
        --pool 4 --jobs 2 --epochs 3 --steps-per-epoch 2 --batch 4 \\
        --seq 16 --kill-tick 8

    # replay an explicit fault script (JSON; see --save-fault-plan)
    PYTHONPATH=src python -m repro.launch.fleet --simulate --reduced \\
        --fault-plan faults.json --jobs 2

Without ``--fault-plan`` a default script is generated: job *i* is
submitted at tick ``2·i``, and (when ``--kill-tick`` is set) the pool's
last device is killed at that tick — it silently stops heartbeating and
is evicted only after the heartbeat timeout, exactly as a real loss
would play out. Cached epochs keep running through the kill: the
elastic DP runner reshards the chunk placement onto the survivors with
bit-identical numerics (``repro.fleet.elastic``), so the printed losses
match a fault-free run float-for-float.

``--bind-devices`` backs members with distinct fake host devices
(``compat.force_host_device_count``, sized to the pool *before* JAX
initialises — the same pre-backend hook the trainer uses); the default
keeps members logical on one device, which exercises identical
scheduling/resharding logic and is what CI smokes.
"""

from __future__ import annotations

import argparse

from repro import compat

_EPILOG = "Flag reference: docs/CLI.md. Architecture: docs/ARCHITECTURE.md."


def default_fault_plan(n_jobs: int, pool: list, kill_tick=None):
    """submit job-i at tick 2i; optionally kill the last device."""
    from repro.fleet import FaultPlan, FleetEvent

    events = [FleetEvent(2 * i, "submit", job=f"job{i}")
              for i in range(n_jobs)]
    if kill_tick is not None and pool:
        events.append(FleetEvent(kill_tick, "kill", device=pool[-1]))
    return FaultPlan(events)


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, epilog=_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--simulate", action="store_true", required=True,
                    help="run the deterministic failure simulation (the only "
                         "mode; the flag is explicit so a future live mode "
                         "can default differently)")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", help="CPU-scale variant")
    ap.add_argument("--pool", type=int, default=4, help="initial device count")
    ap.add_argument("--jobs", type=int, default=2, help="jobs to submit")
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--steps-per-epoch", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--r", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0,
                    help="job i runs with seed+i (distinct corpora)")
    ap.add_argument("--chunk", type=int, default=1,
                    help="sequences per elastic work unit (batch %% chunk == 0)")
    ap.add_argument("--quantum", type=int, default=None,
                    help="preempt a running job after this many ticks when "
                         "others wait (checkpointed via --snapshot-dir)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="preemption snapshots go here (default: in-memory)")
    ap.add_argument("--cache-dir", default=None,
                    help="per-job persistent activation caches under this dir "
                         "(<dir>/job0, ...) — a rerun resumes warm with zero "
                         "backbone forwards")
    ap.add_argument("--cache-compress", default="f32",
                    choices=["f32", "bf16", "int8"])
    ap.add_argument("--kernels", default="ref", choices=["ref", "pallas"])
    ap.add_argument("--heartbeat-timeout", type=float, default=1.5,
                    help="simulated seconds without a heartbeat before a "
                         "device is declared lost (ticks advance 1s each)")
    ap.add_argument("--kill-tick", type=int, default=None,
                    help="kill the pool's last device at this tick "
                         "(ignored with --fault-plan)")
    ap.add_argument("--fault-plan", default=None,
                    help="JSON fault script to replay (FaultPlan.save format)")
    ap.add_argument("--save-fault-plan", default=None,
                    help="write the executed fault script as JSON")
    ap.add_argument("--bind-devices", action="store_true",
                    help="back members with distinct fake host devices "
                         "(forces the device count pre-backend)")
    ap.add_argument("--max-ticks", type=int, default=200)
    args = ap.parse_args()

    if args.bind_devices:
        # pre-backend, like the trainer: the fake-device count must be
        # locked in before the first JAX backend initialisation
        compat.force_host_device_count(max(args.pool, 1))

    from repro.fleet import (
        DeviceMember,
        DevicePool,
        FaultPlan,
        FleetScheduler,
        ScriptedEvents,
        SessionJob,
        SimClock,
    )
    from repro.runtime import RunSpec

    member_names = [f"dev{i}" for i in range(args.pool)]
    if args.fault_plan:
        plan = FaultPlan.load(args.fault_plan)
    else:
        plan = default_fault_plan(args.jobs, member_names, args.kill_tick)
    if args.save_fault_plan:
        print(f"fault plan saved: {plan.save(args.save_fault_plan)}")

    pool = DevicePool(
        [DeviceMember(n) for n in member_names], clock=SimClock(),
        heartbeat_timeout=args.heartbeat_timeout,
        bind_devices=args.bind_devices)
    sched = FleetScheduler(
        pool, events=ScriptedEvents(plan), quantum=args.quantum,
        snapshot_dir=args.snapshot_dir, max_ticks=args.max_ticks, log=print)

    base = RunSpec(
        arch=args.arch, reduced=args.reduced, epochs=args.epochs,
        steps_per_epoch=args.steps_per_epoch, batch=args.batch, seq=args.seq,
        r=args.r, lr=args.lr, cache_compress=args.cache_compress,
        kernels=args.kernels)
    for i in range(args.jobs):
        spec = base.replace(
            seed=args.seed + i,
            cache_dir=f"{args.cache_dir}/job{i}" if args.cache_dir else None)
        sched.register(SessionJob(f"job{i}", spec, chunk=args.chunk))

    report = sched.run()

    print(f"\nfleet: {report.n_ticks} ticks, "
          f"{len(pool)} devices remain, "
          f"{len(report.rejected)} rejected")
    for name in sorted(sched.jobs):
        job = sched.jobs[name]
        losses = report.losses(name)
        final = f"{losses[-1]:.4f}" if losses else "-"
        print(f"  {name}: {job.state} steps={report.job_steps(name)} "
              f"forwards={job.forward_steps} cached={job.cached_steps} "
              f"reshards={job.reshards} final_loss={final}")


if __name__ == "__main__":
    main()
