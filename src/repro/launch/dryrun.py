from repro.compat import force_host_device_count
force_host_device_count(512)

"""Multi-pod dry-run: lower + compile every (arch × input shape) on the
production meshes, and emit the roofline terms.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first *backend initialisation*, and the 512
placeholder host devices exist only for this entry point (tests/benches
see 1).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both --out results/dryrun
"""

import argparse
import os
import json
import time
import traceback

import jax

from repro.configs import INPUT_SHAPES, get_arch, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze, format_row
from repro.launch.specs import build_case

ASSIGNED = [
    "musicgen-large",
    "grok-1-314b",
    "moonshot-v1-16b-a3b",
    "kimi-k2-1t-a32b",
    "qwen2-vl-7b",
    "xlstm-125m",
    "gemma2-2b",
    "jamba-1.5-large-398b",
    "internlm2-1.8b",
    "granite-20b",
]


def run_case(arch: str, shape_name: str, *, multi_pod: bool, technique: str,
             quant_bits=None, kv_quant=None, dtype="f32", out_dir=None, verbose=True):
    import jax.numpy as jnp

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    case = build_case(arch, shape_name, mesh, technique=technique,
                      quant_bits=quant_bits, kv_quant=kv_quant,
                      dtype={"f32": jnp.float32, "bf16": jnp.bfloat16}[dtype])
    with mesh:
        lowered = case.lower()
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
    n_adapter = 0
    if technique.startswith("pac"):
        from repro.core.parallel_adapters import adapter_param_count

        n_adapter = adapter_param_count(case.cfg)
    terms = analyze(
        compiled,
        arch=arch,
        shape=case.shape,
        mesh=mesh,
        technique=technique,
        note=case.note,
        n_active_params=case.cfg.active_param_count(),
        n_adapter_params=n_adapter,
    )
    rec = terms.as_dict()
    rec.update(lower_s=round(t_lower, 2), compile_s=round(t_compile, 2), status="ok")
    if verbose:
        print(format_row(terms))
        print(f"  memory_analysis: {terms.memory_analysis}")
        print(f"  lower={t_lower:.1f}s compile={t_compile:.1f}s")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'pod2' if multi_pod else 'pod1'}_{technique}"
        if quant_bits:
            tag += f"_int{quant_bits}"
        if kv_quant:
            tag += f"_kv{kv_quant}"
        if dtype != "f32":
            tag += f"_{dtype}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (default: all assigned)")
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES), help="input shape")
    ap.add_argument("--technique", default="pac",
                    choices=["pac", "pac_cached", "full", "lora"],
                    help="fine-tuning technique for train shapes")
    ap.add_argument("--quant", type=int, default=None, choices=[4, 8],
                    help="backbone quantization bits")
    ap.add_argument("--kv-quant", type=int, default=None, choices=[8],
                    help="INT8 KV cache for decode shapes (beyond-paper)")
    ap.add_argument("--dtype", default="f32", choices=["f32", "bf16"],
                    help="activation/param dtype (bf16 = TPU-native half)")
    ap.add_argument("--multi-pod", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None, help="directory for JSON records")
    ap.add_argument("--all", action="store_true", help="run the full 10×4 matrix")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                try:
                    run_case(arch, shape, multi_pod=mp, technique=args.technique,
                             quant_bits=args.quant, kv_quant=args.kv_quant,
                             dtype=args.dtype, out_dir=args.out)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"FAIL {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall dry-run cases compiled OK")


if __name__ == "__main__":
    main()
