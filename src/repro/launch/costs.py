"""Unified cost-model surface for planning and performance analysis.

Before this module the repo had three disjoint cost models:

* the planner's **analytic** ``model_layer_costs`` (FLOPs from matmul
  shapes, paper Fig. 3 / Table I accounting);
* the **trip-count-aware HLO** pricer ``launch.hlo_cost.analyze_hlo``
  (what the dry-run matrix and the benchmarks measure);
* the **roofline**'s device-time conversion (peak FLOP/s, HBM, ICI).

They answer the same question — "what does this computation cost?" — at
different fidelities, and they never talked to each other: the planner
partitioned stages from analytic numbers that nothing ever checked
against a compiled module. This module puts them behind one
:class:`CostModel` protocol at the granularity the runtime executes
(**periods**, see :func:`repro.core.planner.period_costs`) and adds the
calibrated backend the ``--calibrate`` trainer flag uses: lower one
period of the real step with :func:`repro.launch.specs.build_case`,
price it with :func:`~repro.launch.hlo_cost.analyze_compiled`, and scale
the analytic per-period FLOPs so their totals match the measured module.
Memory accounting (parameter/activation residency) stays analytic — the
HLO module doesn't expose liveness — so OOM feasibility is unchanged.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Protocol, runtime_checkable

from repro.core.planner import LayerCost, model_layer_costs, period_costs


@runtime_checkable
class CostModel(Protocol):
    """Anything that prices a backbone for the planner.

    Returns one :class:`LayerCost` per *period* — the unit the SPMD
    pipeline can actually cut on (``HybridParallelismPlanner`` fed these
    produces plans whose ``stage_partition()`` is executable as-is).
    """

    def period_costs(self, cfg, technique: str = "pac", seq_len: int = 128) -> List[LayerCost]:
        ...


@dataclass(frozen=True)
class AnalyticCostModel:
    """The paper's closed-form accounting (no compilation needed)."""

    dtype_bytes: int = 4
    quant_bits: Optional[int] = None

    def layer_costs(self, cfg, technique: str = "pac", seq_len: int = 128) -> List[LayerCost]:
        return model_layer_costs(
            cfg, technique, dtype_bytes=self.dtype_bytes, seq_len=seq_len,
            quant_bits=self.quant_bits,
        )

    def period_costs(self, cfg, technique: str = "pac", seq_len: int = 128) -> List[LayerCost]:
        return period_costs(
            cfg, technique, dtype_bytes=self.dtype_bytes, seq_len=seq_len,
            quant_bits=self.quant_bits,
        )


def price_lowered(lowered_or_compiled):
    """Lower/compile as needed and return the trip-count-aware ``Cost``."""
    from repro.launch.hlo_cost import analyze_compiled

    obj = lowered_or_compiled
    if hasattr(obj, "compile"):  # a jax Lowered
        obj = obj.compile()
    return analyze_compiled(obj)


@dataclass(frozen=True)
class HloCalibratedCostModel:
    """Analytic memory model + HLO-measured compute.

    Calibration lowers small cases at the *actual* trainer shape
    (micro-batch × seq): the ``pac`` step and the ``pac_cached`` step on a
    one-period model, whose difference isolates the measured
    backbone-forward FLOPs per period; and the cached step again on a
    two-period model, so the *slope* between the two cached measurements
    prices one period of the trainable side while the intercept is the
    shared head/CE/optimizer overhead (spread evenly over periods —
    without the slope/intercept split a one-period measurement divided by
    n_periods would under-count the adapter by ~n_periods×). Scales apply
    uniformly over periods — per-period *shape* heterogeneity (MoE vs
    dense layers) still comes from the analytic ratios, so a hybrid
    pattern keeps its relative weights while the absolute FLOPs match the
    compiled HLO.
    """

    micro_batch: int = 4
    dtype_bytes: int = 4
    quant_bits: Optional[int] = None

    def _measure(self, cfg, technique: str, seq_len: int, periods: int = 1):
        from repro.configs.base import InputShape
        from repro.launch import mesh as mesh_mod
        from repro.launch.specs import build_case

        cfgN = dataclasses.replace(cfg, n_layers=periods * cfg.period)
        shape = InputShape("calibrate", seq_len, self.micro_batch, "train")
        mesh = mesh_mod.make_mesh((1, 1), ("data", "model"))
        case = build_case(
            cfgN, shape, mesh, technique=technique, quant_bits=self.quant_bits
        )
        return price_lowered(case.lower())

    def period_costs(self, cfg, technique: str = "pac", seq_len: int = 128) -> List[LayerCost]:
        base = period_costs(
            cfg, technique, dtype_bytes=self.dtype_bytes, seq_len=seq_len,
            quant_bits=self.quant_bits,
        )
        if technique not in ("pac", "pac_cached"):
            return base  # calibration targets the PAC+ trainer path
        mb = self.micro_batch
        pac = self._measure(cfg, "pac", seq_len)
        cached1 = self._measure(cfg, "pac_cached", seq_len)
        # per-sample measured FLOPs: pac-minus-cached on the same 1-period
        # model ≈ one backbone-period forward
        meas_fwd = max(pac.flops - cached1.flops, 0.0) / mb
        if cfg.n_periods > 1:
            cached2 = self._measure(cfg, "pac_cached", seq_len, periods=2)
            # slope = one period of adapter fwd+bwd; intercept = the
            # period-count-independent head/CE/optimizer overhead
            per_period = max(cached2.flops - cached1.flops, 0.0) / mb
            overhead = max(cached1.flops / mb - per_period, 0.0)
        else:
            per_period, overhead = cached1.flops / mb, 0.0
        # every period tiles the same pattern, so the analytic per-period
        # costs are identical — one measured period calibrates them all
        ana_fwd = base[0].fwd_flops
        ana_bwd = base[0].bwd_flops
        s_fwd = meas_fwd / ana_fwd if ana_fwd else 1.0
        s_bwd = per_period / ana_bwd if ana_bwd else 1.0
        extra_bwd = overhead / len(base)  # shared overhead, spread evenly
        return [
            dataclasses.replace(
                c,
                fwd_flops=c.fwd_flops * s_fwd,
                bwd_flops=c.bwd_flops * s_bwd + extra_bwd,
            )
            for c in base
        ]


def resolve_cost_model(calibrate: bool, micro_batch: int = 4, quant_bits: Optional[int] = None) -> CostModel:
    """The trainer's ``--calibrate`` switch in one place."""
    if calibrate:
        return HloCalibratedCostModel(micro_batch=micro_batch, quant_bits=quant_bits)
    return AnalyticCostModel(quant_bits=quant_bits)
