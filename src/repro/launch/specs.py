"""Abstract input construction for the dry-run / roofline matrix.

``build_case(arch, shape, mesh, ...)`` returns everything needed to lower
one (architecture × input-shape) cell: the step function, abstract
(ShapeDtypeStruct) arguments, and in/out shardings — no device memory is
ever allocated (the same pattern as shannon/kernels: weak-type-correct,
shardable stand-ins).

Modality carve-out: for [audio]/[vlm] archs the frontend is a stub —
``input_specs`` supplies precomputed frame/patch **embeddings** of the
right shape (plus M-RoPE position ids for qwen2-vl), per the assignment.

Decode shapes lower ``decode_step`` (ONE token against a seq_len-deep
cache). ``long_500k`` uses each arch's sub-quadratic path; for pure
full-attention archs the serving variant forces ``window=8192`` on every
layer (marked ``sw8k`` in the roofline table).
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, ArchConfig, InputShape, get_arch
from repro.core import steps
from repro.core.parallel_adapters import abstract_adapter
from repro.core.quantization import quantize_tree
from repro.launch import sharding as shard
from repro.models import backbone as bb
from repro.optim import adamw_init

SERVE_WINDOW = 8192  # sliding-window serving variant for long_500k


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def abstract_params(cfg: ArchConfig, quant_bits: Optional[int] = None, dtype=jnp.float32):
    """Abstract backbone params (optionally in quantized storage)."""
    def build():
        p = bb.init_backbone(jax.random.PRNGKey(0), cfg, dtype)
        if quant_bits:
            p = quantize_tree(p, bits=quant_bits)
        return p

    return jax.eval_shape(build)


def input_specs(cfg: ArchConfig, shape: InputShape, dtype=jnp.float32) -> dict:
    """Abstract batch for the given input shape (assignment step 2)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.mode == "decode":
        S_tok = 1
    else:
        S_tok = S
    batch: dict = {}
    if cfg.frontend is not None:
        # stub modality frontend: precomputed embeddings
        batch["embeds"] = _sds((B, S_tok, cfg.d_model), dtype)
    else:
        batch["tokens"] = _sds((B, S_tok), jnp.int32)
    if cfg.rope == "mrope":
        batch["positions"] = _sds((3, B, S_tok), jnp.int32)
    if shape.mode == "train":
        batch["labels"] = _sds((B, S_tok), jnp.int32)
    return batch


@dataclass
class Case:
    """One lowering cell: callable + abstract args + shardings."""

    name: str
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    cfg: ArchConfig
    shape: InputShape
    note: str = ""

    def lower(self):
        jitted = jax.jit(
            self.fn, in_shardings=self.in_shardings, out_shardings=self.out_shardings
        )
        return jitted.lower(*self.args)


def resolve_cfg_for_shape(cfg: ArchConfig, shape: InputShape) -> tuple:
    """Apply the long-context serving variant where required."""
    note = ""
    if shape.name == "long_500k" and not cfg.is_subquadratic():
        cfg = cfg.with_window(SERVE_WINDOW)
        note = "sw8k"
    return cfg, note


def build_case(
    arch: str,
    shape_name: str,
    mesh,
    technique: str = "pac",
    quant_bits: Optional[int] = None,
    r: int = 8,
    dtype=jnp.float32,
    kv_quant: Optional[int] = None,
) -> Case:
    cfg = get_arch(arch) if isinstance(arch, str) else arch
    # registered shape by name, or an ad-hoc InputShape (e.g. the planner's
    # HLO calibration lowers one period at the trainer's actual batch/seq)
    shape = shape_name if isinstance(shape_name, InputShape) else INPUT_SHAPES[shape_name]
    cfg, note = resolve_cfg_for_shape(cfg, shape)
    if quant_bits:
        note = (note + f" int{quant_bits}").strip()
    if kv_quant:
        note = (note + f" kv{kv_quant}").strip()

    params = abstract_params(cfg, quant_bits, dtype)
    p_spec = shard.to_named(shard.param_specs(params, mesh), mesh)
    batch = input_specs(cfg, shape, dtype)
    b_spec = shard.to_named(shard.batch_specs(batch, mesh, shard_batch=shape.global_batch > 1), mesh)

    if shape.mode == "train":
        if technique == "pac":
            adapter = abstract_adapter(cfg, r, dtype)
            a_spec = shard.to_named(shard.param_specs(adapter, mesh), mesh)
            opt = jax.eval_shape(adamw_init, adapter)
            o_spec = shard.to_named(shard.param_specs(opt, mesh), mesh)
            fn = functools.partial(steps.pac_train_step, cfg=cfg, r=r)
            args = (params, adapter, opt, batch)
            in_sh = (p_spec, a_spec, o_spec, b_spec)
            # taps/b0/b_final (the activation-cache outputs) must stay
            # batch-sharded, never replicated (§Perf iteration 1)
            B, S = shape.global_batch, shape.seq_len
            dp = shard.data_axes(mesh)
            dps = dp if len(dp) > 1 else dp[0]
            # sequence-parallel residual stream (§Perf iteration 4): taps
            # leave the step S-sharded over `model`
            sq = "model" if S % mesh.shape["model"] == 0 else None
            nb = shard.to_named
            out_sh = (
                None,  # loss
                a_spec,
                o_spec,
                (
                    nb(P(dps, sq, None), mesh),
                    nb(P(None, dps, sq, None), mesh),
                    nb(P(dps, sq, None), mesh),
                ),
            )
        elif technique == "pac_cached":
            adapter = abstract_adapter(cfg, r, dtype)
            a_spec = shard.to_named(shard.param_specs(adapter, mesh), mesh)
            opt = jax.eval_shape(adamw_init, adapter)
            o_spec = shard.to_named(shard.param_specs(opt, mesh), mesh)
            B, S = shape.global_batch, shape.seq_len
            cached = {
                "b0": _sds((B, S, cfg.d_model), dtype),
                "taps": _sds((cfg.n_periods, B, S, cfg.d_model), dtype),
                "b_final": _sds((B, S, cfg.d_model), dtype),
                "labels": _sds((B, S), jnp.int32),
            }
            c_spec = shard.to_named(shard.batch_specs(cached, mesh), mesh)
            fn = functools.partial(steps.pac_cached_train_step, cfg=cfg, r=r)
            args = (params, adapter, opt, cached)
            in_sh = (p_spec, a_spec, o_spec, c_spec)
            out_sh = None
        elif technique == "full":
            opt = jax.eval_shape(adamw_init, params)
            o_spec = shard.to_named(shard.param_specs(opt, mesh), mesh)
            fn = functools.partial(steps.full_train_step, cfg=cfg)
            args = (params, opt, batch)
            in_sh = (p_spec, o_spec, b_spec)
            out_sh = None
        elif technique == "lora":
            from repro.core.peft import init_lora

            lora = jax.eval_shape(lambda: init_lora(jax.random.PRNGKey(0), cfg, dtype=dtype))
            l_spec = shard.to_named(shard.param_specs(lora, mesh), mesh)
            opt = jax.eval_shape(adamw_init, lora)
            o_spec = shard.to_named(shard.param_specs(opt, mesh), mesh)
            fn = functools.partial(steps.lora_train_step, cfg=cfg)
            args = (params, lora, opt, batch)
            in_sh = (p_spec, l_spec, o_spec, b_spec)
            out_sh = None
        else:
            raise ValueError(technique)
    elif shape.mode == "prefill":
        fn = functools.partial(steps.prefill_step, cfg=cfg)
        args = (params, batch)
        in_sh = (p_spec, b_spec)
        out_sh = None
    else:  # decode
        B, S = shape.global_batch, shape.seq_len
        cache = jax.eval_shape(lambda: bb.init_cache(cfg, B, S, dtype, kv_quant=kv_quant))
        c_spec = shard.to_named(shard.cache_specs(cache, mesh, B), mesh)
        pos = _sds((), jnp.int32)
        fn = functools.partial(steps.decode_step, cfg=cfg)
        args = (params, batch, cache, pos)
        in_sh = (p_spec, b_spec, c_spec, shard.to_named(P(), mesh))
        # cache sharding must be stable step-over-step; logits layout is free
        out_sh = (None, c_spec)
    return Case(
        name=f"{cfg.name}×{shape.name}",
        fn=fn,
        args=args,
        in_shardings=in_sh,
        out_shardings=out_sh,
        cfg=cfg,
        shape=shape,
        note=note,
    )
