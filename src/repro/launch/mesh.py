"""Production mesh construction.

Target: TPU v5e — 16×16 = 256 chips per pod, 2 pods = 512 chips.
Axes: ``data`` (batch + FSDP weight sharding), ``model`` (tensor/expert
parallel), and ``pod`` (outer data parallelism across the inter-pod
links) in the multi-pod configuration.

Defined as functions, never module-level constants, so importing this
module never touches jax device state (the dry-run entry point must set
``XLA_FLAGS`` before *any* jax initialisation).
"""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests/benchmarks on host devices)."""
    return compat.make_mesh(shape, axes)


def make_edge_mesh(dp: int, stages: int, devices=None):
    """2-D ``(dp, stage)`` mesh for the hybrid DP×PP edge trainer.

    ``devices`` defaults to the first dp·stages of ``jax.devices()`` (on
    CPU, fake host devices from ``compat.force_host_device_count``)."""
    import jax

    total = dp * stages
    if devices is None:
        devices = jax.devices()
    if len(devices) < total:
        raise RuntimeError(
            f"need {total} devices for a {dp}×{stages} (dp, stage) mesh, "
            f"have {len(devices)}; on CPU call "
            f"compat.force_host_device_count({total}) before any JAX use"
        )
    return compat.make_mesh((dp, stages), ("dp", "stage"), devices=devices[:total])


def make_plan_mesh(partition, devices=None, dp: int = None):
    """2-D ``(dp, stage)`` mesh shaped by an executable
    :class:`~repro.core.planner.StagePartition`: the plan's stage count
    becomes the ``stage`` axis; ``dp`` defaults to the widest replica
    count the device pool supports (pool // stages — the uniform-mesh
    rendering of the plan's per-stage device groups)."""
    import jax

    stages = partition.n_stages
    if devices is None:
        devices = jax.devices()
    if dp is None:
        dp = max(1, len(devices) // stages)
    return make_edge_mesh(dp, stages, devices)


def data_axes(mesh) -> tuple:
    """Mesh axes that shard the batch (pod composes with data; the edge
    trainer's 2-D mesh calls its batch axis dp)."""
    return tuple(a for a in ("pod", "data", "dp") if a in mesh.axis_names)


def model_axis(mesh) -> str:
    return "model"


# hardware constants for the roofline (TPU v5e)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
