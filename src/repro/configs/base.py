"""Architecture configuration system.

Every architecture in the zoo is an :class:`ArchConfig` — a declarative
description of a decoder backbone as a *layer pattern* (one period of
heterogeneous layers, tiled ``n_layers // len(pattern)`` times).  The
backbone (`repro.models.backbone`) scans over periods with stacked
parameters, so the HLO stays compact regardless of depth.

``reduced()`` produces the CPU-smoke-test variant of the same family
(≤2 periods, d_model ≤ 512, ≤4 experts) as required by the assignment.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer / MoE specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoESpec:
    """Mixture-of-experts settings for layers whose ``LayerSpec.moe`` is True."""

    n_experts: int
    top_k: int
    d_expert: int  # hidden dim of each expert's FFN
    capacity_factor: float = 1.25
    router_jitter: float = 0.0

    def scaled(self, n_experts: int, d_expert: int) -> "MoESpec":
        return dataclasses.replace(
            self, n_experts=n_experts, top_k=min(self.top_k, n_experts), d_expert=d_expert
        )


@dataclass(frozen=True)
class LayerSpec:
    """One position inside the layer pattern period.

    kind: "attn" | "mamba" | "mlstm" | "slstm"
    window: sliding-window size for attention (None = full causal)
    moe: replace the dense FFN with the arch's MoESpec
    ffn: whether the layer has a separate FFN at all (xLSTM blocks do not)
    """

    kind: str = "attn"
    window: Optional[int] = None
    moe: bool = False
    ffn: bool = True


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    moe: Optional[MoESpec] = None
    head_dim: Optional[int] = None  # default d_model // n_heads
    rope: str = "rope"  # "rope" | "mrope" | "none"
    rope_theta: float = 10_000.0
    logit_softcap: Optional[float] = None
    attn_softcap: Optional[float] = None
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    frontend: Optional[str] = None  # "audio" | "vision" stub frontends
    # SSM hyper-params (mamba / xlstm layers)
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    mlstm_chunk: int = 256
    # citation for the config (paper/model card)
    source: str = ""
    # set for serving variants: overrides every attention layer's window
    serve_window: Optional[int] = None

    # -- derived ------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by pattern period {self.period}"
        )
        return self.n_layers // self.period

    @property
    def d_inner(self) -> int:
        """Mamba inner dim."""
        return self.ssm_expand * self.d_model

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        """The full, tiled list of layers (length n_layers)."""
        return tuple(self.pattern) * self.n_periods

    def with_window(self, window: int) -> "ArchConfig":
        """Serving variant: force a sliding window on every attention layer."""
        pat = tuple(
            dataclasses.replace(s, window=window if s.kind == "attn" else s.window)
            for s in self.pattern
        )
        return dataclasses.replace(self, pattern=pat, serve_window=window)

    def is_subquadratic(self) -> bool:
        """True if no layer attends over unbounded context."""
        return all(s.kind != "attn" or s.window is not None for s in self.pattern)

    # -- reduced smoke-test variant ------------------------------------------
    def reduced(self) -> "ArchConfig":
        """CPU-runnable variant of the same family: ≤2 periods, d≤256, ≤4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        # keep GQA ratio representative
        if self.n_kv_heads < self.n_heads:
            n_kv = max(1, n_heads // max(1, self.n_heads // self.n_kv_heads))
        hd = d_model // n_heads
        moe = None
        if self.moe is not None:
            moe = self.moe.scaled(n_experts=min(4, self.moe.n_experts), d_expert=max(32, d_model // 4))
            # no-drop capacity for exact prefill≡decode equivalence in tests
            moe = dataclasses.replace(moe, capacity_factor=float(moe.n_experts))
        pat = tuple(
            dataclasses.replace(s, window=min(s.window, 32) if s.window else s.window)
            for s in self.pattern
        )
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=self.period * min(2, self.n_periods),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=max(64, min(self.d_ff, 4 * d_model)) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            pattern=pat,
            moe=moe,
            ssm_d_state=min(self.ssm_d_state, 8),
            mlstm_chunk=16,
        )

    # -- analytics -----------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, hd = self.d_model, self.hd
        n = self.vocab * d  # embedding
        if not self.tie_embeddings:
            n += self.vocab * d  # lm head
        for s in self.layer_specs():
            n += 2 * d  # norms
            if s.kind == "attn":
                n += d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
            elif s.kind == "mamba":
                di, ds = self.d_inner, self.ssm_d_state
                n += d * 2 * di + di * self.ssm_d_conv + di * (2 * ds + 1) + di + di * d
            elif s.kind in ("mlstm", "slstm"):
                # q,k,v,o plus gates
                n += 4 * d * (self.n_heads * hd) + 2 * d * self.n_heads
            if s.ffn:
                if s.moe and self.moe is not None:
                    n += d * self.moe.n_experts  # router
                    n += self.moe.n_experts * 3 * d * self.moe.d_expert
                elif self.d_ff:
                    n += 3 * d * self.d_ff  # gated mlp
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        n = self.param_count()
        for s in self.layer_specs():
            if s.moe:
                dead = (self.moe.n_experts - self.moe.top_k) * 3 * self.d_model * self.moe.d_expert
                n -= dead
        return n


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    # import the config modules lazily so `register` runs
    from repro import configs as _c  # noqa: F401

    _c.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    from repro import configs as _c

    _c.load_all()
    return sorted(_REGISTRY)
