"""Kimi K2 — trillion-parameter MoE (paper-table spec).

[arXiv:2501.kimi2] — 61L, d_model=7168, 64 heads (GQA kv=8), per-expert
FFN d_ff=2048, vocab=163840, 384 experts top-8.
"""

from repro.configs.base import ArchConfig, LayerSpec, MoESpec, register

KIMI_K2 = register(
    ArchConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=2048,
        vocab=163840,
        pattern=(LayerSpec(kind="attn", moe=True),),
        moe=MoESpec(n_experts=384, top_k=8, d_expert=2048),
        head_dim=112,  # 7168 / 64
        source="arXiv:2501.kimi2",
    )
)
