"""xLSTM-125M — sLSTM + mLSTM blocks.

[arXiv:2405.04517] — 12L, d_model=768, 4 heads, d_ff=0 (xLSTM blocks carry
their own up/down projections), vocab=50304. We use the paper's 7:1-style
mixing at small scale: sLSTM at one position per 4-layer period.
"""

from repro.configs.base import ArchConfig, LayerSpec, register

XLSTM_125M = register(
    ArchConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        pattern=(
            LayerSpec(kind="mlstm", ffn=False),
            LayerSpec(kind="mlstm", ffn=False),
            LayerSpec(kind="mlstm", ffn=False),
            LayerSpec(kind="slstm", ffn=False),
        ),
        rope="none",
        source="arXiv:2405.04517",
    )
)
