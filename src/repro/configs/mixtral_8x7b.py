"""Mixtral 8x7B sparse MoE (bonus pool arch, beyond the assigned ten).

[arXiv:2401.04088] — 32L, d_model=4096, 32 heads (GQA kv=8), expert FFN
d_ff=14336, vocab=32000, 8 experts top-2, sliding-window 4096 attention.
Exercises the E < model-axis expert path (TP_ALT) at llama-class dims and
the window+MoE combination no assigned arch covers.
"""

from repro.configs.base import ArchConfig, LayerSpec, MoESpec, register

MIXTRAL = register(
    ArchConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=32000,
        pattern=(LayerSpec(kind="attn", moe=True, window=4096),),
        moe=MoESpec(n_experts=8, top_k=2, d_expert=14336),
        source="arXiv:2401.04088",
    )
)
