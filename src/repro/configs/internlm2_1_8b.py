"""InternLM2-1.8B — llama-style GQA decoder.

[arXiv:2403.17297] — 24L, d_model=2048, 16 heads (GQA kv=8), d_ff=8192,
vocab=92544.
"""

from repro.configs.base import ArchConfig, LayerSpec, register

INTERNLM2_1_8B = register(
    ArchConfig(
        name="internlm2-1.8b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab=92544,
        pattern=(LayerSpec(kind="attn"),),
        rope_theta=1_000_000.0,
        source="arXiv:2403.17297",
    )
)
