"""Qwen2-VL-7B language backbone with M-RoPE.

[arXiv:2409.12191] — 28L, d_model=3584, 28 heads (GQA kv=4), d_ff=18944,
vocab=152064. M-RoPE: 3-D (temporal/height/width) rotary position ids
provided by the stub vision frontend; dynamic-resolution patching is the
frontend's job and is stubbed per the assignment carve-out.
"""

from repro.configs.base import ArchConfig, LayerSpec, register

QWEN2_VL_7B = register(
    ArchConfig(
        name="qwen2-vl-7b",
        family="vlm",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab=152064,
        pattern=(LayerSpec(kind="attn"),),
        rope="mrope",
        rope_theta=1_000_000.0,
        frontend="vision",
        source="arXiv:2409.12191",
    )
)
