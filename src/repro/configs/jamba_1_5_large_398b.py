"""Jamba-1.5-Large 398B — Mamba+attention 1:7 interleave with MoE.

[arXiv:2403.19887] — 72L, d_model=8192, 64 heads (GQA kv=8), expert FFN
d_ff=24576 (MoE 16e top-2 on every other layer), vocab=65536. Each 8-layer
period = 7 Mamba layers + 1 attention layer; MoE at odd positions.
"""

from repro.configs.base import ArchConfig, LayerSpec, MoESpec, register

_PERIOD = tuple(
    LayerSpec(kind=("attn" if i == 3 else "mamba"), moe=(i % 2 == 1))
    for i in range(8)
)

JAMBA_1_5_LARGE = register(
    ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab=65536,
        pattern=_PERIOD,
        moe=MoESpec(n_experts=16, top_k=2, d_expert=24576),
        ssm_d_state=16,
        ssm_d_conv=4,
        ssm_expand=2,
        source="arXiv:2403.19887",
    )
)
