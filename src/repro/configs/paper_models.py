"""The paper's own evaluation models (Table III), as decoder-only analogues.

PAC+ evaluates T5-Base (0.25B), BART-Large (0.41B), T5-Large (0.74B) —
encoder-decoder models. The PAC+ technique is agnostic to the
encoder/decoder split (adapters consume per-layer activations), so we
carry decoder-only configs with the same layer/width/head budget, which is
what the assigned architecture pool exercises. Layer counts are doubled
to account for the encoder+decoder stacks (12+12 → 24 etc.).
"""

from repro.configs.base import ArchConfig, LayerSpec, register

T5_BASE = register(
    ArchConfig(
        name="t5-base-pac",
        family="dense",
        n_layers=24,  # 12 enc + 12 dec
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab=32128,
        pattern=(LayerSpec(kind="attn"),),
        source="arXiv:1910.10683 (T5), PAC+ Table III",
    )
)

BART_LARGE = register(
    ArchConfig(
        name="bart-large-pac",
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab=50265,
        pattern=(LayerSpec(kind="attn"),),
        source="ACL 2020 (BART), PAC+ Table III",
    )
)

T5_LARGE = register(
    ArchConfig(
        name="t5-large-pac",
        family="dense",
        n_layers=48,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab=32128,
        pattern=(LayerSpec(kind="attn"),),
        source="arXiv:1910.10683 (T5), PAC+ Table III",
    )
)
