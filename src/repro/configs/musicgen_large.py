"""MusicGen-Large decoder backbone over EnCodec tokens.

[arXiv:2306.05284] — 48L, d_model=2048, 32 heads (kv=32, i.e. MHA),
d_ff=8192, vocab=2048 (one EnCodec codebook; the conv codec frontend is a
stub per the assignment — `input_specs()` supplies frame embeddings).
"""

from repro.configs.base import ArchConfig, LayerSpec, register

MUSICGEN_LARGE = register(
    ArchConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=2048,
        pattern=(LayerSpec(kind="attn"),),
        rope="none",  # musicgen uses learned sinusoidal offsets; positionless here
        frontend="audio",
        source="arXiv:2306.05284",
    )
)
