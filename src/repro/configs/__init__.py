"""Architecture config registry.

Each assigned architecture lives in its own module and registers an
:class:`~repro.configs.base.ArchConfig` with the exact published
hyper-parameters (source cited in the config).
"""

import importlib

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    ArchConfig,
    InputShape,
    LayerSpec,
    MoESpec,
    get_arch,
    list_archs,
    register,
)

_MODULES = [
    "musicgen_large",
    "grok_1_314b",
    "moonshot_v1_16b_a3b",
    "kimi_k2_1t_a32b",
    "qwen2_vl_7b",
    "xlstm_125m",
    "gemma2_2b",
    "jamba_1_5_large_398b",
    "internlm2_1_8b",
    "granite_20b",
    "mixtral_8x7b",
    "paper_models",
]

_loaded = False


def load_all() -> None:
    global _loaded
    if _loaded:
        return
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True
