"""Gemma-2 2B — alternating local/global attention, logit softcapping.

[arXiv:2408.00118] — 26L, d_model=2304, 8 heads (GQA kv=4), d_ff=9216,
vocab=256000. Sliding window 4096 on every other layer; attention softcap
50.0, final-logit softcap 30.0; tied embeddings.
"""

from repro.configs.base import ArchConfig, LayerSpec, register

GEMMA2_2B = register(
    ArchConfig(
        name="gemma2-2b",
        family="dense",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        d_ff=9216,
        vocab=256000,
        pattern=(
            LayerSpec(kind="attn", window=4096),
            LayerSpec(kind="attn"),
        ),
        head_dim=256,
        logit_softcap=30.0,
        attn_softcap=50.0,
        tie_embeddings=True,
        source="arXiv:2408.00118",
    )
)
