"""Moonlight-16B-A3B (Moonshot) fine-grained MoE.

[hf:moonshotai/Moonlight-16B-A3B] — 48L, d_model=2048, 16 heads (kv=16),
per-expert FFN d_ff=1408, vocab=163840, 64 routed experts top-6.
The assignment tags it "dense" but the parameterisation is MoE; we follow
the parameters (64e top-6).
"""

from repro.configs.base import ArchConfig, LayerSpec, MoESpec, register

MOONSHOT_16B = register(
    ArchConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=163840,
        pattern=(LayerSpec(kind="attn", moe=True),),
        moe=MoESpec(n_experts=64, top_k=6, d_expert=1408),
        source="hf:moonshotai/Moonlight-16B-A3B",
    )
)
