"""Multi-tenant serving: paged INT8 KV cache + continuous batching.

Layers (ROADMAP serving item):

* `repro.serve.paging` — page pools, free-list allocator, page tables.
* `repro.serve.decode` — the jitted batched paged decode/prefill steps
  (multi-adapter: B requests, B different adapters per step).
* `repro.serve.engine` — :class:`ServeEngine`: continuous batching,
  size-bucketed jit shapes, per-request streaming handles.
"""

from repro.serve.engine import RequestHandle, ServeEngine
from repro.serve.paging import (
    OutOfPagesError,
    PageAllocator,
    PageTable,
    init_pools,
    kv_bytes_per_token,
)

__all__ = [
    "OutOfPagesError",
    "PageAllocator",
    "PageTable",
    "RequestHandle",
    "ServeEngine",
    "init_pools",
    "kv_bytes_per_token",
]
