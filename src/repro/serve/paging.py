"""Paged KV cache: page pools, free-list allocator, page tables.

The multi-tenant serving engine (`repro.serve.engine`) keeps every
request's KV cache in fixed-size **pages** drawn from one global pool,
so admission/completion never reshapes a device buffer — a request's
cache is just the list of page ids its page-table row points at.

Device side, one *pool* per attention pattern position (stacked over
periods like `repro.models.backbone.init_cache`):

* ``int8`` policy — pages live in block-absmax storage form,
  ``{"q": int8 (n_p, n_pages, page, Hkv, hd),
     "scale": f32 (n_p, n_pages, page, Hkv)}``
  per K and V — the paper's Eq. 1 absmax quantization at per-(token,
  kv-head) granularity, the same scheme as
  `repro.models.layers.quantize_kv_token`. The payload is dequantized
  **only** inside the attention kernels (in-VMEM); this module writes
  pages but never reads them back to f32 (the palint ``storage-form``
  rule pins that contract).
* ``f32``/``bf16`` — plain arrays of the same page geometry, kept for
  parity testing and as the byte-stability reference.

Page id **0 is the null page**: allocators never hand it out, padded
prompt positions and masked batch rows scatter their garbage there, and
attention masks it out by position. Non-attention pattern positions
(SSM layers in hybrid archs) are not paged — their O(1) per-request
states live in per-slot rows (`init_state_rows`).

Host side, :class:`PageAllocator` (a free list) and :class:`PageTable`
(per-request page-id runs with a ragged ``indptr`` view and a dense
``(B, max_pages)`` block-table export for the kernels) are plain
Python — they run between decode steps, never inside jit.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ssm

KV_POLICIES = ("f32", "bf16", "int8")


class OutOfPagesError(RuntimeError):
    """The pool has no free page left — admit fewer/shorter requests."""


# ---------------------------------------------------------------------------
# Device pools
# ---------------------------------------------------------------------------


def quantize_kv_pages(t: jax.Array):
    """Per-(token, kv-head) absmax INT8 over the last axis — the same
    math as `repro.models.layers.quantize_kv_token`, shape-polymorphic
    (t: (..., Hkv, hd) → int8 payload + f32 scale (..., Hkv))."""
    absmax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _attn_pool(cfg, n_pages: int, page: int, policy: str):
    shape = (cfg.n_periods, n_pages, page, cfg.n_kv_heads, cfg.hd)
    if policy == "int8":
        entry = {
            "q": jnp.zeros(shape, jnp.int8),
            "scale": jnp.zeros(shape[:-1], jnp.float32),
        }
        return {"k": entry, "v": jax.tree.map(jnp.copy, entry)}
    dtype = jnp.bfloat16 if policy == "bf16" else jnp.float32
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_state_rows(cfg, spec, n_slots: int):
    """Per-slot recurrent state rows for one non-attention pattern
    position, stacked over periods — (n_p, n_slots, ...) leaves."""
    if spec.kind == "mamba":
        single = ssm.init_mamba_cache(cfg, n_slots, jnp.float32)
    elif spec.kind == "mlstm":
        single = ssm.init_mlstm_cache(cfg, n_slots)
    elif spec.kind == "slstm":
        single = ssm.init_slstm_cache(cfg, n_slots)
    else:
        raise ValueError(spec.kind)
    return jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (cfg.n_periods,) + t.shape), single
    )


def init_pools(cfg, n_pages: int, page: int, n_slots: int, policy: str = "int8"):
    """One pool entry per pattern position: paged KV for attention,
    per-slot state rows for SSM kinds. ``n_pages`` includes the null
    page (usable pages = n_pages - 1)."""
    if policy not in KV_POLICIES:
        raise ValueError(f"kv policy must be one of {KV_POLICIES}, got {policy!r}")
    pools = []
    for spec in cfg.pattern:
        if spec.kind == "attn":
            pools.append(_attn_pool(cfg, n_pages, page, policy))
        else:
            pools.append(init_state_rows(cfg, spec, n_slots))
    return pools


def is_paged_entry(entry) -> bool:
    """True for an attention page pool ({"k": ..., "v": ...})."""
    return isinstance(entry, dict) and set(entry) == {"k", "v"}


def entry_page_size(entry) -> int:
    leaf = entry["k"]["q"] if isinstance(entry["k"], dict) else entry["k"]
    return leaf.shape[-3]


# ---------------------------------------------------------------------------
# Page writes (device, called inside the jitted steps)
# ---------------------------------------------------------------------------


def _scatter(pool: jax.Array, vals: jax.Array, pages: jax.Array, offs: jax.Array,
             periods: bool):
    """pool: (n_pages, page, ...) or — with ``periods`` — a leading n_p
    axis; vals: matching (N, ...) / (n_p, N, ...). Duplicate (page, off)
    targets (the null page) resolve arbitrarily — it holds garbage by
    contract."""
    if periods:
        return pool.at[:, pages, offs].set(vals)
    return pool.at[pages, offs].set(vals)


def _token_coords(block_tables, lengths, page: int):
    """Page/offset of the slot each request's *next* token lands in."""
    max_pages = block_tables.shape[1]
    rows = jnp.arange(block_tables.shape[0])
    idx = jnp.minimum(lengths // page, max_pages - 1)
    return block_tables[rows, idx], lengths % page


def write_token_kv(entry, k, v, block_tables, lengths):
    """Write one new token's K/V into the pages. ``entry`` is one
    *period slice* of an attention pool (no leading n_p axis);
    k, v: (B, 1, Hkv, hd) post-rope; lengths: (B,) write index.
    Masked rows must point their block-table row at the null page."""
    page = entry_page_size(entry)
    pages, offs = _token_coords(block_tables, lengths, page)
    k, v = k[:, 0], v[:, 0]  # (B, Hkv, hd)
    if isinstance(entry["k"], dict):
        kq, ks = quantize_kv_pages(k)
        vq, vs = quantize_kv_pages(v)
        return {
            "k": {"q": _scatter(entry["k"]["q"], kq, pages, offs, False),
                  "scale": _scatter(entry["k"]["scale"], ks, pages, offs, False)},
            "v": {"q": _scatter(entry["v"]["q"], vq, pages, offs, False),
                  "scale": _scatter(entry["v"]["scale"], vs, pages, offs, False)},
        }
    return {
        "k": _scatter(entry["k"], k.astype(entry["k"].dtype), pages, offs, False),
        "v": _scatter(entry["v"], v.astype(entry["v"].dtype), pages, offs, False),
    }


def write_prompt_kv(entry, k, v, block_tables, lengths):
    """Scatter a whole prompt's K/V into the pages in one shot (the
    prefill path). ``entry`` keeps its leading n_p axis; k, v:
    (n_p, B, S, Hkv, hd); positions ``s >= lengths[b]`` (padding) go to
    the null page."""
    page = entry_page_size(entry)
    n_p, B, S = k.shape[:3]
    max_pages = block_tables.shape[1]
    s_idx = jnp.arange(S)
    pages = block_tables[
        jnp.arange(B)[:, None], jnp.minimum(s_idx[None, :] // page, max_pages - 1)
    ]
    valid = s_idx[None, :] < lengths[:, None]
    pages = jnp.where(valid, pages, 0)
    offs = jnp.broadcast_to(s_idx % page, (B, S))
    pages_f, offs_f = pages.reshape(-1), offs.reshape(-1)

    def flat(t):
        return t.reshape((n_p, B * S) + t.shape[3:])

    if isinstance(entry["k"], dict):
        kq, ks = quantize_kv_pages(k)
        vq, vs = quantize_kv_pages(v)
        return {
            "k": {"q": _scatter(entry["k"]["q"], flat(kq), pages_f, offs_f, True),
                  "scale": _scatter(entry["k"]["scale"], flat(ks), pages_f, offs_f, True)},
            "v": {"q": _scatter(entry["v"]["q"], flat(vq), pages_f, offs_f, True),
                  "scale": _scatter(entry["v"]["scale"], flat(vs), pages_f, offs_f, True)},
        }
    return {
        "k": _scatter(entry["k"], flat(k.astype(entry["k"].dtype)), pages_f, offs_f, True),
        "v": _scatter(entry["v"], flat(v.astype(entry["v"].dtype)), pages_f, offs_f, True),
    }


def kv_bytes_per_token(cfg, policy: str) -> int:
    """HBM bytes one token's KV occupies across all attention layers —
    the serving-memory figure the decode benchmark reports."""
    n_attn = sum(1 for s in cfg.pattern if s.kind == "attn") * cfg.n_periods
    width = {"f32": 4, "bf16": 2, "int8": 1}[policy]
    per_layer = 2 * cfg.n_kv_heads * cfg.hd * width
    if policy == "int8":
        per_layer += 2 * cfg.n_kv_heads * 4  # f32 absmax scales
    return n_attn * per_layer


# ---------------------------------------------------------------------------
# Host-side allocator + page table
# ---------------------------------------------------------------------------


class PageAllocator:
    """Free-list block allocator over page ids ``1..n_pages-1`` (page 0
    is the null page and is never handed out)."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need at least 2 pages (one is the null page)")
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, 0, -1))  # pop() -> low ids first

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise OutOfPagesError(
                f"requested {n} pages, {len(self._free)} free")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if not 0 < p < self.n_pages:
                raise ValueError(f"bad page id {p}")
        self._free.extend(pages)


class PageTable:
    """Per-request page-id runs over a shared :class:`PageAllocator`.

    Each open request owns an ordered page list (token ``t`` lives in
    its ``t // page``-th page). :meth:`ragged` is the canonical
    ``(indptr, pages)`` view; :meth:`dense` exports the rectangular
    ``(B, max_pages)`` block table + lengths the kernels consume (rows
    in the caller's order, unused entries = the null page)."""

    def __init__(self, allocator: PageAllocator, page: int, max_pages: int):
        self.allocator = allocator
        self.page = page
        self.max_pages = max_pages
        self._pages: Dict[int, List[int]] = {}
        self._len: Dict[int, int] = {}

    def open(self, rid: int, n_tokens: int = 0) -> None:
        if rid in self._pages:
            raise ValueError(f"request {rid} already open")
        self._pages[rid], self._len[rid] = [], 0
        if n_tokens:
            self.extend_to(rid, n_tokens)
            self._len[rid] = n_tokens

    def close(self, rid: int) -> None:
        self.allocator.free(self._pages.pop(rid))
        del self._len[rid]

    def length(self, rid: int) -> int:
        return self._len[rid]

    def extend_to(self, rid: int, n_tokens: int) -> None:
        """Grow the page run to cover ``n_tokens`` tokens (allocates)."""
        need = -(-n_tokens // self.page)
        if need > self.max_pages:
            raise OutOfPagesError(
                f"request {rid}: {n_tokens} tokens need {need} pages "
                f"> max_pages {self.max_pages}")
        have = len(self._pages[rid])
        if need > have:
            self._pages[rid].extend(self.allocator.alloc(need - have))

    def append_token(self, rid: int) -> None:
        """Account one more token, allocating a page on a boundary."""
        self.extend_to(rid, self._len[rid] + 1)
        self._len[rid] += 1

    def ragged(self, rids: Optional[Sequence[int]] = None):
        """(indptr (B+1,), pages (nnz,)) int32 — per-request page runs
        concatenated, CSR style."""
        rids = list(self._pages) if rids is None else list(rids)
        indptr = np.zeros(len(rids) + 1, np.int32)
        flat: List[int] = []
        for i, rid in enumerate(rids):
            flat.extend(self._pages[rid])
            indptr[i + 1] = len(flat)
        return indptr, np.asarray(flat, np.int32)

    def dense(self, rids: Sequence[int], rows: Optional[int] = None
              ) -> Tuple[np.ndarray, np.ndarray]:
        """((rows, max_pages) block table, (rows,) lengths) int32 —
        rows beyond ``len(rids)`` are null-page/zero-length padding."""
        rows = len(rids) if rows is None else rows
        bt = np.zeros((rows, self.max_pages), np.int32)
        lengths = np.zeros(rows, np.int32)
        for i, rid in enumerate(rids):
            run = self._pages[rid]
            bt[i, : len(run)] = run
            lengths[i] = self._len[rid]
        return bt, lengths
