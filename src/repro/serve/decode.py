"""Batched paged decode + prefill steps — the engine's jitted units.

:func:`paged_pac_decode_step` is the paged, multi-adapter twin of
`repro.core.steps.pac_decode_step`: one step serves B requests with B
*different* adapters (a gathered ``(B, ...)`` adapter batch, see
`repro.core.parallel_adapters.gather_adapters`) against KV that lives in
the shared page pool — each request's cache is its block-table row, so
batch composition is free to change between steps without reshaping any
device buffer. Per-request ``lengths`` replace the single scalar ``pos``
(continuous batching is ragged by construction).

Attention dispatches through ``ops.paged_attention`` — the OpSet seam —
so ``--kernels pallas`` runs the Pallas page-walking kernel
(`repro.kernels.paged_attention`) and ``ref`` the gather-then-dense
oracle; INT8 pools are dequantized inside those kernels only.

:func:`paged_prefill` is the one-shot prompt path: a single batched
forward with KV capture (``apply_block(..., return_kv=True)``) scattered
into the pages, replacing the token-by-token teacher-forcing loop the
serve examples used to run. Attention-only patterns — SSM/hybrid archs
have no forward-returns-final-state API and take the engine's stepwise
fallback (prompt tokens fed through the decode step) instead.
"""

from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.core.opset import get_opset
from repro.core.parallel_adapters import (
    batched_adapter_decode,
    batched_adapter_prefill,
)
from repro.models import ssm
from repro.models.backbone import (
    _REF_OPS,
    apply_block,
    embed_inputs,
    logits_from_hidden,
)
from repro.models.layers import _project_qkv, mlp_forward
from repro.models.moe import moe_forward
from repro.serve.paging import write_prompt_kv, write_token_kv


def _resolve_ops(kernel_impl, interpret):
    if kernel_impl == "ref":
        return _REF_OPS
    return get_opset(kernel_impl, interpret=interpret)


def _paged_attention_block(p, h, cfg, spec, entry, block_tables, lengths, ops):
    """One attention mixer against the page pool. h: (B,1,d);
    entry: one period slice of an attention pool. Returns (mix, entry')."""
    B = h.shape[0]
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(lengths[None, :, None], (3, B, 1)).astype(jnp.int32)
    else:
        positions = lengths[:, None].astype(jnp.int32)
    q, k, v = _project_qkv(p, h, cfg, positions, ops)
    entry = write_token_kv(entry, k, v, block_tables, lengths)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    qh = q[:, 0].reshape(B, cfg.n_kv_heads, n_rep, cfg.hd)
    if isinstance(entry["k"], dict):  # INT8 pages: payload + scales
        o = ops.paged_attention(
            qh, entry["k"]["q"], entry["v"]["q"],
            entry["k"]["scale"], entry["v"]["scale"],
            block_tables, lengths, cfg, spec,
        )
    else:
        o = ops.paged_attention(
            qh, entry["k"], entry["v"], None, None,
            block_tables, lengths, cfg, spec,
        )
    o = o.reshape(B, 1, cfg.n_heads * cfg.hd).astype(h.dtype)
    return ops.matmul(o, p["wo"]), entry


def _apply_block_paged(p, x, cfg, spec, entry, block_tables, lengths, ops):
    """`apply_block_decode` with the attention cache paged; SSM kinds run
    on per-slot state rows (entry: (B, ...) leaves) unchanged."""
    p = ops.prepare_block(p, spec)
    h = ops.rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.kind == "attn":
        mix, new_entry = _paged_attention_block(
            p["mixer"], h, cfg, spec, entry, block_tables, lengths, ops
        )
    elif spec.kind == "mamba":
        mix, new_entry = ssm.mamba_decode(p["mixer"], h, cfg, entry)
    elif spec.kind == "mlstm":
        mix, new_entry = ssm.mlstm_decode(p["mixer"], h, cfg, entry)
    elif spec.kind == "slstm":
        mix, new_entry = ssm.slstm_decode(p["mixer"], h, cfg, entry)
    else:
        raise ValueError(spec.kind)
    x = x + mix
    if "ffn" in p:
        h = ops.rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.moe and cfg.moe is not None:
            # decode: T = B tokens — widen capacity like apply_block_decode
            x = x + moe_forward(
                p["ffn"], h, cfg.moe, capacity_factor=2.0 * cfg.moe.capacity_factor
            )
        else:
            x = x + mlp_forward(p["ffn"], h, ops=ops)
    return x, new_entry


def paged_pac_decode_step(
    backbone_params,
    adapter_batch,
    tokens: jax.Array,
    pools: List,
    block_tables: jax.Array,
    lengths: jax.Array,
    adapter_cache,
    *,
    cfg,
    r: int = 8,
    kernel_impl: str = "ref",
    interpret: Optional[bool] = None,
):
    """One continuous-batching decode step: B requests, B adapters.

    tokens: (B,1) int32; pools: per pattern position — attention entries
    are whole page pools (leaves (n_p, n_pages, page, ...)), SSM entries
    per-slot state rows sliced to B; block_tables: (B, max_pages) int32;
    lengths: (B,) int32 per-request write index; adapter_batch /
    adapter_cache: ``None`` to serve the bare backbone, else a gathered
    (B, ...) adapter tree + its (n_p, B, L, ...) cache.

    Returns (logits (B,1,V), pools', adapter_cache'). Row b equals a
    B=1 call for request b alone — the batch never mixes rows.
    """
    ops = _resolve_ops(kernel_impl, interpret)
    block_tables = block_tables.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    x = ops.embed_lookup(backbone_params["embed"], tokens)

    def period_fn(carry, xs):
        block_slice, pool_slice = xs
        h = carry
        new_entries = []
        for i, spec in enumerate(cfg.pattern):
            h, ne = _apply_block_paged(
                block_slice[i], h, cfg, spec, pool_slice[i],
                block_tables, lengths, ops,
            )
            new_entries.append(ne)
        return h, (tuple(new_entries), h)

    b_final, (new_pools, taps_t) = jax.lax.scan(
        period_fn, x, (tuple(backbone_params["blocks"]), tuple(pools))
    )
    if adapter_batch is None:
        side, new_acache = 0.0, adapter_cache
    else:
        side, new_acache = batched_adapter_decode(
            adapter_batch, cfg, x, taps_t, adapter_cache, lengths, r
        )
    logits = logits_from_hidden(backbone_params, cfg, b_final + side)
    return logits, list(new_pools), new_acache


def paged_prefill(
    backbone_params,
    adapter_batch,
    tokens: jax.Array,
    lengths: jax.Array,
    pools: List,
    block_tables: jax.Array,
    *,
    cfg,
    max_len: int,
    r: int = 8,
    kernel_impl: str = "ref",
    interpret: Optional[bool] = None,
):
    """One-shot prompt ingestion: a single batched forward whose captured
    per-layer K/V is scattered into the page pool, plus the adapter-side
    prefill — the prompt is processed once, not token by token.

    tokens: (B, S) int32, left-aligned, padded past ``lengths[b]``
    (padding KV lands on the null page); block_tables must already cover
    ``ceil(lengths/page)`` pages per row. Returns
    (last-token logits (B,1,V), pools', adapter_caches) — adapter caches
    in the `init_adapter_cache` layout, ``None`` when ``adapter_batch``
    is.
    """
    if any(s.kind != "attn" for s in cfg.pattern):
        raise ValueError(
            "one-shot paged prefill needs an all-attention pattern; "
            f"{cfg.name} has {tuple(s.kind for s in cfg.pattern)} — "
            "the engine's stepwise prompt path covers SSM/hybrid archs"
        )
    ops = _resolve_ops(kernel_impl, interpret)
    block_tables = block_tables.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    x, positions = embed_inputs(backbone_params, cfg, {"tokens": tokens}, ops=ops)
    x0 = x

    def period_fn(carry, block_slice):
        h = carry
        kvs = []
        for i, spec in enumerate(cfg.pattern):
            h, kv = apply_block(
                block_slice[i], h, cfg, spec, positions, ops=ops, return_kv=True
            )
            kvs.append(kv)
        return h, (tuple(kvs), h)

    b_final, (kvs, taps) = jax.lax.scan(
        period_fn, x, tuple(backbone_params["blocks"])
    )
    new_pools = [
        write_prompt_kv(pools[i], k, v, block_tables, lengths)
        for i, (k, v) in enumerate(kvs)
    ]
    if adapter_batch is None:
        side, acaches = 0.0, None
    else:
        side, acaches = batched_adapter_prefill(
            adapter_batch, cfg, x0, taps, positions, max_len, r
        )
    h = b_final + side
    idx = jnp.maximum(lengths - 1, 0)
    h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)
    logits = logits_from_hidden(backbone_params, cfg, h_last)
    return logits, new_pools, acaches
