"""Continuous-batching multi-tenant serving engine.

One :class:`ServeEngine` serves many concurrent requests, each with its
own fine-tuned adapter, over a single shared KV page pool
(`repro.serve.paging`) — the paper's personal-LLM endgame: every edge
user's side network is a few MB, so one host serves a whole pool of
personalised models from one frozen (quantized) backbone.

Scheduling model:

* **Continuous batching** — requests join and leave the running decode
  batch between steps. A request's cache is its page-table row, so
  admission/completion never reshapes device state; only the small
  per-slot rows (adapter cache, SSM states) live at fixed row indices,
  kept compacted to a prefix by swap-remove on completion.
* **Fixed jit shapes** — each decode step runs at the smallest
  power-of-two bucket ≥ the active count (capped at ``max_batch``), so
  the engine compiles a handful of shapes up front and admission never
  retriggers compilation (``n_traces`` counts traces; tests pin it).
* **Two prompt paths** — all-attention archs prefill the whole prompt in
  one batched forward with KV capture (`repro.serve.decode.paged_prefill`);
  SSM/hybrid archs fall back to *stepwise* prefill, feeding prompt
  tokens through the same paged decode step (no extra compilation).

Requests stream: :meth:`submit` returns a :class:`RequestHandle` whose
``tokens()`` generator yields ids as they are produced (thread-safe —
:meth:`start` runs the step loop in a background thread; or call
:meth:`drain` inline). Sampling is greedy (argmax), the deterministic
path the parity tests pin.
"""

from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.parallel_adapters import (
    gather_adapters,
    init_adapter_cache,
    stack_adapters,
)
from repro.serve import paging
from repro.serve.decode import paged_pac_decode_step, paged_prefill


def _bucket(n: int, cap: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


class RequestHandle:
    """Streaming view of one request."""

    def __init__(self, rid: int, prompt: Sequence[int]):
        self.rid = rid
        self.prompt = list(prompt)
        self._queue = queue.Queue()
        self._done = threading.Event()
        self._generated: List[int] = []

    def _emit(self, tok: int) -> None:
        self._generated.append(tok)
        self._queue.put(tok)

    def _finish(self) -> None:
        self._done.set()
        self._queue.put(None)

    def tokens(self):
        """Yield generated token ids as they arrive (blocks; ends when
        the request completes)."""
        while True:
            t = self._queue.get()
            if t is None:
                return
            yield t

    def result(self, timeout: Optional[float] = None) -> List[int]:
        """Block until completion; returns all generated token ids."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} still running")
        return list(self._generated)

    @property
    def done(self) -> bool:
        return self._done.is_set()


class _Request:
    __slots__ = (
        "rid", "prompt", "max_new", "adapter_idx", "handle",
        "last_token", "n_generated", "n_consumed", "finished",
    )

    def __init__(self, rid, prompt, max_new, adapter_idx, n_consumed):
        self.rid = rid
        self.prompt = list(prompt)
        self.max_new = max_new
        self.adapter_idx = adapter_idx
        self.handle = RequestHandle(rid, prompt)
        self.last_token = self.prompt[-1]
        self.n_generated = 0
        self.n_consumed = n_consumed  # prompt tokens already in the cache
        self.finished = False

    def next_input(self) -> int:
        if self.n_consumed < len(self.prompt):
            return self.prompt[self.n_consumed]
        return self.last_token

    def advance(self) -> bool:
        """Account one step. True while the step only consumed a prompt
        token (stepwise prefill — nothing to emit yet)."""
        if self.n_consumed < len(self.prompt):
            self.n_consumed += 1
            return self.n_consumed < len(self.prompt)
        return False


class ServeEngine:
    """Multi-tenant paged-KV serving engine (see module docstring).

    backbone_params may be the quantized frozen tree (pair with
    ``kernel_impl="pallas"`` to decode on still-quantized weights);
    ``adapters`` maps user name → fine-tuned adapter tree, stacked once
    into a resident bank and gathered per request row at each step.
    ``kv_policy``: "int8" (paged block-absmax storage form), "bf16" or
    "f32" (parity/reference). ``n_pages`` defaults to enough for
    ``max_batch`` full-length requests (+ the null page).
    """

    def __init__(
        self,
        backbone_params,
        cfg,
        adapters: Optional[Dict[str, dict]] = None,
        *,
        r: int = 8,
        kernel_impl: str = "ref",
        kv_policy: str = "int8",
        page_size: int = 8,
        max_len: int = 128,
        max_batch: int = 8,
        n_pages: Optional[int] = None,
        eos_id: Optional[int] = None,
        interpret: Optional[bool] = None,
    ):
        self.backbone = backbone_params
        self.cfg = cfg
        self.r = r
        self.kernel_impl = kernel_impl
        self.kv_policy = kv_policy
        self.page = page_size
        self.max_len = max_len
        self.max_batch = max_batch
        self.eos_id = eos_id
        self.interpret = interpret
        self.max_pages = -(-max_len // page_size)
        if n_pages is None:
            n_pages = max_batch * self.max_pages + 1
        self.pools = paging.init_pools(cfg, n_pages, page_size, max_batch, kv_policy)
        self.allocator = paging.PageAllocator(n_pages)
        self.table = paging.PageTable(self.allocator, page_size, self.max_pages)
        self.prefill_mode = (
            "oneshot" if all(s.kind == "attn" for s in cfg.pattern) else "stepwise"
        )
        if adapters:
            self.adapter_names = list(adapters)
            self._adapter_idx = {n: i for i, n in enumerate(self.adapter_names)}
            self.bank = stack_adapters([adapters[n] for n in self.adapter_names])
            self.acache = init_adapter_cache(cfg, max_batch, max_len, r)
        else:
            self.adapter_names, self._adapter_idx = [], {}
            self.bank, self.acache = None, None
        self._pending: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._active: List[_Request] = []
        self._next_rid = 0
        self._decode_fns: Dict[int, object] = {}
        self._prefill_fns: Dict[tuple, object] = {}
        self.n_traces = 0  # jit trace counter — admission must not grow it
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- submission -----------------------------------------------------

    def submit(
        self,
        prompt: Sequence[int],
        adapter: Optional[str] = None,
        max_new_tokens: int = 16,
    ) -> RequestHandle:
        """Queue a request; returns its streaming handle (thread-safe)."""
        prompt = list(int(t) for t in prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_len {self.max_len}"
            )
        if self.bank is not None:
            name = adapter if adapter is not None else self.adapter_names[0]
            if name not in self._adapter_idx:
                raise KeyError(f"unknown adapter {name!r}; have {self.adapter_names}")
            adapter_idx = self._adapter_idx[name]
        else:
            if adapter is not None:
                raise ValueError("engine was built without adapters")
            adapter_idx = 0
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            n_consumed = len(prompt) if self.prefill_mode == "oneshot" else 0
            req = _Request(rid, prompt, max_new_tokens, adapter_idx, n_consumed)
            self._pending.append(req)
        return req.handle

    def _pop_pending(self) -> Optional[_Request]:
        with self._lock:
            return self._pending.popleft() if self._pending else None

    def _push_front(self, req: _Request) -> None:
        with self._lock:
            self._pending.appendleft(req)

    def _has_pending(self) -> bool:
        with self._lock:
            return bool(self._pending)

    # -- jitted steps (cached per bucket shape) -------------------------

    def _paged_positions(self) -> List[bool]:
        return [s.kind == "attn" for s in self.cfg.pattern]

    def _decode_fn(self, bucket: int):
        if bucket not in self._decode_fns:
            cfg, r = self.cfg, self.r
            impl, interp = self.kernel_impl, self.interpret
            has_adapter = self.bank is not None
            paged = self._paged_positions()

            def fn(backbone, bank, user_idx, tokens, pools, bt, lengths, acache):
                self.n_traces += 1  # executes at trace time only
                B = tokens.shape[0]
                pools_b = [
                    e if is_attn else jax.tree.map(lambda t: t[:, :B], e)
                    for e, is_attn in zip(pools, paged)
                ]
                if has_adapter:
                    ab = gather_adapters(bank, user_idx)
                    ac_b = jax.tree.map(lambda t: t[:, :B], acache)
                else:
                    ab, ac_b = None, None
                logits, new_pools_b, new_ac_b = paged_pac_decode_step(
                    backbone, ab, tokens, pools_b, bt, lengths, ac_b,
                    cfg=cfg, r=r, kernel_impl=impl, interpret=interp,
                )
                new_pools = [
                    nb if is_attn
                    else jax.tree.map(
                        lambda full, new: full.at[:, :B].set(new), e, nb)
                    for e, nb, is_attn in zip(pools, new_pools_b, paged)
                ]
                new_acache = (
                    jax.tree.map(
                        lambda full, new: full.at[:, :B].set(new),
                        acache, new_ac_b)
                    if has_adapter else acache
                )
                return logits, new_pools, new_acache

            self._decode_fns[bucket] = jax.jit(fn)
        return self._decode_fns[bucket]

    def _prefill_fn(self, bucket: int, s_pad: int):
        key = (bucket, s_pad)
        if key not in self._prefill_fns:
            cfg, r, max_len = self.cfg, self.r, self.max_len
            impl, interp = self.kernel_impl, self.interpret
            has_adapter = self.bank is not None

            def fn(backbone, bank, user_idx, tokens, lengths, pools, bt,
                   acache, row_idx):
                self.n_traces += 1
                ab = gather_adapters(bank, user_idx) if has_adapter else None
                logits, new_pools, acaches = paged_prefill(
                    backbone, ab, tokens, lengths, pools, bt,
                    cfg=cfg, max_len=max_len, r=r,
                    kernel_impl=impl, interpret=interp,
                )
                if has_adapter:
                    # row_idx of padding lanes is out of bounds on purpose:
                    # mode="drop" discards their scatter
                    acache = jax.tree.map(
                        lambda full, new: full.at[:, row_idx].set(
                            new, mode="drop"),
                        acache, acaches,
                    )
                return logits, new_pools, acache

            self._prefill_fns[key] = jax.jit(fn)
        return self._prefill_fns[key]

    # -- row-state bookkeeping (adapter cache + SSM states) -------------

    def _move_row(self, src: int, dst: int) -> None:
        move = lambda tree: jax.tree.map(lambda t: t.at[:, dst].set(t[:, src]), tree)
        if self.acache is not None:
            self.acache = move(self.acache)
        self.pools = [
            e if is_attn else move(e)
            for e, is_attn in zip(self.pools, self._paged_positions())
        ]

    def _zero_row(self, row: int) -> None:
        zero = lambda tree: jax.tree.map(
            lambda t: t.at[:, row].set(jnp.zeros_like(t[:, row])), tree)
        if self.acache is not None:
            self.acache = zero(self.acache)
        self.pools = [
            e if is_attn else zero(e)
            for e, is_attn in zip(self.pools, self._paged_positions())
        ]

    # -- admission ------------------------------------------------------

    def _admit(self) -> None:
        new_reqs: List[_Request] = []
        row0 = len(self._active)
        while len(self._active) < self.max_batch:
            req = self._pop_pending()
            if req is None:
                break
            if self.prefill_mode == "oneshot":
                need = -(-len(req.prompt) // self.page)
                if need > self.allocator.free_pages:
                    self._push_front(req)  # not enough pages yet
                    break
                self.table.open(req.rid, len(req.prompt))
            else:
                if self.allocator.free_pages < 1:
                    self._push_front(req)
                    break
                self.table.open(req.rid, 0)
                self._zero_row(len(self._active))
            self._active.append(req)
            new_reqs.append(req)
        if new_reqs and self.prefill_mode == "oneshot":
            self._run_prefill(new_reqs, row0)

    def _run_prefill(self, reqs: List[_Request], row0: int) -> None:
        n = len(reqs)
        bucket = _bucket(n, self.max_batch)
        s_max = max(len(r.prompt) for r in reqs)
        s_pad = _bucket(s_max, 1 << 30)
        tokens = np.zeros((bucket, s_pad), np.int32)
        user_idx = np.zeros(bucket, np.int32)
        row_idx = np.full(bucket, self.max_batch, np.int32)  # OOB = dropped
        for i, req in enumerate(reqs):
            tokens[i, : len(req.prompt)] = req.prompt
            user_idx[i] = req.adapter_idx
            row_idx[i] = row0 + i
        bt, lengths = self.table.dense([r.rid for r in reqs], rows=bucket)
        fn = self._prefill_fn(bucket, s_pad)
        logits, self.pools, self.acache = fn(
            self.backbone, self.bank, user_idx, tokens, lengths,
            self.pools, bt, self.acache, row_idx,
        )
        toks = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i, req in enumerate(reqs):
            self._accept_token(req, int(toks[i]))

    # -- the step loop --------------------------------------------------

    def _accept_token(self, req: _Request, tok: int) -> None:
        req.last_token = tok
        req.n_generated += 1
        req.handle._emit(tok)
        if req.n_generated >= req.max_new or tok == self.eos_id:
            req.finished = True

    def _retire_finished(self) -> None:
        for idx in range(len(self._active) - 1, -1, -1):
            req = self._active[idx]
            if not req.finished:
                continue
            last = len(self._active) - 1
            if idx != last:  # swap-remove keeps rows a compact prefix
                self._move_row(last, idx)
                self._active[idx] = self._active[last]
            self._active.pop()
            self.table.close(req.rid)
            req.handle._finish()

    def step(self) -> bool:
        """Admit pending requests and run one decode step for the whole
        active batch. Returns True while any work remains."""
        self._admit()
        self._retire_finished()  # prefill alone may complete a request
        if not self._active:
            return self._has_pending()
        n = len(self._active)
        bucket = _bucket(n, self.max_batch)
        rids = []
        for req in self._active:
            # page for the incoming token, before the dense export
            self.table.extend_to(req.rid, self.table.length(req.rid) + 1)
            rids.append(req.rid)
        bt, lengths = self.table.dense(rids, rows=bucket)
        tokens = np.zeros((bucket, 1), np.int32)
        user_idx = np.zeros(bucket, np.int32)
        for i, req in enumerate(self._active):
            tokens[i, 0] = req.next_input()
            user_idx[i] = req.adapter_idx
        fn = self._decode_fn(bucket)
        logits, self.pools, self.acache = fn(
            self.backbone, self.bank, user_idx, tokens,
            self.pools, bt, lengths, self.acache,
        )
        toks = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i, req in enumerate(self._active):
            self.table.append_token(req.rid)
            if req.advance():
                continue  # stepwise prefill: prompt token consumed
            self._accept_token(req, int(toks[i]))
        for req in self._active:  # out of cache room → forced completion
            if not req.finished and self.table.length(req.rid) >= self.max_len:
                req.finished = True
        self._retire_finished()
        return bool(self._active) or self._has_pending()

    def drain(self) -> None:
        """Step until every submitted request has completed."""
        while self.step():
            pass

    # -- background serving ---------------------------------------------

    def start(self) -> None:
        """Run the step loop in a daemon thread (idles when empty)."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if not self.step():
                    time.sleep(0.005)

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
