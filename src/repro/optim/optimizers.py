"""Optimizers from scratch (no optax in this environment).

AdamW and SGD+momentum over arbitrary pytrees, with global-norm clipping
and the usual schedules. State layouts are plain pytrees so they shard
with the same rules as their parameters (FSDP-friendly).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def _zeros_like_tree(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params):
    return {"mu": _zeros_like_tree(params), "nu": _zeros_like_tree(params), "count": jnp.zeros((), jnp.int32)}


def adamw_update(
    params,
    grads,
    state,
    lr=1e-3,
    b1=0.9,
    b2=0.999,
    eps=1e-8,
    weight_decay=0.01,
):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["mu"], grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["nu"], grads
    )
    mu_hat_scale = 1.0 / (1 - b1 ** c)
    nu_hat_scale = 1.0 / (1 - b2 ** c)

    def upd(p, m, v):
        step = m * mu_hat_scale / (jnp.sqrt(v * nu_hat_scale) + eps)
        return (p.astype(jnp.float32) - lr * (step + weight_decay * p.astype(jnp.float32))).astype(
            p.dtype
        )

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}


# ---------------------------------------------------------------------------
# SGD + momentum
# ---------------------------------------------------------------------------


def sgdm_init(params):
    return {"m": _zeros_like_tree(params)}


def sgdm_update(params, grads, state, lr=1e-2, momentum=0.9):
    m = jax.tree.map(lambda m_, g: momentum * m_ + g.astype(jnp.float32), state["m"], grads)
    new_params = jax.tree.map(
        lambda p, m_: (p.astype(jnp.float32) - lr * m_).astype(p.dtype), params, m
    )
    return new_params, {"m": m}


# ---------------------------------------------------------------------------
# Utilities
# ---------------------------------------------------------------------------


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def linear_warmup(step, warmup_steps: int, peak_lr: float):
    return peak_lr * jnp.minimum(1.0, (step + 1) / warmup_steps)


def cosine_schedule(step, total_steps: int, peak_lr: float, warmup_steps: int = 0, final_frac=0.1):
    warm = jnp.minimum(1.0, (step + 1) / jnp.maximum(warmup_steps, 1))
    prog = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * warm * cos
