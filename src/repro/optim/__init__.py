from repro.optim.optimizers import (  # noqa: F401
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    linear_warmup,
    sgdm_init,
    sgdm_update,
)
