"""State-space / recurrent sequence mixers: Mamba (S6), xLSTM mLSTM & sLSTM.

TPU adaptation notes (see DESIGN.md §2):

* **mLSTM** uses the chunkwise-parallel formulation — quadratic *within* a
  chunk (MXU-friendly (c×c) matmuls), recurrent *across* chunks with a
  stabilised (C, n, m) matrix-memory carry. This is the TPU-native
  re-think of the CUDA fused-scan kernel in the xLSTM release.
* **Mamba** runs the selective scan as a ``lax.scan`` over time steps,
  chunk-checkpointed so the backward pass recomputes states within a
  chunk instead of materialising (B, S, d_inner, d_state) residuals.
* **sLSTM** is inherently sequential (recurrent h→gates dependency) and
  runs as a plain scan with per-head block-diagonal recurrent weights.

All mixers expose ``*_forward`` (train/prefill over a full sequence) and
``*_decode`` (single step with an explicit state cache).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Mamba (S6)
# ---------------------------------------------------------------------------


def init_mamba(rng, cfg, dtype=jnp.float32):
    d, di, ds, dc = cfg.d_model, cfg.d_inner, cfg.ssm_d_state, cfg.ssm_d_conv
    dt_rank = max(1, d // 16)
    ks = jax.random.split(rng, 7)
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * d ** -0.5).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, di)) * dc ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_bc": (jax.random.normal(ks[2], (di, 2 * ds)) * di ** -0.5).astype(dtype),
        "w_dt1": (jax.random.normal(ks[3], (di, dt_rank)) * di ** -0.5).astype(dtype),
        "w_dt2": (jax.random.normal(ks[4], (dt_rank, di)) * dt_rank ** -0.5).astype(dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "a_log": jnp.log(jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(ks[5], (di, d)) * di ** -0.5).astype(dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,di); w: (dc,di)."""
    dc = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(xp[:, i : xp.shape[1] - dc + 1 + i, :] * w[i] for i in range(dc))
    return out + b


def _mamba_chunk(h0, xs, a):
    """Inner sequential scan over one chunk. h0: (B,di,ds)."""

    def step(h, t):
        xt, dt, bt, ct = t  # (B,di), (B,di), (B,ds), (B,ds)
        da = jnp.exp(dt[..., None] * a)  # (B,di,ds)
        h = h * da + (dt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, ct)
        return h, y

    h, ys = jax.lax.scan(step, h0, xs)
    return h, ys  # ys: (c, B, di)


def mamba_forward(p, x: jax.Array, cfg, chunk: int = 128) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    di, ds = cfg.d_inner, cfg.ssm_d_state
    xz = x @ p["in_proj"]
    xs, res = jnp.split(xz, 2, axis=-1)
    xs = jax.nn.silu(_causal_conv(xs, p["conv_w"], p["conv_b"]))  # (B,S,di)
    bc = xs @ p["w_bc"]
    b_t, c_t = jnp.split(bc.astype(jnp.float32), 2, axis=-1)  # (B,S,ds)
    dt = jax.nn.softplus((xs @ p["w_dt1"]) @ p["w_dt2"] + p["dt_bias"]).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])  # (di,ds)

    nc = max(1, -(-S // chunk))
    pad = nc * chunk - S
    seqs = (xs.astype(jnp.float32), dt, b_t, c_t)
    if pad:
        seqs = tuple(jnp.pad(t, ((0, 0), (0, pad), (0, 0))) for t in seqs)
    # (nc, chunk, B, ...)
    seqs = tuple(
        t.reshape(B, nc, chunk, t.shape[-1]).transpose(1, 2, 0, 3) for t in seqs
    )

    chunk_fn = jax.checkpoint(lambda h, t: _mamba_chunk(h, t, a))

    def outer(h, t):
        h, ys = chunk_fn(h, t)
        return h, ys

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    _, ys = jax.lax.scan(outer, h0, seqs)  # (nc, chunk, B, di)
    y = ys.transpose(2, 0, 1, 3).reshape(B, nc * chunk, di)[:, :S]
    y = y + xs.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(res.astype(jnp.float32))).astype(x.dtype)
    return y @ p["out_proj"]


def init_mamba_cache(cfg, B: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((B, cfg.d_inner, cfg.ssm_d_state), jnp.float32),
        "conv": jnp.zeros((B, cfg.ssm_d_conv - 1, cfg.d_inner), dtype),
    }


def mamba_decode(p, x: jax.Array, cfg, cache):
    """x: (B,1,d); cache: {"h": (B,di,ds), "conv": (B,dc-1,di)}."""
    B = x.shape[0]
    xz = x[:, 0] @ p["in_proj"]
    xs, res = jnp.split(xz, 2, axis=-1)  # (B,di)
    conv_in = jnp.concatenate([cache["conv"], xs[:, None, :]], axis=1)  # (B,dc,di)
    xc = jnp.einsum("bcd,cd->bd", conv_in, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    bc = (xc @ p["w_bc"]).astype(jnp.float32)
    b_t, c_t = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus((xc @ p["w_dt1"]) @ p["w_dt2"] + p["dt_bias"]).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt[..., None] * a)
    h = cache["h"] * da + (dt * xc.astype(jnp.float32))[..., None] * b_t[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, c_t) + xc.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(res.astype(jnp.float32))).astype(x.dtype)
    out = (y @ p["out_proj"])[:, None, :]
    new_cache = {"h": h, "conv": conv_in[:, 1:, :]}
    return out, new_cache


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory) — chunkwise parallel
# ---------------------------------------------------------------------------


def init_mlstm(rng, cfg, dtype=jnp.float32):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(rng, 6)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (d, H * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, H * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, H * hd)) * s).astype(dtype),
        "wi": (jax.random.normal(ks[3], (d, H)) * s).astype(jnp.float32),
        "wf": (jax.random.normal(ks[4], (d, H)) * s).astype(jnp.float32),
        "f_bias": jnp.full((H,), 3.0, jnp.float32),  # bias toward remembering
        "wo": (jax.random.normal(ks[5], (H * hd, d)) * s).astype(dtype),
        "ogate": (jax.random.normal(ks[0], (d, H * hd)) * s).astype(dtype),
    }


def _mlstm_chunk(carry, xs, hd):
    """One chunk of the stabilised chunkwise mLSTM.

    carry: C (B,H,hd,hd), n (B,H,hd), m (B,H)
    xs: q,k,v (c,B,H,hd); lf, li (c,B,H) log-gates
    """
    C, n, m = carry
    q, k, v, lf, li = xs
    c = q.shape[0]
    # cumulative log-forget inside the chunk, F_t = sum_{s<=t} lf_s
    F = jnp.cumsum(lf, axis=0)  # (c,B,H)
    Ftot = F[-1]
    # A[i,j] = F_i - F_j + li_j  (contribution of step j to step i), j<=i
    Aij = F[:, None] - F[None, :] + li[None, :]  # (c,c,B,H)
    tri = jnp.tril(jnp.ones((c, c), bool))
    Aij = jnp.where(tri[:, :, None, None], Aij, -jnp.inf)
    # carry contribution log-scale per row: F_i + m
    carry_scale = F + m[None]  # (c,B,H)
    M = jnp.maximum(jnp.max(Aij, axis=1), carry_scale)  # (c,B,H)
    M = jnp.maximum(M, -1e30)
    D = jnp.exp(Aij - M[:, None])  # (c,c,B,H) intra-chunk decay weights
    S = jnp.einsum("ibhd,jbhd->ijbh", q, k) * (hd ** -0.5) * D
    num_intra = jnp.einsum("ijbh,jbhd->ibhd", S, v)
    den_intra = jnp.sum(S, axis=1)  # (c,B,H)
    carry_w = jnp.exp(carry_scale - M)  # (c,B,H)
    num_carry = jnp.einsum("ibhd,bhde->ibhe", q, C) * (hd ** -0.5) * carry_w[..., None]
    den_carry = jnp.einsum("ibhd,bhd->ibh", q, n) * (hd ** -0.5) * carry_w
    num = num_intra + num_carry
    den = den_intra + den_carry
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-M))[..., None]
    # update carry to end of chunk
    m_new = jnp.maximum(Ftot + m, jnp.max(Ftot[None] - F + li, axis=0))
    w_old = jnp.exp(Ftot + m - m_new)  # (B,H)
    w_j = jnp.exp(Ftot[None] - F + li - m_new[None])  # (c,B,H)
    C_new = C * w_old[..., None, None] + jnp.einsum("jbhd,jbhe->bhde", k * w_j[..., None], v)
    n_new = n * w_old[..., None] + jnp.einsum("jbhd,jbh->bhd", k, w_j)
    return (C_new, n_new, m_new), h


def mlstm_forward(p, x: jax.Array, cfg) -> jax.Array:
    """x: (B,S,d) -> (B,S,d). Chunkwise-parallel stabilised mLSTM."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    chunk = min(cfg.mlstm_chunk, S)
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, H, hd)
    v = (x @ p["wv"]).reshape(B, S, H, hd)
    og = jax.nn.sigmoid((x @ p["ogate"]).reshape(B, S, H, hd))
    li = (x.astype(jnp.float32) @ p["wi"])  # log input gate (pre-exp)
    lf = jax.nn.log_sigmoid(x.astype(jnp.float32) @ p["wf"] + p["f_bias"])

    nc = max(1, -(-S // chunk))
    pad = nc * chunk - S

    def prep(t, fill=0.0):
        if pad:
            cfgpad = [(0, 0)] * t.ndim
            cfgpad[1] = (0, pad)
            t = jnp.pad(t, cfgpad, constant_values=fill)
        t = t.reshape((B, nc, chunk) + t.shape[2:])
        return jnp.moveaxis(t, 0, 2).reshape((nc, chunk, B) + t.shape[3:])

    qs, ks_, vs = prep(q.astype(jnp.float32)), prep(k.astype(jnp.float32)), prep(v.astype(jnp.float32))
    lis = prep(li, fill=-1e30)  # padded steps contribute nothing
    lfs = prep(lf, fill=0.0)

    chunk_fn = jax.checkpoint(functools.partial(_mlstm_chunk, hd=hd))
    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    # m is the log-scale of the (zero) initial carry; 0 keeps padded-chunk
    # arithmetic finite (never -inf - -inf).
    m0 = jnp.zeros((B, H), jnp.float32)
    _, hs = jax.lax.scan(chunk_fn, (C0, n0, m0), (qs, ks_, vs, lfs, lis))
    # hs: (nc, chunk, B, H, hd) -> (B, S, H, hd)
    h = jnp.moveaxis(hs.reshape(nc * chunk, B, H, hd), 1, 0)[:, :S]
    h = (h.astype(x.dtype) * og).reshape(B, S, H * hd)
    return h @ p["wo"]


def init_mlstm_cache(cfg, B: int):
    H, hd = cfg.n_heads, cfg.hd
    return {
        "C": jnp.zeros((B, H, hd, hd), jnp.float32),
        "n": jnp.zeros((B, H, hd), jnp.float32),
        "m": jnp.zeros((B, H), jnp.float32),
    }


def mlstm_decode(p, x: jax.Array, cfg, cache):
    """Single-step recurrent mLSTM. x: (B,1,d)."""
    B, _, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    xt = x[:, 0]
    q = (xt @ p["wq"]).reshape(B, H, hd).astype(jnp.float32)
    k = (xt @ p["wk"]).reshape(B, H, hd).astype(jnp.float32)
    v = (xt @ p["wv"]).reshape(B, H, hd).astype(jnp.float32)
    og = jax.nn.sigmoid((xt @ p["ogate"]).reshape(B, H, hd))
    li = xt.astype(jnp.float32) @ p["wi"]  # (B,H)
    lf = jax.nn.log_sigmoid(xt.astype(jnp.float32) @ p["wf"] + p["f_bias"])
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(lf + m, li)
    wf = jnp.exp(lf + m - m_new)
    wi = jnp.exp(li - m_new)
    C = C * wf[..., None, None] + wi[..., None, None] * jnp.einsum("bhd,bhe->bhde", k, v)
    n = n * wf[..., None] + wi[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C) * (hd ** -0.5)
    den = jnp.einsum("bhd,bhd->bh", q, n) * (hd ** -0.5)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h = (h.astype(x.dtype) * og).reshape(B, 1, H * hd)
    return h @ p["wo"], {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar memory) — sequential with block-diagonal recurrence
# ---------------------------------------------------------------------------


def init_slstm(rng, cfg, dtype=jnp.float32):
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    ks = jax.random.split(rng, 6)
    s = d ** -0.5
    return {
        "wz": (jax.random.normal(ks[0], (d, d)) * s).astype(dtype),
        "wi": (jax.random.normal(ks[1], (d, d)) * s).astype(jnp.float32),
        "wf": (jax.random.normal(ks[2], (d, d)) * s).astype(jnp.float32),
        "wog": (jax.random.normal(ks[3], (d, d)) * s).astype(dtype),
        # block-diagonal recurrent weights, one (hd,hd) block per head
        "rz": (jax.random.normal(ks[4], (H, hd, hd)) * hd ** -0.5).astype(jnp.float32),
        "ri": jnp.zeros((H, hd, hd), jnp.float32),
        "rf": jnp.zeros((H, hd, hd), jnp.float32),
        "f_bias": jnp.full((d,), 3.0, jnp.float32),
        "wo": (jax.random.normal(ks[5], (d, d)) * s).astype(dtype),
    }


def _slstm_step(p, carry, xt, H):
    """carry: (c, n, h, m) each (B, d) f32; xt pre-projected gates."""
    c, n, h, m = carry
    xz, xi, xf, xo = xt
    B, d = h.shape
    hh = h.reshape(B, H, d // H)
    rec = lambda r: jnp.einsum("bhd,hde->bhe", hh, r).reshape(B, d)
    z = jnp.tanh(xz + rec(p["rz"]))
    li = xi + rec(p["ri"])
    lf = jax.nn.log_sigmoid(xf + rec(p["rf"]) + p["f_bias"])
    o = jax.nn.sigmoid(xo)
    m_new = jnp.maximum(lf + m, li)
    c = c * jnp.exp(lf + m - m_new) + jnp.exp(li - m_new) * z
    n = n * jnp.exp(lf + m - m_new) + jnp.exp(li - m_new)
    h_new = o * c / jnp.maximum(n, 1e-6)
    return (c, n, h_new, m_new)


def slstm_forward(p, x: jax.Array, cfg, chunk: int = 64) -> jax.Array:
    B, S, d = x.shape
    H = cfg.n_heads
    xz = (x @ p["wz"]).astype(jnp.float32)
    xi = x.astype(jnp.float32) @ p["wi"]
    xf = x.astype(jnp.float32) @ p["wf"]
    xo = (x @ p["wog"]).astype(jnp.float32)

    def step(carry, t):
        new = _slstm_step(p, carry, t, H)
        return new, new[2]

    def chunk_body(carry, ts):
        return jax.lax.scan(step, carry, ts)

    nc = max(1, -(-S // chunk))
    pad = nc * chunk - S
    seqs = (xz, xi, xf, xo)
    if pad:
        seqs = tuple(jnp.pad(t, ((0, 0), (0, pad), (0, 0))) for t in seqs)
    seqs = tuple(t.reshape(B, nc, chunk, d).transpose(1, 2, 0, 3) for t in seqs)
    z0 = jnp.zeros((B, d), jnp.float32)
    carry0 = (z0, z0, z0, jnp.full((B, d), -1e30, jnp.float32))
    _, hs = jax.lax.scan(jax.checkpoint(chunk_body), carry0, seqs)
    h = hs.reshape(nc * chunk, B, d).transpose(1, 0, 2)[:, :S]
    return h.astype(x.dtype) @ p["wo"]


def init_slstm_cache(cfg, B: int):
    d = cfg.d_model
    z = jnp.zeros((B, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((B, d), -1e30, jnp.float32)}


def slstm_decode(p, x: jax.Array, cfg, cache):
    B = x.shape[0]
    xt = x[:, 0]
    t = (
        (xt @ p["wz"]).astype(jnp.float32),
        xt.astype(jnp.float32) @ p["wi"],
        xt.astype(jnp.float32) @ p["wf"],
        (xt @ p["wog"]).astype(jnp.float32),
    )
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = _slstm_step(p, carry, t, cfg.n_heads)
    out = (h.astype(x.dtype) @ p["wo"])[:, None, :]
    return out, {"c": c, "n": n, "h": h, "m": m}
