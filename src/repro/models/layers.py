"""Core transformer layers: norms, rotary embeddings, GQA attention.

Attention is implemented as a *blocked online-softmax* ("flash") function
with a custom VJP so that neither forward nor backward ever materialises
the (S×S) score matrix — the backward pass recomputes per-KV-block scores,
exactly like the TPU Pallas kernel in ``repro.kernels.flash_attention``
(this function doubles as its reference oracle at block granularity).

Supports: causal masking, sliding windows (gemma2/serving variants),
attention logit soft-capping (gemma2/grok), and GQA/MQA head layouts.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight)).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE and Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float = 1_000_000.0, sections=(2, 3, 3)
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, hd); positions: (3, B, S) — temporal/height/width ids.
    The hd/2 frequency slots are split across the three position streams in
    the ratio ``sections`` (t:h:w), per arXiv:2409.12191.
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = rope_freqs(hd, theta)  # (half,)
    total = sum(sections)
    bounds = []
    acc = 0
    for s in sections:
        acc += s
        bounds.append(half * acc // total)
    slot = jnp.arange(half)
    # stream index per frequency slot: 0,1,2
    stream = jnp.select(
        [slot < bounds[0], slot < bounds[1]], [0, 1], default=2
    )  # (half,)
    pos = positions.astype(jnp.float32)  # (3, B, S)
    # pick the position stream per frequency slot: (B, S, half)
    pos_per_slot = jnp.moveaxis(pos, 0, -1)[:, :, stream]
    angles = pos_per_slot * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked ("flash") attention with custom VJP
# ---------------------------------------------------------------------------

_NEG_INF = -1e30
_PAD_POS = 2 ** 30  # sentinel position for padded KV slots (never attended)


def _block_mask(
    q_pos: jax.Array, k_pos: jax.Array, causal: bool, window: Optional[int]
) -> jax.Array:
    """(Sq, blk) boolean mask: True = attend."""
    m = k_pos[None, :] < _PAD_POS  # padded slots are masked everywhere
    d = q_pos[:, None] - k_pos[None, :]
    if causal:
        m &= d >= 0
    if window is not None:
        m &= d < window
    return m


def _scores(q, k, scale, cap):
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    return softcap(s, cap)


def _dscores(q, k, scale, cap, ds_post):
    """VJP of _scores wrt the pre-cap logits -> propagate to q,k later."""
    if cap is None:
        return ds_post * scale
    s_pre = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    t = jnp.tanh(s_pre / cap)
    return ds_post * (1.0 - jnp.square(t)) * scale


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8)
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    block_k: int = 512,
):
    """Memory-bounded attention.

    q: (B, H, Sq, hd); k, v: (B, H, Sk, hd) — GQA repeat must already be
    applied (or use grouped heads upstream). q_pos: (Sq,), k_pos: (Sk,).
    Returns (B, H, Sq, hd) in q.dtype.
    """
    o, _ = _flash_fwd_impl(q, k, v, q_pos, k_pos, causal, window, attn_softcap, block_k)
    return o


def _flash_fwd_impl(q, k, v, q_pos, k_pos, causal, window, cap, block_k):
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    scale = 1.0 / (hd ** 0.5)
    nb = max(1, -(-Sk // block_k))
    pad = nb * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=_PAD_POS)
    # §Perf (internlm2 iter 5): scan over block *indices* and
    # dynamic-slice K/V in the body — the (nb,B,H,blk,hd) pre-stacked
    # transpose materialized 2 copies of K/V per layer (2×1.1 GB/layer
    # measured on internlm2×train_4k).
    pb = k_pos.reshape(nb, block_k)

    def body(carry, xs):
        o, m, l = carry
        j, pj = xs
        kj = jax.lax.dynamic_slice_in_dim(k, j * block_k, block_k, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(v, j * block_k, block_k, axis=2)
        s = _scores(q, kj, scale, cap)  # (B,H,Sq,blk) f32
        mask = _block_mask(q_pos, pj, causal, window)
        s = jnp.where(mask[None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        o = o * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vj.astype(jnp.float32)
        )
        return (o, m_new, l), None

    o0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    m0 = jnp.full((B, H, Sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0), (jnp.arange(nb), pb))
    l = jnp.maximum(l, 1e-30)
    o = (o / l[..., None]).astype(q.dtype)
    lse = m + jnp.log(l)
    return o, lse


def _flash_fwd(q, k, v, q_pos, k_pos, causal, window, cap, block_k):
    o, lse = _flash_fwd_impl(q, k, v, q_pos, k_pos, causal, window, cap, block_k)
    return o, (q, k, v, q_pos, k_pos, o, lse)


def _flash_bwd(causal, window, cap, block_k, res, do):
    q, k, v, q_pos, k_pos, o, lse = res
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    scale = 1.0 / (hd ** 0.5)
    do_f = do.astype(jnp.float32)
    o_f = o.astype(jnp.float32)
    delta = jnp.sum(do_f * o_f, axis=-1)  # (B,H,Sq)

    nb = max(1, -(-Sk // block_k))
    pad = nb * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=_PAD_POS)
    pb = k_pos.reshape(nb, block_k)

    def body(dq, xs):
        j, pj = xs
        kj = jax.lax.dynamic_slice_in_dim(k, j * block_k, block_k, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(v, j * block_k, block_k, axis=2)
        s = _scores(q, kj, scale, cap)
        mask = _block_mask(q_pos, pj, causal, window)
        s = jnp.where(mask[None, None], s, _NEG_INF)
        p = jnp.exp(s - lse[..., None])  # (B,H,Sq,blk)
        from repro.core.psharding import constrain_spec

        p = constrain_spec(p, ("batch", None, "model", None))  # as ds below
        dv_j = jnp.einsum("bhqk,bhqd->bhkd", p, do_f)
        dp = jnp.einsum("bhqd,bhkd->bhqk", do_f, vj.astype(jnp.float32))
        ds = p * (dp - delta[..., None])  # d wrt post-cap logits
        ds = _dscores(q, kj, scale, cap, ds)  # includes scale & cap chain
        ds = jnp.where(mask[None, None], ds, 0.0)
        # keep ds/p row-sharded (q rows live on `model` under sequence
        # parallelism): the dk/dv contractions then partial-sum + AR the
        # small (B,H,blk,hd) blocks instead of all-gathering the
        # score-sized tensors (412 GB/step on internlm2×train_4k, LoRA)
        ds = constrain_spec(ds, ("batch", None, "model", None))
        dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kj.astype(jnp.float32))
        dk_j = jnp.einsum("bhqk,bhqd->bhkd", ds, q.astype(jnp.float32))
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    dq, (dkb, dvb) = jax.lax.scan(body, dq0, (jnp.arange(nb), pb))
    dk = dkb.transpose(1, 2, 0, 3, 4).reshape(B, H, nb * block_k, hd)[:, :, :Sk]
    dv = dvb.transpose(1, 2, 0, 3, 4).reshape(B, H, nb * block_k, hd)[:, :, :Sk]
    return (
        dq.astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
        jnp.zeros_like(q_pos),
        jnp.zeros_like(k_pos),
    )


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# Full GQA attention layer (projections + rope + flash / decode paths)
# ---------------------------------------------------------------------------


def init_attention(rng, cfg, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.hd
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(k1, (d, cfg.n_heads * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, cfg.n_kv_heads * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, cfg.n_kv_heads * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (cfg.n_heads * hd, d)) * s).astype(dtype),
    }


def _project_qkv(p, x, cfg, positions, ops=None):
    B, S, _ = x.shape
    hd = cfg.hd
    mm = ops.matmul if ops is not None else (lambda a, w: a @ w)
    q = mm(x, p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = mm(x, p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = mm(x, p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.rope == "rope":
        pos = positions if positions.ndim == 2 else positions[0]
        rope = ops.apply_rope if ops is not None else apply_rope
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    elif cfg.rope == "mrope":
        mrope = ops.apply_mrope if ops is not None else apply_mrope
        q = mrope(q, positions, cfg.rope_theta)
        k = mrope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    B, S, Hkv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, Hkv, n_rep, hd)).reshape(
        B, S, Hkv * n_rep, hd
    )


def ref_attention_core(q, k, v, cfg, spec, block_k: int = 1024) -> jax.Array:
    """The jnp attention core on projected/rope'd q,k,v — the `ref`
    OpSet's attention. q: (B,S,H,hd); k,v: (B,S,Hkv,hd) -> (B,S,H·hd).

    §Perf (kimi iters F+G): gather K/V over `model` once *before* the
    GQA head expansion (n_kv_heads, not n_heads — 8× less traffic on
    kimi), and run *grouped-head* flash: the n_rep query heads sharing
    a KV head are folded into the query-row axis, so the repeated KV is
    never materialized (iter F's repeat cost +29 GB of HBM temp).
    """
    from repro.core.psharding import constrain_spec

    B, S, _, _ = q.shape
    k = constrain_spec(k, ("batch", None, None, None))
    v = constrain_spec(v, ("batch", None, None, None))
    n_rep = cfg.n_heads // cfg.n_kv_heads
    hkv, hd = cfg.n_kv_heads, cfg.hd
    pos1d = jnp.arange(S)
    # q-head g*n_rep+r shares kv head g (matches _repeat_kv layout);
    # row index inside a kv head = r*S + s.
    q = q.reshape(B, S, hkv, n_rep, hd).transpose(0, 2, 3, 1, 4)
    q = q.reshape(B, hkv, n_rep * S, hd)
    q = constrain_spec(q, ("batch", None, "model", None))
    k, v = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)  # (B,Hkv,S,hd)
    o = flash_attention(
        q, k, v, jnp.tile(pos1d, n_rep), pos1d, True, spec.window,
        cfg.attn_softcap, min(block_k, S),
    )
    o = o.reshape(B, hkv, n_rep, S, hd).transpose(0, 3, 1, 2, 4)
    return o.reshape(B, S, cfg.n_heads * hd)


def attention_forward(
    p,
    x: jax.Array,
    cfg,
    spec,
    positions: jax.Array,
    block_k: int = 1024,
    ops=None,
    return_kv: bool = False,
):
    """Full-sequence (train/prefill) attention. x: (B,S,d); positions: (B,S) or (3,B,S).

    ``return_kv=True`` additionally returns the post-rope ``(k, v)``
    pair ((B,S,Hkv,hd) each) — the prefill path of the paged serving
    engine captures them into the page pool instead of re-projecting
    the prompt token by token."""
    q, k, v = _project_qkv(p, x, cfg, positions, ops)
    if ops is not None:
        o = ops.attention(q, k, v, cfg, spec, block_k)
        out = ops.matmul(o, p["wo"])
    else:
        o = ref_attention_core(q, k, v, cfg, spec, block_k)
        out = o @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def quantize_kv_token(t: jax.Array):
    """Per-(B,1,Hkv) absmax INT8 quantization of one K/V token.

    t: (B, 1, Hkv, hd) f32 -> (int8 same shape, f32 scale (B, 1, Hkv)).
    """
    absmax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)  # (B,1,Hkv)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def attention_decode_quant(p, x, cfg, spec, cache, pos, ops=None):
    """Single-token decode against an INT8 KV cache (beyond-paper serving
    feature — the paper's Eq. 1 absmax quantization applied to the KV
    cache, per (token, kv-head) scales).

    Dequantization is folded *after* the score/value einsums so the HBM
    read is the INT8 payload + scales, never a materialized f32 cache.
    cache: {"k": int8 (B,Smax,Hkv,hd), "k_scale": f32 (B,Smax,Hkv), v...}.
    """
    B, _, _ = x.shape
    Smax = cache["k"].shape[1]
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(pos, (3, B, 1)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions, ops)
    kq, ks = quantize_kv_token(k)
    vq, vs = quantize_kv_token(v)
    new_cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, pos, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, pos, axis=1),
        "k_scale": jax.lax.dynamic_update_slice_in_dim(cache["k_scale"], ks, pos, axis=1),
        "v_scale": jax.lax.dynamic_update_slice_in_dim(cache["v_scale"], vs, pos, axis=1),
    }
    n_rep = cfg.n_heads // cfg.n_kv_heads
    hd = cfg.hd
    qh = q.reshape(B, cfg.n_kv_heads, n_rep, hd)
    s = jnp.einsum(
        "bgrd,bsgd->bgrs", qh.astype(jnp.float32), new_cache["k"].astype(jnp.float32)
    ) * (hd ** -0.5)
    s = s * jnp.swapaxes(new_cache["k_scale"], 1, 2)[:, :, None, :]  # fold K scales
    s = softcap(s, cfg.attn_softcap)
    kpos = jnp.arange(Smax)
    valid = kpos <= pos
    if spec.window is not None:
        valid &= kpos > pos - spec.window
    s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    w = w * jnp.swapaxes(new_cache["v_scale"], 1, 2)[:, :, None, :]  # fold V scales
    o = jnp.einsum("bgrs,bsgd->bgrd", w, new_cache["v"].astype(jnp.float32))
    o = o.reshape(B, 1, cfg.n_heads * hd).astype(x.dtype)
    out = ops.matmul(o, p["wo"]) if ops is not None else o @ p["wo"]
    return out, new_cache


def attention_decode(
    p,
    x: jax.Array,
    cfg,
    spec,
    cache_k: jax.Array,
    cache_v: jax.Array,
    pos: jax.Array,
    positions_full=None,
    ops=None,
):
    """Single-token decode. x: (B,1,d); cache_[kv]: (B,Smax,Hkv,hd); pos: () int32.

    Returns (out (B,1,d), new_cache_k, new_cache_v).
    """
    B, _, _ = x.shape
    Smax = cache_k.shape[1]
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(pos, (3, B, 1)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions, ops)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    hd = cfg.hd
    kk = cache_k  # (B,Smax,Hkv,hd)
    vv = cache_v
    qh = q.reshape(B, cfg.n_kv_heads, n_rep, hd)  # query per kv-group
    s = jnp.einsum(
        "bgrd,bsgd->bgrs", qh.astype(jnp.float32), kk.astype(jnp.float32)
    ) * (hd ** -0.5)
    s = softcap(s, cfg.attn_softcap)
    kpos = jnp.arange(Smax)
    valid = kpos <= pos
    if spec.window is not None:
        valid &= kpos > pos - spec.window
    s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrs,bsgd->bgrd", w, vv.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.n_heads * hd).astype(x.dtype)
    out = ops.matmul(o, p["wo"]) if ops is not None else o @ p["wo"]
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# Gated MLP (llama-style)
# ---------------------------------------------------------------------------


def init_mlp(rng, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "wi": (jax.random.normal(k1, (d, d_ff)) * d ** -0.5).astype(dtype),
        "wg": (jax.random.normal(k2, (d, d_ff)) * d ** -0.5).astype(dtype),
        "wo": (jax.random.normal(k3, (d_ff, d)) * d_ff ** -0.5).astype(dtype),
    }


def mlp_forward(p, x: jax.Array, ops=None) -> jax.Array:
    if ops is not None:
        mm = ops.matmul
        return mm(jax.nn.silu(mm(x, p["wg"])) * mm(x, p["wi"]), p["wo"])
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]
