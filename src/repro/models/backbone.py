"""Pattern-driven decoder backbone.

One configurable decoder covers all 10 assigned architectures: an
:class:`~repro.configs.base.ArchConfig` declares a *period* of
heterogeneous :class:`LayerSpec`s (attention / mamba / mLSTM / sLSTM,
dense-FFN / MoE / no-FFN) which is tiled ``n_periods`` times. Parameters
are stacked over periods and the forward pass is a ``jax.lax.scan`` over
the stack, so the lowered HLO is depth-independent (critical for the
512-device dry-run compile budget).

The forward pass optionally emits **taps** — the hidden state after every
period — which are exactly the invariant activations ``b_i`` the PAC+
Parallel Adapters consume (`repro.core.parallel_adapters`) and the
activation cache stores (`repro.core.activation_cache`).

Decode runs one token against a per-layer-kind state cache (KV for
attention, (h, conv) for Mamba, (C, n, m) for mLSTM, (c, n, h, m) for
sLSTM), also scanned over periods.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import psharding
from repro.core.opset import get_opset
from repro.core.quantization import maybe_dequantize_tree
from repro.models import ssm
from repro.models.layers import (
    attention_decode,
    attention_decode_quant,
    attention_forward,
    init_attention,
    init_mlp,
    mlp_forward,
    rms_norm,
    softcap,
)
from repro.models.moe import init_moe, moe_forward, moe_forward_dense

# Every forward below dispatches its primitive ops (matmul, attention,
# embedding gather, tap emission) through an OpSet (core/opset.py) — the
# one seam kernel variants plug into. `ops=None` means the dense jnp
# oracle, bit-identical to the historical dequantize-then-dense code.
_REF_OPS = get_opset("ref")

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_block(rng, cfg, spec, dtype=jnp.float32) -> dict:
    """Parameters for one layer position."""
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {"ln1": jnp.zeros((d,), dtype)}
    if spec.kind == "attn":
        p["mixer"] = init_attention(k1, cfg, dtype)
    elif spec.kind == "mamba":
        p["mixer"] = ssm.init_mamba(k1, cfg, dtype)
    elif spec.kind == "mlstm":
        p["mixer"] = ssm.init_mlstm(k1, cfg, dtype)
    elif spec.kind == "slstm":
        p["mixer"] = ssm.init_slstm(k1, cfg, dtype)
    else:
        raise ValueError(f"unknown layer kind {spec.kind!r}")
    if spec.ffn and (cfg.d_ff or (spec.moe and cfg.moe)):
        p["ln2"] = jnp.zeros((d,), dtype)
        if spec.moe and cfg.moe is not None:
            p["ffn"] = init_moe(k2, d, cfg.moe, dtype)
        else:
            p["ffn"] = init_mlp(k3, d, cfg.d_ff, dtype)
    return p


def init_backbone(rng, cfg, dtype=jnp.float32) -> dict:
    """Full backbone parameter pytree; block leaves stacked over periods."""
    n_p = cfg.n_periods
    k_embed, k_head, k_blocks = jax.random.split(rng, 3)
    blocks = []
    for i, spec in enumerate(cfg.pattern):
        rngs = jax.random.split(jax.random.fold_in(k_blocks, i), n_p)
        blocks.append(jax.vmap(lambda r, s=spec: init_block(r, cfg, s, dtype))(rngs))
    params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) * cfg.d_model ** -0.5).astype(dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "blocks": blocks,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab)) * cfg.d_model ** -0.5
        ).astype(dtype)
    return params


def abstract_backbone(cfg, dtype=jnp.float32):
    """ShapeDtypeStruct pytree of the params — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_backbone(jax.random.PRNGKey(0), cfg, dtype))


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def apply_block(p, x, cfg, spec, positions, ops=None, return_kv: bool = False):
    ops = ops if ops is not None else _REF_OPS
    # FSDP weight gather (§Perf iteration 2): replicate this layer's slice
    # over the data axes so GSPMD all-gathers weights (not activations).
    # Gather BEFORE preparing — the int8 payload is 4× cheaper to move
    # (§Perf kimi iter H). No-op outside a `model`-axis mesh.
    p = psharding.gather_for_compute(p)
    # ref: dequantize the whole block; pallas: matmul weights stay
    # quantized and feed quant_matmul inside ops.matmul
    p = ops.prepare_block(p, spec)
    h = ops.rms_norm(x, p["ln1"], cfg.norm_eps)
    kv = None
    if spec.kind == "attn":
        if return_kv:
            mix, kv = attention_forward(
                p["mixer"], h, cfg, spec, positions, ops=ops, return_kv=True
            )
        else:
            mix = attention_forward(p["mixer"], h, cfg, spec, positions, ops=ops)
    elif spec.kind == "mamba":
        mix = ssm.mamba_forward(p["mixer"], h, cfg)
    elif spec.kind == "mlstm":
        mix = ssm.mlstm_forward(p["mixer"], h, cfg)
    elif spec.kind == "slstm":
        mix = ssm.slstm_forward(p["mixer"], h, cfg)
    x = psharding.constrain_hidden(x + mix)
    if "ffn" in p:
        h = ops.rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.moe and cfg.moe is not None:
            x = x + moe_forward(p["ffn"], h, cfg.moe)
        else:
            x = x + mlp_forward(p["ffn"], h, ops=ops)
        x = psharding.constrain_hidden(x)
    if return_kv:
        # (k, v) post-rope for attention blocks, None otherwise — the
        # paged-serving prefill scatters these into the KV page pool
        return x, kv
    return x


def embed_inputs(params, cfg, batch: dict, ops=None):
    """Token embedding or stub-frontend embeddings.

    batch: {"tokens": (B,S) int32} and/or {"embeds": (B,S,d)};
    optional {"positions": (B,S) or (3,B,S)}.
    """
    ops = ops if ops is not None else _REF_OPS
    if "embeds" in batch:
        x = batch["embeds"]
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = ops.embed_lookup(params["embed"], tokens)
    if "positions" in batch:
        positions = batch["positions"]
    elif cfg.rope == "mrope":
        pos1 = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        positions = jnp.broadcast_to(pos1, (3, B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, positions


def backbone_forward(params, cfg, batch: dict, collect_taps: bool = False,
                     return_inputs: bool = False, ops=None):
    """Returns (final_hidden (B,S,d), taps (n_periods,B,S,d) | None).

    With ``return_inputs=True`` the embedded input and positions are also
    returned — ``(final, taps, x0, positions)`` — so callers that need
    ``b0`` (the PAC+ steps) don't pay the embedding lookup twice.

    Taps pass through ``ops.emit_tap`` at the tap site: under the pallas
    OpSet with an int8/bf16 tap policy they leave the scan already in
    cache storage form (dict of int8 payload + scales / bf16) — no f32
    HBM round-trip on the way to the activation cache.
    """
    ops = ops if ops is not None else _REF_OPS
    x, positions = embed_inputs(params, cfg, batch, ops=ops)
    x0 = x

    def period_fn(carry, block_slice):
        h = carry
        for i, spec in enumerate(cfg.pattern):
            h = apply_block(block_slice[i], h, cfg, spec, positions, ops=ops)
        return h, (ops.emit_tap(h) if collect_taps else None)

    x, taps = jax.lax.scan(period_fn, x, tuple(params["blocks"]))
    if return_inputs:
        return x, taps, x0, positions
    return x, taps


def head_weight(params, cfg):
    """The (d, vocab) LM-head matrix: tied embedding transpose or the
    dedicated head, dequantized — the one definition shared by
    :func:`logits_from_hidden` and the fused cached-step CE kernel."""
    if cfg.tie_embeddings:
        return maybe_dequantize_tree(params["embed"]).T
    return maybe_dequantize_tree(params["lm_head"])


def logits_from_hidden(params, cfg, h):
    p_norm = maybe_dequantize_tree(params["final_norm"])
    h = rms_norm(h, p_norm, cfg.norm_eps)
    logits = h @ head_weight(params, cfg)
    return softcap(logits, cfg.logit_softcap)


def backbone_logits(params, cfg, batch: dict):
    h, _ = backbone_forward(params, cfg, batch)
    return logits_from_hidden(params, cfg, h)


def cross_entropy(logits: jax.Array, labels: jax.Array, ignore: int = -100):
    """Mean CE over non-ignored positions. logits (B,S,V), labels (B,S).

    Implemented as a one-hot contraction rather than take_along_axis: with
    the vocab dim sharded over the `model` mesh axis, a gather-by-label
    would force GSPMD to all-gather the full (B,S,V) logits (~370 GB for
    internlm2×train_4k — measured in EXPERIMENTS.md §Perf iteration 1).
    The one-hot product reduces over the sharded vocab locally and
    all-reduces only (B,S) partials.
    """
    num, den = cross_entropy_parts(logits, labels, ignore)
    return num / jnp.maximum(den, 1)


def cross_entropy_parts(logits: jax.Array, labels: jax.Array, ignore: int = -100):
    """(summed NLL, valid-token count) — the pieces of the mean CE.

    Exposed so data-parallel callers can ``psum`` numerator and
    denominator separately and divide once: a pmean of per-shard means is
    only exact when every shard holds the same number of non-ignored
    tokens."""
    mask = labels != ignore
    labels = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logp.dtype)
    nll = -jnp.einsum("bsv,bsv->bs", logp, onehot)
    return jnp.sum(nll * mask), jnp.sum(mask)


# ---------------------------------------------------------------------------
# Decode (single token against a cache)
# ---------------------------------------------------------------------------


def init_cache(cfg, B: int, max_len: int, dtype=jnp.float32, kv_quant=None):
    """Cache pytree: one entry per pattern position, stacked over periods.

    kv_quant=8 stores attention K/V as INT8 with per-(token, kv-head)
    absmax scales (the paper's Eq. 1 applied to the KV cache — a
    beyond-paper serving feature; 4× less HBM read at decode)."""

    def one(spec):
        if spec.kind == "attn":
            if kv_quant == 8:
                return {
                    "k": jnp.zeros((B, max_len, cfg.n_kv_heads, cfg.hd), jnp.int8),
                    "v": jnp.zeros((B, max_len, cfg.n_kv_heads, cfg.hd), jnp.int8),
                    "k_scale": jnp.zeros((B, max_len, cfg.n_kv_heads), jnp.float32),
                    "v_scale": jnp.zeros((B, max_len, cfg.n_kv_heads), jnp.float32),
                }
            return {
                "k": jnp.zeros((B, max_len, cfg.n_kv_heads, cfg.hd), dtype),
                "v": jnp.zeros((B, max_len, cfg.n_kv_heads, cfg.hd), dtype),
            }
        if spec.kind == "mamba":
            return ssm.init_mamba_cache(cfg, B, dtype)
        if spec.kind == "mlstm":
            return ssm.init_mlstm_cache(cfg, B)
        if spec.kind == "slstm":
            return ssm.init_slstm_cache(cfg, B)
        raise ValueError(spec.kind)

    caches = []
    for spec in cfg.pattern:
        single = one(spec)
        caches.append(
            jax.tree.map(lambda t: jnp.broadcast_to(t[None], (cfg.n_periods,) + t.shape), single)
        )
    return caches


def abstract_cache(cfg, B: int, max_len: int, dtype=jnp.float32, kv_quant=None):
    return jax.eval_shape(lambda: init_cache(cfg, B, max_len, dtype, kv_quant=kv_quant))


def apply_block_decode(p, x, cfg, spec, cache, pos, ops=None):
    ops = ops if ops is not None else _REF_OPS
    p = ops.prepare_block(p, spec)
    h = ops.rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.kind == "attn":
        if "k_scale" in cache:  # INT8 KV cache (beyond-paper serving)
            mix, new_cache = attention_decode_quant(p["mixer"], h, cfg, spec, cache, pos, ops=ops)
        else:
            mix, ck, cv = attention_decode(p["mixer"], h, cfg, spec, cache["k"], cache["v"], pos, ops=ops)
            new_cache = {"k": ck, "v": cv}
    elif spec.kind == "mamba":
        mix, new_cache = ssm.mamba_decode(p["mixer"], h, cfg, cache)
    elif spec.kind == "mlstm":
        mix, new_cache = ssm.mlstm_decode(p["mixer"], h, cfg, cache)
    elif spec.kind == "slstm":
        mix, new_cache = ssm.slstm_decode(p["mixer"], h, cfg, cache)
    x = x + mix
    if "ffn" in p:
        h = ops.rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.moe and cfg.moe is not None:
            # decode: T = B tokens — widen capacity (cheap at decode T) to
            # make token drops rare; serving should not drop tokens.
            x = x + moe_forward(p["ffn"], h, cfg.moe, capacity_factor=2.0 * cfg.moe.capacity_factor)
        else:
            x = x + mlp_forward(p["ffn"], h, ops=ops)
    return x, new_cache


def backbone_decode(params, cfg, token_batch: dict, cache, pos, ops=None):
    """One decode step.

    token_batch: {"tokens": (B,1)} or {"embeds": (B,1,d)}; pos: () int32 —
    the index the new token is written at. Returns (logits (B,1,V), cache').
    """
    ops = ops if ops is not None else _REF_OPS
    if "embeds" in token_batch:
        x = token_batch["embeds"]
    else:
        x = ops.embed_lookup(params["embed"], token_batch["tokens"])

    def period_fn(carry, xs):
        block_slice, cache_slice = xs
        h = carry
        new_caches = []
        for i, spec in enumerate(cfg.pattern):
            h, nc = apply_block_decode(block_slice[i], h, cfg, spec, cache_slice[i], pos, ops=ops)
            new_caches.append(nc)
        return h, tuple(new_caches)

    x, new_cache = jax.lax.scan(period_fn, x, (tuple(params["blocks"]), tuple(cache)))
    return logits_from_hidden(params, cfg, x), list(new_cache)
