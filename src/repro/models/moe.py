"""Mixture-of-Experts with capacity-based token dispatch.

Token-choice top-k routing with a static per-expert capacity
``C = ceil(top_k * T / E * capacity_factor)``: each expert gathers its
highest-priority assigned tokens (priority = router probability), computes
a gated-MLP, and the results are scatter-combined with the routing
weights. Dropped tokens (over capacity) fall back to the residual stream,
the standard GShard/Switch behaviour.

FLOPs are ``E × C × expert_mlp`` ≈ ``top_k × T × expert_mlp ×
capacity_factor`` — i.e. the *active* parameter count, which is what the
roofline's ``6·N_active·D`` model expects.

Sharding: experts are laid out on the ``model`` mesh axis when divisible
(expert parallelism — dispatch/combine lower to all-to-alls under GSPMD);
otherwise the per-expert FFN dim is tensor-parallel (grok: 8 experts on a
16-way axis).

Group-limited routing (§Perf-hillclimb kimi iter B): on a production
mesh, tokens are split into ``G = pod×data`` groups aligned to the batch
sharding and routed *independently* with per-group capacity ``C/G``.
This keeps the dispatch gather local to each data shard (the global
(T,E) route makes GSPMD all-reduce the (E,C,d) dispatched tensor over
``data`` and replicate expert compute ×|data|), and is how
DeepSeek/Kimi-family deployments dispatch in practice. ``n_groups=1``
recovers the exact global-routing semantics (the CPU-test default).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.psharding import ambient_mesh, constrain_spec, n_data_shards


def _local_topk(x, k, axes):
    """jax.lax.top_k with shard-local semantics on a production mesh.

    XLA's TopK/Sort partitioner all-gathers the *batch* dims over `data`
    (measured: 2×98 GB/layer on kimi×train_4k) even when the sort dim is
    unsharded. Wrapping the op in shard_map keeps it local; ``axes`` is a
    per-dim logical spec as in ``constrain_spec``, which must already be
    the operand's sharding. Falls back to plain top_k without a mesh.
    """
    mesh = ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return jax.lax.top_k(x, k)
    dp = [a for a in ("pod", "data") if a in mesh.axis_names]
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax == "batch" and dp and dim % n_data_shards(mesh) == 0:
            spec.append(tuple(dp) if len(dp) > 1 else dp[0])
        elif ax == "model" and dim % mesh.shape["model"] == 0:
            spec.append("model")
        else:
            spec.append(None)
    from jax.sharding import PartitionSpec as P

    pspec = P(*spec)
    return compat.shard_map(
        lambda v: tuple(jax.lax.top_k(v, k)),
        mesh=mesh, in_specs=pspec, out_specs=(pspec, pspec), check_rep=False,
    )(x)


def init_moe(rng, d: int, spec, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    E, de = spec.n_experts, spec.d_expert
    return {
        "router": (jax.random.normal(k1, (d, E)) * d ** -0.5).astype(jnp.float32),
        "wi": (jax.random.normal(k2, (E, d, de)) * d ** -0.5).astype(dtype),
        "wg": (jax.random.normal(k3, (E, d, de)) * d ** -0.5).astype(dtype),
        "wo": (jax.random.normal(k4, (E, de, d)) * de ** -0.5).astype(dtype),
    }


def _capacity(T: int, spec, capacity_factor=None) -> int:
    cf = spec.capacity_factor if capacity_factor is None else capacity_factor
    c = int(spec.top_k * T * cf / spec.n_experts)
    c = -(-max(1, c) // 8) * 8  # round up to the TPU sublane
    return min(T, c)  # top_k needs k <= size along the token axis


def _auto_groups(B: int, S: int, spec) -> int:
    """Batch-aligned group count: pod×data shards when divisible, else 1.

    Grouping only pays when each group has enough tokens to fill expert
    capacity naturally; at decode (T=B tokens) the per-group capacity
    floor (≥1, sublane-rounded) would inflate dispatched slots ~16×
    (measured: kimi×decode_32k collective 0.05→5.2 s). Fall back to
    global routing when K·Tg < 8·E.
    """
    g = n_data_shards()
    if g <= 1 or B % g != 0:
        return 1
    if spec.top_k * (B // g) * S < 8 * spec.n_experts:
        return 1
    return g


def moe_forward(p, x: jax.Array, spec, return_aux: bool = False,
                capacity_factor=None, n_groups: int | None = None):
    """x: (B, S, d) -> (B, S, d) [+ aux losses dict]."""
    B, S, d = x.shape
    E, K = spec.n_experts, spec.top_k
    G = _auto_groups(B, S, spec) if n_groups is None else n_groups
    Tg = (B // G) * S
    C = _capacity(Tg, spec, capacity_factor)
    xg = constrain_spec(x.reshape(G, Tg, d), ("batch", None, None))

    logits = (xg.astype(jnp.float32)) @ p["router"]  # (G, Tg, E)
    logits = constrain_spec(logits, ("batch", None, None))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = _local_topk(probs, K, ("batch", None, None))  # (G, Tg, K)
    top_p = constrain_spec(top_p, ("batch", None, None))
    top_e = constrain_spec(top_e, ("batch", None, None))

    # assignment matrix with router-prob priorities: (G, E, Tg).
    # vmap over G so the scatter keeps G as an operand-batching dim —
    # fancy-indexing G turns it into a scatter dim and GSPMD then
    # replicates the output over the mesh (§Perf-hillclimb kimi iter C).
    def _assign(tp, te):  # (Tg,K) -> (Tg,E)
        a = jnp.zeros((Tg, E), jnp.float32)
        return a.at[jnp.arange(Tg)[:, None], te].set(tp)

    assign = jax.vmap(_assign)(top_p, top_e)
    prio = jnp.swapaxes(assign, 1, 2)  # (G, E, Tg), zero where unassigned
    # slice E over `model` *before* the per-expert top-k so it runs local
    # (iter D: otherwise GSPMD all-gathers the (G,Tg,E) route twice/layer)
    prio = constrain_spec(prio, ("batch", "model", None))

    # Expert-parallel: E over `model` when divisible (the divisibility
    # guard makes this a no-op otherwise — grok instead gets d_ff
    # tensor-parallel experts via the TP_ALT weight rule; a C-sharded
    # dispatch variant was tried and refuted, see §Perf-hillclimbs).
    exp3, exp4 = ("batch", "model", None), ("batch", "model", None, None)

    # per-expert top-C tokens by priority, within each group
    gate, idx = _local_topk(prio, C, exp3)  # (G, E, C)
    gate = constrain_spec(gate, exp3)
    idx = constrain_spec(idx, exp3)
    valid = gate > 0.0

    # dispatch: group-local gather — vmap over G keeps the gather batched
    # (no tokens move between data shards)
    xe = jax.vmap(lambda xt, i: jnp.take(xt, i.reshape(-1), axis=0))(xg, idx)
    xe = xe.reshape(G, E, C, d)
    xe = constrain_spec(xe, exp4)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wg"])) * jnp.einsum(
        "gecd,edf->gecf", xe, p["wi"]
    )
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"])  # (G, E, C, d)
    ye = constrain_spec(ye, exp4)

    # combine: scatter-add gate-weighted expert outputs back to tokens,
    # batched over G (partial over `model` -> one (Tg,d)-sized AR/group)
    w = jnp.where(valid, gate, 0.0).astype(ye.dtype)  # (G, E, C)

    def _combine(i, yw):  # (E,C), (E,C,d) -> (Tg,d)
        o = jnp.zeros((Tg, d), yw.dtype)
        return o.at[i.reshape(-1)].add(yw.reshape(E * C, d))

    out = jax.vmap(_combine)(idx, ye * w[..., None])
    out = constrain_spec(out, ("batch", None, None))
    out = out.reshape(B, S, d).astype(x.dtype)

    if not return_aux:
        return out
    # Switch-style load-balance loss (means over all groups/tokens)
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    fe = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E), axis=2), axis=(0, 1)
    )  # fraction of tokens to each expert
    aux = {
        "load_balance": E * jnp.sum(me * fe),
        "router_z": jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
        "dropped_frac": 1.0 - jnp.sum(valid) / (G * Tg * K),
    }
    return out, aux


def moe_forward_dense(p, x: jax.Array, spec):
    """Dense (all-experts) reference for small-scale correctness checks."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, spec.top_k)
    w = jnp.zeros((T, spec.n_experts), jnp.float32)
    w = w.at[jnp.arange(T)[:, None], top_e].set(top_p)
    h = jax.nn.silu(jnp.einsum("td,edf->etf", xt, p["wg"])) * jnp.einsum(
        "td,edf->etf", xt, p["wi"]
    )
    ye = jnp.einsum("etf,efd->etd", h, p["wo"])  # (E, T, d)
    out = jnp.einsum("te,etd->td", w.astype(ye.dtype), ye)
    return out.reshape(B, S, d).astype(x.dtype)
