"""Pallas kernels vs jnp oracles — interpret mode, shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.quantization import quantize
from repro.kernels import ref
from repro.kernels.adapter_fuse import adapter_fuse
from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.quant_matmul import quant_matmul

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize(
    "M,K,N,bm,bn,bk",
    [
        (64, 256, 128, 64, 128, 128),
        (128, 512, 256, 64, 128, 256),
        (256, 256, 512, 128, 256, 256),
    ],
)
def test_quant_matmul_sweep(bits, M, K, N, bm, bn, bk):
    x = jax.random.normal(KEY, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (K, N))
    qt = quantize(w, bits=bits, block=128)
    out = quant_matmul(x, qt.q, qt.scale, bits=bits, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.quant_matmul_ref(x, qt.q, qt.scale, bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_dtypes(dtype):
    x = jax.random.normal(KEY, (64, 256)).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (256, 128))
    qt = quantize(w, bits=8, block=128)
    out = quant_matmul(x, qt.q, qt.scale, bits=8, bm=64, bn=128, bk=256, interpret=True)
    assert out.dtype == dtype
    want = ref.quant_matmul_ref(x.astype(jnp.float32), qt.q, qt.scale, 8)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want), atol=0.15, rtol=0.05
    )


# ---------------------------------------------------------------------------
# adapter_fuse
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "T,d,da,lam", [(128, 256, 64, 0.5), (256, 512, 128, 0.0), (64, 128, 128, 1.0)]
)
def test_adapter_fuse_sweep(T, d, da, lam):
    b = jax.random.normal(KEY, (T, d))
    w = jax.random.normal(jax.random.fold_in(KEY, 3), (d, da))
    a = jax.random.normal(jax.random.fold_in(KEY, 4), (T, da))
    out = adapter_fuse(b, w, a, jnp.float32(lam), bt=64, bj=64, bk=128, interpret=True)
    want = ref.adapter_fuse_ref(b, w, a, lam)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 32])
@pytest.mark.parametrize("cap", [None, 30.0])
def test_flash_kernel_variants(causal, window, cap):
    BH, S, hd = 3, 128, 32
    q = jax.random.normal(KEY, (BH, S, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (BH, S, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (BH, S, hd))
    out = flash_attention_tpu(
        q, k, v, causal=causal, window=window, attn_softcap=cap, bq=32, bk=32, interpret=True
    )
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window, attn_softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(
    s_exp=st.integers(5, 8),
    hd=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 1000),
)
def test_flash_kernel_property(s_exp, hd, seed):
    S = 2 ** s_exp
    k0 = jax.random.PRNGKey(seed)
    q = jax.random.normal(k0, (2, S, hd))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (2, S, hd))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (2, S, hd))
    out = flash_attention_tpu(q, k, v, bq=32, bk=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)

@pytest.mark.parametrize(
    "T,d,da",
    [(100, 130, 70), (100, 512, 96), (33, 257, 65), (1, 5, 3)],
)
def test_adapter_fuse_ragged_shapes(T, d, da):
    """Non-divisible (T, d, da) — e.g. --seq 100 — must pad-and-slice, not
    assert (ISSUE 3 regression: the kernel hard-asserted divisibility)."""
    b = jax.random.normal(KEY, (T, d))
    w = jax.random.normal(jax.random.fold_in(KEY, 7), (d, da))
    a = jax.random.normal(jax.random.fold_in(KEY, 8), (T, da))
    out = adapter_fuse(b, w, a, jnp.float32(0.7), bt=64, bj=64, bk=128, interpret=True)
    assert out.shape == (T, da)
    want = ref.adapter_fuse_ref(b, w, a, 0.7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)
