"""Fleet scheduler tests: deterministic failure simulation.

The load-bearing assertions are **exact float equality**: killing a
device mid-cached-epoch, deweighting a straggler, or preempting and
resuming a job must not move a single bit of any loss or of the final
adapter — the elastic runner's canonical-order chunk accumulation makes
the numerics placement-independent by construction, and these tests pin
that contract.

Scheduler-invariant property tests drive a no-JAX stub job over seeded
random :class:`FaultPlan` scripts (``tests/_propcheck``): no device is
ever double-booked, chunk shares always cover the batch, and every
admitted job eventually completes once capacity exists.

Heavier bound-device subprocess simulations are marked ``slow_sim``
(excluded from tier-1 by the pyproject addopts; CI's fleet job runs
them on 8 fake host devices).
"""

import os
import subprocess
import sys
from types import SimpleNamespace

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.checkpoint import tree_fingerprint
from repro.core.planner import (
    HybridParallelismPlanner,
    JETSON_NANO_H,
    JETSON_NANO_L,
    model_layer_costs,
)
from repro.configs import get_arch
from repro.fleet import (
    DeviceMember,
    DevicePool,
    FaultPlan,
    FleetEvent,
    FleetScheduler,
    ScriptedEvents,
    SessionJob,
    SimClock,
    assign_chunks,
    slice_cached,
)
from repro.runtime import RunSpec, RunSpecError

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SPEC = RunSpec(arch="internlm2-1.8b", reduced=True, epochs=3,
               steps_per_epoch=2, batch=4, seq=16, r=4, lr=1e-2)


def _pool(n=3, timeout=1.5):
    return DevicePool([DeviceMember(f"dev{i}") for i in range(n)],
                      clock=SimClock(), heartbeat_timeout=timeout)


def _run(events=None, jobs=None, pool=None, **kw):
    sched = FleetScheduler(pool if pool is not None else _pool(),
                           events=events, **kw)
    jobs = jobs if jobs is not None else [SessionJob("alice", SPEC)]
    for j in jobs:
        sched.submit(j)
    report = sched.run()
    return sched, report, jobs


@pytest.fixture(scope="module")
def baseline():
    """The fault-free reference run every fault scenario must match
    float-for-float."""
    _, report, (job,) = _run()
    return SimpleNamespace(
        losses=report.losses("alice"),
        fingerprint=tree_fingerprint(job.session.adapter),
        job=job,
    )


# ---------------------------------------------------------------------------
# clock / events / pool units
# ---------------------------------------------------------------------------


def test_sim_clock():
    c = SimClock(start=2.0)
    assert c.now() == 2.0
    assert c.advance(1.5) == 3.5
    with pytest.raises(ValueError):
        c.advance(-1.0)


def test_fault_plan_validation_and_roundtrip(tmp_path):
    with pytest.raises(ValueError):
        FleetEvent(0, "explode", device="d0")
    with pytest.raises(ValueError):
        FleetEvent(0, "submit")           # needs job=
    with pytest.raises(ValueError):
        FleetEvent(0, "kill")             # needs device=
    with pytest.raises(ValueError):
        FleetEvent(0, "slow", device="d0", factor=0.0)

    plan = FaultPlan([
        FleetEvent(5, "kill", device="d1"),
        FleetEvent(2, "submit", job="j0"),
        FleetEvent(5, "join", device="d9"),
    ])
    # sorted by (tick, kind order): join precedes kill at the same tick
    assert [e.kind for e in plan.events] == ["submit", "join", "kill"]
    assert plan.last_tick == 5
    assert [e.kind for e in plan.at(5)] == ["join", "kill"]

    path = tmp_path / "faults.json"
    plan.save(str(path))
    assert FaultPlan.load(str(path)).events == plan.events


def test_fault_plan_random_is_seed_deterministic():
    a = FaultPlan.random(7, ["d0", "d1"], jobs=["j0"])
    b = FaultPlan.random(7, ["d0", "d1"], jobs=["j0"])
    c = FaultPlan.random(8, ["d0", "d1"], jobs=["j0"])
    assert a.events == b.events
    assert a.events != c.events


def test_scripted_events_deliver_each_tick_once():
    ev = ScriptedEvents(FaultPlan([FleetEvent(1, "kill", device="d0")]))
    assert not ev.exhausted
    assert ev.poll(0) == []
    assert len(ev.poll(1)) == 1
    assert ev.poll(1) == []
    assert ev.exhausted


def test_pool_kill_detected_after_timeout():
    clock = SimClock()
    pool = DevicePool([DeviceMember("a"), DeviceMember("b")],
                      clock=clock, heartbeat_timeout=1.5)
    pool.kill("a")
    for tick in range(4):
        pool.heartbeat_all()          # killed member stops reporting
        lost = pool.check_timeouts()
        if lost:
            assert lost == ["a"]
            assert tick == 2          # deterministic: first tick with age > 1.5
            break
        clock.advance(1.0)
    else:
        pytest.fail("kill never detected")
    assert pool.alive() == ["b"]


def test_pool_slots_recycle_and_speed_scaling():
    pool = DevicePool([DeviceMember("a"), DeviceMember("b")],
                      bind_devices=True, capacity=2)
    assert [pool.member(n).slot for n in ("a", "b")] == [0, 1]
    with pytest.raises(ValueError):
        pool.add(DeviceMember("c"))   # at capacity
    gen = pool.generation
    pool.remove(("a"))
    pool.add(DeviceMember("c"))
    assert pool.member("c").slot == 0            # recycled
    assert pool.generation == gen + 2
    pool.mark_slow("c", 0.5)
    assert pool.member("c").effective_profile().flops == pytest.approx(
        0.5 * pool.member("b").effective_profile().flops)
    assert pool.profiles(["b"])[0] == pool.member("b").profile


# ---------------------------------------------------------------------------
# elastic primitives
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(n_chunks=st.integers(0, 32), n_members=st.integers(1, 6),
       seed=st.integers(0, 99))
def test_assign_chunks_covers_and_is_deterministic(n_chunks, n_members, seed):
    rng = np.random.RandomState(seed)
    weights = rng.uniform(0.1, 4.0, n_members).tolist()
    counts = assign_chunks(n_chunks, n_members, weights)
    assert sum(counts) == n_chunks
    assert all(c >= 0 for c in counts)
    assert counts == assign_chunks(n_chunks, n_members, weights)
    if n_chunks >= n_members:
        # a >2x faster member never gets fewer chunks
        for i in range(n_members):
            for j in range(n_members):
                if weights[i] > 2.0 * weights[j]:
                    assert counts[i] >= counts[j]


def test_slice_cached_axes_and_storage_form():
    cached = {
        "b0": np.arange(24, dtype=np.float32).reshape(4, 3, 2),
        "taps": {"q": np.zeros((2, 4, 3, 2), np.int8),
                 "scale": np.ones((2, 4, 3), np.float32)},
        "b_final": np.zeros((4, 3, 2), np.float32),
        "labels": np.arange(12, dtype=np.int32).reshape(4, 3),
    }
    piece = slice_cached(cached, 1, 3)
    assert piece["b0"].shape == (2, 3, 2)
    np.testing.assert_array_equal(piece["b0"], cached["b0"][1:3])
    # taps (incl. {"q","scale"} leaves) slice on axis 1, not axis 0
    assert piece["taps"]["q"].shape == (2, 2, 3, 2)
    assert piece["taps"]["scale"].shape == (2, 2, 3)
    assert piece["labels"].shape == (2, 3)


def test_elastic_step_is_placement_invariant(baseline):
    """The core numerical contract, unit level: the same cached batch
    stepped under 1-, 2-, and 3-member placements produces bit-identical
    loss/adapter/optimizer."""
    job = baseline.job
    s = job.session
    ids = s.pipe.epoch_order(1)[0]
    hit = s.cache.get_batch(ids, with_final=True, dtype=None)
    assert hit is not None
    b0, taps, bf = hit
    cached = {"b0": b0, "taps": taps, "b_final": bf,
              "labels": s.corpus.batch(ids)["labels"]}
    runner = job._elastic
    outs = []
    for placement in (
        [("a", None, 4)],
        [("a", None, 3), ("b", None, 1)],
        [("a", None, 1), ("b", None, 1), ("c", None, 2)],
    ):
        loss, adapter, opt = runner.step(s.adapter, s.opt, cached, placement)
        outs.append((loss, tree_fingerprint(adapter), tree_fingerprint(opt)))
    assert outs[0] == outs[1] == outs[2]
    with pytest.raises(ValueError):
        runner.step(s.adapter, s.opt, cached, [("a", None, 3)])  # shares != 4


# ---------------------------------------------------------------------------
# planner: incremental subset re-plan
# ---------------------------------------------------------------------------


def test_planner_available_subset_matches_fresh_planner():
    costs = model_layer_costs(get_arch("t5-base-pac"), "pac", seq_len=64)
    devices = [JETSON_NANO_H, JETSON_NANO_L, JETSON_NANO_H, JETSON_NANO_L]
    planner = HybridParallelismPlanner(costs, devices, 4, 4)
    full = planner.plan()
    h_entries = len(planner._h_cache)

    # device 1 "died": re-plan the survivors in place
    sub = planner.plan(available=[0, 2, 3])
    fresh = HybridParallelismPlanner(
        costs, [devices[0], devices[2], devices[3]], 4, 4).plan()
    assert sub.minibatch_latency == pytest.approx(fresh.minibatch_latency)
    assert sub.n_stages == fresh.n_stages
    assert [st_.samples_per_device for st_ in sub.stages] == \
        [st_.samples_per_device for st_ in fresh.stages]
    # Eq. (4) memo carried over — the subset re-plan reused prior groups
    assert len(planner._h_cache) >= h_entries
    # and the full-pool plan is reproducible afterwards (avail resets)
    again = planner.plan()
    assert again.minibatch_latency == pytest.approx(full.minibatch_latency)

    with pytest.raises(ValueError):
        planner.plan(available=[0, 0])
    with pytest.raises(ValueError):
        planner.plan(available=[99])
    with pytest.raises(ValueError):
        planner.plan(available=[])


# ---------------------------------------------------------------------------
# the acceptance scenarios (in-process, logical members)
# ---------------------------------------------------------------------------


def test_kill_mid_cached_epoch_matches_fault_free_exactly(baseline):
    """A device killed mid-cached-epoch: detection after the heartbeat
    timeout, elastic reshard onto the survivors, zero extra backbone
    forwards — and every loss equal to the fault-free run's, exactly."""
    events = ScriptedEvents(FaultPlan([FleetEvent(3, "kill", device="dev1")]))
    _, report, (job,) = _run(events=events)
    assert report.losses("alice") == baseline.losses          # exact floats
    assert tree_fingerprint(job.session.adapter) == baseline.fingerprint
    assert job.state == "done"
    assert job.forward_steps == SPEC.steps_per_epoch          # epoch 1 only
    assert job.reshards >= 1                                  # it DID reshard
    assert any("dev1" in rec.lost for rec in report.ticks)    # it WAS detected
    # post-detection placements exclude the dead device
    lost_at = next(r.tick for r in report.ticks if "dev1" in r.lost)
    for rec in report.ticks:
        if rec.tick >= lost_at:
            assert all("dev1" not in d for d in rec.placements.values())


def test_straggler_deweighted_by_replan_losses_unchanged(baseline):
    """A 4x-slower member keeps its membership but the Eq. (4) re-plan
    moves chunks off it — with zero numerical effect."""
    events = ScriptedEvents(FaultPlan(
        [FleetEvent(3, "slow", device="dev0", factor=0.25)]))
    _, report, (job,) = _run(events=events)
    assert report.losses("alice") == baseline.losses          # exact floats
    assert tree_fingerprint(job.session.adapter) == baseline.fingerprint

    def dev0_share(rec):
        pl, sh = rec.placements["alice"], rec.shares["alice"]
        return dict(zip(pl, sh)).get("dev0", 0)

    before = [dev0_share(r) for r in report.ticks if r.tick < 3 and "alice" in r.shares]
    after = [dev0_share(r) for r in report.ticks if r.tick > 3 and "alice" in r.shares]
    assert after and max(after) < min(before)   # deweighted, still a member
    assert all("dev0" in r.placements["alice"] for r in report.ticks
               if "alice" in r.placements)


def test_preempt_resume_is_bit_identical(baseline, tmp_path):
    """Quantum preemption through the on-disk snapshot path: job A is
    paused for job B and resumed; A's losses and final adapter are
    bit-identical to an uninterrupted run."""
    pool = _pool(1)
    sched = FleetScheduler(pool, quantum=2, snapshot_dir=str(tmp_path))
    alice = SessionJob("alice", SPEC)
    bob = SessionJob("bob", SPEC.replace(seed=1))
    sched.submit(alice)
    sched.submit(bob)
    report = sched.run()
    preempted = [n for rec in report.ticks for n in rec.preempted]
    assert "alice" in preempted                  # it really was interrupted
    assert "alice.ckpt" in os.listdir(str(tmp_path))   # it went through disk
    assert alice.state == "done" and bob.state == "done"
    assert report.losses("alice") == baseline.losses
    assert tree_fingerprint(alice.session.adapter) == baseline.fingerprint
    # bob trained a different corpus — sanity that the jobs were distinct
    assert report.losses("bob") != baseline.losses


def test_rejection_when_pool_can_never_fit():
    pool = DevicePool([DeviceMember("a")], clock=SimClock(), capacity=1)
    sched = FleetScheduler(pool)
    job = SessionJob("alice", SPEC)
    job.min_devices = 2                      # needs more than capacity
    assert not sched.submit(job)
    assert job.state == "rejected"
    assert sched.report.rejected == ["alice"]
    assert sched.quiescent


# ---------------------------------------------------------------------------
# scheduler invariants (no-JAX stub jobs + random fault scripts)
# ---------------------------------------------------------------------------


class StubJob:
    """Duck-typed SessionJob: scheduler logic without any JAX."""

    min_devices = 1

    def __init__(self, name, steps=6, n_chunks=4):
        self.name = name
        self.n_chunks = n_chunks
        self.max_devices = n_chunks
        self.state = "queued"
        self.steps_left = steps
        self.forward_steps = self.cached_steps = self.reshards = 0

    @property
    def done(self):
        return self.steps_left <= 0

    def plan_shares(self, profiles):
        return None                     # scheduler falls back to assign_chunks

    def run_step(self, placement):
        assert placement, "stepped with no devices"
        assert sum(s for _, _, s in placement) == self.n_chunks
        self.steps_left -= 1
        if self.done:
            self.state = "done"
        return SimpleNamespace(loss=float(self.steps_left))

    def pause(self, snapshot_dir=None):
        self.state = "preempted"
        return {"steps_left": self.steps_left}

    def resume(self, snap):
        assert snap["steps_left"] == self.steps_left   # nothing lost in between
        self.state = "queued"


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), quantum=st.sampled_from([None, 2, 3]))
def test_scheduler_invariants_under_random_fault_plans(seed, quantum):
    devices = [f"d{i}" for i in range(6)]
    plan = FaultPlan.random(seed, devices, n_events=10, max_tick=12,
                            jobs=["j0", "j1", "j2"])
    pool = DevicePool([DeviceMember(d) for d in devices[:3]],
                      clock=SimClock(), heartbeat_timeout=1.5)
    sched = FleetScheduler(pool, events=ScriptedEvents(plan), quantum=quantum)
    jobs = [StubJob(f"j{i}") for i in range(3)]
    for j in jobs:
        sched.register(j)
    sched.run(max_ticks=60)

    submitted = [j for j in jobs
                 if any(e.kind == "submit" and e.job == j.name
                        for e in plan.events)]
    for rec in sched.report.ticks:
        placed = [d for devs in rec.placements.values() for d in devs]
        assert len(placed) == len(set(placed)), \
            f"device double-booked at tick {rec.tick}: {rec.placements}"
        # shares cover each stepping job's chunks (asserted inside StubJob
        # too); a job never steps while queued
        assert not (set(rec.steps) & set(rec.queued))

    # liveness: once capacity exists and the script is over, every
    # submitted job runs to completion (placed-or-rejected, eventually)
    if any(not j.done for j in submitted):
        if len(pool) == 0:
            pool.add(DeviceMember("rescue"))
        sched.run(max_ticks=60)
    for j in submitted:
        assert j.done or j.state == "rejected", \
            f"{j.name} starved: state={j.state} steps_left={j.steps_left}"


def test_multi_job_fairness_no_starvation():
    """Three stub jobs on two devices, FIFO + quantum: all make progress
    interleaved — no job waits for another to fully finish."""
    pool = DevicePool([DeviceMember("a"), DeviceMember("b")], clock=SimClock())
    sched = FleetScheduler(pool, quantum=2)
    jobs = [StubJob(f"j{i}", steps=6) for i in range(3)]
    for j in jobs:
        sched.submit(j)
    report = sched.run(max_ticks=60)
    assert all(j.done for j in jobs)
    first = [report.first_step_tick(j.name) for j in jobs]
    assert None not in first
    # nobody's first step waits for another job's completion (6 steps)
    assert max(first) < 6
    # preemption actually rotated the pool
    assert any(rec.preempted for rec in report.ticks)


def test_pool_slot_conservation_under_random_plans():
    """The device-slot allocator across arbitrary join/leave/kill
    sequences: live slots are unique, recycled, and bounded."""
    for seed in range(25):
        plan = FaultPlan.random(seed, [f"d{i}" for i in range(6)],
                                n_events=12, max_tick=10)
        pool = DevicePool([DeviceMember("d0"), DeviceMember("d1")],
                          bind_devices=True, clock=SimClock(),
                          heartbeat_timeout=1.5)
        for tick in range(12):
            for e in plan.at(tick):
                if e.kind == "join" and e.device not in pool:
                    pool.add(DeviceMember(e.device))
                elif e.kind == "leave" and e.device in pool:
                    pool.remove(e.device)
                elif e.kind == "kill" and e.device in pool:
                    pool.kill(e.device)
                elif e.kind == "slow" and e.device in pool:
                    pool.mark_slow(e.device, e.factor)
            pool.heartbeat_all()
            pool.check_timeouts()
            pool.clock.advance(1.0)
            slots = [pool.member(n).slot for n in pool.alive()]
            assert len(slots) == len(set(slots)), f"slot reuse: {slots}"
            assert pool._next_slot <= 6 + 2  # recycling bounds growth
            assert not (set(pool._free_slots) & set(slots))


# ---------------------------------------------------------------------------
# session seams
# ---------------------------------------------------------------------------


def test_session_snapshot_restore_roundtrip(baseline, tmp_path):
    s = baseline.job.session
    snap = s.snapshot(extra={"epoch": 2, "index": 1})
    path = s.save_snapshot(str(tmp_path / "snap.ckpt"),
                           extra={"epoch": 2, "index": 1})
    fp = tree_fingerprint(s.adapter)
    extra = s.restore_snapshot(path)
    assert extra == {"epoch": 2, "index": 1}
    assert tree_fingerprint(s.adapter) == fp          # disk round-trip exact
    assert s.restore(snap) == {"epoch": 2, "index": 1}
    with pytest.raises(RunSpecError):
        s.restore({"adapter": s.adapter, "opt": s.opt, "config": "other-arch"})
    with pytest.raises(RunSpecError):
        s.reshard(2)      # single-device sessions reshard via the fleet


def test_run_spec_replace_validates():
    assert SPEC.replace(seed=5).seed == 5
    assert SPEC.replace(seed=5) != SPEC
    with pytest.raises(RunSpecError):
        SPEC.replace(batch=0)


def test_session_job_rejects_distributed_specs():
    with pytest.raises(RunSpecError):
        SessionJob("x", SPEC.replace(dp=2, stages=2, batch=4, seq=16))
    with pytest.raises(RunSpecError):
        SessionJob("x", SPEC, chunk=3)    # 4 % 3 != 0


# ---------------------------------------------------------------------------
# bound-device simulations (CI fleet job; excluded from tier-1)
# ---------------------------------------------------------------------------


def _run_sub(code):
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600, env=env)


@pytest.mark.slow_sim
def test_kill_simulation_on_bound_fake_devices():
    """The full acceptance scenario with members bound to distinct fake
    host devices: kill one mid-cached-epoch; the faulted run's losses
    and final adapter must equal the fault-free run's exactly."""
    r = _run_sub("""
from repro import compat
compat.force_host_device_count(4)
from repro.checkpoint import tree_fingerprint
from repro.fleet import (DeviceMember, DevicePool, FaultPlan, FleetEvent,
                         FleetScheduler, ScriptedEvents, SessionJob, SimClock)
from repro.runtime import RunSpec

spec = RunSpec(arch="internlm2-1.8b", reduced=True, epochs=3,
               steps_per_epoch=2, batch=4, seq=16, r=4, lr=1e-2)

def run(events):
    pool = DevicePool([DeviceMember(f"dev{i}") for i in range(4)],
                      clock=SimClock(), heartbeat_timeout=1.5,
                      bind_devices=True)
    sched = FleetScheduler(pool, events=events)
    job = SessionJob("alice", spec)
    sched.submit(job)
    report = sched.run()
    assert job.state == "done"
    return report.losses("alice"), tree_fingerprint(job.session.adapter), job

faulted = ScriptedEvents(FaultPlan([FleetEvent(3, "kill", device="dev2")]))
l1, f1, j1 = run(faulted)
l2, f2, j2 = run(None)
assert j1.reshards >= 1 and j1.forward_steps == 2
assert l1 == l2, f"losses diverged: {l1} vs {l2}"
assert f1 == f2, "final adapters differ"
print("BOUND-DEVICE-EXACT-OK")
""")
    assert r.returncode == 0, r.stderr[-4000:]
    assert "BOUND-DEVICE-EXACT-OK" in r.stdout


@pytest.mark.slow_sim
def test_fleet_cli_smoke_two_jobs_one_kill(tmp_path):
    plan = tmp_path / "faults.json"
    r = _run_sub(f"""
import sys
sys.argv = ["fleet", "--simulate", "--reduced", "--pool", "3", "--jobs", "2",
            "--epochs", "2", "--steps-per-epoch", "2", "--batch", "2",
            "--seq", "16", "--r", "4", "--kill-tick", "3",
            "--save-fault-plan", {str(plan)!r}]
from repro.launch.fleet import main
main()
""")
    assert r.returncode == 0, r.stderr[-4000:]
    assert "job0: done" in r.stdout and "job1: done" in r.stdout
    assert plan.exists()


@pytest.mark.slow_sim
def test_distributed_session_reshard_dp2_to_dp1():
    """The EdgeSession.reshard seam on a real mesh: a dp=2 cached epoch
    continues as dp=1 after shrinking — state carries over, losses stay
    finite and close to the dp=2 continuation (shard_map reduction
    order may differ at the last bit, hence allclose not equality)."""
    r = _run_sub("""
import numpy as np
from repro.runtime import RunSpec, EdgeSession
from repro.runtime.runner import EpochRunner

spec = RunSpec(arch="internlm2-1.8b", reduced=True, epochs=3,
               steps_per_epoch=2, batch=4, seq=16, r=4, dp=2, stages=2)

def run(shrink):
    s = EdgeSession(spec).open()
    runner = EpochRunner(s)
    reports = [list(runner.run_epoch(0))[-1], list(runner.run_epoch(1))[-1]]
    if shrink:
        s.reshard(1)
        assert s.exec_dp == 1
    reports.append(list(runner.run_epoch(2))[-1])
    assert reports[1].used_cache and reports[2].used_cache
    return [l for rep in reports for l in rep.losses]

a = run(shrink=True)
b = run(shrink=False)
assert all(np.isfinite(a))
assert np.allclose(a, b, rtol=1e-5), f"{a} vs {b}"
print("RESHARD-DP-OK")
""")
    assert r.returncode == 0, r.stderr[-4000:]
    assert "RESHARD-DP-OK" in r.stdout
