"""Cross-run activation-cache persistence through the trainer CLI.

ISSUE 3 acceptance: a second ``repro.launch.train`` run pointed at the
same ``--cache-dir`` performs **zero** backbone forwards (its epoch 0
already logs ``cached`` mode), and a changed backbone/corpus seed
invalidates the manifest loudly and re-captures.

Each run is a subprocess (fresh JAX backend); the persistent compile
cache set up by conftest keeps the repeated jits cheap.
"""

import os
import subprocess
import sys


def _run(tmpdir, *extra, epochs=2, compress="int8"):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--reduced",
         "--epochs", str(epochs), "--steps-per-epoch", "2", "--batch", "2",
         "--seq", "16", "--cache-dir", str(tmpdir),
         "--cache-compress", compress, *extra],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out


def test_cache_dir_resumes_warm_and_invalidates_on_seed_change(tmp_path):
    cache_dir = tmp_path / "act_cache"

    # run 1: cold — epoch 0 pays the backbone forward, epoch 1 is cached,
    # and a manifest lands in the cache dir
    out1 = _run(cache_dir)
    assert "(full)" in out1.stdout and "(cached)" in out1.stdout
    assert "cache manifest:" in out1.stdout
    assert (cache_dir / "manifest.json").exists()

    # run 2: warm — the manifest validates, *every* epoch (including
    # epoch 0) trains from the cache: zero backbone forwards
    out2 = _run(cache_dir)
    assert "warm manifest" in out2.stdout
    assert "(full)" not in out2.stdout
    assert out2.stdout.count("(cached)") == 2
    assert "epoch 0" in out2.stdout

    # run 3: changed seed — new backbone + corpus fingerprints must
    # invalidate loudly and re-run the forward
    out3 = _run(cache_dir, "--seed", "1")
    assert "ACTIVATION CACHE INVALIDATED" in out3.stderr
    assert "backbone" in out3.stderr and "corpus" in out3.stderr
    assert "(full)" in out3.stdout

    # run 4: the re-captured cache under the new seed is warm again
    out4 = _run(cache_dir, "--seed", "1")
    assert "(full)" not in out4.stdout


def test_cache_policy_change_invalidates(tmp_path):
    cache_dir = tmp_path / "act_cache"
    _run(cache_dir)
    out = _run(cache_dir, epochs=1, compress="bf16")
    assert "ACTIVATION CACHE INVALIDATED" in out.stderr
    assert "compression policy changed" in out.stderr


def test_crash_mid_epoch_restarts_warm_with_identical_losses(tmp_path):
    """Crash recovery: a warm run hard-killed mid-epoch (os._exit, no
    cleanup) must leave the cache dir intact — the restart is still
    warm, performs zero backbone forwards, and reports losses identical
    to an uninterrupted run."""
    import re

    cache_dir = tmp_path / "act_cache"
    _run(cache_dir)                                  # cold capture
    ref = _run(cache_dir)                            # uninterrupted warm run

    # a warm run killed one step into epoch 0 — process dies with the
    # prefetcher thread live and no close()/finish()
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    crash = subprocess.run(
        [sys.executable, "-c", f"""
import os
from repro.runtime import RunSpec, EdgeSession

spec = RunSpec(reduced=True, epochs=2, steps_per_epoch=2, batch=2, seq=16,
               cache_dir={str(cache_dir)!r}, cache_compress="int8")
s = EdgeSession(spec).open()
assert s.warm
batch = next(iter(s.pipe.epoch(0)))
event = s.step(batch, epoch=0, index=0)
assert event.cache_hit
os._exit(17)                   # simulated mid-epoch process kill
"""],
        capture_output=True, text=True, env=env, timeout=600)
    assert crash.returncode == 17, crash.stderr[-3000:]

    after = _run(cache_dir)                          # restart after the crash
    assert "warm manifest" in after.stdout
    assert "(full)" not in after.stdout              # zero backbone forwards
    assert after.stdout.count("(cached)") == 2

    def losses(out):
        return re.findall(r"epoch \d+: loss=([\d.]+)", out.stdout)

    assert losses(after) == losses(ref) and losses(ref)
