"""SSM mixers: chunked-parallel forward ≡ step-by-step recurrent decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import ssm


def _mk_cfg(**kw):
    cfg = get_arch("xlstm-125m").reduced()
    return dataclasses.replace(cfg, **kw) if kw else cfg


def test_mlstm_forward_vs_decode():
    cfg = _mk_cfg(mlstm_chunk=5)  # uneven chunk vs S=13
    p = ssm.init_mlstm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 13
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    full = ssm.mlstm_forward(p, x, cfg)
    cache = ssm.init_mlstm_cache(cfg, B)
    outs = []
    for t in range(S):
        o, cache = ssm.mlstm_decode(p, x[:, t : t + 1], cfg, cache)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4)


@pytest.mark.parametrize("chunk", [1, 3, 64])
def test_mlstm_chunk_invariance(chunk):
    """Output must not depend on the chunk size."""
    cfg = _mk_cfg()
    p = ssm.init_mlstm(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 17, cfg.d_model)) * 0.5
    a = ssm.mlstm_forward(p, x, dataclasses.replace(cfg, mlstm_chunk=chunk))
    b = ssm.mlstm_forward(p, x, dataclasses.replace(cfg, mlstm_chunk=17))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_slstm_forward_vs_decode():
    cfg = _mk_cfg()
    p = ssm.init_slstm(jax.random.PRNGKey(4), cfg)
    B, S = 2, 9
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S, cfg.d_model)) * 0.5
    full = ssm.slstm_forward(p, x, cfg, chunk=4)
    cache = ssm.init_slstm_cache(cfg, B)
    outs = []
    for t in range(S):
        o, cache = ssm.slstm_decode(p, x[:, t : t + 1], cfg, cache)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(full), atol=2e-4
    )


def test_mamba_forward_vs_decode():
    cfg = dataclasses.replace(get_arch("jamba-1.5-large-398b").reduced())
    p = ssm.init_mamba(jax.random.PRNGKey(6), cfg)
    B, S = 2, 11
    x = jax.random.normal(jax.random.PRNGKey(7), (B, S, cfg.d_model)) * 0.5
    full = ssm.mamba_forward(p, x, cfg, chunk=4)
    cache = ssm.init_mamba_cache(cfg, B)
    outs = []
    for t in range(S):
        o, cache = ssm.mamba_decode(p, x[:, t : t + 1], cfg, cache)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(full), atol=2e-4
    )


def test_mamba_gradients_finite_through_chunked_scan():
    cfg = get_arch("jamba-1.5-large-398b").reduced()
    p = ssm.init_mamba(jax.random.PRNGKey(8), cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 16, cfg.d_model))

    def loss(p):
        return jnp.sum(jnp.square(ssm.mamba_forward(p, x, cfg, chunk=4)))

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


def test_mlstm_long_range_memory():
    """The matrix memory must carry information across chunk boundaries."""
    cfg = _mk_cfg(mlstm_chunk=4)
    p = ssm.init_mlstm(jax.random.PRNGKey(10), cfg)
    x = jax.random.normal(jax.random.PRNGKey(11), (1, 16, cfg.d_model))
    base = ssm.mlstm_forward(p, x, cfg)
    x2 = x.at[0, 0].add(1.0)  # perturb first token
    pert = ssm.mlstm_forward(p, x2, cfg)
    # effect visible in the last chunk
    assert float(jnp.max(jnp.abs(pert[0, -1] - base[0, -1]))) > 1e-6
