"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures: instantiate the REDUCED
variant (≤2 periods, d_model≤256, ≤4 experts), run one forward/train step
and one decode step on CPU, assert output shapes and finiteness, and
check prefill≡decode consistency.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import steps
from repro.core.parallel_adapters import init_adapter
from repro.models import backbone as bb
from repro.optim import adamw_init

ASSIGNED = [
    "musicgen-large",
    "grok-1-314b",
    "moonshot-v1-16b-a3b",
    "kimi-k2-1t-a32b",
    "qwen2-vl-7b",
    "xlstm-125m",
    "gemma2-2b",
    "jamba-1.5-large-398b",
    "internlm2-1.8b",
    "granite-20b",
    "mixtral-8x7b",  # bonus pool arch (E<model-axis MoE + window attn)
]


def _batch(cfg, B=2, S=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    batch = {}
    if cfg.frontend is not None:
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model)) * 0.3
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.broadcast_to(pos, (3, B, S))
    batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_config_bounds(arch):
    cfg = get_arch(arch).reduced()
    assert cfg.n_layers <= 2 * cfg.period
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_and_finite(arch):
    cfg = get_arch(arch).reduced()
    params = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits = bb.backbone_logits(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    if cfg.logit_softcap:
        assert float(jnp.max(jnp.abs(logits))) <= cfg.logit_softcap + 1e-4


@pytest.mark.parametrize("arch", ASSIGNED)
def test_pac_train_step(arch):
    """One PAC+ train step: loss finite, only adapter params move."""
    cfg = get_arch(arch).reduced()
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    ap = init_adapter(jax.random.PRNGKey(1), cfg, r=4)
    opt = adamw_init(ap)
    batch = _batch(cfg)
    loss, ap2, opt2, (b0, taps, bf) = steps.pac_train_step(bp, ap, opt, batch, cfg=cfg, r=4)
    assert np.isfinite(float(loss))
    assert taps.shape[0] == cfg.n_periods
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(ap), jax.tree.leaves(ap2))
    )
    assert moved, "adapter params did not update"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_step(arch):
    cfg = get_arch(arch).reduced()
    params = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    B, S = 2, 8
    cache = bb.init_cache(cfg, B, S)
    tok = {"embeds": jnp.zeros((B, 1, cfg.d_model))} if cfg.frontend else {
        "tokens": jnp.zeros((B, 1), jnp.int32)
    }
    logits, cache2 = steps.decode_step(params, tok, cache, jnp.int32(0), cfg=cfg)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize(
    "arch", ["gemma2-2b", "jamba-1.5-large-398b", "xlstm-125m", "granite-20b"]
)
def test_prefill_decode_equivalence(arch):
    cfg = get_arch(arch).reduced()
    params = bb.init_backbone(jax.random.PRNGKey(3), cfg)
    B, S = 2, 10
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab)
    h, _ = bb.backbone_forward(params, cfg, {"tokens": tokens})
    full = bb.logits_from_hidden(params, cfg, h)
    cache = bb.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = bb.backbone_decode(
            params, cfg, {"tokens": tokens[:, t : t + 1]}, cache, jnp.int32(t)
        )
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert float(jnp.max(jnp.abs(dec - full))) / scale < 2e-3


def test_window_variant_lowers_attention_reach():
    """with_window() must make every attention layer sub-quadratic."""
    cfg = get_arch("granite-20b")
    assert not cfg.is_subquadratic()
    assert cfg.with_window(8192).is_subquadratic()
    assert get_arch("xlstm-125m").is_subquadratic()
    assert not get_arch("gemma2-2b").is_subquadratic()  # global every other layer


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma2-2b", "granite-20b"])
def test_int8_kv_cache_decode_close_to_f32(arch):
    """INT8 KV cache (beyond-paper serving): decode logits must track the
    f32-cache decode within quantization tolerance."""
    cfg = get_arch(arch).reduced()
    params = bb.init_backbone(jax.random.PRNGKey(5), cfg)
    B, S = 2, 10
    tokens = jax.random.randint(jax.random.PRNGKey(6), (B, S), 0, cfg.vocab)
    cache_f = bb.init_cache(cfg, B, S)
    cache_q = bb.init_cache(cfg, B, S, kv_quant=8)
    for t in range(S):
        tb = {"tokens": tokens[:, t : t + 1]}
        lf, cache_f = bb.backbone_decode(params, cfg, tb, cache_f, jnp.int32(t))
        lq, cache_q = bb.backbone_decode(params, cfg, tb, cache_q, jnp.int32(t))
    scale = float(jnp.max(jnp.abs(lf))) + 1e-6
    rel = float(jnp.max(jnp.abs(lq - lf))) / scale
    assert np.isfinite(np.asarray(lq)).all()
    assert rel < 0.05, rel  # INT8 absmax: ~1% typical, 5% bound


def test_quantize_kv_token_roundtrip_error_bound():
    from repro.models.layers import quantize_kv_token

    t = jax.random.normal(jax.random.PRNGKey(7), (3, 1, 4, 16)) * 5.0
    q, scale = quantize_kv_token(t)
    assert q.dtype == jnp.int8
    back = q.astype(jnp.float32) * scale[..., None]
    err = jnp.max(jnp.abs(back - t))
    # absmax int8: max error = scale/2 = absmax/254
    assert float(err) <= float(jnp.max(jnp.abs(t))) / 254 + 1e-6
