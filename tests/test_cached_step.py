"""ISSUE 5 acceptance: the fused Pallas cached-epoch step.

Interpret-mode equivalence of ``pac_cached_train_step(kernel_impl=
"pallas")`` against the ref oracle for every cache compression policy,
unit tests for the two new kernels (fused dequant×adapter λ-mix,
blockwise LM-head cross-entropy), the no-eager-upcast guard on the
compressed cache handoff, and a trainer-CLI subprocess check that
``--kernels pallas`` and ``--kernels ref`` converge to matching losses.
"""

import functools
import os
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import steps
from repro.core.activation_cache import ActivationCache
from repro.core.quantization import quantize
from repro.kernels import ref
from repro.kernels.cached_step import dq_adapter_mix, lmhead_ce
from repro.optim import adamw_init

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# dq_adapter_mix: fused dequant × down-projection × λ-mix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("storage", ["f32", "bf16", "int8"])
@pytest.mark.parametrize("T,d,da", [(64, 256, 32), (100, 130, 17), (7, 300, 40)])
def test_dq_adapter_mix_forward(storage, T, d, da):
    """All three storage forms, block-aligned and ragged shapes."""
    b = jax.random.normal(KEY, (T, d))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (d, da)) * 0.1
    a = jax.random.normal(jax.random.fold_in(KEY, 2), (T, da))
    lam = jnp.float32(0.7)
    if storage == "bf16":
        b = b.astype(jnp.bfloat16)
    elif storage == "int8":
        qt = quantize(b, bits=8, block=128)
        b = {"q": qt.q, "scale": qt.scale}
    out = dq_adapter_mix(b, w, a, lam, interpret=True)
    want = ref.dq_adapter_mix_ref(b, w, a, lam, d)
    assert out.shape == (T, da) and out.dtype == a.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=1e-4, rtol=1e-4,
    )


@pytest.mark.parametrize("storage", ["f32", "bf16", "int8"])
def test_dq_adapter_mix_grads(storage):
    """Custom-VJP grads wrt (w_down, a, λ) match jnp autodiff of the ref;
    the cache entry itself is a constant (zero cotangent)."""
    T, d, da = 48, 256, 24
    b = jax.random.normal(KEY, (T, d))
    w = jax.random.normal(jax.random.fold_in(KEY, 3), (d, da)) * 0.1
    a = jax.random.normal(jax.random.fold_in(KEY, 4), (T, da))
    if storage == "bf16":
        b = b.astype(jnp.bfloat16)
    elif storage == "int8":
        qt = quantize(b, bits=8, block=128)
        b = {"q": qt.q, "scale": qt.scale}

    def loss_k(w_, a_, l_):
        return jnp.sum(jnp.sin(dq_adapter_mix(b, w_, a_, l_, interpret=True)))

    def loss_r(w_, a_, l_):
        return jnp.sum(jnp.sin(ref.dq_adapter_mix_ref(b, w_, a_, l_, d)))

    gk = jax.jit(jax.grad(loss_k, argnums=(0, 1, 2)))(w, a, jnp.float32(0.3))
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(w, a, jnp.float32(0.3))
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=2e-4, rtol=1e-3,
        )


# ---------------------------------------------------------------------------
# lmhead_ce: blockwise softmax-cross-entropy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "T,d,V,cap", [(64, 128, 512, None), (50, 96, 300, 30.0), (8, 64, 1000, None)]
)
def test_lmhead_ce_forward_and_grad(T, d, V, cap):
    """Online-softmax NLL and its dh match the full-logits oracle —
    including ragged vocab (masked padding) and tanh soft-capping."""
    h = jax.random.normal(KEY, (T, d))
    w = jax.random.normal(jax.random.fold_in(KEY, 5), (d, V)) * 0.05
    lab = jax.random.randint(jax.random.fold_in(KEY, 6), (T,), 0, V)
    nll = lmhead_ce(h, w, lab, softcap=cap, interpret=True)
    want = ref.lmhead_ce_ref(h, w, lab, softcap=cap)
    np.testing.assert_allclose(
        np.asarray(nll), np.asarray(want), atol=2e-5, rtol=1e-5
    )
    gk = jax.grad(
        lambda h_: jnp.sum(jnp.cos(lmhead_ce(h_, w, lab, softcap=cap, interpret=True)))
    )(h)
    gr = jax.grad(
        lambda h_: jnp.sum(jnp.cos(ref.lmhead_ce_ref(h_, w, lab, softcap=cap)))
    )(h)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# Full cached step: pallas vs ref, per cache policy
# ---------------------------------------------------------------------------


def _cached_from_cache(policy, b0, taps, bf, labels, compressed):
    cache = ActivationCache(budget_bytes=1 << 30, compress=policy)
    ids = list(range(b0.shape[0]))
    cache.put_batch(ids, b0, taps, bf)
    hit = cache.get_batch(ids, with_final=True, dtype=None, compressed=compressed)
    cb0, ct, cbf = (jax.tree.map(jnp.asarray, h) for h in hit)
    return {"b0": cb0, "taps": ct, "b_final": cbf, "labels": labels}


@pytest.mark.parametrize("policy", ["f32", "bf16", "int8"])
def test_pallas_cached_step_matches_ref_per_policy(
    tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch, policy
):
    """ISSUE 5 acceptance: the fused step on *storage-form* entries
    matches the ref oracle on the same entries — loss, adapter grads,
    and post-update params — in interpret mode."""
    cfg, bp, ap, batch = tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch
    opt = adamw_init(ap)
    _, _, _, (b0, taps, bf) = steps.pac_train_step(bp, ap, opt, batch, cfg=cfg, r=4)

    cached_c = _cached_from_cache(policy, b0, taps, bf, batch["labels"], True)
    cached_d = _cached_from_cache(policy, b0, taps, bf, batch["labels"], False)

    # the compressed handoff: int8 entries reach the step as integer
    # payloads + scales, bf16 as bf16 — never an eager f32 upcast
    if policy == "int8":
        assert isinstance(cached_c["taps"], dict)
        assert cached_c["taps"]["q"].dtype == jnp.int8
        assert cached_c["b0"]["q"].dtype == jnp.int8
    elif policy == "bf16":
        assert cached_c["taps"].dtype == jnp.bfloat16

    step_ref = jax.jit(functools.partial(
        steps.pac_cached_train_step, cfg=cfg, r=4, kernel_impl="ref"))
    step_pal = jax.jit(functools.partial(
        steps.pac_cached_train_step, cfg=cfg, r=4, kernel_impl="pallas"))

    loss_ref, ap_ref, _ = step_ref(bp, ap, opt, cached_c)
    loss_pal, ap_pal, _ = step_pal(bp, ap, opt, cached_c)
    # ref on compressed entries == ref on host-decompressed entries
    # (the handoff changes where dequant runs, not its result)
    loss_ref_d, _, _ = step_ref(bp, ap, opt, cached_d)
    assert abs(float(loss_ref) - float(loss_ref_d)) < 1e-5

    assert abs(float(loss_ref) - float(loss_pal)) < 2e-5
    for a, b in zip(jax.tree.leaves(ap_ref), jax.tree.leaves(ap_pal)):
        d = float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        assert d < 5e-5, d

    # gradient-level equivalence (post-update params can mask per-leaf
    # differences behind AdamW's eps)
    from repro.kernels.cached_step import cached_loss_parts

    B, S = batch["labels"].shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def grads(impl):
        def loss_fn(a):
            num, den = cached_loss_parts(
                bp, a, cfg, cached_c, positions, 4, impl=impl, interpret=True
            )
            return num / jnp.maximum(den, 1)

        return jax.grad(loss_fn)(ap)

    g_ref, g_pal = grads("ref"), grads("pallas")
    gmax = max(float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(g_ref))
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pal)):
        d = float(jnp.max(jnp.abs(a - b)))
        assert d <= 1e-4 * max(1.0, gmax), (d, gmax)


def test_prefetcher_compressed_handoff(tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch):
    """The prefetcher's compressed mode yields storage-form batches in
    epoch order — int8 payloads stay int8 all the way to the step."""
    from repro.core.activation_cache import CachePrefetcher

    cfg, bp, ap, batch = tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch
    opt = adamw_init(ap)
    _, _, _, (b0, taps, bf) = steps.pac_train_step(bp, ap, opt, batch, cfg=cfg, r=4)
    cache = ActivationCache(budget_bytes=1 << 30, compress="int8")
    B = b0.shape[0]
    cache.put_batch(list(range(B)), b0, taps, bf)
    pf = CachePrefetcher(
        cache, [np.arange(B, dtype=np.int32)], compressed=True, to_device=True
    )
    got = next(pf)
    assert got is not None
    cb0, ct, cbf = got
    assert isinstance(ct, dict) and ct["q"].dtype == jnp.int8
    assert ct["q"].shape[:1] == (cfg.n_periods,)
    pf.close()
    # and the pallas step consumes the prefetched batch directly
    cached = {"b0": cb0, "taps": ct, "b_final": cbf, "labels": batch["labels"]}
    loss, _, _ = steps.pac_cached_train_step(
        bp, ap, opt, cached, cfg=cfg, r=4, kernel_impl="pallas", interpret=True
    )
    assert np.isfinite(float(loss))


# ---------------------------------------------------------------------------
# Trainer CLI: --kernels pallas vs ref converge to matching losses
# ---------------------------------------------------------------------------


def _run_cli(*extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--reduced",
         "--epochs", "3", "--steps-per-epoch", "2", "--batch", "2",
         "--seq", "16", *extra],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _losses(stdout):
    return [float(m) for m in re.findall(r"epoch \d+: loss=([0-9.]+)", stdout)]


@pytest.mark.parametrize("compress", ["f32", "int8"])
def test_cli_kernels_pallas_matches_ref(compress):
    """ISSUE 5/7 acceptance: a full trainer run with --kernels pallas
    converges to the same per-epoch losses as --kernels ref. Since the
    OpSet dispatch, --kernels pallas also runs epoch 0's frozen forward
    on the pallas path: with the f32 policy that is interpret-tolerance
    identical, while under int8 compression the taps are quantized at
    the tap site, so every epoch carries the (bounded) tap-quantization
    error — the cache entries themselves are bit-identical either way."""
    ref_out = _run_cli("--cache-compress", compress, "--kernels", "ref")
    pal_out = _run_cli("--cache-compress", compress, "--kernels", "pallas")
    l_ref, l_pal = _losses(ref_out), _losses(pal_out)
    assert len(l_ref) == 3 and len(l_pal) == 3
    tol = 5e-4 if compress == "f32" else 5e-2
    for a, b in zip(l_ref, l_pal):
        assert abs(a - b) < tol, (l_ref, l_pal)
    # sanity: training is actually learning (losses decrease)
    assert l_ref[-1] < l_ref[0] and l_pal[-1] < l_pal[0]
