"""Hypothesis-optional property-testing shim.

Test modules import ``given``/``settings``/``strategies`` from here
instead of from ``hypothesis`` directly. When hypothesis is installed it
is used verbatim (shrinking, the example database, all of it). When it
is not — stock edge images rarely ship it — a minimal vendored fallback
runs each property over ``max_examples`` pseudo-random samples drawn
from a per-test deterministic seed, so failures reproduce across runs
and machines.

The fallback implements exactly the strategy surface this repo uses:
``integers``, ``floats``, ``booleans``, ``sampled_from``, ``lists``,
``tuples``, ``just``, plus ``.map``/``.filter``. Add here before using a
new strategy in a test.
"""

from __future__ import annotations

try:  # real hypothesis when available
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random
    import zlib

    _DEFAULT_MAX_EXAMPLES = 20
    _FILTER_RETRIES = 1000

    class _Strategy:
        """A sampler: ``draw(rng) -> value``."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def map(self, f):
            return _Strategy(lambda rng: f(self._draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(_FILTER_RETRIES):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise RuntimeError("propcheck: filter predicate never satisfied")

            return _Strategy(draw)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*strats):
            return _Strategy(lambda rng: tuple(s.draw(rng) for s in strats))

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

    strategies = _Strategies()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        """Accepts (and ignores) hypothesis-only knobs like ``deadline``."""

        def deco(fn):
            fn._propcheck_max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        """Keyword-strategies decorator; runs the test over N samples.

        The RNG seed is derived from the test's qualified name, so every
        run (and every machine) replays the same examples — no flaky
        property tests, and a failing sample stays failing while it is
        being fixed.
        """
        if not strats:
            raise TypeError("propcheck given() requires keyword strategies")

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(
                    wrapper,
                    "_propcheck_max_examples",
                    getattr(fn, "_propcheck_max_examples", _DEFAULT_MAX_EXAMPLES),
                )
                seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
                rng = random.Random(seed)
                for i in range(n):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"propcheck: falsifying example {i + 1}/{n} "
                            f"for {fn.__qualname__}: {drawn!r}"
                        ) from e

            # hide the strategy kwargs from pytest's fixture resolution
            # (functools.wraps exposes fn's signature via __wrapped__)
            sig = inspect.signature(fn)
            kept = [p for n, p in sig.parameters.items() if n not in strats]
            wrapper.__signature__ = sig.replace(parameters=kept)
            del wrapper.__wrapped__
            return wrapper

        return deco
