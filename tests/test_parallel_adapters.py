"""PAC+ core invariants: gradient highway, cache, init methods."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import steps
from repro.core.activation_cache import ActivationCache, cache_bytes_per_sequence
from repro.core.init_methods import distillation_init, pruning_init
from repro.core.parallel_adapters import (
    adapter_config,
    adapter_forward,
    adapter_param_count,
    init_adapter,
    pac_logits,
)
from repro.core.quantization import quantize_tree
from repro.models import backbone as bb
from repro.optim import adamw_init

def test_gradient_highway_no_backbone_grads(tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch):
    """d(loss)/d(backbone) must be exactly zero — the paper's core claim."""
    cfg, bp, ap, batch = tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch

    def loss_wrt_backbone(bp):
        return steps.pac_loss_fn(ap, bp, cfg, batch, r=4)

    g = jax.grad(loss_wrt_backbone)(bp)
    # every *trunk* (per-layer) grad identically zero — no backward pass
    # through the backbone. (The frozen LM head / final norm sit after the
    # side-tuning sum, so math grads exist for them; PAC+ simply never
    # computes them — grads are taken wrt adapter params only.)
    trunk = [float(jnp.max(jnp.abs(l))) for l in jax.tree.leaves(g["blocks"])]
    assert max(trunk) == 0.0
    emb = float(jnp.max(jnp.abs(g["embed"])))
    assert emb == 0.0  # b0 is stop_gradient'd too


def test_adapter_grads_nonzero(tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch):
    cfg, bp, ap, batch = tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch
    g = jax.grad(lambda a: steps.pac_loss_fn(a, bp, cfg, batch, r=4))(ap)
    total = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert total > 0


def test_adapter_is_lightweight():
    """Adapter ≈ (1/r²) of backbone size (paper: ~2% trainable)."""
    cfg = get_arch("internlm2-1.8b")
    n_adapter = adapter_param_count(cfg, r=8)
    n_backbone = cfg.param_count()
    assert n_adapter / n_backbone < 0.06


def test_cached_step_equals_uncached(tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch):
    cfg, bp, ap, batch = tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch
    opt = adamw_init(ap)
    loss, ap1, _, (b0, taps, bf) = steps.pac_train_step(bp, ap, opt, batch, cfg=cfg, r=4)
    cached = {"b0": b0, "taps": taps, "b_final": bf, "labels": batch["labels"]}
    loss_c, ap2, _ = steps.pac_cached_train_step(bp, ap, opt, cached, cfg=cfg, r=4)
    assert abs(float(loss) - float(loss_c)) < 1e-6
    for a, b in zip(jax.tree.leaves(ap1), jax.tree.leaves(ap2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_taps_invariant_across_epochs(tiny_cfg, tiny_backbone, tiny_batch):
    """Frozen backbone ⇒ identical activations for the same input (§IV-B)."""
    cfg, bp, batch = tiny_cfg, tiny_backbone, tiny_batch
    _, t1 = bb.backbone_forward(bp, cfg, batch, collect_taps=True)
    _, t2 = bb.backbone_forward(bp, cfg, batch, collect_taps=True)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_activation_cache_roundtrip_and_spill(tmp_path):
    cache = ActivationCache(budget_bytes=1 << 16, spill_dir=str(tmp_path))
    b0 = np.random.randn(4, 8, 16).astype(np.float32)
    taps = np.random.randn(3, 4, 8, 16).astype(np.float32)
    cache.put_batch([1, 2, 3, 4], b0, taps)
    got = cache.get_batch([2, 4])
    np.testing.assert_allclose(got[0], b0[[1, 3]])
    np.testing.assert_allclose(got[1], taps[:, [1, 3]])
    assert cache.get(99) is None
    assert len(cache) == 4
    cache.clear()
    assert len(cache) == 0


def test_cache_storage_cost_matches_paper_formula():
    cfg = get_arch("t5-base-pac")
    # paper §V-B: <1 GB for 500 sequences of length 30 on T5-Base (their
    # l=12-layer stacks; our decoder-only analogue has 24 periods, so the
    # same formula lands at ~1.07 GB — same order, bound relaxed to 1.2)
    per_seq = cache_bytes_per_sequence(cfg, seq_len=30)
    assert per_seq * 500 < 1.2 * (1 << 30)
    # and per the formula s·h·(l+1)·4B exactly
    assert per_seq == (cfg.n_periods + 1) * 30 * cfg.d_model * 4


def test_quantized_backbone_pac_step(tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch):
    cfg, bp, ap, batch = tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch
    for bits in (8, 4):
        bq = quantize_tree(bp, bits=bits, min_size=1024)
        loss, *_ = steps.pac_train_step(bq, ap, adamw_init(ap), batch, cfg=cfg, r=4)
        assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "xlstm-125m", "jamba-1.5-large-398b", "gemma2-2b"])
def test_pruning_init_smooth_start(arch):
    """Pruning init + zero W_up ⇒ PAC+ output == backbone output at step 0."""
    cfg = get_arch(arch).reduced()
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    ap = pruning_init(jax.random.PRNGKey(1), bp, cfg, r=4)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)}
    x, pos = bb.embed_inputs(bp, cfg, batch)
    bf, taps = bb.backbone_forward(bp, cfg, batch, collect_taps=True)
    lg = pac_logits(bp, ap, cfg, x, taps, bf, pos, r=4)
    ref = bb.logits_from_hidden(bp, cfg, bf)
    np.testing.assert_array_equal(np.asarray(lg), np.asarray(ref))


def test_distillation_init_reduces_kl(tiny_cfg, tiny_backbone):
    cfg, bp = tiny_cfg, tiny_backbone
    calib = [
        {"tokens": jax.random.randint(jax.random.PRNGKey(i), (2, 8), 0, cfg.vocab)}
        for i in range(2)
    ]
    ap = distillation_init(
        jax.random.PRNGKey(5), bp, cfg, calib, r=4, steps=8, from_pruning=False
    )
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(ap))


def test_cache_path_loss_and_grad_equivalence_end_to_end(
    tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch
):
    """Paper's epoch≥2 correctness claim, end-to-end: training the adapter
    from the activation cache must produce the same loss AND the same
    adapter gradients as recomputing the frozen backbone forward — both
    paths jitted, as they run in the trainer."""
    import functools

    from repro.core.parallel_adapters import pac_logits
    from repro.models.backbone import cross_entropy
    from repro.optim import adamw_init

    cfg, bp, ap, batch = tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch
    opt = adamw_init(ap)

    step1 = jax.jit(functools.partial(steps.pac_train_step, cfg=cfg, r=4))
    stepN = jax.jit(functools.partial(steps.pac_cached_train_step, cfg=cfg, r=4))

    # epoch-1 path: backbone forward, capture the cacheable activations
    loss1, ap1, _, (b0, taps, b_final) = step1(bp, ap, opt, batch)
    cached = {"b0": b0, "taps": taps, "b_final": b_final, "labels": batch["labels"]}
    # epoch≥2 path: same minibatch served from the cache
    lossN, apN, _ = stepN(bp, ap, opt, cached)

    assert abs(float(loss1) - float(lossN)) < 1e-6
    for a, b in zip(jax.tree.leaves(ap1), jax.tree.leaves(apN)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)

    # gradient-level equivalence (stronger than the post-update params:
    # AdamW's eps could mask per-leaf grad differences)
    def recompute_loss(a):
        return steps.pac_loss_fn(a, bp, cfg, batch, r=4)

    B, S = b0.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def cached_loss(a):
        logits = pac_logits(bp, a, cfg, b0, taps, b_final, positions, 4)
        return cross_entropy(logits, cached["labels"])

    g_re = jax.jit(jax.grad(recompute_loss))(ap)
    g_ca = jax.jit(jax.grad(cached_loss))(ap)
    assert jax.tree.structure(g_re) == jax.tree.structure(g_ca)
    for a, b in zip(jax.tree.leaves(g_re), jax.tree.leaves(g_ca)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_adapter_decode_bf16_params(tiny_cfg):
    """Regression: adapter_decode must cast the λ-mixed tap/carry sum back
    to the carry dtype like adapter_forward does — with bf16 adapter
    params the f32 λ upcast the carry and scan rejected the carry type."""
    from repro.core.parallel_adapters import adapter_decode, init_adapter_cache

    cfg = tiny_cfg
    ap16 = init_adapter(jax.random.PRNGKey(1), cfg, r=4, dtype=jnp.bfloat16)
    B = 2
    acache = init_adapter_cache(cfg, B, 8, r=4, dtype=jnp.bfloat16)
    b0_t = jnp.ones((B, 1, cfg.d_model), jnp.bfloat16) * 0.1
    taps_t = jnp.ones((cfg.n_periods, B, 1, cfg.d_model), jnp.bfloat16) * 0.1
    out, new_cache = adapter_decode(ap16, cfg, b0_t, taps_t, acache, jnp.int32(0), r=4)
    assert out.shape == (B, 1, cfg.d_model)
    assert np.isfinite(np.asarray(out, np.float32)).all()


def test_adapter_config_scaling():
    cfg = get_arch("kimi-k2-1t-a32b")
    acfg = adapter_config(cfg, r=8)
    assert acfg.d_model <= cfg.d_model // 8 + 64
    assert acfg.moe is None  # MoE becomes dense in the side network
    assert acfg.n_layers == cfg.n_layers


@pytest.mark.parametrize(
    "policy,loss_tol,grad_tol",
    [
        # f32 entries are bit-exact; bf16 carries ~2^-8 relative error on
        # the taps, int8 ~1/254 of each block's absmax — the documented
        # tolerances of the README's compression table. Adapter grads are
        # compared on max|Δ| relative to the reference grad magnitude.
        ("f32", 0.0, 0.0),
        ("bf16", 5e-2, 5e-2),
        ("int8", 1e-1, 1e-1),
    ],
)
def test_cached_epoch_equivalence_per_policy(
    tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch, policy, loss_tol, grad_tol
):
    """ISSUE 3 acceptance: training from compressed cache entries matches
    the uncached path — exactly for f32, within dtype tolerance for
    bf16/int8 — through the same put_batch/get_batch path the trainer
    uses (b_final folded into the entry)."""
    import functools

    cfg, bp, ap, batch = tiny_cfg, tiny_backbone, tiny_adapter, tiny_batch
    opt = adamw_init(ap)

    loss_ref, grads_ref = jax.value_and_grad(
        lambda a: steps.pac_loss_fn(a, bp, cfg, batch, r=4)
    )(ap)
    _, _, _, (b0, taps, bf) = steps.pac_train_step(bp, ap, opt, batch, cfg=cfg, r=4)

    cache = ActivationCache(budget_bytes=1 << 30, compress=policy)
    ids = list(range(b0.shape[0]))
    cache.put_batch(ids, b0, taps, bf)
    cb0, ctaps, cbf = cache.get_batch(ids, with_final=True, dtype=None)
    cached = {
        "b0": jnp.asarray(cb0),
        "taps": jnp.asarray(ctaps),
        "b_final": jnp.asarray(cbf),
        "labels": batch["labels"],
    }

    from repro.core.parallel_adapters import pac_logits
    from repro.models.backbone import cross_entropy

    B, S = b0.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def cached_loss(a):
        cb = {k: jnp.asarray(v, jnp.float32) for k, v in cached.items() if k != "labels"}
        logits = pac_logits(bp, a, cfg, cb["b0"], cb["taps"], cb["b_final"], positions, 4)
        return cross_entropy(logits, cached["labels"])

    loss_c, grads_c = jax.value_and_grad(cached_loss)(ap)

    if policy == "f32":
        assert float(loss_ref) == pytest.approx(float(loss_c), abs=1e-6)
    else:
        assert abs(float(loss_ref) - float(loss_c)) <= loss_tol, (
            float(loss_ref), float(loss_c))
    gmax_ref = max(float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(grads_ref))
    for a, b in zip(jax.tree.leaves(grads_ref), jax.tree.leaves(grads_c)):
        d = float(jnp.max(jnp.abs(a - b)))
        if policy == "f32":
            # the entry round-trip is bit-exact; the residual is f32
            # evaluation-order noise between the two loss graphs
            assert d <= 1e-6, d
        else:
            assert d <= grad_tol * max(1.0, gmax_ref), (d, gmax_ref)

    # and the full jitted cached *train step* stays finite + loss matches
    stepN = jax.jit(functools.partial(steps.pac_cached_train_step, cfg=cfg, r=4))
    loss_s, ap2, _ = stepN(bp, ap, opt, cached)
    assert abs(float(loss_s) - float(loss_c)) < 1e-6
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(ap2))
