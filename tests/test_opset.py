"""ISSUE 7 acceptance: the OpSet dispatch layer.

Golden ref bit-identity (the ``ref`` OpSet IS the historical model
code), a property sweep over ragged shapes × backbone storage forms
({f32, bf16, int8, int4}) asserting pallas-interpret vs ref equivalence
of losses, adapter grads and emitted taps through ``backbone_forward``,
the storage-form tap contract with the activation cache, the staged
(shard_map) pipeline equivalence in a 4-device subprocess, the
prepare_block no-dequant guarantee, and the registry seam.
"""

import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.configs import get_arch
from repro.core import steps
from repro.core.activation_cache import ActivationCache
from repro.core.opset import TAP_BLOCK, OpSet, get_opset, register_opset
from repro.core.parallel_adapters import init_adapter
from repro.core.quantization import QTensor, quantize, quantize_tree
from repro.models import backbone as bb
from repro.optim import adamw_init

KEY = jax.random.PRNGKey(0)
CFG = get_arch("internlm2-1.8b").reduced()
STORAGES = ("f32", "bf16", "int8", "int4")
# bf16 halves the mantissa on every weight; the two legs then disagree
# through the attention kernel's different accumulation order
_TOL = {"f32": 2e-4, "bf16": 3e-2, "int8": 2e-4, "int4": 2e-4}


@functools.lru_cache(maxsize=None)
def _backbone(storage: str):
    bp = bb.init_backbone(KEY, CFG)
    if storage == "f32":
        return bp
    if storage == "bf16":
        return jax.tree.map(
            lambda t: t.astype(jnp.bfloat16) if t.dtype == jnp.float32 else t, bp)
    return quantize_tree(bp, bits={"int8": 8, "int4": 4}[storage], min_size=1024)


def _batch(B, S, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    return {
        "tokens": jax.random.randint(ks[0], (B, S), 0, CFG.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, CFG.vocab),
    }


def _pallas_loss(ap, bp, batch, tap_policy="f32", r=4):
    """The pallas epoch-1 adapter loss, composed exactly as
    ``pac_train_step(kernel_impl="pallas")`` builds it."""
    from repro.kernels.cached_step import cached_loss_parts

    ops = get_opset("pallas", tap_policy, True)
    b_final, taps, x, positions = bb.backbone_forward(
        bp, CFG, batch, collect_taps=True, return_inputs=True, ops=ops)
    b0_s, bf_s = ops.emit_tap(x), ops.emit_tap(b_final)
    b0_s, taps, bf_s = jax.lax.stop_gradient((b0_s, taps, bf_s))
    cached = {"b0": b0_s, "taps": taps, "b_final": bf_s, "labels": batch["labels"]}
    num, den = cached_loss_parts(
        bp, ap, CFG, cached, positions, r, impl="pallas", interpret=True)
    return num / jnp.maximum(den, 1)


# ---------------------------------------------------------------------------
# Golden: the ref OpSet is bit-identical to the historical defaults
# ---------------------------------------------------------------------------


def test_ref_opset_bit_identical_forward():
    """ops=None (the default) and the explicit ref OpSet produce the exact
    same bits — the refactor did not move the oracle."""
    bp = _backbone("int8")
    batch = _batch(2, 12)
    h0, taps0, x0, _ = bb.backbone_forward(
        bp, CFG, batch, collect_taps=True, return_inputs=True)
    h1, taps1, x1, _ = bb.backbone_forward(
        bp, CFG, batch, collect_taps=True, return_inputs=True,
        ops=get_opset("ref"))
    for a, b in ((h0, h1), (taps0, taps1), (x0, x1)):
        assert jnp.array_equal(a, b), "ref OpSet is not bit-identical"


def test_ref_opset_bit_identical_step():
    """pac_train_step's default and kernel_impl="ref" are the same step:
    identical loss bits, identical updated adapter bits."""
    bp, batch = _backbone("f32"), _batch(2, 12)
    ap = init_adapter(jax.random.PRNGKey(1), CFG, r=4)
    opt = adamw_init(ap)
    l0, ap0, _, acts0 = steps.pac_train_step(bp, ap, opt, batch, cfg=CFG, r=4)
    l1, ap1, _, acts1 = steps.pac_train_step(
        bp, ap, opt, batch, cfg=CFG, r=4, kernel_impl="ref")
    assert jnp.array_equal(l0, l1)
    for a, b in zip(jax.tree.leaves(ap0), jax.tree.leaves(ap1)):
        assert jnp.array_equal(a, b)
    for a, b in zip(jax.tree.leaves(acts0), jax.tree.leaves(acts1)):
        assert jnp.array_equal(a, b)


# ---------------------------------------------------------------------------
# Property sweep: ragged shapes × storage forms, pallas-interpret ≡ ref
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("storage", STORAGES)
@settings(max_examples=3, deadline=None)
@given(B=st.integers(1, 3), S=st.sampled_from([5, 17, 33]))
def test_epoch1_parity_losses_grads_taps(storage, B, S):
    """Loss, adapter grads, and the emitted taps of the pallas-interpret
    epoch-1 forward match the ref oracle on the SAME weights, for every
    backbone storage form and ragged (B, S)."""
    bp, batch = _backbone(storage), _batch(B, S, seed=B * 100 + S)
    ap = init_adapter(jax.random.PRNGKey(1), CFG, r=4)
    tol = _TOL[storage]

    l_ref, g_ref = jax.value_and_grad(steps.pac_loss_fn)(
        ap, bp, CFG, batch, r=4)
    l_pal, g_pal = jax.value_and_grad(_pallas_loss)(ap, bp, batch, r=4)
    assert abs(float(l_ref) - float(l_pal)) < tol, (storage, float(l_ref), float(l_pal))
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pal)):
        scale = max(float(jnp.max(jnp.abs(a))), 1e-3)
        assert float(jnp.max(jnp.abs(a - b))) < tol * max(scale, 1.0), storage

    # taps (f32 tap policy: emit_tap is identity) — the frozen hiddens
    # themselves agree between the two compute paths
    _, taps_ref = bb.backbone_forward(bp, CFG, batch, collect_taps=True)
    _, taps_pal = bb.backbone_forward(
        bp, CFG, batch, collect_taps=True, ops=get_opset("pallas", "f32", True))
    diff = float(jnp.max(jnp.abs(
        taps_ref.astype(jnp.float32) - taps_pal.astype(jnp.float32))))
    ref_mag = max(float(jnp.max(jnp.abs(taps_ref.astype(jnp.float32)))), 1.0)
    assert diff < tol * 10 * ref_mag, (storage, diff, ref_mag)


# ---------------------------------------------------------------------------
# Storage-form taps: quantized at the tap site, adopted by the cache
# ---------------------------------------------------------------------------


def test_int8_taps_are_cache_storage_form():
    """tap_policy="int8" emits {q, scale} == the cache's own compression
    of the same hidden, and put_batch adopts the payload without a second
    quantization round-trip."""
    bp, batch = _backbone("int8"), _batch(2, 12)
    ops = get_opset("pallas", "int8", True)
    b_final, taps, x, _ = bb.backbone_forward(
        bp, CFG, batch, collect_taps=True, return_inputs=True, ops=ops)
    assert isinstance(taps, dict) and set(taps) == {"q", "scale"}
    assert taps["q"].dtype == jnp.int8

    # bit-identical to what the f32-tap path + cache-side compression makes
    _, taps_f32 = bb.backbone_forward(
        bp, CFG, batch, collect_taps=True, ops=get_opset("pallas", "f32", True))
    qt = quantize(taps_f32.astype(jnp.float32), bits=8, block=TAP_BLOCK)
    assert jnp.array_equal(taps["q"], qt.q)
    # the scale reduction fuses into the forward trace — last-ulp only
    np.testing.assert_allclose(
        np.asarray(taps["scale"]), np.asarray(qt.scale), rtol=1e-6)

    # the cache adopts storage-form entries as-is
    cache = ActivationCache(budget_bytes=1 << 30, compress="int8")
    b0_s, bf_s = ops.emit_tap(x), ops.emit_tap(b_final)
    cache.put_batch(list(range(2)), b0_s, taps, bf_s, orig_last=CFG.d_model)
    cb0, ctaps, _ = cache.get_batch(list(range(2)), with_final=True, compressed=True)
    assert np.array_equal(np.asarray(ctaps["q"]), np.asarray(taps["q"]))
    assert np.array_equal(np.asarray(cb0["q"]), np.asarray(b0_s["q"]))

    # a non-int8 cache refuses a quantized payload instead of guessing
    with pytest.raises(ValueError):
        ActivationCache(budget_bytes=1 << 30, compress="f32").put_batch(
            [0, 1], b0_s, taps, bf_s, orig_last=CFG.d_model)


def test_int8_tap_loss_close_to_ref():
    """End-to-end epoch-1 step with storage-form taps: the loss carries
    only the int8 tap quantization error."""
    bp, batch = _backbone("int8"), _batch(2, 12)
    ap = init_adapter(jax.random.PRNGKey(1), CFG, r=4)
    opt = adamw_init(ap)
    l_ref, *_ = steps.pac_train_step(bp, ap, opt, batch, cfg=CFG, r=4)
    l_pal, _, _, (b0, taps, bf) = steps.pac_train_step(
        bp, ap, opt, batch, cfg=CFG, r=4, kernel_impl="pallas",
        tap_policy="int8", interpret=True)
    assert abs(float(l_ref) - float(l_pal)) < 5e-2
    assert isinstance(taps, dict) and taps["q"].dtype == jnp.int8
    assert isinstance(b0, dict) and isinstance(bf, dict)


# ---------------------------------------------------------------------------
# prepare_block: the pallas path never dequantizes the matmul weights
# ---------------------------------------------------------------------------


def test_prepare_block_keeps_matmul_weights_quantized():
    bp = _backbone("int8")
    spec = CFG.pattern[0]
    assert spec.kind == "attn"
    p = jax.tree.map(lambda t: t[0], bp["blocks"][0])  # period 0's block
    out = get_opset("pallas", "f32", True).prepare_block(p, spec)
    for name in ("wq", "wk", "wv", "wo"):
        assert isinstance(out["mixer"][name], QTensor), name
    for name in ("wi", "wg", "wo"):
        assert isinstance(out["ffn"][name], QTensor), name
    # norm gains have no quantized kernel — those ARE dequantized
    for leaf in jax.tree.leaves(out["ln1"]) + jax.tree.leaves(out["ln2"]):
        assert not isinstance(leaf, QTensor)
    # the ref OpSet dequantizes everything (the historical idiom)
    for leaf in jax.tree.leaves(get_opset("ref").prepare_block(p, spec)):
        assert not isinstance(leaf, QTensor)


# ---------------------------------------------------------------------------
# Registry seam
# ---------------------------------------------------------------------------


def test_registry_unknown_opset_raises():
    with pytest.raises(ValueError, match="unknown OpSet"):
        get_opset("not-a-kernel-impl")


def test_registry_extension_point():
    class _Dummy(OpSet):
        name = "dummy-test"

        def __init__(self, tap_policy="f32", interpret=None):
            self.tap_policy = tap_policy

    register_opset("dummy-test", _Dummy)
    assert isinstance(get_opset("dummy-test", "bf16"), _Dummy)
    # instances are cached per (name, tap_policy, interpret)
    assert get_opset("dummy-test", "bf16") is get_opset("dummy-test", "bf16")


def test_models_layer_never_imports_kernels():
    """The seam the CI grep enforces: model code reaches kernels only
    through the OpSet registry (docstring mentions are fine; import
    statements are not)."""
    import re

    pat = re.compile(r"^\s*(from\s+repro\.kernels|import\s+repro\.kernels"
                     r"|from\s+repro\s+import\s+.*\bkernels\b)")
    root = os.path.join(os.path.dirname(__file__), "..", "src", "repro", "models")
    for fn in os.listdir(root):
        if fn.endswith(".py"):
            with open(os.path.join(root, fn)) as f:
                for i, line in enumerate(f, 1):
                    assert not pat.match(line), f"{fn}:{i}: {line.strip()}"


# ---------------------------------------------------------------------------
# Staged pipeline: shard_map epoch-1 on the pallas OpSet (4-dev subprocess)
# ---------------------------------------------------------------------------

_PIPELINE_PARITY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from repro.configs import get_arch
    from repro.core import steps
    from repro.core.parallel_adapters import init_adapter
    from repro.core.quantization import quantize_tree
    from repro.launch.mesh import make_edge_mesh
    from repro.models import backbone as bb

    cfg = get_arch("internlm2-1.8b").reduced()
    mesh = make_edge_mesh(2, 2)
    bp = quantize_tree(bb.init_backbone(jax.random.PRNGKey(0), cfg),
                       bits=8, min_size=1024)
    ap = init_adapter(jax.random.PRNGKey(1), cfg, r=4)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0, cfg.vocab),
    }

    l_ref, g_ref = jax.value_and_grad(
        lambda a: steps.pac_loss_fn(a, bp, cfg, batch, r=4))(ap)

    # f32 taps: tight parity of the staged pallas forward against ref
    l_pal, g_pal, (b0, taps, bf) = steps.pipeline_pac_loss_and_grads(
        bp, ap, batch, cfg=cfg, mesh=mesh, n_micro=2, r=4,
        kernel_impl="pallas", tap_policy="f32", interpret=True)
    assert abs(float(l_ref) - float(l_pal)) < 1e-3, (float(l_ref), float(l_pal))
    gmax = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pal)))
    assert gmax < 1e-3, f"adapter grad mismatch {gmax}"
    print("PIPELINE_PALLAS_F32_OK")

    # int8 taps: storage-form pytrees flow through the staged forward
    l_q, g_q, (b0q, tapsq, bfq) = steps.pipeline_pac_loss_and_grads(
        bp, ap, batch, cfg=cfg, mesh=mesh, n_micro=2, r=4,
        kernel_impl="pallas", tap_policy="int8", interpret=True)
    assert isinstance(tapsq, dict) and tapsq["q"].dtype == jnp.int8, tapsq
    assert isinstance(b0q, dict) and isinstance(bfq, dict)
    assert abs(float(l_ref) - float(l_q)) < 5e-2, (float(l_ref), float(l_q))
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(g_q))
    print("PIPELINE_PALLAS_INT8_OK")
    """
)


def test_staged_pipeline_pallas_parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", _PIPELINE_PARITY],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PIPELINE_PALLAS_F32_OK" in out.stdout
    assert "PIPELINE_PALLAS_INT8_OK" in out.stdout
