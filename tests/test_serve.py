"""Serving layer: allocator/page-table bookkeeping, INT8 page round
trips, and the multi-tenant engine acceptance gate — a continuously
batched B-adapter run over paged INT8 KV must produce the same greedy
streams as B independent single-request runs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.serve import (
    OutOfPagesError,
    PageAllocator,
    PageTable,
    ServeEngine,
    kv_bytes_per_token,
)
from repro.serve.paging import quantize_kv_pages


# ---------------------------------------------------------------- paging


def test_allocator_never_hands_out_the_null_page():
    a = PageAllocator(5)
    got = a.alloc(4)
    assert sorted(got) == [1, 2, 3, 4]
    assert a.free_pages == 0
    with pytest.raises(OutOfPagesError):
        a.alloc(1)
    a.free([2, 3])
    assert sorted(a.alloc(2)) == [2, 3]
    with pytest.raises(ValueError):
        a.free([0])  # the null page is not the allocator's to recycle


def test_page_table_growth_and_release():
    table = PageTable(PageAllocator(8), page=4, max_pages=3)
    table.open(7, n_tokens=5)          # 5 tokens -> 2 pages
    assert table.length(7) == 5
    indptr, flat = table.ragged([7])
    assert list(indptr) == [0, 2] and len(flat) == 2
    table.extend_to(7, 6)              # idempotent within the same page
    for _ in range(3):
        table.append_token(7)          # crosses into page 3 at token 9
    assert table.length(7) == 8
    bt, lengths = table.dense([7], rows=2)
    assert bt.shape == (2, 3) and lengths[0] == 8
    assert (bt[1] == 0).all() and lengths[1] == 0   # padding row -> null page
    with pytest.raises(OutOfPagesError):
        table.extend_to(7, 13)         # 4 pages > max_pages
    free_before = table.allocator.free_pages
    table.close(7)
    assert table.allocator.free_pages == free_before + 2
    with pytest.raises(KeyError):
        table.length(7)


def test_page_table_rejects_double_open():
    table = PageTable(PageAllocator(4), page=4, max_pages=2)
    table.open(0)
    with pytest.raises(ValueError):
        table.open(0)


def test_int8_page_round_trip_accuracy():
    t = jax.random.normal(jax.random.PRNGKey(0), (6, 4, 32))
    q, scale = quantize_kv_pages(t)
    assert q.dtype == jnp.int8 and scale.shape == (6, 4)
    back = q.astype(jnp.float32) * scale[..., None]
    err = jnp.max(jnp.abs(back - t)) / jnp.max(jnp.abs(t))
    assert err < 1 / 127  # absmax quantization: one step of the grid


def test_kv_bytes_per_token_orders_policies(tiny_cfg):
    f32, bf16, int8 = (kv_bytes_per_token(tiny_cfg, p)
                       for p in ("f32", "bf16", "int8"))
    assert f32 == 2 * bf16
    assert int8 < bf16 < f32  # int8 pays +4B/head scale but stays smallest


# ---------------------------------------------------------------- engine


PROMPTS = [[5, 7, 11, 2, 9], [3, 1], [8, 8, 4, 6], [2, 2, 2]]
USERS = ["alice", "bob", "alice", "bob"]


@pytest.fixture(scope="module")
def adapters(tiny_cfg):
    from repro.core.parallel_adapters import init_adapter

    return {
        "alice": init_adapter(jax.random.PRNGKey(1), tiny_cfg, r=4),
        "bob": init_adapter(jax.random.PRNGKey(2), tiny_cfg, r=4),
    }


def _engine(tiny_backbone, tiny_cfg, adapters, **kw):
    base = dict(r=4, kernel_impl="ref", kv_policy="int8", page_size=4,
                max_len=32, max_batch=2)
    base.update(kw)
    return ServeEngine(tiny_backbone, tiny_cfg, adapters, **base)


def _singles(tiny_backbone, tiny_cfg, adapters, n_new, **kw):
    outs = []
    for p, u in zip(PROMPTS, USERS):
        eng = _engine(tiny_backbone, tiny_cfg, adapters, max_batch=1, **kw)
        h = eng.submit(p, u, max_new_tokens=n_new)
        eng.drain()
        outs.append(h.result())
    return outs


def test_batched_multi_adapter_equals_single_request_streams(
        tiny_backbone, tiny_cfg, adapters):
    """The acceptance gate: 4 requests / 2 adapters continuously batched
    (max_batch=2 forces admission waves and swap-remove retirement) over
    paged INT8 KV through the Pallas kernel == the same requests served
    one at a time."""
    eng = _engine(tiny_backbone, tiny_cfg, adapters, kernel_impl="pallas")
    handles = [eng.submit(p, u, max_new_tokens=5)
               for p, u in zip(PROMPTS, USERS)]
    eng.drain()
    batched = [h.result() for h in handles]
    assert batched == _singles(tiny_backbone, tiny_cfg, adapters, 5,
                               kernel_impl="pallas")
    assert all(len(r) == 5 for r in batched)


def test_staggered_admission_matches_upfront_submission(
        tiny_backbone, tiny_cfg, adapters):
    """Joining a half-decoded batch must not perturb resident requests."""
    eng = _engine(tiny_backbone, tiny_cfg, adapters, max_batch=4)
    h0 = eng.submit(PROMPTS[0], USERS[0], max_new_tokens=6)
    for _ in range(2):
        eng.step()
    late = [eng.submit(p, u, max_new_tokens=6)
            for p, u in zip(PROMPTS[1:], USERS[1:])]
    eng.drain()
    got = [h0.result()] + [h.result() for h in late]
    assert got == _singles(tiny_backbone, tiny_cfg, adapters, 6)


def test_streaming_thread_and_handle_generator(
        tiny_backbone, tiny_cfg, adapters):
    eng = _engine(tiny_backbone, tiny_cfg, adapters, kv_policy="f32",
                  max_batch=4)
    eng.start()
    try:
        hs = [eng.submit(p, u, max_new_tokens=4)
              for p, u in zip(PROMPTS[:3], USERS[:3])]
        streamed = [list(h.tokens()) for h in hs]
    finally:
        eng.stop()
    assert streamed == _singles(tiny_backbone, tiny_cfg, adapters, 4,
                                kv_policy="f32")[:3]


def test_warm_buckets_do_not_retrace(tiny_backbone, tiny_cfg, adapters):
    """Admission waves reuse the size-bucketed jitted steps: a second
    identical wave of work compiles nothing new."""
    eng = _engine(tiny_backbone, tiny_cfg, adapters, max_batch=4)
    for p, u in zip(PROMPTS, USERS):
        eng.submit(p, u, max_new_tokens=4)
    eng.drain()
    warm = eng.n_traces
    assert warm > 0
    for p, u in zip(PROMPTS, USERS):
        eng.submit(p, u, max_new_tokens=4)
    eng.drain()
    assert eng.n_traces == warm


def test_submit_validates_against_engine_limits(
        tiny_backbone, tiny_cfg, adapters):
    eng = _engine(tiny_backbone, tiny_cfg, adapters)
    with pytest.raises(ValueError):
        eng.submit(list(range(40)), "alice", max_new_tokens=1)  # > max_len
    with pytest.raises(KeyError):
        eng.submit([1, 2], "mallory", max_new_tokens=2)  # unknown adapter


# ---------------------------------------------------------- paging properties


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_paging_random_op_sequences_conserve_pages(seed):
    """Allocator/page-table invariants under random open/grow/close
    traffic: pages are conserved (free + owned == n_pages - 1), no page
    is ever in two runs, the null page is never handed out, and the CSR
    and dense exports always agree."""
    import random as _random

    rng = _random.Random(seed)
    n_pages, page, max_pages = 17, 4, 5
    alloc = PageAllocator(n_pages)
    table = PageTable(alloc, page=page, max_pages=max_pages)
    live: list = []
    next_rid = 0

    for _ in range(60):
        op = rng.choice(["open", "close", "append", "extend"])
        try:
            if op == "open":
                table.open(next_rid, n_tokens=rng.randrange(0, page * max_pages + 1))
                live.append(next_rid)
                next_rid += 1
            elif op == "close" and live:
                table.close(live.pop(rng.randrange(len(live))))
            elif op == "append" and live:
                table.append_token(rng.choice(live))
            elif op == "extend" and live:
                rid = rng.choice(live)
                table.extend_to(rid, rng.randrange(0, page * max_pages + 2))
        except OutOfPagesError:
            if op == "open" and next_rid in table._pages:
                # failed admission leaves an empty, zero-length run —
                # release it, as the engine's admission control does
                assert table._pages[next_rid] == []
                table.close(next_rid)

        # conservation: every non-null page is free XOR owned by one run
        owned = [p for rid in live for p in table._pages[rid]]
        assert len(owned) == len(set(owned)), f"page double-owned: {owned}"
        assert 0 not in owned
        assert alloc.free_pages + len(owned) == n_pages - 1
        # each run covers its token count, within max_pages
        for rid in live:
            run = table._pages[rid]
            assert len(run) <= max_pages
            assert len(run) * page >= table.length(rid)
        # CSR vs dense agree for a random row order
        rids = rng.sample(live, len(live))
        indptr, flat = table.ragged(rids)
        bt, lengths = table.dense(rids)
        assert indptr[-1] == len(flat)
        for i, rid in enumerate(rids):
            run = flat[indptr[i]:indptr[i + 1]].tolist()
            assert run == table._pages[rid]
            assert bt[i, :len(run)].tolist() == run
            assert not bt[i, len(run):].any()          # null-page padding
            assert lengths[i] == table.length(rid)

    for rid in list(live):
        table.close(rid)
    assert alloc.free_pages == n_pages - 1             # everything returned
