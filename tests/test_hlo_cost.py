"""The trip-count-aware HLO cost model vs known-cost programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_hlo, parse_module


def _cost(fn, *args):
    return analyze_hlo(jax.jit(fn).lower(*args).compile().as_text())


def test_xla_cost_analysis_undercounts_scans():
    """Documents WHY this module exists: XLA counts a while body once."""
    d = 128
    W = jnp.zeros((10, d, d))
    x = jnp.zeros((4, d))

    def f(x, W):
        return jax.lax.scan(lambda h, w: (h @ w, None), x, W)[0]

    xla = jax.jit(f).lower(x, W).compile().cost_analysis()
    if isinstance(xla, list):
        xla = xla[0]
    expected = 10 * 2 * 4 * d * d
    assert xla["flops"] < 0.2 * expected  # XLA sees ~1/10th
    ours = _cost(f, x, W)
    np.testing.assert_allclose(ours.flops, expected, rtol=0.15)


def test_scan_equals_unroll():
    d = 64
    W = jnp.zeros((8, d, d))
    x = jnp.zeros((2, d))

    def f_scan(x, W):
        return jax.lax.scan(lambda h, w: (jnp.tanh(h @ w), None), x, W)[0]

    def f_unroll(x, W):
        h = x
        for i in range(8):
            h = jnp.tanh(h @ W[i])
        return h

    a, b = _cost(f_scan, x, W), _cost(f_unroll, x, W)
    np.testing.assert_allclose(a.flops, b.flops, rtol=0.05)


def test_dot_flops_with_batch_dims():
    a = jnp.zeros((4, 8, 16))
    b = jnp.zeros((4, 16, 32))
    c = _cost(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b)
    np.testing.assert_allclose(c.flops, 2 * 4 * 8 * 16 * 32, rtol=0.05)


def test_nested_scan_trip_counts_multiply():
    d = 32
    W = jnp.zeros((3, 4, d, d))
    x = jnp.zeros((2, d))

    def inner(h, ws):
        return jax.lax.scan(lambda h, w: (h @ w, None), h, ws)[0]

    def f(x, W):
        return jax.lax.scan(lambda h, ws: (inner(h, ws), None), x, W)[0]

    c = _cost(f, x, W)
    dot_flops = 12 * 2 * 2 * d * d
    # dot flops fully counted; elementwise/slicing overhead adds <1× on top
    assert dot_flops <= c.flops < 2 * dot_flops


def test_gather_not_charged_full_table():
    table = jnp.zeros((50_000, 64))
    idx = jnp.zeros((8,), jnp.int32)
    c = _cost(lambda t, i: jnp.take(t, i, axis=0), table, idx)
    assert c.bytes < table.nbytes / 10  # charged ~result, not the table


def test_parse_module_computations():
    txt = jax.jit(lambda x: jnp.sin(x) + 1).lower(jnp.zeros((4,))).compile().as_text()
    comps = parse_module(txt)
    assert any(c for c in comps)
