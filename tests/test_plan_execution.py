"""Plan-driven execution: the planner's Plan as the runtime contract.

Acceptance contract of the plan→execution path (ISSUE 4):

* a *uniform* Plan executed via its StagePartition produces **bit-
  identical** loss/adapter-grads to the --dp/--stages path (the partition
  dispatches to exactly the same code);
* a *ragged* Plan (uneven periods per stage) matches the single-device
  reference loss/grads/taps within fp32 tolerance, and its layer-ordered
  taps round-trip through the ActivationCache so epoch ≥2 runs zero
  backbone forwards;
* Plan JSON round-trips losslessly (save once, replay on the pool);
* the trainer CLI executes ``--plan auto`` end to end and replays a
  ``--save-plan`` file to the same losses.

Multi-device tests run in subprocesses with
``--xla_force_host_platform_device_count`` (this process keeps the
single real device).
"""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.planner import (
    HybridParallelismPlanner,
    JETSON_NANO_H,
    JETSON_NANO_L,
    JETSON_TX2_H,
    JETSON_TX2_L,
    Plan,
    StagePartition,
    aggregate_periods,
    model_layer_costs,
    period_costs,
)
from repro.configs import get_arch


def _run_sub(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=600
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# StagePartition: the executable artifact
# ---------------------------------------------------------------------------


def test_stage_partition_shape_and_masks():
    p = StagePartition(boundaries=(0, 2, 6, 10),
                       samples_per_device=((4,), (4,), (2, 2)), n_micro=2)
    assert p.n_stages == 3 and p.n_periods == 10
    assert p.periods_per_stage == (2, 4, 4) and p.max_periods == 4
    assert not p.is_uniform
    assert p.masks() == (
        (True, True, False, False),
        (True, True, True, True),
        (True, True, True, True),
    )
    u = StagePartition(boundaries=(0, 5, 10), samples_per_device=((4,), (4,)), n_micro=2)
    assert u.is_uniform and u.masks() == ((True,) * 5, (True,) * 5)


def test_stage_partition_rejects_bad_boundaries():
    with pytest.raises(ValueError):
        StagePartition(boundaries=(1, 3), samples_per_device=((1,),), n_micro=1)
    with pytest.raises(ValueError):
        StagePartition(boundaries=(0, 3, 2), samples_per_device=((1,), (1,)), n_micro=1)
    with pytest.raises(ValueError):  # splits/stages mismatch
        StagePartition(boundaries=(0, 2, 4), samples_per_device=((1,),), n_micro=1)


def test_plan_partition_from_planner_is_executable():
    cfg = get_arch("internlm2-1.8b").reduced()
    plan = HybridParallelismPlanner(
        period_costs(cfg, "pac", seq_len=32), [JETSON_NANO_H] * 4, 4, 2,
    ).plan()
    part = plan.stage_partition()
    assert part.n_periods == cfg.n_periods
    assert sum(part.periods_per_stage) == cfg.n_periods
    assert part.n_micro == plan.micro_batches


def test_layer_granularity_plan_refuses_off_period_cut():
    """A plan cut inside a period is a report, not a contract — deriving a
    partition from it must fail loudly."""
    cfg = get_arch("t5-base-pac")
    costs = model_layer_costs(cfg, "full", seq_len=64)
    plan = HybridParallelismPlanner(costs, [JETSON_NANO_H] * 4, 2, 4).plan()
    if plan.n_stages == 1:
        pytest.skip("planner chose a single stage; no interior cut to test")
    lpp = len(costs)  # pretend one huge period: every interior cut is illegal
    with pytest.raises(ValueError):
        plan.stage_partition(layers_per_period=lpp)


def test_aggregate_periods_sums_flops_keeps_boundary_act():
    cfg = get_arch("t5-base-pac")
    layer = model_layer_costs(cfg, "pac", seq_len=64)
    per = aggregate_periods(layer, cfg.period)
    assert len(per) == cfg.n_periods
    assert per[0].fwd_flops == pytest.approx(
        sum(c.fwd_flops for c in layer[: cfg.period]))
    # inter-stage comm is the boundary activation, not the sum
    assert per[0].act_bytes == layer[cfg.period - 1].act_bytes
    with pytest.raises(ValueError):
        aggregate_periods(layer, len(layer) + 1)


def test_hlo_calibrated_cost_model():
    """The calibrated backend keeps analytic memory accounting, prices the
    backbone forward close to the analytic model (they should agree — both
    count the same matmuls), and captures the head/CE/optimizer overhead
    the closed form omits on the trainable side."""
    from repro.launch.costs import AnalyticCostModel, CostModel, HloCalibratedCostModel

    cfg = get_arch("internlm2-1.8b").reduced()
    ana = AnalyticCostModel()
    cal = HloCalibratedCostModel(micro_batch=2)
    assert isinstance(ana, CostModel) and isinstance(cal, CostModel)
    base = ana.period_costs(cfg, "pac", seq_len=16)
    pc = cal.period_costs(cfg, "pac", seq_len=16)
    assert len(pc) == cfg.n_periods == len(base)
    for b, c in zip(base, pc):
        assert c.param_bytes == b.param_bytes  # memory stays analytic
        assert c.resident_act_bytes == b.resident_act_bytes
        # measured backbone fwd within 25% of the analytic count
        assert c.fwd_flops == pytest.approx(b.fwd_flops, rel=0.25)
        # the trainable side includes head/CE/optimizer the analytic omits
        assert c.bwd_flops > b.bwd_flops
    # calibration targets the PAC+ path; other techniques pass through
    assert cal.period_costs(cfg, "full", seq_len=16) == ana.period_costs(
        cfg, "full", seq_len=16)


# ---------------------------------------------------------------------------
# Plan JSON round-trip
# ---------------------------------------------------------------------------


def test_plan_json_roundtrip(tmp_path):
    from repro.core.pipeline import simulate_plan

    cfg = get_arch("internlm2-1.8b").reduced()
    env_b = [JETSON_NANO_H, JETSON_NANO_L, JETSON_TX2_H, JETSON_TX2_L]
    plan = HybridParallelismPlanner(
        period_costs(cfg, "pac", seq_len=32), env_b, 4, 2,
    ).plan()
    path = plan.save(str(tmp_path / "plan.json"))
    back = Plan.load(path)
    assert back.describe() == plan.describe()
    assert back.minibatch_latency == pytest.approx(plan.minibatch_latency)
    assert back.stage_partition() == plan.stage_partition()
    for a, b in zip(plan.stages, back.stages):
        assert (a.fwd_time, a.bwd_time) == pytest.approx((b.fwd_time, b.bwd_time))
        assert a.devices == b.devices
    assert simulate_plan(back)["minibatch_time"] == pytest.approx(
        simulate_plan(plan)["minibatch_time"])


def test_plan_json_rejects_unknown_version():
    with pytest.raises(ValueError):
        Plan.from_json('{"version": 99}')


# ---------------------------------------------------------------------------
# Execution equivalence (subprocess: needs >1 device)
# ---------------------------------------------------------------------------

_UNIFORM_BITWISE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_arch
    from repro.core import steps
    from repro.core.parallel_adapters import init_adapter
    from repro.core.planner import StagePartition
    from repro.launch.mesh import make_edge_mesh
    from repro.models import backbone as bb

    cfg = get_arch("internlm2-1.8b").reduced()   # 2 periods
    mesh = make_edge_mesh(2, 2)
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    ap = init_adapter(jax.random.PRNGKey(1), cfg, r=4)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (8, 16), 0, cfg.vocab),
    }
    part = StagePartition(boundaries=(0, 1, 2),
                          samples_per_device=((2, 2), (2, 2)), n_micro=2)
    assert part.is_uniform
    l_ref, g_ref, acts_ref = steps.pipeline_pac_loss_and_grads(
        bp, ap, batch, cfg=cfg, mesh=mesh, n_micro=2, r=4)
    l_pl, g_pl, acts_pl = steps.pipeline_pac_loss_and_grads(
        bp, ap, batch, cfg=cfg, mesh=mesh, n_micro=2, r=4, partition=part)
    assert np.array_equal(np.asarray(l_ref), np.asarray(l_pl)), "loss not bit-identical"
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pl)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), "grads not bit-identical"
    for a, b in zip(jax.tree.leaves(acts_ref), jax.tree.leaves(acts_pl)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), "acts not bit-identical"
    print("UNIFORM_BITWISE_OK")
    """
)


def test_uniform_plan_is_bit_identical_to_stages_path():
    """The equivalence bar for uniform plans is exact: same stage function,
    same stacking, same collectives."""
    assert "UNIFORM_BITWISE_OK" in _run_sub(_UNIFORM_BITWISE)


_RAGGED_EQUIV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
    import dataclasses, functools
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_arch
    from repro.core import steps
    from repro.core.activation_cache import ActivationCache
    from repro.core.parallel_adapters import init_adapter
    from repro.core.planner import (
        HybridParallelismPlanner, JETSON_NANO_H, JETSON_NANO_L, JETSON_TX2_H,
        period_costs)
    from repro.launch.mesh import make_plan_mesh
    from repro.models import backbone as bb
    from repro.optim import adamw_init

    cfg = get_arch("internlm2-1.8b").reduced()
    cfg = dataclasses.replace(cfg, name="plan5p", n_layers=5 * cfg.period)
    assert cfg.n_periods == 5

    # a real planner-made RAGGED plan: heterogeneous speeds + memory too
    # tight for one device force an uneven 3-stage split of 5 periods
    pc = period_costs(cfg, "pac", seq_len=16)
    need = sum(c.param_bytes + 2 * c.trainable_bytes for c in pc)
    env = [dataclasses.replace(d, memory_bytes=need * f)
           for d, f in ((JETSON_NANO_L, 0.5), (JETSON_TX2_H, 0.5), (JETSON_NANO_H, 0.5))]
    plan = HybridParallelismPlanner(pc, env, 4, 2).plan(max_stages=3)
    part = plan.stage_partition()
    assert part.n_stages == 3, part
    assert not part.is_uniform, f"want a ragged demo plan, got {part.periods_per_stage}"

    mesh = make_plan_mesh(part)   # (dp=1, stage=3)
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    ap = init_adapter(jax.random.PRNGKey(1), cfg, r=4)
    B, S = 4, 16
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab),
    }

    loss_ref, grads_ref = jax.value_and_grad(
        lambda a: steps.pac_loss_fn(a, bp, cfg, batch, r=4))(ap)
    loss_pp, grads_pp, (b0, taps, bf) = steps.pipeline_pac_loss_and_grads(
        bp, ap, batch, cfg=cfg, mesh=mesh, n_micro=part.n_micro, r=4, partition=part)
    assert abs(float(loss_ref) - float(loss_pp)) < 1e-4, (float(loss_ref), float(loss_pp))
    gmax = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(grads_ref), jax.tree.leaves(grads_pp)))
    assert gmax < 1e-4, f"adapter grad mismatch {gmax}"

    # taps from the uneven boundaries assemble in true layer order
    bf_ref, taps_ref, b0_ref, _ = bb.backbone_forward(
        bp, cfg, batch, collect_taps=True, return_inputs=True)
    assert taps.shape == taps_ref.shape, (taps.shape, taps_ref.shape)
    assert float(jnp.max(jnp.abs(taps - taps_ref))) < 1e-4, "taps mismatch"
    assert float(jnp.max(jnp.abs(bf - bf_ref))) < 1e-4, "b_final mismatch"
    assert float(jnp.max(jnp.abs(b0 - b0_ref))) < 1e-6, "b0 mismatch"
    print("RAGGED_EQUIV_OK")

    # layer-ordered taps feed the cache: epoch>=2 adapter-only step from the
    # cached entries matches the single-device cached step (zero backbone fwd)
    ids = np.arange(B, dtype=np.int32)
    cache = ActivationCache(budget_bytes=1 << 30)
    cache.put_batch(ids, b0, taps, bf)
    hit = cache.get_batch(ids, with_final=True)
    assert hit is not None
    cb0, ctaps, cbf = (jnp.asarray(x) for x in hit)
    cached = {"b0": cb0, "taps": ctaps, "b_final": cbf, "labels": batch["labels"]}
    ref_cached = {"b0": b0_ref, "taps": taps_ref, "b_final": bf_ref,
                  "labels": batch["labels"]}
    opt = adamw_init(ap)
    stepN = functools.partial(steps.pac_cached_train_step, cfg=cfg, r=4)
    l_pipe, ap_pipe, _ = stepN(bp, ap, opt, cached)
    l_1dev, ap_1dev, _ = stepN(bp, ap, opt, ref_cached)
    assert abs(float(l_pipe) - float(l_1dev)) < 1e-4
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(ap_pipe), jax.tree.leaves(ap_1dev)))
    assert d < 1e-3, f"cached-step adapter mismatch {d}"
    print("RAGGED_CACHE_OK")
    """
)


def test_ragged_plan_matches_single_device_and_feeds_cache():
    """10-periods-over-3-stages style ragged execution: loss/grads/taps ≡
    single device, and the taps round-trip the activation cache."""
    out = _run_sub(_RAGGED_EQUIV)
    assert "RAGGED_EQUIV_OK" in out
    assert "RAGGED_CACHE_OK" in out


# ---------------------------------------------------------------------------
# Trainer CLI: --plan auto end to end, --save-plan / replay
# ---------------------------------------------------------------------------


def _run_train(tmp, *extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)  # the CLI must force its own device count
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--reduced",
         "--epochs", "2", "--steps-per-epoch", "2", "--batch", "4",
         "--seq", "16", *extra],
        capture_output=True, text=True, env=env, timeout=600, cwd=str(tmp),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_train_cli_plan_auto(tmp_path):
    """`--plan auto` plans, builds the mesh from the plan, executes epoch 1
    through the pipeline and epoch 2 from the cache."""
    out = _run_train(tmp_path, "--plan", "auto", "--pool", "4", "--micro", "2")
    assert "mesh: plan-driven dp=2×pp=2" in out
    assert "(plan-driven dp2xpp2)" in out
    assert "(cached pure-dp)" in out


def test_train_cli_plan_save_and_replay(tmp_path):
    """--save-plan emits a JSON the trainer replays to identical losses."""
    out1 = _run_train(tmp_path, "--plan", "auto", "--pool", "4",
                      "--micro", "2", "--save-plan", "plan.json")
    assert (tmp_path / "plan.json").exists()
    out2 = _run_train(tmp_path, "--plan", "plan.json", "--pool", "4")
    losses1 = [l for l in out1.splitlines() if l.startswith("epoch ")]
    losses2 = [l for l in out2.splitlines() if l.startswith("epoch ")]
    def strip_time(lines):
        return [l.split(" time=")[0] for l in lines]
    assert strip_time(losses1) == strip_time(losses2)
