"""Sharding rules, data pipeline, checkpoint, optimizer units."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import INPUT_SHAPES, get_arch
from repro.core.quantization import QTensor, quantize_tree
from repro.data import DataPipeline, SyntheticPersonalCorpus, glue_like_task
from repro.launch import sharding as shard
from repro.launch.mesh import make_mesh
from repro.launch.specs import abstract_params, input_specs, resolve_cfg_for_shape
from repro.models import backbone as bb
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


ASSIGNED = [
    "musicgen-large", "grok-1-314b", "moonshot-v1-16b-a3b", "kimi-k2-1t-a32b",
    "qwen2-vl-7b", "xlstm-125m", "gemma2-2b", "jamba-1.5-large-398b",
    "internlm2-1.8b", "granite-20b",
]


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_specs_cover_all_leaves(arch):
    cfg = get_arch(arch)
    mesh = _mesh11()
    params = abstract_params(cfg)
    specs = shard.param_specs(params, mesh)
    n_p = len(jax.tree.leaves(params, is_leaf=lambda x: isinstance(x, QTensor)))
    n_s = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, (P, QTensor))))
    assert n_p == n_s
    for leaf, spec in zip(
        jax.tree.leaves(params, is_leaf=lambda x: isinstance(x, QTensor)),
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, (P, QTensor))),
    ):
        if isinstance(leaf, QTensor):
            assert isinstance(spec, QTensor)
        else:
            assert len(spec) <= leaf.ndim


@pytest.mark.parametrize("arch", ["kimi-k2-1t-a32b", "granite-20b"])
def test_quantized_param_specs(arch):
    cfg = get_arch(arch)
    mesh = _mesh11()
    params = abstract_params(cfg, quant_bits=8)
    specs = shard.param_specs(params, mesh)
    qleaves = [
        l for l in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, QTensor))
        if isinstance(l, QTensor)
    ]
    assert qleaves, "quantized params must produce QTensor specs"


@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
@pytest.mark.parametrize("arch", ["internlm2-1.8b", "qwen2-vl-7b", "musicgen-large"])
def test_input_specs_shapes(arch, shape_name):
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    cfg2, note = resolve_cfg_for_shape(cfg, shape)
    batch = input_specs(cfg2, shape)
    B = shape.global_batch
    if cfg.frontend:
        assert batch["embeds"].shape[0] == B
        assert batch["embeds"].shape[2] == cfg.d_model
    else:
        assert batch["tokens"].shape[0] == B
    if shape.mode == "decode":
        lead = batch.get("tokens", batch.get("embeds"))
        assert lead.shape[1] == 1  # ONE new token
    if shape.name == "long_500k" and not cfg.is_subquadratic():
        assert note == "sw8k" and cfg2.is_subquadratic()


def test_corpus_learnable_structure_and_determinism():
    c1 = SyntheticPersonalCorpus(128, 16, 32, seed=7)
    c2 = SyntheticPersonalCorpus(128, 16, 32, seed=7)
    np.testing.assert_array_equal(c1.tokens, c2.tokens)
    b = c1.batch(np.arange(4))
    assert b["tokens"].shape == (4, 15) and b["labels"].shape == (4, 15)
    # labels are next tokens
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_pipeline_epochs_shuffle_and_microbatch():
    corpus = glue_like_task("mrpc", 128, 16, scale=0.01)
    pipe = DataPipeline(corpus, global_batch=8, seed=3)
    e0 = [b["seq_ids"] for b in pipe.epoch(0)]
    e1 = [b["seq_ids"] for b in pipe.epoch(1)]
    assert not all(np.array_equal(a, b) for a, b in zip(e0, e1))
    mb = DataPipeline.microbatches(corpus.batch(np.arange(8)), 4)
    assert mb["tokens"].shape[:2] == (4, 2)


def test_dp_microbatches_layout_and_validation():
    """The hybrid-trainer batch layout: (B,) → (n_micro, mb) with dim 1
    contiguous-chunk shardable over dp ranks, and clear errors (not
    asserts) on indivisible CLI combinations."""
    corpus = glue_like_task("mrpc", 128, 16, scale=0.01)
    batch = corpus.batch(np.arange(8))
    mb = DataPipeline.dp_microbatches(batch, n_micro=2, dp=2)
    assert mb["tokens"].shape[:2] == (2, 4)
    # micro m, dp rank r owns samples [m*mb + r*mb/dp, ...): contiguous
    np.testing.assert_array_equal(mb["seq_ids"][0], batch["seq_ids"][:4])
    np.testing.assert_array_equal(mb["seq_ids"][1], batch["seq_ids"][4:])
    with pytest.raises(ValueError, match="divisible"):
        DataPipeline.dp_microbatches(batch, n_micro=2, dp=3)
    with pytest.raises(ValueError, match=">= 1"):
        DataPipeline.dp_microbatches(batch, n_micro=0, dp=1)


def test_checkpoint_roundtrip_nested(tmp_path):
    tree = {
        "a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
        "nested": {"b": [jnp.ones((4,)), jnp.zeros((2, 2), jnp.int32)]},
        "q": quantize_tree({"w": jax.random.normal(jax.random.PRNGKey(0), (128, 128))})["w"],
        "scalar": 3,
        "s": "hello",
    }
    p = str(tmp_path / "t.msgpack")
    n = save_checkpoint(p, tree)
    assert n > 0
    back = load_checkpoint(p)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert isinstance(back["q"], QTensor)
    np.testing.assert_array_equal(np.asarray(back["q"].q), np.asarray(tree["q"].q))
    assert back["scalar"] == 3 and back["s"] == "hello"


def test_adamw_descends_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(params, grads, state, lr=5e-2, weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.15


def test_clip_and_schedule():
    g, norm = clip_by_global_norm({"a": jnp.full((4,), 10.0)}, 1.0)
    assert float(jnp.sqrt(jnp.sum(jnp.square(g["a"])))) <= 1.0 + 1e-5
    lrs = [float(cosine_schedule(s, 100, 1.0, warmup_steps=10)) for s in range(100)]
    assert lrs[0] < lrs[9] and lrs[20] > lrs[90]


# ---------------------------------------------------------------------------
# psharding rule-table units (TP_ALT fallback, stacked-vs-slice lookup)
# ---------------------------------------------------------------------------


def test_resolve_tp_alt_fallback_fires_only_when_tp_fails():
    from repro.compat import abstract_mesh
    from repro.core.psharding import FSDP, TP, TP_ALT, resolve

    mesh = abstract_mesh((2, 2), ("data", "model"))
    # E=8 divides model=2 -> TP wins, TP_ALT stays None
    spec = resolve((None, TP, FSDP, TP_ALT), (4, 8, 16, 32), mesh)
    assert spec == P(None, "model", "data", None)
    # E=3 does not divide -> TP_ALT takes the model axis (grok case)
    spec = resolve((None, TP, FSDP, TP_ALT), (4, 3, 16, 32), mesh)
    assert spec == P(None, None, "data", "model")
    # neither divides -> nothing gets model
    spec = resolve((None, TP, FSDP, TP_ALT), (4, 3, 16, 33), mesh)
    assert spec == P(None, None, "data", None)


def test_constrain_spec_is_noop_without_mesh():
    from repro.core.psharding import constrain_spec

    x = jnp.ones((4, 8, 16))
    y = constrain_spec(x, ("batch", "model", None))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_slice_lookup_uses_stacked_rules():
    """The scan slice of stacked MoE weights (E,d,f) must keep E on the
    model axis (hillclimb kimi iter A): the rule lookup for a sliced leaf
    goes through the stacked (ndim+1) table minus the scan dim."""
    from repro.core.psharding import TP, TP_ALT, logical_for_param

    # sliced MoE expert weight (E, d, f): stacked rule is (None, TP, FSDP, TP_ALT)
    logical = logical_for_param(["blocks", "ffn", "wi"], 3 + 1)[1:]
    kept = tuple(ax if ax in (TP, TP_ALT) else None for ax in logical)
    assert kept == (TP, None, TP_ALT)
    # sliced attention weight (d, H*hd): stacked rule (None, FSDP, TP)
    logical = logical_for_param(["blocks", "mixer", "wq"], 2 + 1)[1:]
    kept = tuple(ax if ax in (TP, TP_ALT) else None for ax in logical)
    assert kept == (None, TP)
