"""Planner (paper Alg. 1) tests: DP optimality, memory, heterogeneity."""

import pytest
from _propcheck import given, settings, strategies as st

from repro.configs import get_arch
from repro.core.planner import (
    INF,
    DeviceProfile,
    HybridParallelismPlanner,
    JETSON_NANO_H,
    JETSON_NANO_L,
    JETSON_TX2_H,
    JETSON_TX2_L,
    LayerCost,
    brute_force_plan,
    model_layer_costs,
    plan_pure_dp,
    plan_pure_pp,
)

ENV_A = [JETSON_NANO_H] * 4
ENV_B = [JETSON_NANO_H, JETSON_NANO_L, JETSON_TX2_H, JETSON_TX2_L]


def _costs(tech="pac", arch="t5-base-pac", L=None, seq=128):
    c = model_layer_costs(get_arch(arch), tech, seq_len=seq)
    return c[:L] if L else c


def test_planner_beats_or_matches_pure_baselines():
    for tech in ("pac", "full", "lora"):
        costs = _costs(tech)
        hp = HybridParallelismPlanner(costs, ENV_A, 4, 4).plan()
        for base in (plan_pure_dp(costs, ENV_A, 4, 4), plan_pure_pp(costs, ENV_A, 4, 4)):
            if base is not None:
                assert hp.minibatch_latency <= base.minibatch_latency + 1e-9


def test_full_ft_ooms_on_dp_but_not_hp():
    """Paper Table V: Standalone/DP OOM for full FT; PP/HP survive."""
    costs = _costs("full", arch="bart-large-pac")
    assert plan_pure_dp(costs, ENV_A, 4, 4) is None
    hp = HybridParallelismPlanner(costs, ENV_A, 4, 4).plan()
    assert hp.n_stages > 1  # must partition to fit


def test_pac_relaxes_memory_pressure():
    """PAC+ fits with fewer stages than full FT (lighter activations)."""
    full = HybridParallelismPlanner(_costs("full"), ENV_A, 4, 4).plan()
    pac = HybridParallelismPlanner(_costs("pac"), ENV_A, 4, 4).plan()
    assert pac.minibatch_latency < full.minibatch_latency


def test_dp_matches_brute_force_small():
    costs = _costs("full", L=5, seq=64)
    devs = [JETSON_NANO_H, JETSON_TX2_H, JETSON_NANO_L]
    dp = HybridParallelismPlanner(costs, devs, 3, 2).plan()
    bf = brute_force_plan(costs, devs, 3, 2)
    assert dp.minibatch_latency <= bf.minibatch_latency + 1e-9


@settings(max_examples=8, deadline=None)
@given(
    flops=st.lists(st.floats(1e9, 1e12), min_size=2, max_size=4),
    L=st.integers(2, 5),
    seed=st.integers(0, 50),
)
def test_dp_optimality_property(flops, L, seed):
    """Planner DP ≡ brute force over random device pools (hypothesis)."""
    import random

    rng = random.Random(seed)
    devs = [
        DeviceProfile(f"d{i}", f, 8 * 2**30, 125e6) for i, f in enumerate(flops)
    ]
    costs = [
        LayerCost(
            fwd_flops=rng.uniform(1e9, 5e10),
            bwd_flops=rng.uniform(1e9, 1e11),
            param_bytes=rng.uniform(1e6, 1e8),
            trainable_bytes=1e6,
            act_bytes=1e6,
            resident_act_bytes=rng.uniform(1e5, 1e7),
        )
        for _ in range(L)
    ]
    p = HybridParallelismPlanner(costs, devs, 2, 2)
    p.plan()
    # The DP guarantee (paper Eq. 3) is optimal *stage balance* per stage
    # count s (σ-selection by Eqs. 5-7 is a separate argmin over those
    # balanced configs). Verify the balance objective against brute force.
    import itertools

    n, L = len(devs), len(costs)
    for s in range(1, min(n, L) + 1):
        w_dp, cfgs = p._w(L - 1, n, s)
        if cfgs is None:
            continue
        best = INF
        for cuts in itertools.combinations(range(L - 1), s - 1):
            bounds = [(a + 1, b) for a, b in zip((-1,) + cuts, cuts + (L - 1,))]
            for dcuts in itertools.combinations(range(1, n), s - 1):
                dbounds = [(a, b) for a, b in zip((0,) + dcuts, dcuts + (n,))]
                worst = 0.0
                for (x, y), (da, db) in zip(bounds, dbounds):
                    t, _ = p.stage_dispatch(x, y, tuple(range(da, db)), 2)
                    worst = max(worst, t)
                best = min(best, worst)
        assert w_dp <= best + 1e-9


def test_infeasible_raises():
    tiny = [DeviceProfile("t", 1e9, 1 << 20)] * 2  # 1 MB devices
    costs = _costs("full")
    with pytest.raises(RuntimeError):
        HybridParallelismPlanner(costs, tiny, 4, 4).plan()


def test_heterogeneity_aware_beats_oblivious():
    """Paper Fig. 12: het-aware planning ≤ uniform-split planning."""
    costs = _costs("pac", arch="bart-large-pac")
    het = HybridParallelismPlanner(costs, ENV_B, 8, 4).plan()
    obl = HybridParallelismPlanner(costs, ENV_B, 8, 4, heterogeneity_aware=False).plan()
    assert het.minibatch_latency <= obl.minibatch_latency + 1e-9


def test_stage_dispatch_respects_speed_ordering():
    """Faster devices get ≥ samples of slower ones in one group."""
    costs = _costs("pac", L=4)
    pl = HybridParallelismPlanner(costs, [JETSON_NANO_L, JETSON_TX2_H], 8, 2)
    t, split = pl.stage_dispatch(0, 3, (0, 1), 8)
    assert split[1] >= split[0]  # tx2-h is ~2.7× faster than nano-l


def test_layer_costs_reflect_techniques():
    """PAC+ backward ≪ LoRA backward ≪ full backward (paper Fig. 13a)."""
    cfg = get_arch("bart-large-pac")
    full = sum(c.bwd_flops for c in model_layer_costs(cfg, "full"))
    lora = sum(c.bwd_flops for c in model_layer_costs(cfg, "lora"))
    pac = sum(c.bwd_flops for c in model_layer_costs(cfg, "pac"))
    pac_total = sum(c.fwd_flops + c.bwd_flops for c in model_layer_costs(cfg, "pac"))
    cached = sum(c.fwd_flops + c.bwd_flops for c in model_layer_costs(cfg, "pac_cached"))
    assert pac < 0.15 * full  # ~92% backward reduction in the paper
    assert lora <= full
    assert cached < 0.2 * pac_total  # cache removes the backbone forward
