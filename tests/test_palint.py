"""palint self-tests: every rule fires on a bad fixture and stays quiet
on the matching good one, suppressions work, ``--json`` has the
documented shape, and — the real gate — the repo itself lints clean.

Fixtures are miniature source trees written under ``tmp_path`` and
analyzed through the :func:`tools.palint.run` API with ``root`` pointed
at the fixture, so rule paths (``src/repro/models/...``) behave exactly
as in the real repo. palint never imports the code it analyzes, so the
fixtures only need to *parse*.
"""

import json
import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from tools.palint import Context, run  # noqa: E402


def lint_tree(tmp_path, files, **ctx_kw):
    """Write ``{relpath: source}`` under tmp_path and lint the tree."""
    for rel, source in files.items():
        path = tmp_path / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(source)
    root = str(tmp_path)
    return run(root=root, ctx=Context(root=root, **ctx_kw))


def rules_fired(result):
    return {f.rule for f in result.findings}


# ---------------------------------------------------------------- compat


def test_compat_surface_flags_gated_apis_outside_compat(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/models/m.py": (
            "from jax.experimental.shard_map import shard_map\n"
            "import jax\n"
            "mesh = jax.make_mesh((2,), ('dp',), axis_types=(1,))\n"
        ),
    })
    assert rules_fired(result) == {"compat-surface"}
    assert len(result.findings) == 2  # the import and the kwarg


def test_compat_surface_allows_compat_py(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/compat.py": (
            "from jax.experimental.shard_map import shard_map\n"
            "from jax.sharding import AxisType\n"
        ),
    })
    assert result.ok, [f.render() for f in result.findings]


# -------------------------------------------------------------- layering


def test_layering_models_must_not_import_kernels(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/models/m.py": "from repro.kernels import quant_matmul\n",
    })
    assert rules_fired(result) == {"layering"}


def test_layering_examples_must_not_touch_trainer_privates(tmp_path):
    result = lint_tree(tmp_path, {
        "examples/e.py": (
            "from repro.launch.train import _build_state\n"
            "import repro.launch.train as train\n"
            "train._run_epoch()\n"
        ),
    })
    assert rules_fired(result) == {"layering"}
    assert len(result.findings) == 2


def test_layering_core_may_import_kernels(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/core/opset.py": "from repro.kernels import quant_matmul\n",
        "examples/e.py": "from repro.runtime import EdgeSession\n",
    })
    assert result.ok, [f.render() for f in result.findings]


# ------------------------------------------------------------ jit-purity


def test_jit_purity_flags_host_effects_in_traced_bodies(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/core/s.py": (
            "import time\n"
            "import jax\n"
            "import numpy as np\n"
            "@jax.jit\n"
            "def step(x):\n"
            "    print('tracing')\n"
            "    t = time.perf_counter()\n"
            "    noise = np.random.normal()\n"
            "    return x + noise + t\n"
        ),
    })
    assert rules_fired(result) == {"jit-purity"}
    assert len(result.findings) == 3


def test_jit_purity_resolves_pallas_call_kernel_by_name(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/kernels/k.py": (
            "import jax\n"
            "from jax.experimental import pallas as pl\n"
            "def _kernel(x_ref, o_ref):\n"
            "    print('inside kernel')\n"
            "    o_ref[...] = x_ref[...]\n"
            "def launch(x):\n"
            "    return pl.pallas_call(\n"
            "        _kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype)\n"
            "    )(x)\n"
        ),
    })
    assert rules_fired(result) == {"jit-purity"}


def test_jit_purity_ignores_effects_outside_traced_code(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/core/s.py": (
            "import time\n"
            "import jax\n"
            "@jax.jit\n"
            "def step(x):\n"
            "    return x * 2\n"
            "def bench(x):\n"
            "    t0 = time.perf_counter()\n"
            "    y = step(x)\n"
            "    print(time.perf_counter() - t0)\n"
            "    return y\n"
        ),
    })
    assert result.ok, [f.render() for f in result.findings]


# ------------------------------------------------------- pallas-blockspec

_PALLAS_HEADER = (
    "import jax\n"
    "import jax.numpy as jnp\n"
    "from jax.experimental import pallas as pl\n"
    "def _k(x_ref, o_ref):\n"
    "    o_ref[...] = x_ref[...]\n"
)


def test_blockspec_index_map_arity_must_match_grid_rank(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/kernels/k.py": _PALLAS_HEADER + (
            "def launch(x):\n"
            "    return pl.pallas_call(\n"
            "        _k,\n"
            "        grid=(2, 2),\n"
            "        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],\n"
            "        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),\n"
            "        out_shape=jax.ShapeDtypeStruct((16, 16), jnp.float32),\n"
            "    )(x)\n"
        ),
    })
    assert rules_fired(result) == {"pallas-blockspec"}
    (finding,) = result.findings
    assert "index_map takes 1" in finding.message


def test_blockspec_block_must_divide_output_dim(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/kernels/k.py": _PALLAS_HEADER + (
            "def launch(x):\n"
            "    return pl.pallas_call(\n"
            "        _k,\n"
            "        grid=(13,),\n"
            "        out_specs=pl.BlockSpec((8, 100), lambda i: (i, 0)),\n"
            "        out_shape=jax.ShapeDtypeStruct((100, 100), jnp.float32),\n"
            "    )(x)\n"
        ),
    })
    assert rules_fired(result) == {"pallas-blockspec"}
    (finding,) = result.findings
    assert "does not divide" in finding.message


def test_blockspec_vmem_budget_and_per_site_report(tmp_path):
    huge = _PALLAS_HEADER + (
        "def launch(x):\n"
        "    bm = 4096\n"
        "    return pl.pallas_call(\n"
        "        _k,\n"
        "        grid=(2,),\n"
        "        in_specs=[pl.BlockSpec((bm, bm), lambda i: (i, 0))],\n"
        "        out_specs=pl.BlockSpec((bm, bm), lambda i: (i, 0)),\n"
        "        out_shape=jax.ShapeDtypeStruct((8192, 4096), jnp.float32),\n"
        "    )(x)\n"
    )
    result = lint_tree(tmp_path, {"src/repro/kernels/k.py": huge})
    assert rules_fired(result) == {"pallas-blockspec"}
    (finding,) = result.findings
    assert "VMEM" in finding.message
    # every site gets an informational report, violation or not
    (report,) = result.reports
    # 2 blocks x (4096*4096*4 bytes) x2 double-buffering = 256 MiB
    assert report.data["vmem_bytes"] == 2 * 4096 * 4096 * 4 * 2
    assert report.data["exact"] is True

    # the same site passes with a raised budget
    ok = lint_tree(tmp_path, {"src/repro/kernels/k.py": huge},
                   vmem_budget_bytes=512 * 2**20)
    assert ok.ok


def test_blockspec_unwraps_prefetch_scalar_grid_spec(tmp_path):
    """grid_spec=PrefetchScalarGridSpec(...) sites get the same checks
    as flat kwargs, with index_map arity = grid rank + num_scalar_prefetch."""
    src = _PALLAS_HEADER + (
        "from jax.experimental.pallas import tpu as pltpu\n"
        "def launch(x):\n"
        "    spec = pl.BlockSpec((8, 8), lambda i, j, bt: (bt[i], j))\n"
        "    gs = pltpu.PrefetchScalarGridSpec(\n"
        "        num_scalar_prefetch=1,\n"
        "        grid=(2, 2),\n"
        "        in_specs=[spec],\n"
        "        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),\n"
        "        scratch_shapes=[pltpu.VMEM((8, 8), jnp.float32)],\n"
        "    )\n"
        "    return pl.pallas_call(\n"
        "        _k,\n"
        "        grid_spec=gs,\n"
        "        out_shape=jax.ShapeDtypeStruct((16, 16), jnp.float32),\n"
        "    )(x)\n"
    )
    result = lint_tree(tmp_path, {"src/repro/kernels/k.py": src})
    # the out_spec lambda takes 2 args but the site expects 2 + 1 prefetch
    assert rules_fired(result) == {"pallas-blockspec"}
    (finding,) = result.findings
    assert "scalar-prefetch" in finding.message and "takes 2" in finding.message
    (report,) = result.reports
    assert report.data["num_scalar_prefetch"] == 1
    assert report.data["grid_rank"] == 2
    assert report.data["n_scratch"] == 1
    # 2 blocks ×(8·8·4)×2 double-buffer + 8·8·4 scratch
    assert report.data["vmem_bytes"] == 2 * 8 * 8 * 4 * 2 + 8 * 8 * 4


def test_blockspec_assert_envelope_bounds_vmem_estimate(tmp_path):
    """`assert dim <= N` declares a ceiling for a runtime-unpacked dim —
    the VMEM estimate uses it (inexactly) instead of --assume-dim."""
    src = _PALLAS_HEADER + (
        "def launch(x):\n"
        "    bm, bn = x.shape\n"
        "    assert bm <= 8 and bn <= 16\n"
        "    return pl.pallas_call(\n"
        "        _k,\n"
        "        grid=(2,),\n"
        "        in_specs=[pl.BlockSpec((bm, bn), lambda i: (i, 0))],\n"
        "        out_specs=pl.BlockSpec((bm, bn), lambda i: (i, 0)),\n"
        "        out_shape=jax.ShapeDtypeStruct((16, 16), jnp.float32),\n"
        "    )(x)\n"
    )
    result = lint_tree(tmp_path, {"src/repro/kernels/k.py": src})
    assert result.ok, [f.render() for f in result.findings]
    (report,) = result.reports
    assert report.data["vmem_bytes"] == 2 * 8 * 16 * 4 * 2
    assert report.data["exact"] is False
    assert report.data["assumed_dims"] == []


def test_blockspec_clean_site_reports_but_does_not_fire(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/kernels/k.py": _PALLAS_HEADER + (
            "def launch(x):\n"
            "    return pl.pallas_call(\n"
            "        _k,\n"
            "        grid=(2, 2),\n"
            "        in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],\n"
            "        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),\n"
            "        out_shape=jax.ShapeDtypeStruct((16, 16), jnp.float32),\n"
            "    )(x)\n"
        ),
    })
    assert result.ok, [f.render() for f in result.findings]
    assert len(result.reports) == 1


# ------------------------------------------------------------- axis-name


def test_axis_name_flags_unbound_collective_axis(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/core/c.py": (
            "import jax\n"
            "def allreduce(x):\n"
            "    return jax.lax.psum(x, 'dp')\n"
        ),
    })
    assert rules_fired(result) == {"axis-name"}


def test_axis_name_accepts_mesh_bound_axis(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/core/c.py": (
            "import jax\n"
            "mesh = jax.make_mesh((2,), ('dp',))\n"
            "def allreduce(x):\n"
            "    return jax.lax.psum(x, 'dp')\n"
        ),
    })
    assert result.ok, [f.render() for f in result.findings]


# ---------------------------------------------------------- storage-form


def test_storage_form_flags_eager_dequant_outside_kernels(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/models/m.py": (
            "import jax.numpy as jnp\n"
            "def widen(w):\n"
            "    return w['q'].astype(jnp.float32) * w['scale']\n"
        ),
    })
    assert rules_fired(result) == {"storage-form"}


def test_storage_form_allows_kernels_and_cache(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/kernels/k.py": (
            "import jax.numpy as jnp\n"
            "def widen(w):\n"
            "    return w['q'].astype(jnp.float32) * w['scale']\n"
        ),
    })
    assert result.ok, [f.render() for f in result.findings]


def test_storage_form_sanctions_paging_but_not_the_engine(tmp_path):
    """The paged-KV pool owns quantise-on-write; the serving engine and
    decode step must never widen an INT8 page outside the kernels."""
    widen = (
        "import jax.numpy as jnp\n"
        "def widen(page):\n"
        "    return page['q'].astype(jnp.float32) * page['scale']\n"
    )
    ok = lint_tree(tmp_path, {"src/repro/serve/paging.py": widen})
    assert ok.ok, [f.render() for f in ok.findings]
    bad = lint_tree(tmp_path, {"src/repro/serve/engine.py": widen})
    assert rules_fired(bad) == {"storage-form"}


# ---------------------------------------------------------- bench-schema


GOOD_BENCH = {
    "arch": "gemma2-2b", "backend": "cpu", "pallas_interpret_mode": True,
    "batch": 8, "seq": 128, "steps": 4, "step_ms": 12.5,
}


def test_bench_schema_accepts_valid_record(tmp_path):
    result = lint_tree(tmp_path, {
        "BENCH_good.json": json.dumps(GOOD_BENCH),
    })
    assert result.ok, [f.render() for f in result.findings]


def test_bench_schema_flags_missing_and_mistyped_keys(tmp_path):
    bad = dict(GOOD_BENCH)
    del bad["pallas_interpret_mode"]   # required key missing
    bad["step_ms"] = "12.5"            # numeric field as string
    result = lint_tree(tmp_path, {"BENCH_bad.json": json.dumps(bad)})
    assert rules_fired(result) == {"bench-schema"}
    assert len(result.findings) == 2


def test_bench_schema_per_file_required_keys(tmp_path):
    """The serving bench additionally needs page geometry and the
    per-policy breakdown; rate fields must be numeric."""
    good = dict(GOOD_BENCH, page_size=8, policies={
        "int8": {"kv_bytes_per_token": 544, "ref_tokens_per_s": 100.0},
    })
    ok = lint_tree(tmp_path / "good", {
        "BENCH_decode_step.json": json.dumps(good),
        # the per-file keys do not leak onto other records
        "BENCH_other.json": json.dumps(GOOD_BENCH),
    })
    assert ok.ok, [f.render() for f in ok.findings]

    bad = dict(GOOD_BENCH, policies={
        "int8": {"kv_bytes_per_token": "544", "ref_tokens_per_s": "fast"},
    })  # page_size missing, both rate/footprint fields stringly typed
    result = lint_tree(tmp_path / "bad",
                       {"BENCH_decode_step.json": json.dumps(bad)})
    assert rules_fired(result) == {"bench-schema"}
    assert len(result.findings) == 3


# ----------------------------------------------------------- suppression


def test_per_line_suppression_silences_named_rule(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/models/m.py": (
            "from repro.kernels import quant_matmul"
            "  # palint: disable=layering  -- fixture exercising suppression\n"
        ),
    })
    assert result.ok, [f.render() for f in result.findings]


def test_suppression_is_rule_specific(tmp_path):
    result = lint_tree(tmp_path, {
        "src/repro/models/m.py": (
            "from repro.kernels import quant_matmul"
            "  # palint: disable=compat-surface\n"
        ),
    })
    assert rules_fired(result) == {"layering"}


# --------------------------------------------------- CLI + self-run gate


def run_cli(*argv, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "tools.palint", *argv],
        capture_output=True, text=True, cwd=cwd,
    )


def test_cli_json_shape_and_repo_is_clean():
    proc = run_cli("--json")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert set(payload) == {"version", "ok", "files_scanned", "findings",
                            "reports"}
    assert payload["ok"] is True and payload["findings"] == []
    assert payload["files_scanned"] > 50
    for report in payload["reports"]:
        assert set(report) == {"rule", "path", "line", "data"}


def test_self_run_reports_vmem_for_every_pallas_site():
    result = run(root=REPO)
    assert result.ok, [f.render() for f in result.findings]
    sites = [r for r in result.reports if r.rule == "pallas-blockspec"]
    assert len(sites) >= 7  # the repo's pallas_call sites, all budgeted
    assert {r.path for r in sites} >= {
        "src/repro/kernels/quant_matmul.py",
        "src/repro/kernels/adapter_fuse.py",
        "src/repro/kernels/flash_attention.py",
        "src/repro/kernels/cached_step.py",
    }
    for r in sites:
        assert isinstance(r.data["vmem_bytes"], int)
        assert r.data["vmem_bytes"] <= r.data["budget_bytes"]


def test_cli_nonzero_exit_on_findings(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "bad.py").write_text(
        "from jax.experimental.shard_map import shard_map\n"
    )
    proc = run_cli("--root", str(tmp_path), cwd=REPO)
    assert proc.returncode == 1
    assert "[compat-surface]" in proc.stdout
