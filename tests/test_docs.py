"""Fast tier-1 leg of the docs CI: link integrity + block extraction.

The CI ``lint`` job additionally *executes* the marked blocks
(``python tools/check_docs.py --exec``); here we keep the cheap
invariants in every local run: no broken relative links anywhere, and
the extraction machinery actually finds the marked blocks (an
accidentally reformatted marker would otherwise silently stop the CI
job from executing anything).
"""

import os
import sys

_TOOLS = os.path.join(os.path.dirname(__file__), "..", "tools")
sys.path.insert(0, _TOOLS)

import check_docs  # noqa: E402


def test_no_broken_relative_links():
    bad = {}
    for path in check_docs.doc_files():
        broken = check_docs.check_links(path)
        if broken:
            bad[os.path.relpath(path, check_docs.REPO)] = broken
    assert not bad, f"broken relative links: {bad}"


def test_docs_cover_readme_and_docs_dir():
    files = [os.path.relpath(p, check_docs.REPO) for p in check_docs.doc_files()]
    assert "README.md" in files
    assert os.path.join("docs", "ARCHITECTURE.md") in files
    assert os.path.join("docs", "CLI.md") in files


def test_marked_blocks_are_found():
    """At least one executable block exists, and every marked block is
    non-empty python (so the CI smoke actually runs something)."""
    total = 0
    for path in check_docs.doc_files():
        for lineno, code in check_docs.extract_marked_blocks(path):
            assert code.strip(), f"{path}:{lineno} empty marked block"
            compile(code, f"{path}:{lineno}", "exec")  # syntax-checks only
            total += 1
    assert total >= 2  # README + ARCHITECTURE each carry one


def test_marker_requires_adjacency():
    """The mark only applies to the fence it directly precedes —
    intervening prose cancels it (documented contract)."""
    import tempfile

    md = "\n".join([
        check_docs.EXEC_MARK,
        "",
        "```python",
        "x = 1",
        "```",
        check_docs.EXEC_MARK,
        "some prose in between",
        "```python",
        "y = 2",
        "```",
        "```python",
        "z = 3  # unmarked",
        "```",
    ])
    with tempfile.NamedTemporaryFile("w", suffix=".md", delete=False) as f:
        f.write(md)
        path = f.name
    try:
        blocks = check_docs.extract_marked_blocks(path)
        assert len(blocks) == 1 and blocks[0][1] == "x = 1"
    finally:
        os.unlink(path)
