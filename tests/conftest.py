"""Shared test harness.

* Pins tests to the single real CPU device (only the dry-run entry point
  fakes 512 devices, in its own process).
* Enables JAX's persistent compilation cache — the suite otherwise
  burns minutes recompiling identical tiny programs on every run. The
  in-process enablement is the ``compat.enable_compilation_cache()``
  config call below; the env vars exist so subprocess tests
  (test_pipeline) inherit the same cache. Override the location with
  ``REPRO_JAX_CACHE_DIR``.
* Session-scoped tiny-config/params/batch fixtures shared across
  modules, so each module stops re-initialising the same reduced model.
"""

import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")

_HERE = os.path.dirname(__file__)
sys.path.insert(0, os.path.join(_HERE, "..", "src"))
sys.path.insert(0, _HERE)  # `import _propcheck` from test modules

import jax  # noqa: E402
import pytest  # noqa: E402

from repro import compat  # noqa: E402

# env (not jax.config) so the test subprocesses pick the cache up too
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", compat.default_cache_dir())
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "-1")

jax.config.update("jax_enable_x64", False)
compat.enable_compilation_cache()


# ---------------------------------------------------------------------------
# Shared tiny-model fixtures (session-scoped: JAX arrays are immutable and
# every consumer treats params/batches as read-only inputs)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="session")
def tiny_cfg():
    """The reduced dense transformer used by most correctness tests."""
    from repro.configs import get_arch

    return get_arch("internlm2-1.8b").reduced()


@pytest.fixture(scope="session")
def tiny_backbone(tiny_cfg):
    from repro.models import backbone as bb

    return bb.init_backbone(jax.random.PRNGKey(0), tiny_cfg)


@pytest.fixture(scope="session")
def tiny_adapter(tiny_cfg):
    from repro.core.parallel_adapters import init_adapter

    return init_adapter(jax.random.PRNGKey(1), tiny_cfg, r=4)


@pytest.fixture(scope="session")
def tiny_batch(tiny_cfg):
    B, S = 2, 12
    return {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(2), (B, S), 0, tiny_cfg.vocab
        ),
        "labels": jax.random.randint(
            jax.random.PRNGKey(3), (B, S), 0, tiny_cfg.vocab
        ),
    }
