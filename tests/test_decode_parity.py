"""Decode-path parity: the paged serving step (`repro.serve.decode`)
against its two oracles, over ragged batches and every KV page policy.

* ref vs pallas — the Pallas paged-attention kernel (interpret mode)
  must match the gather-then-dense reference op for identical pools.
* paged vs linear — the batched multi-adapter paged step at f32 KV must
  reproduce the legacy single-request `pac_decode_step` path it
  replaced (same greedy tokens, float-tolerance logits: the paged ref
  masks by position instead of slicing, so reductions reorder).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.parallel_adapters import (
    gather_adapters,
    init_adapter,
    init_adapter_cache,
    stack_adapters,
)
from repro.core.quantization import quantize_tree
from repro.core.steps import pac_decode_step
from repro.serve import paging
from repro.serve.decode import paged_pac_decode_step, paged_prefill

PROMPTS = [[5, 7, 11, 2, 9], [3, 1], [8, 8, 4, 6]]  # ragged on purpose
PAGE, MAX_LEN = 4, 16
R = 4
N_STEPS = 2
#: |ref - pallas| logits ceiling per policy. f32/int8 share the exact
#: dequant math (tiny float-reorder slack); bf16 rounds K/V storage.
TOL = {"f32": 2e-4, "bf16": 3e-2, "int8": 2e-4}


@pytest.fixture(scope="module")
def serving_model(tiny_cfg, tiny_backbone, tiny_adapter):
    """The serving configuration: INT8 backbone + two-adapter bank
    gathered over the ragged batch."""
    backbone = quantize_tree(tiny_backbone, bits=8, min_size=1024)
    bank = stack_adapters(
        [tiny_adapter, init_adapter(jax.random.PRNGKey(2), tiny_cfg, r=R)])
    abatch = gather_adapters(bank, jnp.arange(len(PROMPTS)) % 2)
    return backbone, abatch


def _prefill(cfg, backbone, abatch, policy):
    max_pages = MAX_LEN // PAGE
    table = paging.PageTable(
        paging.PageAllocator(len(PROMPTS) * max_pages + 1), PAGE, max_pages)
    pools = paging.init_pools(
        cfg, table.allocator.n_pages, PAGE, len(PROMPTS), policy)
    for i, p in enumerate(PROMPTS):
        table.open(i, len(p))
    bt, lengths = table.dense(range(len(PROMPTS)))
    S = max(len(p) for p in PROMPTS)
    toks = np.zeros((len(PROMPTS), S), np.int32)
    for i, p in enumerate(PROMPTS):
        toks[i, : len(p)] = p
    logits, pools, acache = paged_prefill(
        backbone, abatch, jnp.asarray(toks), jnp.asarray(lengths), pools,
        jnp.asarray(bt), cfg=cfg, max_len=MAX_LEN, r=R)
    return table, pools, acache, logits


@pytest.mark.parametrize("policy", ("f32", "bf16", "int8"))
def test_ref_vs_pallas_paged_decode(policy, tiny_cfg, serving_model):
    backbone, abatch = serving_model
    table, pools, acache, logits = _prefill(tiny_cfg, backbone, abatch, policy)
    step = {
        impl: functools.partial(
            paged_pac_decode_step, cfg=tiny_cfg, r=R, kernel_impl=impl,
            interpret=True)
        for impl in ("ref", "pallas")
    }
    tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
    state = {impl: (pools, acache) for impl in step}
    for _ in range(N_STEPS):
        for i in range(len(PROMPTS)):
            table.extend_to(i, table.length(i) + 1)
        bt, lengths = table.dense(range(len(PROMPTS)))
        bt, lengths = jnp.asarray(bt), jnp.asarray(lengths)
        out = {}
        for impl, fn in step.items():
            lg, p2, a2 = fn(backbone, abatch, tok, *state[impl][:1], bt,
                            lengths, state[impl][1])
            out[impl] = np.asarray(lg[:, 0])
            state[impl] = (p2, a2)
        for i in range(len(PROMPTS)):
            table.append_token(i)
        err = np.max(np.abs(out["ref"] - out["pallas"]))
        assert err < TOL[policy], f"{policy}: |ref-pallas| = {err:.3e}"
        ref_tok = np.argmax(out["ref"], axis=-1)
        assert (np.argmax(out["pallas"], axis=-1) == ref_tok).all()
        tok = jnp.asarray(ref_tok, jnp.int32)[:, None]


def test_paged_batch_matches_linear_singles_f32(tiny_cfg, serving_model):
    """One batched paged step == N legacy single-request linear-cache
    steps: same greedy tokens, logits within float-reorder slack."""
    from repro.models import backbone as bb

    backbone, abatch = serving_model
    table, pools, acache, logits = _prefill(tiny_cfg, backbone, abatch, "f32")

    adapters = [jax.tree.map(lambda t: t[i], abatch)
                for i in range(len(PROMPTS))]
    linear = []  # per request: logits after prompt, then N_STEPS greedy
    for i, prompt in enumerate(PROMPTS):
        cache = bb.init_cache(tiny_cfg, 1, MAX_LEN)
        ac = init_adapter_cache(tiny_cfg, 1, MAX_LEN, r=R)
        for pos, t in enumerate(prompt):
            lg, cache, ac = pac_decode_step(
                backbone, adapters[i], {"tokens": jnp.asarray([[t]], jnp.int32)},
                cache, ac, pos, cfg=tiny_cfg, r=R)
        seq = [np.asarray(lg[0, 0])]
        for s in range(N_STEPS):
            nxt = jnp.asarray([[np.argmax(seq[-1])]], jnp.int32)
            lg, cache, ac = pac_decode_step(
                backbone, adapters[i], {"tokens": nxt}, cache, ac,
                len(prompt) + s, cfg=tiny_cfg, r=R)
            seq.append(np.asarray(lg[0, 0]))
        linear.append(seq)

    pre = np.asarray(logits[:, 0])
    for i in range(len(PROMPTS)):
        assert np.max(np.abs(pre[i] - linear[i][0])) < 1e-4
        assert np.argmax(pre[i]) == np.argmax(linear[i][0])

    tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
    for s in range(N_STEPS):
        for i in range(len(PROMPTS)):
            table.extend_to(i, table.length(i) + 1)
        bt, lengths = table.dense(range(len(PROMPTS)))
        lg, pools, acache = paged_pac_decode_step(
            backbone, abatch, tok, pools, jnp.asarray(bt),
            jnp.asarray(lengths), acache, cfg=tiny_cfg, r=R)
        for i in range(len(PROMPTS)):
            table.append_token(i)
        got = np.asarray(lg[:, 0])
        for i in range(len(PROMPTS)):
            assert np.max(np.abs(got[i] - linear[i][1 + s])) < 1e-4
            assert np.argmax(got[i]) == np.argmax(linear[i][1 + s])
        tok = jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32)[:, None]
