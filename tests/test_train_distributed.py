"""Hybrid DP×PP trainer: equivalence with single-device training + CLI.

The acceptance contract of the distributed path (paper Fig. 10/11):

* epoch-1 ``pipeline_pac_train_step`` on a 2-D (dp, stage) mesh produces
  the SAME loss, adapter gradients, and cacheable activations as the
  single-device ``pac_train_step`` (fp32 tolerance) — and it runs the
  backbone forward through ``pipeline_apply`` (1F1B), not a fallback;
* epoch≥2 cached steps under dp sharding match the single-device cached
  step;
* the ``repro.launch.train`` CLI completes 3 epochs with --dp 2
  --stages 2 on an emulated 4-device CPU mesh (epoch 1 hybrid, rest
  cached pure-DP).

Multi-device tests run in subprocesses with
``--xla_force_host_platform_device_count`` (this process keeps the
single real device).
"""

import os
import subprocess
import sys
import textwrap


def _run_sub(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=600
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


_EQUIVALENCE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import functools
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_arch
    from repro.core import steps
    from repro.core.parallel_adapters import init_adapter
    from repro.launch import sharding as shard
    from repro.launch.mesh import make_edge_mesh
    from repro.models import backbone as bb
    from repro.optim import adamw_init

    cfg = get_arch("internlm2-1.8b").reduced()
    mesh = make_edge_mesh(2, 2)
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    ap = init_adapter(jax.random.PRNGKey(1), cfg, r=4)
    opt = adamw_init(ap)
    B, S = 8, 16
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab),
    }

    # ---- epoch-1: staged forward + dp grads vs the single-device step ----
    loss_ref, grads_ref = jax.value_and_grad(
        lambda a: steps.pac_loss_fn(a, bp, cfg, batch, r=4))(ap)
    loss_pp, grads_pp, (b0, taps, b_final) = steps.pipeline_pac_loss_and_grads(
        bp, ap, batch, cfg=cfg, mesh=mesh, n_micro=2, r=4)
    assert abs(float(loss_ref) - float(loss_pp)) < 1e-4, (float(loss_ref), float(loss_pp))
    gmax = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(grads_ref), jax.tree.leaves(grads_pp))
    )
    assert gmax < 1e-4, f"adapter grad mismatch {gmax}"

    # the cacheable activations the pipeline emits == recomputed taps
    bf_ref, taps_ref, b0_ref, _ = bb.backbone_forward(
        bp, cfg, batch, collect_taps=True, return_inputs=True)
    assert float(jnp.max(jnp.abs(taps - taps_ref))) < 1e-4, "taps mismatch"
    assert float(jnp.max(jnp.abs(b_final - bf_ref))) < 1e-4, "b_final mismatch"
    assert float(jnp.max(jnp.abs(b0 - b0_ref))) < 1e-6, "b0 mismatch"

    # full update step parity (clip + AdamW on the AllReduced grads)
    _, ap_ref, _, _ = steps.pac_train_step(bp, ap, opt, batch, cfg=cfg, r=4)
    _, ap_pp, _, _ = steps.pipeline_pac_train_step(
        bp, ap, opt, batch, cfg=cfg, mesh=mesh, n_micro=2, r=4)
    d = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(ap_ref), jax.tree.leaves(ap_pp))
    )
    # AdamW's m/(sqrt(v)+eps) amplifies cross-shard f32 reduction-order
    # noise near zero-gradient elements (same bound as test_pipeline's
    # SPMD step test); real distribution bugs are O(1) off
    assert d < 1e-3, f"updated adapter mismatch {d}"
    print("PIPELINE_STEP_OK")

    # ---- epoch>=2: cached step under pure-dp sharding vs single device ----
    # round-trip through numpy like the trainer's ActivationCache does, so
    # the arrays arrive uncommitted (jit's in_shardings then places them)
    cached = {
        "b0": jnp.asarray(np.asarray(b0)),
        "taps": jnp.asarray(np.asarray(taps)),
        "b_final": jnp.asarray(np.asarray(b_final)),
        "labels": batch["labels"],
    }
    stepN = functools.partial(steps.pac_cached_train_step, cfg=cfg, r=4)
    loss_1dev, apN_ref, _ = stepN(bp, ap, opt, cached)
    with mesh:
        jN = jax.jit(stepN, in_shardings=shard.cached_step_shardings(
            bp, ap, opt, cached, mesh))
        loss_dp, apN_dp, _ = jN(bp, ap, opt, cached)
    assert abs(float(loss_1dev) - float(loss_dp)) < 1e-4
    d = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(apN_ref), jax.tree.leaves(apN_dp))
    )
    assert d < 1e-3, f"cached-dp adapter mismatch {d}"
    print("CACHED_DP_OK")
    """
)


def test_hybrid_step_matches_single_device():
    """Epoch-1 pipeline grads/loss/taps and epoch≥2 dp cached step ≡ 1-device."""
    out = _run_sub(_EQUIVALENCE)
    assert "PIPELINE_STEP_OK" in out
    assert "CACHED_DP_OK" in out


_LAYOUT_ERRORS = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    from repro.configs import get_arch
    from repro.core import steps
    from repro.core.parallel_adapters import init_adapter
    from repro.launch.mesh import make_edge_mesh
    from repro.models import backbone as bb

    cfg = get_arch("internlm2-1.8b").reduced()   # 2 periods
    mesh = make_edge_mesh(2, 2)
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    ap = init_adapter(jax.random.PRNGKey(1), cfg, r=4)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (6, 8), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (6, 8), 0, cfg.vocab),
    }
    try:  # B=6 does not divide n_micro*dp = 4
        steps.pipeline_pac_loss_and_grads(bp, ap, batch, cfg=cfg, mesh=mesh, n_micro=2, r=4)
        raise SystemExit("expected ValueError for indivisible batch")
    except ValueError as e:
        assert "divisible" in str(e), e
    mesh3 = None
    try:  # 2 periods cannot split into 4 stages (and 4x1 has too few periods)
        from repro.launch.mesh import make_edge_mesh as mk
        mesh4 = mk(1, 4)
        steps.pipeline_pac_loss_and_grads(
            bp, ap, {k: v[:4] for k, v in batch.items()},
            cfg=cfg, mesh=mesh4, n_micro=2, r=4)
        raise SystemExit("expected ValueError for stages > periods")
    except ValueError as e:
        assert "divisible" in str(e), e
    print("LAYOUT_GUARDS_OK")
    """
)


def test_layout_misconfiguration_raises_clear_errors():
    assert "LAYOUT_GUARDS_OK" in _run_sub(_LAYOUT_ERRORS)


def test_train_cli_hybrid_three_epochs():
    """Acceptance: `repro.launch.train --reduced --dp 2 --stages 2` completes
    3 epochs on an emulated 4-device mesh — epoch 1 through the 1F1B
    pipeline (hybrid mode printed, no fallback path exists in the
    distributed branch), epochs 2-3 from the cache in pure DP."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)  # the CLI must force its own device count
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--reduced",
         "--dp", "2", "--stages", "2", "--epochs", "3",
         "--steps-per-epoch", "2", "--batch", "4", "--seq", "16"],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "mesh: hybrid dp=2×pp=2 on 4 devices" in out.stdout
    assert "epoch 0" in out.stdout and "(hybrid dp2xpp2)" in out.stdout
    assert "epoch 2" in out.stdout and out.stdout.count("(cached pure-dp)") == 2
