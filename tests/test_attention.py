"""Attention layer tests: flash vs naive, gradients, RoPE/M-RoPE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import apply_mrope, apply_rope, flash_attention, softcap
from repro.kernels.ref import flash_attention_ref


def _qkv(key, B=2, H=3, S=37, hd=16, Sk=None):
    ks = jax.random.split(key, 3)
    Sk = Sk or S
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, Sk, hd))
    v = jax.random.normal(ks[2], (B, H, Sk, hd))
    return q, k, v


@pytest.mark.parametrize("window", [None, 8])
@pytest.mark.parametrize("cap", [None, 20.0])
@pytest.mark.parametrize("block_k", [7, 16, 64])
def test_flash_matches_naive(window, cap, block_k):
    q, k, v = _qkv(jax.random.PRNGKey(0))
    S = q.shape[2]
    pos = jnp.arange(S)
    out = flash_attention(q, k, v, pos, pos, True, window, cap, block_k)
    ref = flash_attention_ref(
        q.reshape(-1, S, 16), k.reshape(-1, S, 16), v.reshape(-1, S, 16),
        causal=True, window=window, attn_softcap=cap,
    ).reshape(q.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("window,cap", [(None, None), (8, None), (None, 20.0)])
def test_flash_gradients_match_naive(window, cap):
    q, k, v = _qkv(jax.random.PRNGKey(1), B=1, H=2, S=24, hd=8)
    S = q.shape[2]
    pos = jnp.arange(S)

    def loss_flash(q, k, v):
        o = flash_attention(q, k, v, pos, pos, True, window, cap, 8)
        return jnp.sum(o * jnp.cos(o))

    def loss_ref(q, k, v):
        hd = q.shape[-1]
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * hd ** -0.5
        s = softcap(s, cap)
        qp, kp = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
        m = qp >= kp
        if window is not None:
            m &= (qp - kp) < window
        s = jnp.where(m[None, None], s, -1e30)
        o = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)
        return jnp.sum(o * jnp.cos(o))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4)


def test_flash_uneven_kv_padding():
    """Sk not divisible by block_k exercises the padded tail."""
    q, k, v = _qkv(jax.random.PRNGKey(2), S=11, Sk=29)
    posq = jnp.arange(11) + 18  # decode-ish offset: queries after keys
    posk = jnp.arange(29)
    out = flash_attention(q, k, v, posq, posk, True, None, None, 8)
    hd = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * hd ** -0.5
    m = (posq[:, None] - posk[None, :]) >= 0
    s = jnp.where(m[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_rope_relative_property():
    """RoPE: q·k depends only on relative offset."""
    hd = 32
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 1, hd))
    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.array([[pq]]))
        kr = apply_rope(k, jnp.array([[pk]]))
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(5, 3) - dot_at(5, 4)) > 1e-5  # actually varies


def test_mrope_matches_rope_for_text():
    """With t==h==w position ids, M-RoPE must reduce to plain RoPE."""
    B, S, H, hd = 2, 6, 2, 16
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S, H, hd))
    pos1 = jnp.broadcast_to(jnp.arange(S), (B, S))
    pos3 = jnp.broadcast_to(pos1, (3, B, S))
    a = apply_rope(x, pos1, theta=1e6)
    b = apply_mrope(x, pos3, theta=1e6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = softcap(x, 30.0)
    assert jnp.all(jnp.abs(y) <= 30.0)
    np.testing.assert_allclose(np.asarray(softcap(x, None)), np.asarray(x))


def test_grouped_head_gqa_equals_repeated_kv():
    """§Perf kimi iter G: folding the n_rep q-heads sharing a KV head into
    the query-row axis must equal explicit KV repetition."""
    B, Hkv, n_rep, S, hd = 2, 2, 4, 32, 8
    H = Hkv * n_rep
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))
    pos = jnp.arange(S)

    # reference: repeat KV to H heads (q head g*n_rep+r <- kv head g)
    kr = jnp.repeat(k, n_rep, axis=1)
    vr = jnp.repeat(v, n_rep, axis=1)
    ref = flash_attention(q, kr, vr, pos, pos, True, None, None, 16)

    # grouped: (B,H,S,hd) -> (B,Hkv,n_rep*S,hd), row r*S+s
    qg = q.reshape(B, Hkv, n_rep, S, hd).reshape(B, Hkv, n_rep * S, hd)
    og = flash_attention(qg, k, v, jnp.tile(pos, n_rep), pos, True, None, None, 16)
    og = og.reshape(B, Hkv, n_rep, S, hd).reshape(B, H, S, hd)
    np.testing.assert_allclose(np.asarray(og), np.asarray(ref), atol=2e-5)


def test_flash_block_index_slicing_matches_across_block_sizes():
    """iter 6 (dynamic-slice KV in the scan body): results must be
    invariant to block_k, including non-divisible sizes."""
    q, k, v = _qkv(jax.random.PRNGKey(8), B=1, H=2, S=29, hd=8)
    pos = jnp.arange(29)
    outs = [
        flash_attention(q, k, v, pos, pos, True, None, None, bk)
        for bk in (4, 8, 29, 64)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=2e-5)
