"""Quantization unit + property tests (paper §IV-D / Eq. 1-2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.quantization import (
    QTensor,
    dequantize,
    maybe_dequantize_tree,
    quantize,
    quantize_tree,
    tree_storage_bytes,
)


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 8),
    cols=st.integers(1, 300),
    bits=st.sampled_from([8, 4]),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_error_bound(rows, cols, bits, scale, seed):
    """|x - dequant(quant(x))| ≤ blockwise absmax / qmax / 2 per element."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, cols)) * scale, jnp.float32)
    qt = quantize(x, bits=bits, block=128)
    xd = dequantize(qt)
    assert xd.shape == x.shape
    qmax = 127 if bits == 8 else 7
    block = min(128, cols)
    nb = -(-cols // block)
    xpad = jnp.pad(x, ((0, 0), (0, nb * block - cols)))
    absmax = jnp.max(jnp.abs(xpad.reshape(rows, nb, block)), axis=-1)
    # half-step rounding bound with f32 slack (x·inv rounds in f32)
    bound = jnp.repeat(absmax / qmax, block, axis=-1)[:, :cols] * 0.5
    assert jnp.all(jnp.abs(xd - x) <= bound * 1.01 + 1e-5 * (1 + jnp.abs(x)))


def test_exact_on_zero_and_extremes():
    x = jnp.zeros((4, 64))
    assert jnp.all(dequantize(quantize(x)) == 0)
    x = jnp.full((2, 128), 3.5)
    xd = dequantize(quantize(x, bits=8))
    np.testing.assert_allclose(np.asarray(xd), 3.5, rtol=1e-6)


def test_int4_packing_halves_bytes():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256))
    q8 = quantize(x, bits=8)
    q4 = quantize(x, bits=4)
    assert q4.q.size == q8.q.size // 2
    assert q8.nbytes < x.size * 4 / 3.5  # ~4x smaller + scales


def test_quantize_tree_skips_small_and_1d():
    tree = {
        "big": jax.random.normal(jax.random.PRNGKey(0), (256, 256)),
        "norm": jnp.ones((256,)),
        "tiny": jnp.ones((4, 4)),
    }
    qt = quantize_tree(tree, bits=8)
    assert isinstance(qt["big"], QTensor)
    assert not isinstance(qt["norm"], QTensor)
    assert not isinstance(qt["tiny"], QTensor)
    back = maybe_dequantize_tree(qt)
    assert back["big"].shape == (256, 256)
    assert tree_storage_bytes(qt) < tree_storage_bytes(tree) / 2


def test_memory_footprint_ratio_matches_paper():
    """INT8 ≈ 4× smaller, INT4 ≈ 8× smaller than FP32 (paper Fig. 15)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (1024, 1024))
    f32 = x.size * 4
    r8 = f32 / quantize(x, bits=8).nbytes
    r4 = f32 / quantize(x, bits=4).nbytes
    assert 3.5 < r8 <= 4.0
    assert 6.5 < r4 <= 8.0


def test_dequant_inside_jit_and_grad_flow_blocked():
    """QTensor dequant works under jit; quantized weights carry no grads."""
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 128))
    qt = quantize(jax.random.normal(jax.random.PRNGKey(3), (128, 64)))

    @jax.jit
    def f(a, q):
        return jnp.sum(a @ dequantize(q))

    v = f(x, qt)
    assert jnp.isfinite(v)
