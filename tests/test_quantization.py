"""Quantization unit + property tests (paper §IV-D / Eq. 1-2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.quantization import (
    QTensor,
    dequantize,
    maybe_dequantize_tree,
    quantize,
    quantize_tree,
    tree_storage_bytes,
)


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 8),
    cols=st.integers(1, 300),
    bits=st.sampled_from([8, 4]),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_error_bound(rows, cols, bits, scale, seed):
    """|x - dequant(quant(x))| ≤ blockwise absmax / qmax / 2 per element."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, cols)) * scale, jnp.float32)
    qt = quantize(x, bits=bits, block=128)
    xd = dequantize(qt)
    assert xd.shape == x.shape
    qmax = 127 if bits == 8 else 7
    block = min(128, cols)
    nb = -(-cols // block)
    xpad = jnp.pad(x, ((0, 0), (0, nb * block - cols)))
    absmax = jnp.max(jnp.abs(xpad.reshape(rows, nb, block)), axis=-1)
    # half-step rounding bound with f32 slack (x·inv rounds in f32)
    bound = jnp.repeat(absmax / qmax, block, axis=-1)[:, :cols] * 0.5
    assert jnp.all(jnp.abs(xd - x) <= bound * 1.01 + 1e-5 * (1 + jnp.abs(x)))


def test_exact_on_zero_and_extremes():
    x = jnp.zeros((4, 64))
    assert jnp.all(dequantize(quantize(x)) == 0)
    x = jnp.full((2, 128), 3.5)
    xd = dequantize(quantize(x, bits=8))
    np.testing.assert_allclose(np.asarray(xd), 3.5, rtol=1e-6)


def test_int4_packing_halves_bytes():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256))
    q8 = quantize(x, bits=8)
    q4 = quantize(x, bits=4)
    assert q4.q.size == q8.q.size // 2
    assert q8.nbytes < x.size * 4 / 3.5  # ~4x smaller + scales


def test_quantize_tree_skips_small_and_1d():
    tree = {
        "big": jax.random.normal(jax.random.PRNGKey(0), (256, 256)),
        "norm": jnp.ones((256,)),
        "tiny": jnp.ones((4, 4)),
    }
    qt = quantize_tree(tree, bits=8)
    assert isinstance(qt["big"], QTensor)
    assert not isinstance(qt["norm"], QTensor)
    assert not isinstance(qt["tiny"], QTensor)
    back = maybe_dequantize_tree(qt)
    assert back["big"].shape == (256, 256)
    assert tree_storage_bytes(qt) < tree_storage_bytes(tree) / 2


def test_memory_footprint_ratio_matches_paper():
    """INT8 ≈ 4× smaller, INT4 ≈ 8× smaller than FP32 (paper Fig. 15)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (1024, 1024))
    f32 = x.size * 4
    r8 = f32 / quantize(x, bits=8).nbytes
    r4 = f32 / quantize(x, bits=4).nbytes
    assert 3.5 < r8 <= 4.0
    assert 6.5 < r4 <= 8.0


def test_dequant_inside_jit_and_grad_flow_blocked():
    """QTensor dequant works under jit; quantized weights carry no grads."""
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 128))
    qt = quantize(jax.random.normal(jax.random.PRNGKey(3), (128, 64)))

    @jax.jit
    def f(a, q):
        return jnp.sum(a @ dequantize(q))

    v = f(x, qt)
    assert jnp.isfinite(v)


# ---------------------------------------------------------------------------
# Round-trip property tests: INT4 nibble padding, zero blocks, nbytes
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 6),
    cols=st.sampled_from([1, 3, 5, 7, 99, 127, 129, 255]),
    seed=st.integers(0, 2**31 - 1),
)
def test_int4_odd_last_dim_roundtrip(rows, cols, seed):
    """Odd ``orig_last`` exercises the nibble-pad path: the packed byte
    array covers an even padded length, dequantize slices back exactly."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, cols)), jnp.float32)
    qt = quantize(x, bits=4, block=128)
    xd = dequantize(qt)
    assert xd.shape == x.shape
    # packed bytes cover an even number of nibbles >= cols
    assert qt.q.shape[-1] * 2 >= cols
    assert qt.q.shape[-1] * 2 % 2 == 0
    block = min(128, cols) + (min(128, cols) % 2)  # quantize's even bump
    nb = -(-cols // block)
    xpad = jnp.pad(x, ((0, 0), (0, nb * block - cols)))
    absmax = jnp.max(jnp.abs(xpad.reshape(rows, nb, block)), axis=-1)
    bound = jnp.repeat(absmax / 7, block, axis=-1)[:, :cols] * 0.5
    assert jnp.all(jnp.abs(xd - x) <= bound * 1.01 + 1e-5 * (1 + jnp.abs(x)))


@pytest.mark.parametrize("bits", [8, 4])
def test_all_zero_blocks_roundtrip_exact(bits):
    """An all-zero block hits the scale==0 branch: inv is forced to 0 (no
    divide-by-zero, no NaN) and the block dequantizes to exact zeros,
    also when only *some* blocks are zero."""
    x = np.zeros((2, 256), np.float32)
    x[:, 128:] = np.random.default_rng(0).standard_normal((2, 128))
    qt = quantize(jnp.asarray(x), bits=bits, block=128)
    scale = np.asarray(qt.scale)
    assert np.all(scale[:, 0] == 0)  # zero block -> zero scale
    xd = np.asarray(dequantize(qt))
    assert np.all(np.isfinite(xd))
    np.testing.assert_array_equal(xd[:, :128], 0)
    assert np.max(np.abs(xd[:, 128:] - x[:, 128:])) <= np.max(np.abs(x)) / (
        127 if bits == 8 else 7
    ) * 0.51


@settings(max_examples=25, deadline=None)
@given(
    cols=st.integers(1, 300),
    bits=st.sampled_from([8, 4]),
    block=st.sampled_from([16, 64, 128]),
)
def test_qtensor_nbytes_accounting(cols, bits, block):
    """nbytes == int payload bytes + 4 per f32 scale, exactly — what the
    activation cache budgets against."""
    x = jnp.ones((4, cols))
    qt = quantize(x, bits=bits, block=block)
    eff_block = min(block, cols)
    if bits == 4 and eff_block % 2:
        eff_block += 1
    nb = -(-cols // eff_block)
    padded = nb * eff_block
    expect_q = 4 * (padded // 2 if bits == 4 else padded)
    expect_scale = 4 * nb * 4
    assert qt.nbytes == expect_q + expect_scale
    assert np.asarray(qt.q).nbytes == expect_q


# ---------------------------------------------------------------------------
# quantize_tree: the router skip list (ISSUE 3 regression)
# ---------------------------------------------------------------------------


def test_quantize_tree_skips_router_by_name():
    """The docstring's promise — "routers are quantization-sensitive" —
    must be enforced: a `"router"` leaf stays f32 no matter its size,
    while sibling expert weights of the same size quantize."""
    from repro.models.moe import init_moe
    from repro.configs import get_arch

    spec = get_arch("mixtral-8x7b").reduced().moe
    p = init_moe(jax.random.PRNGKey(0), 128, spec)
    assert p["router"].size >= 256  # large enough that size alone won't skip it
    qt = quantize_tree(p, bits=8, min_size=256)
    assert not isinstance(qt["router"], QTensor)
    assert qt["router"].dtype == jnp.float32
    assert isinstance(qt["wi"], QTensor) and isinstance(qt["wo"], QTensor)
    # dequant path leaves the router untouched bit-for-bit
    back = maybe_dequantize_tree(qt)
    np.testing.assert_array_equal(np.asarray(back["router"]), np.asarray(p["router"]))


def test_quantize_tree_skip_applies_at_any_depth():
    tree = {
        "blocks": [
            {"router": jnp.ones((64, 64)), "w": jnp.ones((64, 64))},
            {"router": jnp.ones((64, 64)), "w": jnp.ones((64, 64))},
        ]
    }
    qt = quantize_tree(tree, min_size=1024)
    for blk in qt["blocks"]:
        assert not isinstance(blk["router"], QTensor)
        assert isinstance(blk["w"], QTensor)


def test_quantize_tree_on_full_moe_backbone():
    """End-to-end: every router in an MoE backbone survives quantize_tree
    as f32 (the trainer's --quant path on mixtral/grok-style archs)."""
    from repro import compat
    from repro.configs import get_arch
    from repro.models import backbone as bb

    cfg = get_arch("mixtral-8x7b").reduced()
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    bq = quantize_tree(bp, bits=8, min_size=1024)
    routers = []

    def check(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", ""))))
                 for k in path]
        if "router" in names:
            routers.append(leaf)
            assert not isinstance(leaf, QTensor), names
        return leaf

    compat.tree_map_with_path(check, bq, is_leaf=lambda x: isinstance(x, QTensor))
    assert routers, "MoE backbone should contain router leaves"


def test_quantize_tree_skip_matches_router_like_names():
    """The skip list is substring-based: router-like keys under any name
    ("moe_router", "router_w") stay f32, and a bare-string skip_names is
    one name, not a character set."""
    tree = {
        "moe_router": jnp.ones((64, 64)),
        "router_w": jnp.ones((64, 64)),
        "w": jnp.ones((64, 64)),
    }
    qt = quantize_tree(tree, min_size=1024)
    assert not isinstance(qt["moe_router"], QTensor)
    assert not isinstance(qt["router_w"], QTensor)
    assert isinstance(qt["w"], QTensor)
    qt2 = quantize_tree(tree, min_size=1024, skip_names="w")
    assert not isinstance(qt2["w"], QTensor)
    assert not isinstance(qt2["router_w"], QTensor)
    assert isinstance(qt2["moe_router"], QTensor)  # no "w" in the key
    qt3 = quantize_tree(tree, min_size=1024, skip_names=("zzz",))
    assert all(isinstance(v, QTensor) for v in qt3.values())
