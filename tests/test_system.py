"""End-to-end system behaviour: the full PAC+ workflow (paper Fig. 4).

Step 1-2: quantize backbone + build/initialise Parallel Adapters;
Step 3-4: profile + plan; Step 5: epoch-1 hybrid training; Step 6:
epoch≥2 cache-hit training. Asserts: loss ↓, cache hit path ≡ recompute,
checkpoint round-trip.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_arch
from repro.core import steps
from repro.core.activation_cache import ActivationCache
from repro.core.init_methods import pruning_init
from repro.core.planner import (
    HybridParallelismPlanner,
    JETSON_NANO_H,
    model_layer_costs,
)
from repro.core.quantization import quantize_tree
from repro.data import DataPipeline, SyntheticPersonalCorpus
from repro.models import backbone as bb
from repro.optim import adamw_init


def test_full_pac_workflow(tmp_path):
    cfg = get_arch("internlm2-1.8b").reduced()
    B, S, EPOCHS = 4, 24, 3

    # Step 1-2: pre-process — quantize backbone, pruning-init adapters
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    bq = quantize_tree(bp, bits=8, min_size=1024)
    ap = pruning_init(jax.random.PRNGKey(1), bp, cfg, r=4)
    opt = adamw_init(ap)

    # Step 3-4: profile + plan (analytic profile at this scale)
    costs = model_layer_costs(cfg, "pac", seq_len=S)
    plan = HybridParallelismPlanner(costs, [JETSON_NANO_H] * 4, B, 2).plan()
    assert plan.minibatch_latency > 0

    corpus = SyntheticPersonalCorpus(cfg.vocab, S + 1, 16, seed=0)
    pipe = DataPipeline(corpus, global_batch=B, shuffle=True)
    cache = ActivationCache(budget_bytes=1 << 30)

    step1 = jax.jit(functools.partial(steps.pac_train_step, cfg=cfg, r=4))
    stepN = jax.jit(functools.partial(steps.pac_cached_train_step, cfg=cfg, r=4))

    losses = []
    epoch_orders = []
    for epoch in range(EPOCHS):
        ep_losses = []
        order = []
        # real epoch index: order reshuffles every epoch, and the cache
        # still hits — keys are per-sequence, exactly the paper's
        # re-batching/redistribution of cached activations
        for batch in pipe.epoch(epoch):
            ids = batch.pop("seq_ids")
            order.extend(int(k) for k in ids)
            hit = cache.get_batch(ids, with_final=True)
            if hit is None:
                # Step 5: epoch-1 — backbone forward + adapter update;
                # b_final is folded into the budgeted cache entry
                loss, ap, opt, (b0, taps, bf) = step1(bq, ap, opt, batch)
                cache.put_batch(ids, b0, taps, bf)
            else:
                # Step 6: epoch≥2 — activation-cache hit, adapter-only
                b0, taps, bfh = hit
                cached = {
                    "b0": jnp.asarray(b0),
                    "taps": jnp.asarray(taps),
                    "b_final": jnp.asarray(bfh),
                    "labels": batch["labels"],
                }
                loss, ap, opt = stepN(bq, ap, opt, cached)
            ep_losses.append(float(loss))
        losses.append(float(np.mean(ep_losses)))
        epoch_orders.append(order)

    assert cache.hits > 0 and cache.misses > 0
    # shuffling varied the batch order across epochs (same id *set*)...
    assert epoch_orders[0] != epoch_orders[1]
    assert set(epoch_orders[0]) == set(epoch_orders[1])
    # ...while every epoch≥2 sequence still hit the cache: 1 miss epoch
    # plus (EPOCHS-1) fully-hit epochs over the 16-sequence corpus
    assert cache.misses == 16 and cache.hits == (EPOCHS - 1) * 16
    assert losses[-1] < losses[0], f"no learning: {losses}"

    # checkpoint round-trip (quantized backbone + adapters)
    path = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(path, {"backbone": bq, "adapter": ap})
    loaded = load_checkpoint(path)
    for a, b in zip(jax.tree.leaves(loaded["adapter"]), jax.tree.leaves(ap)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_finetuned_model():
    """pac_decode_step: serving the personalised model token-by-token."""
    cfg = get_arch("internlm2-1.8b").reduced()
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    from repro.core.parallel_adapters import init_adapter, init_adapter_cache

    ap = init_adapter(jax.random.PRNGKey(1), cfg, r=4)
    B, S = 2, 8
    cache = bb.init_cache(cfg, B, S)
    acache = init_adapter_cache(cfg, B, S, r=4)
    tok = jnp.zeros((B, 1), jnp.int32)
    for t in range(4):
        logits, cache, acache = steps.pac_decode_step(
            bp, ap, {"tokens": tok}, cache, acache, jnp.int32(t), cfg=cfg, r=4
        )
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits[:, :, :], axis=-1).astype(jnp.int32)


def test_activation_cache_disk_spill_roundtrip(tmp_path):
    """§V-B storage-cost path: over-budget entries spill to disk and read
    back bit-exact; RAM usage stays within budget."""
    import numpy as np

    from repro.core.activation_cache import ActivationCache

    S, d, n_p = 16, 8, 3
    one = S * d * 4 + n_p * S * d * 4  # bytes per entry
    cache = ActivationCache(budget_bytes=2 * one + 1, spill_dir=str(tmp_path))
    entries = {}
    for k in range(6):
        b0 = np.random.RandomState(k).randn(S, d).astype(np.float32)
        taps = np.random.RandomState(100 + k).randn(n_p, S, d).astype(np.float32)
        cache.put(k, b0, taps)
        entries[k] = (b0, taps)
    assert len(cache) == 6
    assert cache.nbytes <= 2 * one + 1  # RAM stayed within budget
    assert len(list(tmp_path.iterdir())) >= 4  # the rest spilled
    for k, (b0, taps) in entries.items():
        got_b0, got_taps = cache.get(k)
        np.testing.assert_array_equal(got_b0, b0)
        np.testing.assert_array_equal(got_taps, taps)
