"""Regression tests for ActivationCache byte accounting and fd hygiene,
plus the v2 surface: compressed entries, folded b_final, async prefetch,
and cross-run persistence."""

import os
import time

import numpy as np
import pytest

from repro.core.activation_cache import (
    ActivationCache,
    CachePrefetcher,
    MANIFEST_NAME,
    cache_bytes_per_sequence,
    manifest_for,
    open_persistent,
    policy_bytes_per_value,
)


def _entry(seed, S=8, d=4, n_p=2):
    b0 = np.random.RandomState(seed).randn(S, d).astype(np.float32)
    taps = np.random.RandomState(100 + seed).randn(n_p, S, d).astype(np.float32)
    return b0, taps


def _entry_bytes(S=8, d=4, n_p=2):
    return S * d * 4 + n_p * S * d * 4


def test_reput_same_key_does_not_inflate_ram_bytes():
    """Re-putting an existing key replaces it — bytes must not accumulate."""
    cache = ActivationCache(budget_bytes=1 << 20)
    b0, taps = _entry(0)
    for _ in range(5):
        cache.put(1, b0, taps)
    assert len(cache) == 1
    assert cache.nbytes == _entry_bytes()


def test_reput_does_not_trigger_spurious_eviction():
    """Epoch-style overwrite of every key must not evict anything: the
    replaced entry's bytes are retired before the budget check."""
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=3 * one)
    entries = {k: _entry(k) for k in range(3)}
    for rounds in range(3):  # 3 epochs of identical puts, exactly at budget
        for k, (b0, taps) in entries.items():
            cache.put(k, b0, taps)
        assert len(cache) == 3
        assert cache.nbytes == 3 * one
    for k, (b0, taps) in entries.items():
        got = cache.get(k)
        np.testing.assert_array_equal(got[0], b0)
        np.testing.assert_array_equal(got[1], taps)


def test_reput_updates_accounting_for_new_size():
    cache = ActivationCache(budget_bytes=1 << 20)
    cache.put(7, *_entry(0, S=8))
    cache.put(7, *_entry(1, S=16))  # replace with a bigger entry
    assert cache.nbytes == _entry_bytes(S=16)
    cache.put(7, *_entry(2, S=4))  # and a smaller one
    assert cache.nbytes == _entry_bytes(S=4)


def test_reput_of_spilled_key_drops_stale_disk_entry(tmp_path):
    """A key that spilled to disk and is later re-put into RAM must not be
    double-counted by len() nor leave an orphan spill file."""
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=one + 1, spill_dir=str(tmp_path))
    cache.put(0, *_entry(0))
    cache.put(1, *_entry(1))  # over budget -> spills to disk
    assert len(cache) == 2
    assert len(list(tmp_path.iterdir())) == 1
    # shrink both entries so they fit in RAM: the spilled key must move
    # back, deleting its stale spill file
    cache.put(0, *_entry(2, S=1))
    cache.put(1, *_entry(3, S=1))
    assert len(cache) == 2
    assert cache.nbytes == 2 * _entry_bytes(S=1)
    assert list(tmp_path.iterdir()) == []


def test_disk_get_closes_npz_handle(tmp_path, monkeypatch):
    """The disk path of get() must close the npz archive it opens.

    Tracked per-instance via a wrapped np.load (patching NpzFile.close
    on the class segfaults numpy's __del__ during monkeypatch undo).
    """
    closed = []
    opened = []
    real_load = np.load

    def tracking_load(*args, **kwargs):
        z = real_load(*args, **kwargs)
        real_close = z.close
        def close_once():
            if z not in closed:
                closed.append(z)
            real_close()
        z.close = close_once  # instance attr shadows the method
        opened.append(z)
        return z

    monkeypatch.setattr(np, "load", tracking_load)
    cache = ActivationCache(budget_bytes=1, spill_dir=str(tmp_path))
    b0, taps = _entry(3)
    cache.put(5, b0, taps)  # budget 1 byte -> straight to disk
    got_b0, got_taps = cache.get(5)
    np.testing.assert_array_equal(got_b0, b0)
    np.testing.assert_array_equal(got_taps, taps)
    assert opened, "disk get should have gone through np.load"
    assert closed == opened, "get() must close the npz archive it opened"
    for z in opened:  # break the z -> close_once -> z ref cycle
        del z.close


def test_eviction_spills_oldest_keeps_recent_in_ram(tmp_path):
    """Under budget pressure the *oldest* RAM entries move to disk and the
    new entry stays RAM-resident — later traffic must not be frozen out
    of RAM by the earliest sequences (the pre-fix policy spilled every
    new entry once RAM filled)."""
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=2 * one, spill_dir=str(tmp_path))
    for k in range(6):
        cache.put(k, *_entry(k))
    # the two most recent keys are in RAM, the four oldest on disk
    assert set(cache._ram) == {4, 5}
    assert set(cache._disk) == {0, 1, 2, 3}
    assert cache.nbytes <= 2 * one
    for k in range(6):  # nothing was dropped
        got = cache.get(k)
        ref = _entry(k)
        np.testing.assert_array_equal(got[0], ref[0])
        np.testing.assert_array_equal(got[1], ref[1])


def test_disk_hit_promoted_back_to_ram(tmp_path, monkeypatch):
    """A disk hit is promoted into RAM so a re-read serves from memory; the
    npz stays behind as a *clean* copy, so evicting the promoted entry
    later is free and a cyclic sweep of an over-budget corpus never pays
    a write per read."""
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=2 * one, spill_dir=str(tmp_path))
    for k in range(4):
        cache.put(k, *_entry(k))
    assert 0 in cache._disk
    got = cache.get(0)
    np.testing.assert_array_equal(got[0], _entry(0)[0])
    assert 0 in cache._ram  # promoted (clean disk copy kept)
    assert cache.nbytes <= 2 * one
    assert len(cache) == 4  # overlap not double-counted
    # second read must not touch disk
    loads = []
    real_load = np.load
    monkeypatch.setattr(np, "load", lambda *a, **k: loads.append(a) or real_load(*a, **k))
    got2 = cache.get(0)
    np.testing.assert_array_equal(got2[0], _entry(0)[0])
    assert loads == []
    # epoch sweeps over the over-budget corpus: the first sweep may spill
    # still-dirty entries once; after that every entry has a clean disk
    # copy, so promotions/evictions never write again (mtimes stay fixed)
    for k in range(4):
        cache.get(k)  # warm-up sweep
    mtimes = {p: os.path.getmtime(p) for p in map(str, tmp_path.iterdir())}
    for _ in range(2):
        for k in range(4):
            got = cache.get(k)
            np.testing.assert_array_equal(got[0], _entry(k)[0])
    after = {p: os.path.getmtime(p) for p in map(str, tmp_path.iterdir())}
    assert after == mtimes, "promotion must not rewrite clean spill files"


def test_oversized_entry_spills_without_flushing_ram(tmp_path):
    """An entry larger than the whole budget goes straight to disk — it
    must not evict the (hot) RAM working set to make room that can never
    suffice."""
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=2 * one, spill_dir=str(tmp_path))
    cache.put(0, *_entry(0))
    cache.put(1, *_entry(1))
    cache.put(99, *_entry(9, S=64))  # 8x the budget
    assert set(cache._ram) == {0, 1}, "hot set must survive an oversized put"
    assert 99 in cache._disk
    got = cache.get(99)
    np.testing.assert_array_equal(got[0], _entry(9, S=64)[0])


def test_ram_hit_refreshes_recency(tmp_path):
    """Reading a RAM entry protects it from the next eviction round."""
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=2 * one, spill_dir=str(tmp_path))
    cache.put(0, *_entry(0))
    cache.put(1, *_entry(1))
    cache.get(0)  # 0 is now more recent than 1
    cache.put(2, *_entry(2))  # evicts 1, not 0
    assert set(cache._ram) == {0, 2}
    assert set(cache._disk) == {1}


def test_eviction_without_spill_dir_still_drops_oldest():
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=2 * one)
    for k in range(3):
        cache.put(k, *_entry(k))
    assert set(cache._ram) == {1, 2}
    assert cache.get(0) is None  # dropped, not spilled: re-forward later


def test_oversized_entry_without_spill_dir_keeps_hot_set():
    """No spill_dir: an over-budget entry is dropped (one re-forward),
    not inserted by flushing every hot entry (N re-forwards)."""
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=2 * one)
    cache.put(0, *_entry(0))
    cache.put(1, *_entry(1))
    cache.put(99, *_entry(9, S=64))  # 8x the budget
    assert set(cache._ram) == {0, 1}
    assert cache.get(99) is None
    assert cache.nbytes == 2 * one


def test_disk_hit_survives_spill_file_rewrite(tmp_path):
    """Repeated spills of the same key overwrite in place (no dup files)."""
    cache = ActivationCache(budget_bytes=1, spill_dir=str(tmp_path))
    cache.put(9, *_entry(0))
    b0, taps = _entry(4)
    cache.put(9, b0, taps)
    assert len(list(tmp_path.iterdir())) == 1
    got = cache.get(9)
    np.testing.assert_array_equal(got[0], b0)
    np.testing.assert_array_equal(got[1], taps)


# ---------------------------------------------------------------------------
# v2: compressed entries + folded b_final
# ---------------------------------------------------------------------------


def _entry_f(seed, S=8, d=256, n_p=2):
    rng = np.random.RandomState(seed)
    return (
        rng.randn(S, d).astype(np.float32),
        rng.randn(n_p, S, d).astype(np.float32),
        rng.randn(S, d).astype(np.float32),
    )


@pytest.mark.parametrize("policy", ["f32", "bf16", "int8"])
def test_policy_roundtrip_tolerance(policy):
    """f32 exact; bf16 within 2^-8 relative; int8 within the blockwise
    absmax/127 half-step bound (same scheme as the weight quantizer)."""
    cache = ActivationCache(budget_bytes=1 << 24, compress=policy)
    b0, taps, bf = _entry_f(0)
    cache.put(1, b0, taps, bf)
    got = cache.get(1, with_final=True)
    for ref, out in zip((b0, taps, bf), got):
        assert out.shape == ref.shape and out.dtype == np.float32
        if policy == "f32":
            np.testing.assert_array_equal(out, ref)
        elif policy == "bf16":
            assert np.max(np.abs(out - ref)) <= 2.0**-8 * np.max(np.abs(ref)) + 1e-6
        else:
            bound = np.max(np.abs(ref)) / 127 * 0.51 + 1e-6
            assert np.max(np.abs(out - ref)) <= bound


def test_invalid_policy_rejected():
    with pytest.raises(ValueError):
        ActivationCache(compress="fp8")


def test_compressed_nbytes_budget_accounting():
    """The budget covers *compressed* bytes: int8 ≥3x smaller than f32,
    bf16 exactly half (scale overhead included for int8)."""
    sizes = {}
    for policy in ("f32", "bf16", "int8"):
        cache = ActivationCache(budget_bytes=1 << 24, compress=policy)
        cache.put(1, *_entry_f(0))
        sizes[policy] = cache.nbytes
    assert sizes["bf16"] * 2 == sizes["f32"]
    assert sizes["int8"] * 3 < sizes["f32"]
    # and the analytic per-value model matches the measured bytes
    n_values = sum(a.size for a in _entry_f(0))
    for policy, nb in sizes.items():
        assert nb == pytest.approx(n_values * policy_bytes_per_value(policy), rel=0.01)


def test_b_final_folded_into_entry_accounting():
    """b_final rides in the same budgeted entry as b0/taps (ISSUE 3: the
    trainer's former side dict was unbudgeted and never spilled)."""
    cache = ActivationCache(budget_bytes=1 << 24)
    b0, taps, bf = _entry_f(0)
    cache.put(1, b0, taps)
    without = cache.nbytes
    cache.put(1, b0, taps, bf)
    assert cache.nbytes == without + bf.nbytes


def test_with_final_miss_when_entry_lacks_it():
    cache = ActivationCache(budget_bytes=1 << 24)
    b0, taps, bf = _entry_f(0)
    cache.put(1, b0, taps)  # legacy two-part entry
    assert cache.get(1) is not None
    assert cache.get(1, with_final=True) is None  # incomplete -> miss
    assert cache.misses == 1
    cache.put(1, b0, taps, bf)  # re-put replaces with the full entry
    got = cache.get(1, with_final=True)
    np.testing.assert_array_equal(got[2], bf)


@pytest.mark.parametrize("policy", ["f32", "bf16", "int8"])
def test_policy_spill_roundtrip_bit_exact(policy, tmp_path):
    """Disk round-trip preserves the *compressed* payload bit-exactly:
    RAM-served and npz-served reads decompress identically."""
    cache = ActivationCache(budget_bytes=1 << 24, compress=policy,
                            spill_dir=str(tmp_path))
    b0, taps, bf = _entry_f(3)
    cache.put(7, b0, taps, bf)
    from_ram = cache.get(7, with_final=True)
    cache.flush()
    cache._ram.clear()
    cache._ram_bytes = 0
    from_disk = cache.get(7, with_final=True)
    for a, b in zip(from_ram, from_disk):
        np.testing.assert_array_equal(a, b)


def test_get_batch_with_final_and_raw_dtype():
    cache = ActivationCache(budget_bytes=1 << 24, compress="bf16")
    b0 = np.random.RandomState(0).randn(4, 8, 32).astype(np.float32)
    taps = np.random.RandomState(1).randn(2, 4, 8, 32).astype(np.float32)
    bf = np.random.RandomState(2).randn(4, 8, 32).astype(np.float32)
    cache.put_batch([0, 1, 2, 3], b0, taps, bf)
    got = cache.get_batch([2, 0], with_final=True)
    assert got[0].shape == (2, 8, 32) and got[1].shape == (2, 2, 8, 32)
    assert all(g.dtype == np.float32 for g in got)
    # dtype=None ships bf16 payloads raw (half the host->device bytes);
    # the cached train step upcasts on device
    raw = cache.get_batch([2, 0], with_final=True, dtype=None)
    import ml_dtypes

    assert all(g.dtype == ml_dtypes.bfloat16 for g in raw)
    np.testing.assert_array_equal(
        np.asarray(raw[0], np.float32), got[0]
    )


# ---------------------------------------------------------------------------
# v2: async prefetch
# ---------------------------------------------------------------------------


def _filled_cache(n=8, spill_dir=None, budget=1 << 24):
    cache = ActivationCache(budget_bytes=budget, spill_dir=spill_dir)
    for k in range(n):
        cache.put(k, *_entry_f(k, d=32))
    return cache


def test_prefetcher_matches_sync_reads(tmp_path):
    """The prefetcher yields exactly what synchronous get_batch returns,
    in batch order — including entries that must come off disk."""
    one = sum(a.nbytes for a in _entry_f(0, d=32))
    cache = _filled_cache(8, spill_dir=str(tmp_path), budget=3 * one)
    order = [np.array([0, 5]), np.array([2, 7]), np.array([4, 1]), np.array([6, 3])]
    want = [cache.get_batch(keys, with_final=True) for keys in order]
    got = list(CachePrefetcher(cache, order, to_device=False))
    assert len(got) == len(want)
    for w, g in zip(want, got):
        for a, b in zip(w, g):
            np.testing.assert_array_equal(a, b)


def test_prefetcher_device_put_yields_jax_arrays():
    import jax

    cache = _filled_cache(4)
    order = [np.array([0, 1]), np.array([2, 3])]
    got = list(CachePrefetcher(cache, order, to_device=True))
    assert all(isinstance(part, jax.Array) for batch in got for part in batch)


def test_prefetcher_bounded_queue_blocks_ahead():
    """depth=1 must not race through the whole epoch before consumption —
    the worker blocks on the bounded queue (double-buffering, not
    load-everything)."""
    cache = _filled_cache(8)
    order = [np.array([k]) for k in range(8)]
    pf = CachePrefetcher(cache, order, to_device=False, depth=1)
    deadline = time.time() + 5
    while pf._q.qsize() < 1 and time.time() < deadline:
        time.sleep(0.01)
    time.sleep(0.05)  # give the worker a chance to (wrongly) run ahead
    # at most depth items buffered + one blocked in-flight inside put()
    assert pf._q.qsize() <= 2
    assert len(list(pf)) == 8  # and draining still yields everything


def test_prefetcher_yields_none_on_missing_key():
    cache = _filled_cache(2)
    order = [np.array([0]), np.array([99]), np.array([1])]
    got = list(CachePrefetcher(cache, order, to_device=False))
    assert got[1] is None
    assert got[0] is not None and got[2] is not None


def test_prefetcher_context_manager_joins_worker_on_early_exit():
    """Abandoning an epoch mid-stream (exception, early break) must not
    leak the worker: `with` closes the prefetcher — stop flag, queue
    drain (so a blocked put() unblocks), thread join."""
    cache = _filled_cache(8)
    order = [np.array([k]) for k in range(8)]
    with pytest.raises(RuntimeError):
        with CachePrefetcher(cache, order, to_device=False, depth=1) as pf:
            assert next(pf) is not None  # consume one of eight
            raise RuntimeError("train step blew up")
    assert not pf._thread.is_alive()
    assert pf._q.qsize() == 0


def test_prefetcher_close_is_idempotent_and_safe_after_drain():
    cache = _filled_cache(4)
    order = [np.array([k]) for k in range(4)]
    with CachePrefetcher(cache, order, to_device=False) as pf:
        assert len(list(pf)) == 4  # fully drained: sentinel consumed
    assert not pf._thread.is_alive()
    pf.close()  # second close is a no-op
    # plain (non-`with`) use still works and can be closed manually
    pf2 = CachePrefetcher(cache, order, to_device=False)
    assert len(list(pf2)) == 4
    pf2.close()


# ---------------------------------------------------------------------------
# v2: the shared manifest identity
# ---------------------------------------------------------------------------


def test_manifest_for_fingerprints_backbone_and_corpus():
    """manifest_for is THE cache identity: same inputs → same dict;
    any backbone/corpus/shape change → different dict (invalidation)."""
    import types

    cfg = types.SimpleNamespace(name="demo-arch")
    backbone = {"w": np.arange(12, dtype=np.float32).reshape(3, 4)}
    corpus = np.arange(64, dtype=np.int32)
    m = manifest_for(cfg, reduced=True, seq_len=16, quant_bits=None,
                     backbone=backbone, corpus_tokens=corpus)
    assert m == manifest_for(cfg, reduced=True, seq_len=16, quant_bits=None,
                             backbone=backbone, corpus_tokens=corpus)
    assert set(m) == {"arch", "reduced", "seq", "quant", "backbone", "corpus"}
    assert m["arch"] == "demo-arch" and m["quant"] == 0 and m["seq"] == 16
    m8 = manifest_for(cfg, reduced=True, seq_len=16, quant_bits=8,
                      backbone=backbone, corpus_tokens=corpus)
    assert m8["quant"] == 8
    other_bb = {"w": backbone["w"] + 1}
    assert manifest_for(cfg, reduced=True, seq_len=16, quant_bits=None,
                        backbone=other_bb, corpus_tokens=corpus) != m
    assert manifest_for(cfg, reduced=True, seq_len=16, quant_bits=None,
                        backbone=backbone, corpus_tokens=corpus + 1) != m


# ---------------------------------------------------------------------------
# v2: cross-run persistence
# ---------------------------------------------------------------------------


_META = {"backbone": "abc123", "corpus": "def456", "seq": 16}


def test_persistence_warm_reopen(tmp_path):
    cache, warm = open_persistent(str(tmp_path), _META, compress="int8")
    assert not warm
    b0, taps, bf = _entry_f(0)
    cache.put(3, b0, taps, bf)
    cache.put(5, b0, taps, bf)
    cache.save_manifest(_META)
    assert (tmp_path / MANIFEST_NAME).exists()

    cache2, warm2 = open_persistent(str(tmp_path), _META, compress="int8")
    assert warm2
    assert cache2.covers([3, 5], with_final=True)
    got = cache2.get(3, with_final=True)
    ref = cache.get(3, with_final=True)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a, b)


def test_persistence_meta_mismatch_invalidates(tmp_path, capsys):
    cache, _ = open_persistent(str(tmp_path), _META)
    cache.put(1, *_entry_f(0))
    cache.save_manifest(_META)
    changed = dict(_META, backbone="zzz")
    cache2, warm = open_persistent(str(tmp_path), changed)
    assert not warm
    assert "INVALIDATED" in capsys.readouterr().err
    # stale entries and manifest are gone; a fresh save works
    assert not (tmp_path / MANIFEST_NAME).exists()
    assert not list(tmp_path.glob("act_*.npz"))


def test_persistence_policy_change_invalidates(tmp_path):
    cache, _ = open_persistent(str(tmp_path), _META, compress="f32")
    cache.put(1, *_entry_f(0))
    cache.save_manifest(_META)
    _, warm = open_persistent(str(tmp_path), _META, compress="bf16")
    assert not warm


def test_persistence_missing_entry_file_invalidates(tmp_path):
    cache, _ = open_persistent(str(tmp_path), _META)
    cache.put(1, *_entry_f(0))
    cache.put(2, *_entry_f(1))
    cache.save_manifest(_META)
    os.remove(str(tmp_path / "act_2.npz"))
    _, warm = open_persistent(str(tmp_path), _META)
    assert not warm


def test_persistence_records_final_absence(tmp_path):
    """Entries saved without b_final reopen as covers(with_final)=False,
    so a warm trainer knows it must re-forward them."""
    cache, _ = open_persistent(str(tmp_path), _META)
    b0, taps, bf = _entry_f(0)
    cache.put(1, b0, taps)  # no b_final
    cache.put(2, b0, taps, bf)
    cache.save_manifest(_META)
    cache2, warm = open_persistent(str(tmp_path), _META)
    assert warm
    assert cache2.covers([1, 2])
    assert cache2.covers([2], with_final=True)
    assert not cache2.covers([1, 2], with_final=True)


def test_entries_do_not_alias_the_batch_array():
    """A per-sequence entry must own its bytes: an f32 view of one row
    would pin the entire (n_p,B,S,d) batch array in RAM, making the byte
    budget meaningless (code-review regression)."""
    cache = ActivationCache(budget_bytes=1 << 24, compress="f32")
    B = 4
    b0 = np.random.RandomState(0).randn(B, 8, 32).astype(np.float32)
    taps = np.random.RandomState(1).randn(2, B, 8, 32).astype(np.float32)
    bf = np.random.RandomState(2).randn(B, 8, 32).astype(np.float32)
    cache.put_batch(list(range(B)), b0, taps, bf)
    for entry in cache._ram.values():
        for _, ct in entry.parts():
            assert ct.data.base is None, "entry payload is a view"
            assert not np.shares_memory(ct.data, taps)
            assert not np.shares_memory(ct.data, b0)
    # the single-sequence path owns its buffer too
    cache.put(99, b0[0], taps[:, 0], bf[0])
    for _, ct in cache._ram[99].parts():
        assert not np.shares_memory(ct.data, taps)
        assert not np.shares_memory(ct.data, b0)


@pytest.mark.parametrize("policy", ["f32", "bf16", "int8"])
def test_put_batch_matches_per_sequence_puts(policy):
    """Batch-level compression + slicing must be bit-identical to
    compressing each sequence separately (blocks run along the last
    axis, so they never straddle the sliced dims)."""
    B = 3
    b0 = np.random.RandomState(0).randn(B, 8, 200).astype(np.float32)
    taps = np.random.RandomState(1).randn(2, B, 8, 200).astype(np.float32)
    bf = np.random.RandomState(2).randn(B, 8, 200).astype(np.float32)
    batched = ActivationCache(budget_bytes=1 << 26, compress=policy)
    batched.put_batch(list(range(B)), b0, taps, bf)
    single = ActivationCache(budget_bytes=1 << 26, compress=policy)
    for i in range(B):
        single.put(i, b0[i], taps[:, i], bf[i])
    assert batched.nbytes == single.nbytes
    for i in range(B):
        for a, b in zip(
            batched.get(i, with_final=True), single.get(i, with_final=True)
        ):
            np.testing.assert_array_equal(a, b)


def test_cache_bytes_per_sequence_with_final():
    from repro.configs import get_arch

    cfg = get_arch("t5-base-pac")
    base = cache_bytes_per_sequence(cfg, 30)
    assert base == (cfg.n_periods + 1) * 30 * cfg.d_model * 4  # paper formula
    v2 = cache_bytes_per_sequence(
        cfg, 30, policy_bytes_per_value("int8"), with_final=True
    )
    assert v2 == int((cfg.n_periods + 2) * 30 * cfg.d_model * policy_bytes_per_value("int8"))


# ---------------------------------------------------------------------------
# prefetcher hardening for elastic resharding (repro.fleet)
# ---------------------------------------------------------------------------


def test_prefetcher_next_after_close_raises():
    """A stale iterator after close() must fail loudly — before the
    `_closed` flag a next() here blocked forever on the drained queue
    (the fleet reshard path closes mid-epoch)."""
    cache = _filled_cache(4)
    order = [np.array([k]) for k in range(4)]
    pf = CachePrefetcher(cache, order, to_device=False)
    assert next(pf) is not None
    pf.close()
    with pytest.raises(RuntimeError, match="after close"):
        next(pf)


def test_prefetcher_reshard_close_reopen_mid_epoch():
    """The elastic-reshard lifecycle: consume part of an epoch, close,
    re-open a fresh prefetcher over the remaining order. No deadlock, no
    leaked worker thread, and the stitched stream equals direct reads."""
    import threading

    def workers():
        return [t for t in threading.enumerate()
                if t.name == "activation-cache-prefetch" and t.is_alive()]

    cache = _filled_cache(8)
    order = [np.array([k, k + 1]) for k in range(0, 8, 2)]
    base = len(workers())

    pf = CachePrefetcher(cache, order, to_device=False, depth=1)
    got = [next(pf), next(pf)]
    pf.close()                                   # reshard point, mid-epoch
    assert len(workers()) == base                # worker joined, not leaked

    pf2 = CachePrefetcher(cache, order[2:], to_device=False, depth=1)
    got.extend(pf2)
    assert len(workers()) == base

    want = [cache.get_batch(keys, with_final=True) for keys in order]
    assert len(got) == len(want)
    for w, g in zip(want, got):
        for a, b in zip(w, g):
            np.testing.assert_array_equal(a, b)
