"""Regression tests for ActivationCache byte accounting and fd hygiene."""

import os

import numpy as np
import pytest

from repro.core.activation_cache import ActivationCache


def _entry(seed, S=8, d=4, n_p=2):
    b0 = np.random.RandomState(seed).randn(S, d).astype(np.float32)
    taps = np.random.RandomState(100 + seed).randn(n_p, S, d).astype(np.float32)
    return b0, taps


def _entry_bytes(S=8, d=4, n_p=2):
    return S * d * 4 + n_p * S * d * 4


def test_reput_same_key_does_not_inflate_ram_bytes():
    """Re-putting an existing key replaces it — bytes must not accumulate."""
    cache = ActivationCache(budget_bytes=1 << 20)
    b0, taps = _entry(0)
    for _ in range(5):
        cache.put(1, b0, taps)
    assert len(cache) == 1
    assert cache.nbytes == _entry_bytes()


def test_reput_does_not_trigger_spurious_eviction():
    """Epoch-style overwrite of every key must not evict anything: the
    replaced entry's bytes are retired before the budget check."""
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=3 * one)
    entries = {k: _entry(k) for k in range(3)}
    for rounds in range(3):  # 3 epochs of identical puts, exactly at budget
        for k, (b0, taps) in entries.items():
            cache.put(k, b0, taps)
        assert len(cache) == 3
        assert cache.nbytes == 3 * one
    for k, (b0, taps) in entries.items():
        got = cache.get(k)
        np.testing.assert_array_equal(got[0], b0)
        np.testing.assert_array_equal(got[1], taps)


def test_reput_updates_accounting_for_new_size():
    cache = ActivationCache(budget_bytes=1 << 20)
    cache.put(7, *_entry(0, S=8))
    cache.put(7, *_entry(1, S=16))  # replace with a bigger entry
    assert cache.nbytes == _entry_bytes(S=16)
    cache.put(7, *_entry(2, S=4))  # and a smaller one
    assert cache.nbytes == _entry_bytes(S=4)


def test_reput_of_spilled_key_drops_stale_disk_entry(tmp_path):
    """A key that spilled to disk and is later re-put into RAM must not be
    double-counted by len() nor leave an orphan spill file."""
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=one + 1, spill_dir=str(tmp_path))
    cache.put(0, *_entry(0))
    cache.put(1, *_entry(1))  # over budget -> spills to disk
    assert len(cache) == 2
    assert len(list(tmp_path.iterdir())) == 1
    # shrink both entries so they fit in RAM: the spilled key must move
    # back, deleting its stale spill file
    cache.put(0, *_entry(2, S=1))
    cache.put(1, *_entry(3, S=1))
    assert len(cache) == 2
    assert cache.nbytes == 2 * _entry_bytes(S=1)
    assert list(tmp_path.iterdir()) == []


def test_disk_get_closes_npz_handle(tmp_path, monkeypatch):
    """The disk path of get() must close the npz archive it opens.

    Tracked per-instance via a wrapped np.load (patching NpzFile.close
    on the class segfaults numpy's __del__ during monkeypatch undo).
    """
    closed = []
    opened = []
    real_load = np.load

    def tracking_load(*args, **kwargs):
        z = real_load(*args, **kwargs)
        real_close = z.close
        def close_once():
            if z not in closed:
                closed.append(z)
            real_close()
        z.close = close_once  # instance attr shadows the method
        opened.append(z)
        return z

    monkeypatch.setattr(np, "load", tracking_load)
    cache = ActivationCache(budget_bytes=1, spill_dir=str(tmp_path))
    b0, taps = _entry(3)
    cache.put(5, b0, taps)  # budget 1 byte -> straight to disk
    got_b0, got_taps = cache.get(5)
    np.testing.assert_array_equal(got_b0, b0)
    np.testing.assert_array_equal(got_taps, taps)
    assert opened, "disk get should have gone through np.load"
    assert closed == opened, "get() must close the npz archive it opened"
    for z in opened:  # break the z -> close_once -> z ref cycle
        del z.close


def test_eviction_spills_oldest_keeps_recent_in_ram(tmp_path):
    """Under budget pressure the *oldest* RAM entries move to disk and the
    new entry stays RAM-resident — later traffic must not be frozen out
    of RAM by the earliest sequences (the pre-fix policy spilled every
    new entry once RAM filled)."""
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=2 * one, spill_dir=str(tmp_path))
    for k in range(6):
        cache.put(k, *_entry(k))
    # the two most recent keys are in RAM, the four oldest on disk
    assert set(cache._ram) == {4, 5}
    assert set(cache._disk) == {0, 1, 2, 3}
    assert cache.nbytes <= 2 * one
    for k in range(6):  # nothing was dropped
        got = cache.get(k)
        ref = _entry(k)
        np.testing.assert_array_equal(got[0], ref[0])
        np.testing.assert_array_equal(got[1], ref[1])


def test_disk_hit_promoted_back_to_ram(tmp_path, monkeypatch):
    """A disk hit is promoted into RAM so a re-read serves from memory; the
    npz stays behind as a *clean* copy, so evicting the promoted entry
    later is free and a cyclic sweep of an over-budget corpus never pays
    a write per read."""
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=2 * one, spill_dir=str(tmp_path))
    for k in range(4):
        cache.put(k, *_entry(k))
    assert 0 in cache._disk
    got = cache.get(0)
    np.testing.assert_array_equal(got[0], _entry(0)[0])
    assert 0 in cache._ram  # promoted (clean disk copy kept)
    assert cache.nbytes <= 2 * one
    assert len(cache) == 4  # overlap not double-counted
    # second read must not touch disk
    loads = []
    real_load = np.load
    monkeypatch.setattr(np, "load", lambda *a, **k: loads.append(a) or real_load(*a, **k))
    got2 = cache.get(0)
    np.testing.assert_array_equal(got2[0], _entry(0)[0])
    assert loads == []
    # epoch sweeps over the over-budget corpus: the first sweep may spill
    # still-dirty entries once; after that every entry has a clean disk
    # copy, so promotions/evictions never write again (mtimes stay fixed)
    for k in range(4):
        cache.get(k)  # warm-up sweep
    mtimes = {p: os.path.getmtime(p) for p in map(str, tmp_path.iterdir())}
    for _ in range(2):
        for k in range(4):
            got = cache.get(k)
            np.testing.assert_array_equal(got[0], _entry(k)[0])
    after = {p: os.path.getmtime(p) for p in map(str, tmp_path.iterdir())}
    assert after == mtimes, "promotion must not rewrite clean spill files"


def test_oversized_entry_spills_without_flushing_ram(tmp_path):
    """An entry larger than the whole budget goes straight to disk — it
    must not evict the (hot) RAM working set to make room that can never
    suffice."""
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=2 * one, spill_dir=str(tmp_path))
    cache.put(0, *_entry(0))
    cache.put(1, *_entry(1))
    cache.put(99, *_entry(9, S=64))  # 8x the budget
    assert set(cache._ram) == {0, 1}, "hot set must survive an oversized put"
    assert 99 in cache._disk
    got = cache.get(99)
    np.testing.assert_array_equal(got[0], _entry(9, S=64)[0])


def test_ram_hit_refreshes_recency(tmp_path):
    """Reading a RAM entry protects it from the next eviction round."""
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=2 * one, spill_dir=str(tmp_path))
    cache.put(0, *_entry(0))
    cache.put(1, *_entry(1))
    cache.get(0)  # 0 is now more recent than 1
    cache.put(2, *_entry(2))  # evicts 1, not 0
    assert set(cache._ram) == {0, 2}
    assert set(cache._disk) == {1}


def test_eviction_without_spill_dir_still_drops_oldest():
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=2 * one)
    for k in range(3):
        cache.put(k, *_entry(k))
    assert set(cache._ram) == {1, 2}
    assert cache.get(0) is None  # dropped, not spilled: re-forward later


def test_oversized_entry_without_spill_dir_keeps_hot_set():
    """No spill_dir: an over-budget entry is dropped (one re-forward),
    not inserted by flushing every hot entry (N re-forwards)."""
    one = _entry_bytes()
    cache = ActivationCache(budget_bytes=2 * one)
    cache.put(0, *_entry(0))
    cache.put(1, *_entry(1))
    cache.put(99, *_entry(9, S=64))  # 8x the budget
    assert set(cache._ram) == {0, 1}
    assert cache.get(99) is None
    assert cache.nbytes == 2 * one


def test_disk_hit_survives_spill_file_rewrite(tmp_path):
    """Repeated spills of the same key overwrite in place (no dup files)."""
    cache = ActivationCache(budget_bytes=1, spill_dir=str(tmp_path))
    cache.put(9, *_entry(0))
    b0, taps = _entry(4)
    cache.put(9, b0, taps)
    assert len(list(tmp_path.iterdir())) == 1
    got = cache.get(9)
    np.testing.assert_array_equal(got[0], b0)
    np.testing.assert_array_equal(got[1], taps)
