"""The runtime layer: RunSpec + EdgeSession + EpochRunner.

Acceptance contract of the session refactor:

* :class:`RunSpec` is typed, validated, and JSON-round-trippable — the
  trainer flags are a veneer over it (checked literally: the CLI and an
  equivalent RunSpec produce byte-identical output modulo timings);
* :class:`EdgeSession` is *golden-equivalent* to the pre-refactor
  trainer loop: its losses match a hand-composed
  ``pac_train_step``/``pac_cached_train_step`` (and pipeline/sharded)
  loop bit-for-bit, on the single-device, hybrid dp2×pp2, and Pallas
  cached paths;
* :class:`EpochRunner` streams typed records (StepEvent*, EpochReport)
  and fires hooks in order.

Multi-device tests run in subprocesses (the device count locks at
backend init; this process keeps the single real device).
"""

import os
import re
import subprocess
import sys
import textwrap
import types

import pytest

from repro.runtime import RunSpec, RunSpecError

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_sub(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# RunSpec: serialisation
# ---------------------------------------------------------------------------


def test_runspec_json_round_trip():
    spec = RunSpec(arch="t5-base-pac", reduced=True, epochs=5, batch=8,
                   quant=8, micro=2, dp=2, stages=2, cache_compress="int8",
                   kernels="pallas", plan=None, lr=1e-4)
    assert RunSpec.from_json(spec.to_json()) == spec
    assert RunSpec.from_dict(spec.to_dict()) == spec
    # defaults survive too
    assert RunSpec.from_json(RunSpec().to_json()) == RunSpec()


def test_runspec_save_load(tmp_path):
    spec = RunSpec(reduced=True, epochs=2, cache_dir=str(tmp_path / "c"))
    path = spec.save(str(tmp_path / "run.json"))
    assert RunSpec.load(path) == spec


def test_runspec_rejects_unknown_fields():
    with pytest.raises(RunSpecError, match="unknown RunSpec field"):
        RunSpec.from_dict({"epochs": 2, "batch_size": 4, "archh": "x"})


def test_runspec_from_args_inverts_no_cache():
    ns = types.SimpleNamespace(
        arch="internlm2-1.8b", reduced=True, epochs=2, steps_per_epoch=4,
        batch=4, seq=16, seed=1, r=8, init="pruning", quant=None, lr=3e-3,
        no_cache=True, cache_dir=None, cache_compress="f32",
        cache_budget_mb=64, dp=1, stages=1, micro=None, plan=None,
        pool=None, save_plan=None, calibrate=False, kernels="ref", ckpt=None)
    spec = RunSpec.from_args(ns)
    assert spec.use_cache is False and spec.seed == 1 and spec.reduced
    ns.no_cache = False
    assert RunSpec.from_args(ns).use_cache is True


# ---------------------------------------------------------------------------
# RunSpec: validation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw, match", [
    (dict(epochs=0), "epochs"),
    (dict(batch=-1), "batch"),
    (dict(init="magic"), "init"),
    (dict(kernels="cuda"), "kernels"),
    (dict(quant=3), "quant"),
    (dict(cache_compress="zip"), "cache_compress"),
    (dict(pool=0), "pool"),
    (dict(batch=4, micro=3), "divisible"),
    (dict(dp=2, stages=1, batch=4, micro=4), "micro-batch size"),
    # reduced internlm2 has 2 periods: 3 stages can't split them evenly
    (dict(reduced=True, dp=1, stages=3, batch=6, micro=3), "stages"),
])
def test_runspec_validation_errors(kw, match):
    with pytest.raises(RunSpecError, match=match):
        RunSpec(**kw).validate()


def test_runspec_validate_is_chainable_and_accepts_defaults():
    spec = RunSpec()
    assert spec.validate() is spec
    RunSpec(reduced=True, dp=2, stages=2, batch=4, micro=2).validate()


def test_runspec_validates_saved_plan_pool(tmp_path):
    from repro.core.planner import JETSON_NANO_H, Plan, Stage

    plan = Plan(
        stages=[
            Stage(0, 0, (JETSON_NANO_H,), (4,), 0.1),
            Stage(1, 1, (JETSON_NANO_H,), (4,), 0.1),
        ],
        n_stages=2, micro_batches=2,
        latency_begin=0.0, latency_exec=0.2, latency_end=0.0)
    path = plan.save(str(tmp_path / "plan.json"))
    with pytest.raises(RunSpecError, match="smaller than the saved plan"):
        RunSpec(plan=path, pool=1).validate()
    RunSpec(plan=path, pool=2).validate()  # big enough pool is fine
    RunSpec(plan=path).validate()          # pool=None: session sizes it
    with pytest.raises(RunSpecError, match="cannot load plan file"):
        RunSpec(plan=str(tmp_path / "missing.json")).validate()


# ---------------------------------------------------------------------------
# golden equivalence: session == directly-composed steps
# ---------------------------------------------------------------------------


def _reference_losses(spec, *, kernel_impl="ref", compressed=False):
    """The single-device trainer loop, composed by hand from the
    primitive steps exactly as the session composes them (since the
    OpSet dispatch, ``kernel_impl`` governs epoch 1 too: the pallas
    epoch-1 step emits taps in the cache's storage form) — the oracle
    the session must match bit-for-bit."""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.core import steps
    from repro.core.activation_cache import ActivationCache
    from repro.core.init_methods import pruning_init
    from repro.data import DataPipeline, SyntheticPersonalCorpus
    from repro.models import backbone as bb
    from repro.optim import adamw_init

    cfg = spec.arch_config()
    bp = bb.init_backbone(jax.random.PRNGKey(spec.seed), cfg)
    ap = pruning_init(jax.random.PRNGKey(spec.seed + 1), bp, cfg, r=spec.r)
    opt = adamw_init(ap)
    corpus = SyntheticPersonalCorpus(
        cfg.vocab, spec.seq + 1, spec.steps_per_epoch * spec.batch,
        seed=spec.seed)
    pipe = DataPipeline(corpus, global_batch=spec.batch, shuffle=True,
                        seed=spec.seed)
    cache = ActivationCache(budget_bytes=spec.cache_budget_mb << 20,
                            compress=spec.cache_compress)
    tap_policy = spec.cache_compress if kernel_impl == "pallas" else "f32"
    step1 = jax.jit(functools.partial(
        steps.pac_train_step, cfg=cfg, r=spec.r, lr=spec.lr,
        kernel_impl=kernel_impl, tap_policy=tap_policy))
    stepN = jax.jit(functools.partial(
        steps.pac_cached_train_step, cfg=cfg, r=spec.r, lr=spec.lr,
        kernel_impl=kernel_impl), donate_argnums=(1, 2))
    out = []
    for epoch in range(spec.epochs):
        losses = []
        for batch in pipe.epoch(epoch):
            ids = batch.pop("seq_ids")
            if cache.covers(ids, with_final=True):
                hit = cache.get_batch(ids, with_final=True, dtype=None,
                                      compressed=compressed)
                b0, taps, bf = (jax.tree.map(jnp.asarray, h) for h in hit)
                loss, ap, opt = stepN(bp, ap, opt, {
                    "b0": b0, "taps": taps, "b_final": bf,
                    "labels": batch["labels"]})
            else:
                loss, ap, opt, (b0, taps, bf) = step1(bp, ap, opt, batch)
                cache.put_batch(ids, b0, taps, bf, orig_last=cfg.d_model)
            losses.append(float(loss))
        out.append(losses)
    return out


def test_session_matches_composed_steps_single_device():
    """EdgeSession's per-step losses == the hand-composed trainer loop,
    bit-for-bit (same seeds, same data order, same jitted steps)."""
    from repro.runtime import EdgeSession

    spec = RunSpec(arch="internlm2-1.8b", reduced=True, epochs=3,
                   steps_per_epoch=2, batch=2, seq=16, r=4, lr=1e-3)
    reports = EdgeSession(spec).run()
    assert [r.losses for r in reports] == _reference_losses(spec)
    assert [r.used_cache for r in reports] == [False, True, True]
    assert [r.mode for r in reports] == ["full", "cached", "cached"]


def test_session_matches_composed_steps_pallas_interpret():
    """Same golden check on the Pallas cached path: int8 entries reach
    the step in storage form and the fused interpret-mode kernels must
    reproduce the hand-composed loop exactly."""
    from repro.runtime import EdgeSession

    spec = RunSpec(arch="internlm2-1.8b", reduced=True, epochs=2,
                   steps_per_epoch=2, batch=2, seq=16, r=4, lr=1e-3,
                   cache_compress="int8", kernels="pallas")
    reports = EdgeSession(spec).run()
    want = _reference_losses(spec, kernel_impl="pallas", compressed=True)
    assert [r.losses for r in reports] == want
    assert reports[1].used_cache and reports[1].mode == "cached"


_GOLDEN_DP = textwrap.dedent(
    """
    import functools
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.runtime import EdgeSession, RunSpec

    spec = RunSpec(arch="internlm2-1.8b", reduced=True, epochs=2,
                   steps_per_epoch=2, batch=4, seq=16, r=4, lr=1e-3,
                   dp=2, stages=2, micro=2)
    got = [r.losses for r in EdgeSession(spec).run()]

    from repro.core import steps
    from repro.core.activation_cache import ActivationCache
    from repro.core.init_methods import pruning_init
    from repro.data import DataPipeline, SyntheticPersonalCorpus
    from repro.launch import sharding as shard
    from repro.launch.mesh import make_edge_mesh
    from repro.models import backbone as bb
    from repro.optim import adamw_init

    cfg = spec.arch_config()
    mesh = make_edge_mesh(2, 2)
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    ap = pruning_init(jax.random.PRNGKey(1), bp, cfg, r=4)
    opt = adamw_init(ap)
    corpus = SyntheticPersonalCorpus(cfg.vocab, spec.seq + 1,
                                     spec.steps_per_epoch * spec.batch, seed=0)
    pipe = DataPipeline(corpus, global_batch=spec.batch, shuffle=True, seed=0)
    cache = ActivationCache(budget_bytes=spec.cache_budget_mb << 20)
    step1 = jax.jit(functools.partial(
        steps.pipeline_pac_train_step, cfg=cfg, mesh=mesh, n_micro=2,
        r=4, lr=1e-3, partition=None))
    stepN = None
    want = []
    for epoch in range(spec.epochs):
        losses = []
        for batch in pipe.epoch(epoch):
            ids = batch.pop("seq_ids")
            if cache.covers(ids, with_final=True):
                hit = cache.get_batch(ids, with_final=True, dtype=None)
                b0, taps, bf = (jax.tree.map(jnp.asarray, h) for h in hit)
                cached = {"b0": b0, "taps": taps, "b_final": bf,
                          "labels": batch["labels"]}
                if stepN is None:
                    stepN = jax.jit(
                        functools.partial(steps.pac_cached_train_step,
                                          cfg=cfg, r=4, lr=1e-3),
                        in_shardings=shard.cached_step_shardings(
                            bp, ap, opt, cached, mesh),
                        donate_argnums=(1, 2))
                loss, ap, opt = stepN(bp, ap, opt, cached)
            else:
                loss, ap, opt, (b0, taps, bf) = step1(bp, ap, opt, batch)
                cache.put_batch(ids, b0, taps, bf)
            losses.append(float(loss))
        want.append(losses)
    assert got == want, (got, want)
    print("GOLDEN_DP_OK")
    """
)


def test_session_matches_composed_steps_dp2xpp2():
    """Distributed golden: the session's hybrid epoch-1 + cached pure-DP
    losses == the hand-composed pipeline/sharded loop, bit-for-bit.
    (Subprocess: the session forces 4 fake host devices pre-backend and
    the reference loop reuses them.)"""
    assert "GOLDEN_DP_OK" in _run_sub(_GOLDEN_DP)


_VENEER_SPEC = ("RunSpec(arch='internlm2-1.8b', reduced=True, epochs=2, "
                "steps_per_epoch=2, batch=4, seq=16, plan='auto', pool=4, "
                "micro=2)")

_VENEER_API = textwrap.dedent(
    f"""
    from repro.runtime import ConsoleHook, EdgeSession, RunSpec
    EdgeSession({_VENEER_SPEC}, log=print).run(hooks=(ConsoleHook(),))
    """
)

_VENEER_FLAGS = ["--arch", "internlm2-1.8b", "--reduced", "--epochs", "2",
                 "--steps-per-epoch", "2", "--batch", "4", "--seq", "16",
                 "--plan", "auto", "--pool", "4", "--micro", "2"]


def test_cli_is_a_veneer_over_the_session():
    """The trainer CLI and the equivalent RunSpec produce byte-identical
    stdout (timings masked) — flags are a veneer, there is no CLI-only
    logic left. Exercised on the plan-driven path (Alg. 1 auto)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    cli = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *_VENEER_FLAGS],
        capture_output=True, text=True, env=env, timeout=600)
    assert cli.returncode == 0, cli.stderr[-3000:]
    api = _run_sub(_VENEER_API)
    mask = lambda s: re.sub(r"time=[0-9.]+s", "time=*", s)
    assert mask(api) == mask(cli.stdout)
    assert "mesh: plan-driven dp=" in cli.stdout  # the path we meant to hit


# ---------------------------------------------------------------------------
# runner + hooks
# ---------------------------------------------------------------------------


def test_runner_streams_typed_records_and_fires_hooks_in_order():
    from repro.runtime import (
        EdgeSession,
        EpochReport,
        EpochRunner,
        RunHooks,
        StepEvent,
    )

    calls = []

    class Recorder(RunHooks):
        def on_epoch_start(self, session, epoch):
            calls.append(("start", epoch))

        def on_step(self, session, event):
            calls.append(("step", event.epoch, event.index))

        def on_epoch_end(self, session, report):
            calls.append(("end", report.epoch, report.steps))

    spec = RunSpec(arch="internlm2-1.8b", reduced=True, epochs=2,
                   steps_per_epoch=2, batch=2, seq=16, r=4)
    with EdgeSession(spec) as s:
        records = list(EpochRunner(s, hooks=[Recorder()]).events())
    events = [r for r in records if isinstance(r, StepEvent)]
    reports = [r for r in records if isinstance(r, EpochReport)]
    assert len(events) == 4 and len(reports) == 2
    # each epoch: its StepEvents, then its EpochReport (the final record)
    assert [type(r).__name__ for r in records] == [
        "StepEvent", "StepEvent", "EpochReport"] * 2
    assert calls == [("start", 0), ("step", 0, 0), ("step", 0, 1),
                     ("end", 0, 2),
                     ("start", 1), ("step", 1, 0), ("step", 1, 1),
                     ("end", 1, 2)]
    assert [e.cache_hit for e in events] == [False, False, True, True]
    assert [e.mode for e in events] == ["full", "full", "cached", "cached"]
    assert all(e.wall_s > 0 for e in events)
    assert reports[0].mean_loss == pytest.approx(
        sum(reports[0].losses) / len(reports[0].losses))


def test_console_hook_prints_the_classic_epoch_line():
    from repro.runtime import ConsoleHook, EdgeSession

    lines = []
    spec = RunSpec(arch="internlm2-1.8b", reduced=True, epochs=1,
                   steps_per_epoch=1, batch=2, seq=16, r=4)
    EdgeSession(spec).run(hooks=(ConsoleHook(print_fn=lines.append),))
    assert len(lines) == 1
    assert re.fullmatch(
        r"epoch 0: loss=\d+\.\d{4} time=\d+\.\ds \(full\) "
        r"cache\[2 seqs, \d+ MB, f32\]", lines[0]), lines[0]


def test_step_before_open_raises():
    from repro.runtime import EdgeSession

    s = EdgeSession(RunSpec(reduced=True))
    with pytest.raises(RuntimeError, match="open"):
        s.step({"tokens": None, "labels": None, "seq_ids": []})
