"""1F1B schedule, discrete-event simulator, and the SPMD pipeline runtime.

The shard_map pipeline needs >1 device, so those tests run in a
subprocess with --xla_force_host_platform_device_count=4 (tests in this
process keep the single real device).
"""

import os
import subprocess
import sys
import textwrap

import pytest
from _propcheck import given, settings, strategies as st

from repro.core.pipeline import build_1f1b_schedule, simulate_plan, validate_schedule
from repro.core.planner import (
    HybridParallelismPlanner,
    JETSON_NANO_H,
    model_layer_costs,
)
from repro.configs import get_arch


@settings(max_examples=30, deadline=None)
@given(S=st.integers(1, 8), M=st.integers(1, 16))
def test_1f1b_schedule_legal(S, M):
    """Property: every generated schedule validates (covers M<S and M=1)."""
    sched = build_1f1b_schedule(S, M)
    validate_schedule(sched, M)


def test_1f1b_fewer_micros_than_stages():
    """n_micro < n_stages: the warmup ``min(n_stages - s - 1, n_micro)``
    path — early stages cap warmup at M, not the pipeline depth."""
    S, M = 5, 2
    sched = build_1f1b_schedule(S, M)
    validate_schedule(sched, M)
    for s, ops in enumerate(sched):
        leading_f = 0
        for op in ops:
            if op.kind != "F":
                break
            leading_f += 1
        # warmup (capped at M) plus the first steady-state F
        assert leading_f == min(min(S - s - 1, M) + 1, M), (s, ops)
        assert len(ops) == 2 * M  # every micro exactly one F and one B


def test_1f1b_single_micro():
    """n_micro == 1 degenerates to a straight F-then-B pass per stage."""
    sched = build_1f1b_schedule(4, 1)
    validate_schedule(sched, 1)
    for ops in sched:
        assert [(o.kind, o.micro) for o in ops] == [("F", 0), ("B", 0)]


def test_1f1b_memory_bound_tight():
    """Stage 0 of a 4-stage pipeline holds ≤4 in-flight micro-batches."""
    sched = build_1f1b_schedule(4, 8)
    inflight, peak = 0, 0
    for op in sched[0]:
        inflight += 1 if op.kind == "F" else -1
        peak = max(peak, inflight)
    assert peak == 4


def test_simulate_plan_consumes_recorded_fwd_bwd_times():
    """Stage carries its measured tf/tb from LayerCost; the simulator uses
    them instead of the historical hard-coded 1:2 split. A plan whose true
    split is NOT 1:2 therefore times differently from the fallback."""
    from repro.core.planner import DeviceProfile, Plan, Stage

    dev = (DeviceProfile("d", 1e9, 1 << 30),)

    def plan_with(splits):
        stages = [
            Stage(i, i, dev, (1,), tf + tb, fwd_time=tf, bwd_time=tb)
            for i, (tf, tb) in enumerate(splits)
        ]
        return Plan(stages, len(stages), 2, 0.0, 0.0, 0.0)

    def fallback_plan(times):
        stages = [Stage(i, i, dev, (1,), t) for i, t in enumerate(times)]
        return Plan(stages, len(stages), 2, 0.0, 0.0, 0.0)

    # fwd-light stage 0 feeding a balanced stage 1: the 1:2 fallback
    # mis-times both phases
    skewed = plan_with([(0.1, 3.9), (1.0, 1.0)])
    fb = fallback_plan([4.0, 2.0])
    t_skew = simulate_plan(skewed)["minibatch_time"]
    t_fb = simulate_plan(fb)["minibatch_time"]
    assert abs(t_skew - t_fb) > 1e-6, (t_skew, t_fb)
    # recorded times that ARE the 1:2 split reproduce the fallback exactly
    thirds = plan_with([(4.0 / 3, 8.0 / 3), (2.0 / 3, 4.0 / 3)])
    assert simulate_plan(thirds)["minibatch_time"] == pytest.approx(t_fb)


def test_planner_stages_record_fwd_bwd_split():
    """_phase_latencies stores per-stage tf/tb consistent with stage_time
    and with the technique's fwd:bwd FLOP ratio (2:1 bwd:fwd for full FT)."""
    costs = model_layer_costs(get_arch("t5-base-pac"), "full", seq_len=64)
    plan = HybridParallelismPlanner(costs, [JETSON_NANO_H] * 4, 2, 4).plan()
    for st in plan.stages:
        assert st.fwd_time > 0 and st.bwd_time > 0
        assert st.fwd_time + st.bwd_time == pytest.approx(st.stage_time)
        # full fine-tuning: bwd ≈ 2× fwd per LayerCost construction
        assert st.bwd_time == pytest.approx(2.0 * st.fwd_time, rel=1e-6)


def test_simulator_bubble_shrinks_with_microbatches():
    costs = model_layer_costs(get_arch("t5-base-pac"), "full", seq_len=64)
    bubbles = []
    for M in (2, 4, 8):
        plan = HybridParallelismPlanner(costs, [JETSON_NANO_H] * 4, 2, M).plan(max_stages=4)
        # force a multi-stage plan for the bubble comparison
        from repro.core.planner import plan_pure_pp

        pp = plan_pure_pp(costs, [JETSON_NANO_H] * 4, 2, M)
        bubbles.append(simulate_plan(pp)["bubble_fraction"])
    assert bubbles[0] > bubbles[-1]  # classic (S-1)/(M+S-1) behaviour


_SUBPROCESS_PIPELINE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from repro.core.pipeline import stack_stages, pipeline_apply
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("stage",))
    n_p, d = 8, 16
    W = jax.random.normal(jax.random.PRNGKey(0), (n_p, d, d)) * 0.1

    def stage_fn(w_slice, h):
        return jax.lax.scan(lambda h, w: (jnp.tanh(h @ w), None), h, w_slice)[0]

    x = jax.random.normal(jax.random.PRNGKey(1), (6, 3, d))
    with mesh:
        out = pipeline_apply(stage_fn, stack_stages(W, 4), x, mesh)
    ref = x
    for i in range(n_p):
        ref = jnp.tanh(ref @ W[i])
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5, "fwd mismatch"

    def loss_pipe(Wp):
        with mesh:
            return jnp.sum(pipeline_apply(stage_fn, stack_stages(Wp, 4), x, mesh) ** 2)

    def loss_ref(Wp):
        h = x
        for i in range(n_p):
            h = jnp.tanh(h @ Wp[i])
        return jnp.sum(h ** 2)

    g1 = jax.grad(loss_pipe)(W)
    g2 = jax.grad(loss_ref)(W)
    assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-4, "grad mismatch"
    print("PIPELINE_OK")
    """
)


_SUBPROCESS_PIPELINE_TAPS = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from repro.core.pipeline import stack_stages, pipeline_apply
    from repro.launch.mesh import make_mesh

    # 2-D (dp, stage) mesh: dim 1 of x_micro sharded over dp
    mesh = make_mesh((2, 2), ("dp", "stage"))
    n_p, d, n_stages = 8, 16, 2
    W = jax.random.normal(jax.random.PRNGKey(0), (n_p, d, d)) * 0.1

    def stage_fn(w_slice, h):
        # emits every period's activation — the PAC+ tap contract
        return jax.lax.scan(lambda h, w: ((jnp.tanh(h @ w),) * 2), h, w_slice)

    x = jax.random.normal(jax.random.PRNGKey(1), (3, 4, d))  # 3 micro of 4
    with mesh:
        out, taps = pipeline_apply(
            stage_fn, stack_stages(W, n_stages), x, mesh,
            batch_axis="dp", collect_taps=True)
    assert taps.shape == (3, n_p, 4, d), taps.shape
    ref = x
    for i in range(n_p):
        ref = jnp.tanh(ref @ W[i])
        assert float(jnp.max(jnp.abs(taps[:, i] - ref))) < 1e-5, f"tap {i} mismatch"
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5, "fwd mismatch"
    print("PIPELINE_TAPS_OK")
    """
)


def _run_sub(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=600
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_spmd_pipeline_forward_and_grads_match_single_device():
    assert "PIPELINE_OK" in _run_sub(_SUBPROCESS_PIPELINE)


def test_spmd_pipeline_collects_all_stage_taps_on_dp_mesh():
    """Every stage's per-period activations assemble into layer-ordered
    taps (what PAC+ caches), with the micro-batch dim sharded over dp."""
    assert "PIPELINE_TAPS_OK" in _run_sub(_SUBPROCESS_PIPELINE_TAPS)


_SUBPROCESS_DP = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import functools
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs import get_arch
    from repro.core import steps
    from repro.core.parallel_adapters import init_adapter
    from repro.models import backbone as bb
    from repro.optim import adamw_init
    from repro.launch.mesh import make_mesh
    from repro.launch import sharding as shard

    cfg = get_arch("internlm2-1.8b").reduced()
    mesh = make_mesh((4, 2), ("data", "model"))
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    ap = init_adapter(jax.random.PRNGKey(1), cfg, r=4)
    opt = adamw_init(ap)
    B, S = 8, 16
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab),
    }
    fn = functools.partial(steps.pac_train_step, cfg=cfg, r=4)
    # single device reference
    loss_ref, ap_ref, _, _ = fn(bp, ap, opt, batch)
    # sharded execution on the 4x2 mesh
    p_sh = shard.to_named(shard.param_specs(bp, mesh), mesh)
    a_sh = shard.to_named(shard.param_specs(ap, mesh), mesh)
    o_sh = shard.to_named(shard.param_specs(opt, mesh), mesh)
    b_sh = shard.to_named(shard.batch_specs(batch, mesh), mesh)
    with mesh:
        jf = jax.jit(fn, in_shardings=(p_sh, a_sh, o_sh, b_sh))
        loss_sh, ap_sh, _, _ = jf(bp, ap, opt, batch)
    assert abs(float(loss_ref) - float(loss_sh)) < 1e-4, (float(loss_ref), float(loss_sh))
    d = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(ap_ref), jax.tree.leaves(ap_sh))
    )
    # f32 reduction order differs across shards; AdamW's m/(sqrt(v)+eps)
    # amplifies that near zero-gradient elements, so the post-update bound
    # is looser than the loss bound (real sharding bugs are O(1) off)
    assert d < 1e-3, d
    print("SPMD_STEP_OK")
    """
)


def test_sharded_pac_step_matches_single_device():
    """The production sharding rules preserve numerics on a real 4×2 mesh."""
    assert "SPMD_STEP_OK" in _run_sub(_SUBPROCESS_DP)
