"""MoE dispatch tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.configs.base import MoESpec
from repro.models.moe import init_moe, moe_forward, moe_forward_dense


def _spec(E=4, K=2, cf=8.0):
    return MoESpec(n_experts=E, top_k=K, d_expert=32, capacity_factor=cf)


def test_capacity_matches_dense_when_no_drop():
    spec = _spec(cf=8.0)  # capacity ≥ T ⇒ nothing dropped
    p = init_moe(jax.random.PRNGKey(0), 16, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    a = moe_forward(p, x, spec)
    b = moe_forward_dense(p, x, spec)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_capacity_drops_bounded():
    spec = _spec(cf=1.0)
    p = init_moe(jax.random.PRNGKey(2), 16, spec)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 16, 16))
    out, aux = moe_forward(p, x, spec, return_aux=True)
    assert 0.0 <= float(aux["dropped_frac"]) < 0.7
    assert float(aux["load_balance"]) >= 0.99  # ≥1 by Cauchy-Schwarz-ish


def test_aux_losses_finite_and_balanced_router_is_optimal():
    spec = _spec(E=4, K=1, cf=8.0)
    p = init_moe(jax.random.PRNGKey(4), 16, spec)
    # uniform router ⇒ load_balance == 1 exactly
    p = dict(p, router=jnp.zeros_like(p["router"]))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, 16))
    _, aux = moe_forward(p, x, spec, return_aux=True)
    np.testing.assert_allclose(float(aux["load_balance"]), 1.0, atol=0.15)


@settings(max_examples=10, deadline=None)
@given(E=st.sampled_from([2, 4, 8]), K=st.integers(1, 3), seed=st.integers(0, 100))
def test_moe_output_finite_property(E, K, seed):
    K = min(K, E)
    spec = MoESpec(n_experts=E, top_k=K, d_expert=16, capacity_factor=2.0)
    p = init_moe(jax.random.PRNGKey(seed), 8, spec)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, 8))
    out = moe_forward(p, x, spec)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()


def test_moe_grads_flow_to_router_and_experts():
    spec = _spec()
    p = init_moe(jax.random.PRNGKey(6), 16, spec)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 8, 16))

    def loss(p):
        return jnp.sum(jnp.square(moe_forward(p, x, spec)))

    g = jax.grad(loss)(p)
    assert float(jnp.max(jnp.abs(g["router"]))) > 0
    assert float(jnp.max(jnp.abs(g["wi"]))) > 0


# ---------------------------------------------------------------------------
# Group-limited routing (§Perf-hillclimb kimi iters B/C)
# ---------------------------------------------------------------------------


def test_grouped_routing_matches_global_when_capacity_ample():
    """With capacity ≥ per-group tokens, grouping never drops, so grouped
    and global routing agree exactly (routing decisions are per-token)."""
    spec = _spec(cf=16.0)
    p = init_moe(jax.random.PRNGKey(8), 16, spec)
    x = jax.random.normal(jax.random.PRNGKey(9), (4, 8, 16))
    a = moe_forward(p, x, spec, n_groups=1)
    b = moe_forward(p, x, spec, n_groups=4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_grouped_routing_is_group_independent():
    """Group g's output depends only on group g's tokens: permuting the
    other group's tokens leaves it unchanged."""
    spec = _spec(cf=1.0)  # tight capacity: drops happen, but per group
    p = init_moe(jax.random.PRNGKey(10), 16, spec)
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 16, 16))
    out = moe_forward(p, x, spec, n_groups=2)
    x2 = x.at[1].set(x[1, ::-1])  # shuffle group 1's tokens
    out2 = moe_forward(p, x2, spec, n_groups=2)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out2[0]), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(G=st.sampled_from([1, 2, 4]), seed=st.integers(0, 50))
def test_grouped_routing_finite_property(G, seed):
    spec = MoESpec(n_experts=4, top_k=2, d_expert=16, capacity_factor=1.5)
    p = init_moe(jax.random.PRNGKey(seed), 8, spec)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (4, 8, 8))
    out, aux = moe_forward(p, x, spec, return_aux=True, n_groups=G)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert 0.0 <= float(aux["dropped_frac"]) <= 1.0


def test_capacity_rounding_sublane_and_cap():
    from repro.models.moe import _capacity

    spec = _spec(E=4, K=2, cf=1.0)
    # rounds up to a multiple of 8 ...
    assert _capacity(100, spec) % 8 == 0
    # ... but never exceeds the token count (top_k constraint)
    assert _capacity(2, spec) <= 2
    assert _capacity(1, spec) == 1


def test_local_topk_falls_back_without_mesh():
    from repro.models.moe import _local_topk

    x = jax.random.normal(jax.random.PRNGKey(12), (2, 4, 16))
    v1, i1 = _local_topk(x, 3, ("batch", "model", None))
    v2, i2 = jax.lax.top_k(x, 3)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_grouped_routing_drop_rate_near_global():
    """Group-limited routing must not materially increase token drops vs
    global routing at matched total capacity (the statistical argument
    for the beyond-paper dispatch: groups see iid token subsets)."""
    spec = MoESpec(n_experts=8, top_k=2, d_expert=16, capacity_factor=1.25)
    p = init_moe(jax.random.PRNGKey(20), 32, spec)
    x = jax.random.normal(jax.random.PRNGKey(21), (8, 64, 32))
    _, aux1 = moe_forward(p, x, spec, return_aux=True, n_groups=1)
    _, aux4 = moe_forward(p, x, spec, return_aux=True, n_groups=4)
    d1, d4 = float(aux1["dropped_frac"]), float(aux4["dropped_frac"])
    assert d4 <= d1 + 0.05, (d1, d4)
