"""Docs CI: smoke-execute marked code blocks + check relative links.

Keeps README.md and docs/*.md from rotting silently:

* **Marked-block smoke** — a fenced ```python block immediately preceded
  by a ``<!-- docs-exec -->`` comment line is executed in a subprocess
  with ``PYTHONPATH=src`` (only marked blocks: most doc snippets are
  shell commands or illustrative fragments that are not meant to run
  standalone). A block that raises fails the job with its file:line.
* **Relative-link check** — every ``[text](path)`` markdown link that is
  not http(s)/mailto/anchor must resolve to an existing file relative to
  the document (trailing ``#fragment`` stripped).

Usage:
    python tools/check_docs.py            # link check only (fast; tier-1)
    python tools/check_docs.py --exec     # + run marked blocks (CI docs job)
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

if __package__ in (None, ""):  # `python tools/check_docs.py` / tests' import
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from tools.fsutil import doc_files, repo_root  # noqa: E402  (shared with palint)

REPO = repo_root()
EXEC_MARK = "<!-- docs-exec -->"
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def extract_marked_blocks(path: str) -> list:
    """[(lineno_of_fence, code)] for ```python fences preceded by the
    EXEC_MARK comment (ignoring blank lines in between)."""
    blocks = []
    lines = open(path).read().splitlines()
    marked = False
    i = 0
    while i < len(lines):
        line = lines[i].strip()
        if line == EXEC_MARK:
            marked = True
        elif line.startswith("```python") and marked:
            start = i + 1
            j = start
            while j < len(lines) and not lines[j].strip().startswith("```"):
                j += 1
            blocks.append((start, "\n".join(lines[start:j])))
            marked = False
            i = j
        elif line and not line.startswith("```"):
            # any other content between mark and fence cancels the mark
            if marked and line != EXEC_MARK:
                marked = False
        i += 1
    return blocks


def check_links(path: str) -> list:
    """Broken relative links in one markdown file: [(lineno, target)]."""
    bad = []
    base = os.path.dirname(path)
    for lineno, line in enumerate(open(path).read().splitlines(), 1):
        for target in _LINK_RE.findall(line):
            if re.match(r"^(https?:|mailto:|#)", target):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not os.path.exists(os.path.normpath(os.path.join(base, rel))):
                bad.append((lineno, target))
    return bad


def run_block(code: str, timeout: int = 600) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, timeout=timeout, cwd=REPO,
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--exec", dest="do_exec", action="store_true",
                    help="also smoke-execute the marked python blocks")
    args = ap.parse_args()

    failures = 0
    n_links = n_blocks = 0
    for path in doc_files():
        rel = os.path.relpath(path, REPO)
        bad = check_links(path)
        n_links += 1
        for lineno, target in bad:
            print(f"BROKEN LINK {rel}:{lineno}: {target}", file=sys.stderr)
            failures += 1
        blocks = extract_marked_blocks(path)
        n_blocks += len(blocks)
        if args.do_exec:
            for lineno, code in blocks:
                proc = run_block(code)
                if proc.returncode != 0:
                    print(f"BLOCK FAILED {rel}:{lineno}:\n{proc.stderr[-2000:]}",
                          file=sys.stderr)
                    failures += 1
                else:
                    print(f"block OK {rel}:{lineno}")
    mode = "exec" if args.do_exec else "links-only"
    print(f"check_docs ({mode}): {n_links} files, {n_blocks} marked blocks, "
          f"{failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
