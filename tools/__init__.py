# The repo's maintenance tooling (`palint`, `check_docs`) as an importable
# package, so CI can run `python -m tools.palint` and tier-1 tests can
# import the same entry points the workflow invokes.
