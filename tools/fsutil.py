"""Shared filesystem helpers for the repo's ``tools/`` scripts.

One definition of "where is the repo root" and "which files does a tool
walk", used by both :mod:`tools.palint` and ``tools/check_docs.py`` so
the two gates can never disagree about what they cover.
"""

from __future__ import annotations

import fnmatch
import os
from typing import Callable, Iterable, Optional

# Directories no tool ever wants to descend into.
SKIP_DIRS = frozenset(
    {"__pycache__", ".git", ".pytest_cache", ".mypy_cache", ".ruff_cache",
     "node_modules", ".venv", "venv", ".eggs"}
)


def repo_root() -> str:
    """Absolute path of the repository root (the parent of ``tools/``)."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def walk_files(
    paths: Iterable[str],
    *,
    root: Optional[str] = None,
    suffixes: Optional[tuple] = None,
    patterns: Optional[tuple] = None,
    keep: Optional[Callable[[str], bool]] = None,
) -> list:
    """Expand files/directories into a sorted, deduplicated file list.

    ``paths`` entries are taken relative to ``root`` (default:
    :func:`repo_root`) unless absolute; directories are walked
    recursively with :data:`SKIP_DIRS` pruned. A file is kept when it
    matches any of ``suffixes`` (endswith) or ``patterns``
    (fnmatch on the basename) — or unconditionally when neither filter
    is given — and, if supplied, ``keep(path)`` returns True.
    """
    base = root or repo_root()

    def _wanted(path: str) -> bool:
        name = os.path.basename(path)
        if suffixes or patterns:
            ok = bool(suffixes and name.endswith(tuple(suffixes)))
            ok = ok or bool(
                patterns and any(fnmatch.fnmatch(name, p) for p in patterns)
            )
            if not ok:
                return False
        return keep(path) if keep else True

    out = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(base, p)
        if os.path.isfile(full):
            # explicitly named files bypass the suffix/pattern filter:
            # the caller asked for exactly this one
            if keep is None or keep(full):
                out.append(os.path.abspath(full))
        elif os.path.isdir(full):
            for dirpath, dirnames, filenames in os.walk(full):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if d not in SKIP_DIRS and not d.startswith(".")
                )
                for f in sorted(filenames):
                    fp = os.path.join(dirpath, f)
                    if _wanted(fp):
                        out.append(os.path.abspath(fp))
    return sorted(dict.fromkeys(out))


def doc_files(root: Optional[str] = None) -> list:
    """README.md + docs/*.md — the markdown set the docs gate covers."""
    base = root or repo_root()
    files = [os.path.join(base, "README.md")]
    docs = os.path.join(base, "docs")
    if os.path.isdir(docs):
        files += sorted(
            os.path.join(docs, f) for f in os.listdir(docs) if f.endswith(".md")
        )
    return [f for f in files if os.path.exists(f)]
