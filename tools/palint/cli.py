"""palint command line.

Usage::

    python -m tools.palint                      # default paths + BENCH_*.json
    python -m tools.palint src tests            # explicit targets
    python -m tools.palint --json               # machine-readable output
    python -m tools.palint --list-rules         # rule catalog

Exit status: 0 clean, 1 findings, 2 bad invocation.
"""

from __future__ import annotations

import argparse
import json
import sys

from tools.palint.engine import DEFAULT_PATHS, Context, all_rules, run


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m tools.palint",
        description="Project-invariant static analyzer for the PAC "
                    "jax_pallas stack (see docs/LINTING.md).",
    )
    ap.add_argument(
        "paths", nargs="*",
        help=f"files/directories to scan (default: {' '.join(DEFAULT_PATHS)} "
             "+ repo-root BENCH_*.json)",
    )
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings + per-site reports as JSON")
    ap.add_argument("--root", default=None,
                    help="repo root override (used by the test suite)")
    ap.add_argument("--vmem-budget-mib", type=float, default=16.0,
                    help="per-core VMEM budget for pallas-blockspec "
                         "(default: 16 MiB)")
    ap.add_argument("--assume-dim", type=int, default=128,
                    help="value charged for block dims that stay dynamic "
                         "(default: 128)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--verbose", "-v", action="store_true",
                    help="also print per-site reports in text mode")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.name:18s} [{rule.kind}] {rule.summary}")
        return 0

    ctx = Context(
        root="",  # filled by run()
        vmem_budget_bytes=int(args.vmem_budget_mib * 1024 * 1024),
        assume_dim=args.assume_dim,
    )
    result = run(args.paths or None, root=args.root, ctx=ctx)

    if args.as_json:
        print(json.dumps(result.to_json(), indent=1, sort_keys=True))
        return 0 if result.ok else 1

    for f in result.findings:
        print(f.render())
    if args.verbose:
        for r in result.reports:
            print(f"note: {r.path}:{r.line}: [{r.rule}] "
                  + json.dumps(r.data, sort_keys=True))
    status = "clean" if result.ok else f"{len(result.findings)} finding(s)"
    print(f"palint: {result.n_files} files, "
          f"{len(result.reports)} report(s), {status}")
    return 0 if result.ok else 1


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
