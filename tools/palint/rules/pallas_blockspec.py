"""Rule: static sanity + VMEM budgeting for every ``pallas_call`` site.

A bad ``BlockSpec`` fails at *Mosaic compile time on a TPU* — hardware
the CPU-only CI never touches (every committed benchmark is
interpret-mode, which skips these checks entirely). This rule moves
three classes of kernel-launch bugs to lint time:

* **index_map arity** — each BlockSpec's ``index_map`` lambda must take
  exactly one argument per grid dimension.
* **divisibility** — where a block dim and the corresponding output
  array dim are both statically known, the block must divide the
  (padded) dim; Pallas would otherwise round-and-clip silently in
  interpret mode and miscompile on hardware.
* **VMEM footprint** — the summed per-grid-step footprint of all
  in/out blocks (×2: the pipeline emitter double-buffers them) plus
  scratch must fit the per-core VMEM budget (default 16 MiB — the TPU
  figure from the Pallas guide).

Block shapes are resolved by constant propagation over the enclosing
function (parameter defaults, ``min``-clamps, straight-line
assignments). Dims that stay dynamic (e.g. a head dim unpacked from a
runtime shape) are charged a configurable assumption (default 128,
``--assume-dim``) and the estimate is marked inexact — every site still
gets a VMEM report in ``--json``.
"""

from __future__ import annotations

import ast
from typing import List, Optional

from tools.palint.astutil import (
    ConstEnv,
    build_env_for,
    collect_list_parts,
    dtype_width,
    eval_const,
    last_segment,
    module_env,
    resolve_name,
)
from tools.palint.engine import Context, Finding, PyModule, Report, Rule, register

_OFF_VMEM_SPACES = {"SMEM", "HBM", "ANY", "SEMAPHORE"}


class _Spec:
    """One parsed BlockSpec (or scratch shape)."""

    def __init__(self):
        self.dims: List[Optional[float]] = []
        self.dim_nodes: List[ast.AST] = []
        self.exact = True
        self.assumed: List[str] = []
        self.arity: Optional[int] = None
        self.memory_space: Optional[str] = None
        self.width = 4
        self.known_shape = False

    def resolve_dims(self, elts, env: ConstEnv, assume: int):
        self.known_shape = True
        for e in elts:
            v, exact = eval_const(e, env)
            if v is None:
                try:
                    label = ast.unparse(e)[:40]
                except Exception:
                    label = "<expr>"
                self.assumed.append(label)
                v, exact = assume, False
            self.dims.append(v)
            self.dim_nodes.append(e)
            self.exact = self.exact and exact

    @property
    def bytes(self) -> int:
        if not self.known_shape:
            return 0
        n = 1
        for d in self.dims:
            n *= max(int(d), 1)
        return int(n * self.width)


def _parse_blockspec(node: ast.AST, module: PyModule, env: ConstEnv,
                     assume: int) -> Optional[_Spec]:
    if not (isinstance(node, ast.Call)
            and last_segment(module.imports.resolve(node.func)) == "BlockSpec"):
        return None
    spec = _Spec()
    shape = node.args[0] if node.args else None
    index_map = node.args[1] if len(node.args) > 1 else None
    for kw in node.keywords:
        if kw.arg == "block_shape":
            shape = kw.value
        elif kw.arg == "index_map":
            index_map = kw.value
        elif kw.arg == "memory_space":
            spec.memory_space = last_segment(module.imports.resolve(kw.value))
    if isinstance(shape, (ast.Tuple, ast.List)):
        spec.resolve_dims(shape.elts, env, assume)
    if isinstance(index_map, ast.Lambda):
        spec.arity = len(index_map.args.args) + len(index_map.args.posonlyargs)
    return spec


def _spec_list(node: Optional[ast.AST], module: PyModule,
               call: ast.Call, func) -> Optional[List[ast.AST]]:
    """The BlockSpec element ASTs behind an ``in_specs=``-style argument."""
    if node is None:
        return []
    if isinstance(node, (ast.Tuple, ast.List)):
        return list(node.elts)
    if isinstance(node, ast.Name) and func is not None:
        parts = collect_list_parts(node.id, call, func)
        if parts is not None:
            return parts
        resolved = resolve_name(node, call, func)
        if isinstance(resolved, ast.Call):
            return [resolved]  # a Name bound to one BlockSpec
        return None
    return [node]  # single spec


def _out_dtypes_and_dims(node: Optional[ast.AST], module: PyModule,
                         env: ConstEnv):
    """[(width, [dim exprs])] per output, from ``out_shape=``."""
    outs = []
    if node is None:
        return outs
    structs = node.elts if isinstance(node, (ast.Tuple, ast.List)) else [node]
    for s in structs:
        width, dims = 4, None
        if isinstance(s, ast.Call) and last_segment(
                module.imports.resolve(s.func)) == "ShapeDtypeStruct":
            shape = s.args[0] if s.args else None
            dtype = s.args[1] if len(s.args) > 1 else None
            for kw in s.keywords:
                if kw.arg == "shape":
                    shape = kw.value
                elif kw.arg == "dtype":
                    dtype = kw.value
            if dtype is not None:
                width = dtype_width(dtype, module.imports)
            if isinstance(shape, (ast.Tuple, ast.List)):
                dims = shape.elts
        outs.append((width, dims))
    return outs


def _kernel_label(node: ast.AST, module: PyModule) -> str:
    while isinstance(node, ast.Call) and last_segment(
            module.imports.resolve(node.func)) == "partial" and node.args:
        node = node.args[0]
    if isinstance(node, ast.Lambda):
        return "<lambda>"
    return last_segment(module.imports.resolve(node)) or "<kernel>"


def _enclosing_function(module: PyModule, call: ast.Call):
    best = None
    for node in ast.walk(module.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.lineno <= call.lineno <= max(
                getattr(node, "end_lineno", node.lineno), node.lineno
            ):
                if best is None or node.lineno > best.lineno:
                    best = node
    return best


@register
class PallasBlockSpecRule(Rule):
    name = "pallas-blockspec"
    summary = ("pallas_call: index_map arity vs grid rank, literal block "
               "divisibility, per-site VMEM budget")

    def check(self, module: PyModule, ctx: Context):
        base = None
        for call in ast.walk(module.tree):
            if not (isinstance(call, ast.Call) and last_segment(
                    module.imports.resolve(call.func)) == "pallas_call"):
                continue
            if base is None:
                base = module_env(module.tree)
            yield from self._check_site(module, ctx, call, base)

    def _check_site(self, module: PyModule, ctx: Context, call: ast.Call,
                    base: ConstEnv):
        func = _enclosing_function(module, call)
        env = build_env_for(call, func, base) if func is not None else base
        assume = ctx.assume_dim

        kwargs = {kw.arg: kw.value for kw in call.keywords if kw.arg}

        # a grid_spec= bundle (PrefetchScalarGridSpec / GridSpec) carries
        # grid/in_specs/out_specs/scratch_shapes inside the constructor —
        # unwrap it so those sites get the same checks as flat kwargs.
        # num_scalar_prefetch shifts every index_map's expected arity: the
        # prefetched scalar refs are appended after the grid indices.
        n_prefetch = 0
        gs_node = resolve_name(kwargs.pop("grid_spec", None), call, func)
        if isinstance(gs_node, ast.Call) and last_segment(
                module.imports.resolve(gs_node.func)) in (
                "PrefetchScalarGridSpec", "GridSpec"):
            for kw in gs_node.keywords:
                if kw.arg == "num_scalar_prefetch":
                    v, _ = eval_const(kw.value, env)
                    n_prefetch = int(v) if v else 0
                elif kw.arg in ("grid", "in_specs", "out_specs",
                                "scratch_shapes"):
                    kwargs.setdefault(kw.arg, kw.value)

        grid_node = kwargs.get("grid")
        grid_rank: Optional[int] = None
        grid_dims: List[Optional[float]] = []
        if isinstance(grid_node, (ast.Tuple, ast.List)):
            grid_rank = len(grid_node.elts)
            grid_dims = [eval_const(e, env)[0] for e in grid_node.elts]
        elif grid_node is not None:
            grid_rank = 1
            grid_dims = [eval_const(grid_node, env)[0]]

        out_shape_node = kwargs.get("out_shape")
        if out_shape_node is None and len(call.args) > 1:
            out_shape_node = call.args[1]
        out_meta = _out_dtypes_and_dims(out_shape_node, module, env)

        in_nodes = _spec_list(kwargs.get("in_specs"), module, call, func)
        out_nodes = _spec_list(kwargs.get("out_specs"), module, call, func)
        unresolved_lists = in_nodes is None or out_nodes is None

        specs = []  # (role, index, _Spec)
        for role, nodes in (("in", in_nodes or []), ("out", out_nodes or [])):
            for i, n in enumerate(nodes):
                s = _parse_blockspec(
                    resolve_name(n, call, func), module, env, assume)
                if s is not None:
                    if role == "out" and i < len(out_meta):
                        s.width = out_meta[i][0]
                    specs.append((role, i, s))

        # -- index_map arity vs grid rank (+ scalar-prefetch refs) ---------
        if grid_rank is not None:
            want = grid_rank + n_prefetch
            why = (f"the grid has rank {grid_rank} and "
                   f"{n_prefetch} scalar-prefetch operand(s) follow the "
                   "program ids" if n_prefetch else
                   f"the grid has rank {grid_rank} — Pallas passes one "
                   "program id per grid dim")
            for role, i, s in specs:
                if s.arity is not None and s.arity != want:
                    yield Finding(
                        self.name, module.rel, call.lineno,
                        f"{role}_specs[{i}]: index_map takes {s.arity} "
                        f"argument(s) but {why}",
                        col=call.col_offset,
                    )

        # -- literal divisibility of out blocks into out dims --------------
        for role, i, s in specs:
            if role != "out" or i >= len(out_meta) or not s.known_shape:
                continue
            _, arr_dims = out_meta[i]
            if arr_dims is None or len(arr_dims) != len(s.dims):
                continue
            for d, (blk_node, arr_node) in enumerate(
                    zip(s.dim_nodes, arr_dims)):
                bv, bexact = eval_const(blk_node, env)
                av, aexact = eval_const(arr_node, env)
                if bexact and aexact and bv and av and int(av) % int(bv):
                    yield Finding(
                        self.name, module.rel, call.lineno,
                        f"out_specs[{i}] dim {d}: block size {int(bv)} does "
                        f"not divide the output dim {int(av)} — pad the "
                        "operand or pick an aligning block",
                        col=call.col_offset,
                    )

        # -- VMEM footprint -------------------------------------------------
        total = 0
        exact = not unresolved_lists
        assumed: List[str] = []
        n_skipped = 0
        for role, i, s in specs:
            if s.memory_space in _OFF_VMEM_SPACES:
                continue
            if not s.known_shape:
                n_skipped += 1
                exact = False
                continue
            total += s.bytes * 2  # pipeline double-buffering
            exact = exact and s.exact
            assumed += s.assumed

        scratch_nodes = _spec_list(kwargs.get("scratch_shapes"), module,
                                   call, func) or []
        n_scratch = 0
        for n in scratch_nodes:
            if not isinstance(n, ast.Call):
                continue
            seg = last_segment(module.imports.resolve(n.func))
            if seg != "VMEM":
                continue
            n_scratch += 1
            s = _Spec()
            if n.args and isinstance(n.args[0], (ast.Tuple, ast.List)):
                s.resolve_dims(n.args[0].elts, env, assume)
            if len(n.args) > 1:
                s.width = dtype_width(n.args[1], module.imports)
            total += s.bytes
            exact = exact and s.exact
            assumed += s.assumed

        budget = ctx.vmem_budget_bytes
        data = {
            "kernel": _kernel_label(call.args[0], module) if call.args
            else "<kernel>",
            "grid_rank": grid_rank,
            "grid": [int(g) if g is not None else None for g in grid_dims],
            "n_in_specs": len(in_nodes) if in_nodes is not None else None,
            "n_out_specs": len(out_nodes) if out_nodes is not None else None,
            "n_scratch": n_scratch,
            "num_scalar_prefetch": n_prefetch,
            "vmem_bytes": total,
            "vmem_kib": round(total / 1024, 1),
            "budget_bytes": budget,
            "exact": exact,
            "assumed_dims": sorted(set(assumed)),
            "unparsed_specs": n_skipped,
            "double_buffered": True,
        }
        yield Report(self.name, module.rel, call.lineno, data)
        if total > budget:
            yield Finding(
                self.name, module.rel, call.lineno,
                f"estimated per-step VMEM footprint {total / 2**20:.1f} MiB "
                f"exceeds the {budget / 2**20:.1f} MiB budget "
                f"({'exact' if exact else 'estimate; assumed dims: ' + str(sorted(set(assumed)))}) "
                "— shrink the block sizes or raise --vmem-budget-mib",
                col=call.col_offset,
            )
