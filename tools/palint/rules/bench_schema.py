"""Rule: committed ``BENCH_*.json`` records must carry the full schema.

The benchmark JSONs are the repo's perf trajectory; a record missing
``pallas_interpret_mode`` would let an interpret-mode number masquerade
as a hardware measurement, and a stringly-typed step time silently
breaks any script that plots the trend. Malformed records should fail
CI at commit time, not skew an analysis months later.
"""

from __future__ import annotations

import json

from tools.palint.engine import Context, Finding, Rule, register

#: top-level key → required json type(s)
REQUIRED = {
    "arch": str,
    "backend": str,
    "pallas_interpret_mode": bool,
    "batch": int,
    "seq": int,
}

#: extra required keys for specific records, by basename — the serving
#: bench is meaningless without the page geometry and the per-policy
#: breakdown it exists to compare
REQUIRED_BY_NAME = {
    "BENCH_decode_step.json": {"page_size": int, "policies": dict},
}

#: nested keys matching any of these predicates must be numeric
_NUMERIC_SUFFIXES = ("_ms", "_s", "_mb", "_bytes", "_bytes_per_batch",
                     "_per_s", "_per_token")
_NUMERIC_EXACT = {"ms", "batch", "seq", "bm", "bn", "bk", "bits", "steps"}
_NUMERIC_PREFIXES = ("ratio_", "loss_")


def _wants_numeric(key: str) -> bool:
    return (key in _NUMERIC_EXACT or key.endswith(_NUMERIC_SUFFIXES)
            or key.startswith(_NUMERIC_PREFIXES))


def _walk(obj, path, out):
    if isinstance(obj, dict):
        for k, v in obj.items():
            kp = f"{path}.{k}" if path else k
            if _wants_numeric(k) and not (
                isinstance(v, (int, float)) and not isinstance(v, bool)
            ):
                out.append((kp, v))
            _walk(v, kp, out)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            _walk(v, f"{path}[{i}]", out)


@register
class BenchSchemaRule(Rule):
    name = "bench-schema"
    summary = ("BENCH_*.json must have arch/backend/pallas_interpret_mode/"
               "batch/seq and numeric step fields")
    kind = "data"

    def check_data(self, path: str, rel: str, raw: bytes, ctx: Context):
        try:
            data = json.loads(raw)
        except (ValueError, UnicodeDecodeError) as e:
            yield Finding(self.name, rel, 0, f"invalid JSON: {e}")
            return
        if not isinstance(data, dict):
            yield Finding(self.name, rel, 0,
                          "benchmark record must be a JSON object")
            return
        basename = rel.rsplit("/", 1)[-1]
        required = dict(REQUIRED, **REQUIRED_BY_NAME.get(basename, {}))
        for key, typ in required.items():
            if key not in data:
                yield Finding(
                    self.name, rel, 0,
                    f"missing required key {key!r} "
                    f"({'bool' if typ is bool else typ.__name__})",
                )
            elif not isinstance(data[key], typ) or (
                typ is int and isinstance(data[key], bool)
            ):
                yield Finding(
                    self.name, rel, 0,
                    f"key {key!r} must be "
                    f"{'bool' if typ is bool else typ.__name__}, "
                    f"got {type(data[key]).__name__} ({data[key]!r})",
                )
        bad_numeric = []
        _walk(data, "", bad_numeric)
        for kp, v in bad_numeric:
            yield Finding(
                self.name, rel, 0,
                f"field {kp!r} must be numeric, got "
                f"{type(v).__name__} ({v!r})",
            )
