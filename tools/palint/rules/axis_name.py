"""Rule: literal collective axis names must be declared in the module.

``jax.lax.psum(x, "dp")`` with no mesh/shard_map axis named ``"dp"``
reachable from the call fails only at trace time — on a multi-device
mesh, i.e. usually on hardware CI doesn't have. This rule checks every
``psum`` / ``pmean`` / ``axis_index`` call whose axis argument is a
string literal (or tuple of literals) against the axis names declared
anywhere in the same module: ``make_mesh``/``abstract_mesh``/``Mesh``
constructions, ``axis_name=``/``axis_names=``/``axes=`` keywords, and
string-literal defaults of parameters named like an axis
(``axis="stage"``). Variable axis arguments are out of static reach and
are skipped.
"""

from __future__ import annotations

import ast
from typing import Optional, Set

from tools.palint.astutil import last_segment
from tools.palint.engine import Context, Finding, PyModule, Rule, register

_COLLECTIVES = {"psum": 1, "pmean": 1, "pmax": 1, "pmin": 1,
                "axis_index": 0, "all_gather": 1, "ppermute": 1}
_DECL_CALLS = {"make_mesh", "abstract_mesh", "Mesh", "AbstractMesh",
               "mesh_for_pool", "data_stage_mesh"}
_DECL_KWARGS = {"axis_name", "axis_names", "axes", "axis"}
_AXIS_PARAM_NAMES = ("axis", "axes", "axis_name", "batch_axis", "dp_axis",
                     "stage_axis", "model_axis")


def _string_consts(node: ast.AST) -> Set[str]:
    return {
        n.value for n in ast.walk(node)
        if isinstance(n, ast.Constant) and isinstance(n.value, str)
    }


def _declared_axes(module: PyModule) -> Set[str]:
    declared: Set[str] = set()
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Call):
            if last_segment(module.imports.resolve(node.func)) in _DECL_CALLS:
                for a in node.args:
                    declared |= _string_consts(a)
            for kw in node.keywords:
                if kw.arg in _DECL_KWARGS:
                    declared |= _string_consts(kw.value)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = node.args
            pos = args.posonlyargs + args.args
            pairs = list(zip(pos[len(pos) - len(args.defaults):], args.defaults))
            pairs += [
                (a, d) for a, d in zip(args.kwonlyargs, args.kw_defaults) if d
            ]
            for arg, default in pairs:
                if arg.arg in _AXIS_PARAM_NAMES or arg.arg.endswith("_axis"):
                    declared |= _string_consts(default)
    return declared


def _axis_literals(node: ast.AST) -> Optional[list]:
    """["dp", ...] when the axis argument is fully literal, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
            else:
                return None
        return out
    return None


@register
class AxisNameRule(Rule):
    name = "axis-name"
    summary = ("psum/pmean/axis_index literal axis names must match a "
               "mesh/shard_map axis declared in the module")

    def check(self, module: PyModule, ctx: Context):
        declared = None  # computed lazily — most modules have no collectives
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            resolved = module.imports.resolve(node.func) or ""
            seg = last_segment(resolved)
            if seg not in _COLLECTIVES or not (
                resolved.startswith("jax.") or ".lax." in resolved
            ):
                continue
            idx = _COLLECTIVES[seg]
            axis_arg = None
            if len(node.args) > idx:
                axis_arg = node.args[idx]
            else:
                for kw in node.keywords:
                    if kw.arg == "axis_name":
                        axis_arg = kw.value
            if axis_arg is None:
                continue
            literals = _axis_literals(axis_arg)
            if literals is None:
                continue  # dynamic axis — out of static reach
            if declared is None:
                declared = _declared_axes(module)
            for name in literals:
                if name not in declared:
                    yield Finding(
                        self.name, module.rel, node.lineno,
                        f"{seg}(..., {name!r}): axis name {name!r} is not "
                        "declared by any mesh/shard_map axis in this module "
                        f"(declared: {sorted(declared) or 'none'})",
                        col=node.col_offset,
                    )
