"""Rule: storage-form cache entries decompress only where sanctioned.

The PR 5/7 contract: an int8 activation-cache entry (the
``{"q": int8, "scale": f32}`` dict) crosses host→device and HBM at its
*storage* width and is dequantised tile-wise in VMEM by the kernels.
An eager ``entry["q"].astype(f32)`` / ``dequantize(entry[...])`` /
``entry_to_f32(...)`` anywhere else re-materialises the full f32 tap —
exactly the round-trip the fused path exists to avoid — and shows up as
a silent 4× traffic regression, not a test failure.

Sanctioned sites: ``src/repro/kernels/`` (the kernels themselves and
their ref oracle), ``src/repro/core/activation_cache.py`` (the cache
owns its entries' lifecycle), ``src/repro/core/quantization.py``
(defines the primitives) and ``src/repro/serve/paging.py`` (the paged
KV pool, which owns the quantise-on-write side of the same contract).
"""

from __future__ import annotations

import ast

from tools.palint.astutil import last_segment
from tools.palint.engine import Context, Finding, PyModule, Rule, register

ALLOWED_PREFIXES = (
    "src/repro/kernels/",
    "src/repro/core/activation_cache.py",
    "src/repro/core/quantization.py",
    # paged INT8 KV pages reuse the {"q","scale"} storage form; the page
    # pool owns quantise-on-write, the kernels own dequantise-on-read —
    # the engine and decode step in between must never widen a page
    "src/repro/serve/paging.py",
)
_KEYS = {"q", "scale"}


def _touches_storage_key(node: ast.AST) -> bool:
    """True when the expression subtree subscripts a ``"q"``/``"scale"``
    storage-form entry."""
    for n in ast.walk(node):
        if isinstance(n, ast.Subscript):
            sl = n.slice
            if isinstance(sl, ast.Constant) and sl.value in _KEYS:
                return True
    return False


@register
class StorageFormRule(Rule):
    name = "storage-form"
    summary = ("eager f32 decompression of {'q','scale'} cache entries "
               "outside kernels/ and the activation cache")

    def check(self, module: PyModule, ctx: Context):
        if module.rel.startswith(ALLOWED_PREFIXES):
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            seg = last_segment(module.imports.resolve(node.func))
            if seg == "entry_to_f32":
                yield Finding(
                    self.name, module.rel, node.lineno,
                    "entry_to_f32() eagerly decompresses a storage-form "
                    "cache entry — outside kernels/ this re-materialises "
                    "the full f32 tap (use the fused dq_* kernels)",
                    col=node.col_offset,
                )
            elif seg == "dequantize" and any(
                _touches_storage_key(a) for a in list(node.args)
                + [kw.value for kw in node.keywords]
            ):
                yield Finding(
                    self.name, module.rel, node.lineno,
                    "dequantize() of a {'q','scale'} storage-form entry — "
                    "the no-f32-round-trip contract confines this to "
                    "kernels/ and the activation cache",
                    col=node.col_offset,
                )
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "astype" \
                    and _touches_storage_key(node.func.value):
                yield Finding(
                    self.name, module.rel, node.lineno,
                    "entry['q'].astype(...) eagerly upcasts a storage-form "
                    "payload — taps must stay at storage width outside "
                    "kernels/ and the activation cache",
                    col=node.col_offset,
                )
