"""Rule: layer boundaries the architecture depends on.

* ``src/repro/models/`` must not import ``repro.kernels`` — the model
  layer reaches kernels only through the OpSet seam (``core/opset.py``),
  which is what lets ``--kernels ref|pallas`` swap implementations
  without touching model code.
* ``examples/`` and ``benchmarks/`` must not touch
  ``repro.launch.train`` privates (``train._foo``) — they are thin
  clients of the runtime session API; private trainer internals are
  free to change under them.
"""

from __future__ import annotations

import ast

from tools.palint.engine import Context, Finding, PyModule, Rule, register

_TRAIN = "repro.launch.train"


def _imports_kernels(node) -> bool:
    if isinstance(node, ast.ImportFrom):
        if node.module and node.module.startswith("repro.kernels"):
            return True
        if node.module == "repro" and any(a.name == "kernels" for a in node.names):
            return True
        if node.level and node.module and node.module.split(".")[0] == "kernels":
            # relative spelling inside src/repro — `from ..kernels import x`
            return True
    if isinstance(node, ast.Import):
        return any(a.name.startswith("repro.kernels") for a in node.names)
    return False


@register
class LayeringRule(Rule):
    name = "layering"
    summary = ("models/ must not import repro.kernels (OpSet is the seam); "
               "examples/benchmarks must not use repro.launch.train privates")

    def check(self, module: PyModule, ctx: Context):
        if module.rel.startswith("src/repro/models/"):
            for node in ast.walk(module.tree):
                if _imports_kernels(node):
                    yield Finding(
                        self.name, module.rel, node.lineno,
                        "model layer imports repro.kernels — route through "
                        "the OpSet (core/opset.py), the only sanctioned seam",
                    )

        if module.rel.startswith(("examples/", "benchmarks/")):
            for node in ast.walk(module.tree):
                if isinstance(node, ast.ImportFrom) and node.module == _TRAIN:
                    private = [a.name for a in node.names if a.name.startswith("_")]
                    if private:
                        yield Finding(
                            self.name, module.rel, node.lineno,
                            f"imports trainer privates {private} from "
                            f"{_TRAIN} — use the runtime session API "
                            "(repro.runtime) instead",
                        )
                elif isinstance(node, ast.Attribute) and node.attr.startswith("_"):
                    base = module.imports.resolve(node.value)
                    if base == _TRAIN:
                        yield Finding(
                            self.name, module.rel, node.lineno,
                            f"touches {_TRAIN}.{node.attr} — trainer privates "
                            "are not a stable surface for examples/benchmarks",
                            col=node.col_offset,
                        )
