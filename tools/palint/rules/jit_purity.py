"""Rule: no host-side effects lexically inside traced function bodies.

Functions that are jitted, shard_mapped, or handed to ``pallas_call``
run at *trace time*: a ``time.perf_counter()`` measures tracing (once),
a ``print`` fires once per compilation, ``np.random`` bakes one sample
into the compiled program, and module-global mutation silently captures
stale state. All are classic "works in eager, wrong under jit" bugs.
``jax.debug.print`` / ``jax.debug.callback`` are the sanctioned
alternatives and are not flagged.

Detection is lexical: a function counts as traced when it is decorated
with ``jax.jit`` (directly or via ``functools.partial(jax.jit, ...)``),
or passed as the first argument to ``jit`` / ``shard_map`` /
``pallas_call`` (lambdas and local ``def``s both resolve). Everything
lexically inside — nested defs included — is checked.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Set

from tools.palint.astutil import last_segment
from tools.palint.engine import Context, Finding, PyModule, Rule, register

_WRAPPER_SEGMENTS = {"jit", "shard_map", "pallas_call"}
_IMPURE_EXACT = {
    "time.time", "time.perf_counter", "time.monotonic", "time.process_time",
    "time.time_ns", "time.perf_counter_ns",
}
_IMPURE_PREFIXES = ("numpy.random.",)


def _is_wrapper(resolved) -> bool:
    return last_segment(resolved) in _WRAPPER_SEGMENTS


def _unwrap_partial(node: ast.AST, module: PyModule):
    """``functools.partial(f, ...)`` → ``f`` (recursively); else ``node``."""
    while isinstance(node, ast.Call) \
            and last_segment(module.imports.resolve(node.func)) == "partial" \
            and node.args:
        node = node.args[0]
    return node


def _traced_functions(module: PyModule) -> Iterator:
    """(func_node, reason) for every lexically-traced function body."""
    defs_by_name = {}
    assigned = {}
    for node in ast.walk(module.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs_by_name.setdefault(node.name, node)
        elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            assigned.setdefault(node.targets[0].id, []).append(node.value)

    seen: Set[int] = set()

    def mark(target: ast.AST, reason: str, depth: int = 0):
        target = _unwrap_partial(target, module)
        if isinstance(target, ast.Name):
            resolved = defs_by_name.get(target.id)
            if resolved is None and depth < 4:
                # `kernel = functools.partial(_kernel, ...)` then
                # `pallas_call(kernel, ...)` — chase every assignment to
                # the name (several scopes may reuse it; each candidate
                # really is traced somewhere)
                for value in assigned.get(target.id, ()):
                    yield from mark(value, reason, depth + 1)
                return
            target = resolved
        if isinstance(target, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)) \
                and id(target) not in seen:
            seen.add(id(target))
            yield target, reason

    for node in ast.walk(module.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                target = dec
                if isinstance(dec, ast.Call):  # partial(jax.jit, ...) / jit(...)
                    inner = _unwrap_partial(dec, module)
                    if inner is not dec:
                        target = inner  # partial's first arg must be the wrapper
                        if _is_wrapper(module.imports.resolve(target)):
                            yield from mark(node, last_segment(
                                module.imports.resolve(target)))
                        continue
                    target = dec.func
                if _is_wrapper(module.imports.resolve(target)):
                    yield from mark(node, last_segment(module.imports.resolve(target)))
        elif isinstance(node, ast.Call):
            if _is_wrapper(module.imports.resolve(node.func)) and node.args:
                yield from mark(
                    node.args[0], last_segment(module.imports.resolve(node.func))
                )


@register
class JitPurityRule(Rule):
    name = "jit-purity"
    summary = ("time/print/np.random/global-mutation inside jit, shard_map "
               "or pallas_call bodies")

    def check(self, module: PyModule, ctx: Context):
        flagged: List[Finding] = []
        reported: Set[int] = set()
        for func, reason in _traced_functions(module):
            label = getattr(func, "name", "<lambda>")
            for node in ast.walk(func):
                if id(node) in reported:
                    continue
                if isinstance(node, ast.Global):
                    reported.add(id(node))
                    flagged.append(Finding(
                        self.name, module.rel, node.lineno,
                        f"global-statement mutation inside {reason}-traced "
                        f"'{label}' — traced code must not mutate module state",
                    ))
                elif isinstance(node, ast.Call):
                    resolved = module.imports.resolve(node.func) or ""
                    bad = None
                    if resolved == "print":
                        bad = ("print() runs at trace time — use "
                               "jax.debug.print for traced values")
                    elif resolved in _IMPURE_EXACT:
                        bad = (f"{resolved}() measures tracing, not the "
                               "compiled step — time outside the traced body")
                    elif resolved.startswith(_IMPURE_PREFIXES):
                        bad = (f"{resolved}() bakes one host sample into the "
                               "compiled program — use jax.random with a "
                               "traced key")
                    if bad:
                        reported.add(id(node))
                        flagged.append(Finding(
                            self.name, module.rel, node.lineno,
                            f"{bad} (inside {reason}-traced '{label}')",
                            col=node.col_offset,
                        ))
        yield from flagged
