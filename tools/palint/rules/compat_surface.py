"""Rule: version-gated JAX APIs live in ``src/repro/compat.py`` only.

The compat layer exists so exactly one module feature-detects the JAX
surfaces that moved across releases (``shard_map``'s home, ``AxisType``,
the ``check_rep``→``check_vma`` rename, ``make_mesh``'s ``axis_types=``
kwarg). Any other use is a portability bug waiting for the next JAX
pin bump. Matched on the AST — imports, attribute access, call keywords
and ``getattr`` strings — so aliased or re-exported spellings that a
text grep misses are still caught.
"""

from __future__ import annotations

import ast

from tools.palint.engine import Context, Finding, PyModule, Rule, register

ALLOWED = "src/repro/compat.py"
_GATED_NAMES = {"AxisType", "check_vma"}
_GATED_KWARGS = {"axis_types", "check_vma"}


@register
class CompatSurfaceRule(Rule):
    name = "compat-surface"
    summary = ("version-gated JAX APIs (shard_map import, AxisType, "
               "check_vma, axis_types=) outside repro.compat")

    def check(self, module: PyModule, ctx: Context):
        if module.rel == ALLOWED:
            return
        for node in ast.walk(module.tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                if "shard_map" in node.module or (
                    node.module.split(".")[0] == "jax"
                    and any(a.name == "shard_map" for a in node.names)
                ):
                    yield Finding(
                        self.name, module.rel, node.lineno,
                        "import shard_map via repro.compat.shard_map — its "
                        "home moved across JAX versions",
                    )
                gated = _GATED_NAMES.intersection(a.name for a in node.names)
                if node.module.split(".")[0] == "jax" and gated:
                    yield Finding(
                        self.name, module.rel, node.lineno,
                        f"import of version-gated {sorted(gated)} — only "
                        "repro.compat may touch these",
                    )
            elif isinstance(node, ast.Import):
                for a in node.names:
                    if "shard_map" in a.name:
                        yield Finding(
                            self.name, module.rel, node.lineno,
                            "import shard_map via repro.compat.shard_map",
                        )
            elif isinstance(node, ast.Attribute):
                resolved = module.imports.resolve(node)
                if resolved == "jax.shard_map":
                    yield Finding(
                        self.name, module.rel, node.lineno,
                        "jax.shard_map moved across versions — use "
                        "repro.compat.shard_map",
                        col=node.col_offset,
                    )
                elif node.attr in _GATED_NAMES:
                    yield Finding(
                        self.name, module.rel, node.lineno,
                        f"attribute .{node.attr} is version-gated — only "
                        "repro.compat may feature-detect it",
                        col=node.col_offset,
                    )
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg in _GATED_KWARGS:
                        yield Finding(
                            self.name, module.rel, node.lineno,
                            f"keyword {kw.arg}= is version-gated — route "
                            "through repro.compat",
                            col=node.col_offset,
                        )
                if isinstance(node.func, ast.Name) and node.func.id == "getattr":
                    for a in node.args:
                        if isinstance(a, ast.Constant) and a.value in _GATED_NAMES:
                            yield Finding(
                                self.name, module.rel, node.lineno,
                                f"getattr(..., {a.value!r}) feature-detects a "
                                "version-gated API outside repro.compat",
                                col=node.col_offset,
                            )
