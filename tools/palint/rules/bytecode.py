"""Rule: no compiled-bytecode artifacts tracked in git.

A committed ``__pycache__``/``.pyc`` is stale the moment the source
changes and bloats every checkout; this replaces the CI
``git ls-files | grep`` guard. Skips silently when the scan root is not
a git work tree (e.g. fixture directories in the palint test suite).
"""

from __future__ import annotations

import os
import subprocess

from tools.palint.engine import Context, Finding, Rule, register


@register
class BytecodeRule(Rule):
    name = "no-bytecode"
    summary = "no __pycache__/ or .pyc files tracked in git"
    kind = "project"

    def check_project(self, ctx: Context):
        if not os.path.isdir(os.path.join(ctx.root, ".git")):
            return
        try:
            proc = subprocess.run(
                ["git", "ls-files"], cwd=ctx.root, capture_output=True,
                text=True, timeout=60, check=True,
            )
        except (OSError, subprocess.SubprocessError):
            return  # no git available — the guard is CI-side anyway
        for tracked in proc.stdout.splitlines():
            if "__pycache__/" in tracked or tracked.endswith(".pyc"):
                yield Finding(
                    self.name, tracked, 0,
                    "compiled bytecode is tracked in git — remove it and "
                    "add the pattern to .gitignore",
                )
