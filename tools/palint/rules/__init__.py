# Importing this package registers every rule with the engine registry.
from tools.palint.rules import (  # noqa: F401
    axis_name,
    bench_schema,
    bytecode,
    compat_surface,
    jit_purity,
    layering,
    pallas_blockspec,
    storage_form,
)
