"""AST helpers shared by the palint rules.

Two capabilities every rule leans on:

* **Import-aware name resolution** — :class:`ImportMap` records what each
  top-level alias in a module refers to, so ``pl.pallas_call`` resolves
  to ``jax.experimental.pallas.pallas_call`` no matter how the import was
  spelled. Rules match on *resolved* dotted names, which is what makes
  them strictly stronger than the text greps they replace (aliasing,
  ``from x import y as z``, multi-line calls).

* **Best-effort constant resolution** — :class:`ConstEnv` evaluates the
  integer expressions that feed Pallas block shapes (parameter defaults,
  straight-line assignments, ``min``/``max`` clamps, conditional
  expressions). Values carry an ``exact`` bit: a ``min(bk, K)`` with
  unknown ``K`` still yields the *upper bound* ``bk`` (what a VMEM
  budget check wants), just marked inexact.
"""

from __future__ import annotations

import ast
from typing import Dict, Optional, Tuple


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class ImportMap:
    """Top-level alias → fully-qualified dotted name for one module."""

    def __init__(self, tree: ast.AST):
        self.aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.aliases[a.asname or a.name] = f"{node.module}.{a.name}"

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Resolved dotted name of a Name/Attribute chain (imports applied)."""
        dn = dotted_name(node)
        if dn is None:
            return None
        head, _, rest = dn.partition(".")
        full = self.aliases.get(head, head)
        return f"{full}.{rest}" if rest else full


def resolve_call(node: ast.Call, imports: ImportMap) -> Optional[str]:
    """Resolved dotted name of a call's callee."""
    return imports.resolve(node.func)


def last_segment(resolved: Optional[str]) -> str:
    return resolved.rsplit(".", 1)[-1] if resolved else ""


# ---------------------------------------------------------------------------
# Constant resolution
# ---------------------------------------------------------------------------

#: (value, exact) — value None means "could not resolve at all".
Resolved = Tuple[Optional[float], bool]

_UNKNOWN: Resolved = (None, False)


class ConstEnv:
    """Name → (value, exact) environment for one function scope."""

    def __init__(self):
        self.values: Dict[str, Resolved] = {}

    def set(self, name: str, res: Resolved) -> None:
        if res[0] is None and name in self.values:
            # unresolvable reassignment: keep the previous value as an
            # estimate but drop the exactness claim (e.g. `bm = min(bm, M)`
            # with unknown M keeps the default bm as an upper bound)
            old_val, _ = self.values[name]
            self.values[name] = (old_val, False)
        else:
            self.values[name] = res

    def get(self, name: str) -> Resolved:
        return self.values.get(name, _UNKNOWN)

    def clear(self, name: str) -> None:
        """Forget a name entirely (a parameter shadowing a module global)."""
        self.values[name] = _UNKNOWN


def eval_const(node: ast.AST, env: Optional[ConstEnv] = None) -> Resolved:
    """Best-effort numeric evaluation of an expression AST."""
    if isinstance(node, ast.Constant):
        if isinstance(node.value, bool) or not isinstance(node.value, (int, float)):
            return _UNKNOWN
        return (node.value, True)
    if isinstance(node, ast.Name):
        return env.get(node.id) if env else _UNKNOWN
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v, e = eval_const(node.operand, env)
        return (None, False) if v is None else (-v, e)
    if isinstance(node, ast.BinOp):
        lv, le = eval_const(node.left, env)
        rv, re_ = eval_const(node.right, env)
        if lv is None or rv is None:
            return _UNKNOWN
        exact = le and re_
        try:
            if isinstance(node.op, ast.Add):
                return (lv + rv, exact)
            if isinstance(node.op, ast.Sub):
                return (lv - rv, exact)
            if isinstance(node.op, ast.Mult):
                return (lv * rv, exact)
            if isinstance(node.op, ast.FloorDiv):
                return (lv // rv, exact)
            if isinstance(node.op, ast.Div):
                return (lv / rv, exact)
            if isinstance(node.op, ast.Mod):
                return (lv % rv, exact)
            if isinstance(node.op, ast.Pow):
                return (lv ** rv, exact)
        except (ZeroDivisionError, OverflowError):
            return _UNKNOWN
        return _UNKNOWN
    if isinstance(node, ast.IfExp):
        test = _eval_bool(node.test, env)
        if test is None:
            return _UNKNOWN
        return eval_const(node.body if test else node.orelse, env)
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        if node.func.id in ("min", "max") and node.args and not node.keywords:
            vals = [eval_const(a, env) for a in node.args]
            resolved = [v for v, _ in vals if v is not None]
            if not resolved:
                return _UNKNOWN
            all_exact = len(resolved) == len(vals) and all(e for _, e in vals)
            # min over a subset is an upper bound on the true min — usable
            # (inexact); max over a subset may undershoot, equally inexact
            pick = min(resolved) if node.func.id == "min" else max(resolved)
            return (pick, all_exact)
        if node.func.id == "int" and len(node.args) == 1:
            v, e = eval_const(node.args[0], env)
            return _UNKNOWN if v is None else (int(v), e)
    return _UNKNOWN


def _eval_bool(node: ast.AST, env: Optional[ConstEnv]) -> Optional[bool]:
    """Evaluate a comparison/boolean test, or None when undecidable."""
    if isinstance(node, ast.Compare) and len(node.ops) == 1:
        lv, _ = eval_const(node.left, env)
        rv, _ = eval_const(node.comparators[0], env)
        if lv is None or rv is None:
            return None
        op = node.ops[0]
        if isinstance(op, ast.Eq):
            return lv == rv
        if isinstance(op, ast.NotEq):
            return lv != rv
        if isinstance(op, ast.Lt):
            return lv < rv
        if isinstance(op, ast.LtE):
            return lv <= rv
        if isinstance(op, ast.Gt):
            return lv > rv
        if isinstance(op, ast.GtE):
            return lv >= rv
    if isinstance(node, ast.Constant) and isinstance(node.value, bool):
        return node.value
    return None


def scope_nodes(func: ast.AST) -> list:
    """All nodes lexically in ``func``'s own scope (nested function and
    lambda bodies excluded), in source order."""
    out = []

    def visit(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue
            out.append(child)
            visit(child)

    visit(func)
    out.sort(key=lambda n: (getattr(n, "lineno", 0), getattr(n, "col_offset", 0)))
    return out


def module_env(tree: ast.AST) -> ConstEnv:
    """Module-level constants (``QBLOCK = 128`` and friends)."""
    env = ConstEnv()
    for node in ast.iter_child_nodes(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            env.set(node.targets[0].id, eval_const(node.value, env))
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name) \
                and node.value is not None:
            env.set(node.target.id, eval_const(node.value, env))
    return env


def build_env_for(call: ast.Call, func: ast.FunctionDef,
                  base: Optional[ConstEnv] = None) -> ConstEnv:
    """Constant environment at ``call``'s site inside ``func``.

    Starts from ``base`` (module-level constants), seeds parameter
    defaults, then replays every straight-line assignment that textually
    precedes the call (branch conditions are ignored — later assignments
    win, losing exactness when a value cannot be resolved).
    """
    env = ConstEnv()
    if base is not None:
        env.values.update(base.values)
    args = func.args
    pos = args.posonlyargs + args.args
    for arg in pos[:len(pos) - len(args.defaults)]:
        env.clear(arg.arg)  # parameters shadow module globals
    for arg, default in zip(pos[len(pos) - len(args.defaults):], args.defaults):
        env.set(arg.arg, eval_const(default, env))
    for arg, default in zip(args.kwonlyargs, args.kw_defaults):
        if default is not None:
            env.set(arg.arg, eval_const(default, env))
        else:
            env.clear(arg.arg)

    stop = call.lineno
    for node in scope_nodes(func):
        if getattr(node, "lineno", 0) >= stop:
            continue
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            target = node.targets[0]
            if isinstance(target, ast.Name):
                env.set(target.id, eval_const(node.value, env))
            elif isinstance(target, ast.Tuple) and isinstance(node.value, ast.Tuple) \
                    and len(target.elts) == len(node.value.elts):
                for t, v in zip(target.elts, node.value.elts):
                    if isinstance(t, ast.Name):
                        env.set(t.id, eval_const(v, env))
        elif isinstance(node, ast.AugAssign) and isinstance(node.target, ast.Name):
            env.set(node.target.id, _UNKNOWN)
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name) \
                and node.value is not None:
            env.set(node.target.id, eval_const(node.value, env))
        elif isinstance(node, ast.Assert):
            _assert_bounds(node.test, env)
    return env


def _assert_bounds(test: ast.AST, env: ConstEnv) -> None:
    """Harvest upper bounds from envelope asserts.

    ``assert page <= 64 and hd <= 256`` declares the supported envelope
    of a dim that is otherwise unpacked from a runtime shape — for a
    still-unknown name, the bound becomes its (inexact) value, so VMEM
    estimates use the declared ceiling instead of the global assumption.
    """
    tests = test.values if isinstance(test, ast.BoolOp) \
        and isinstance(test.op, ast.And) else [test]
    for t in tests:
        if not isinstance(t, ast.Compare):
            continue
        left = t.left
        for op, comp in zip(t.ops, t.comparators):
            if isinstance(op, (ast.LtE, ast.Lt)) and isinstance(left, ast.Name):
                v, _ = eval_const(comp, env)
                if v is not None and env.get(left.id)[0] is None:
                    bound = v - 1 if isinstance(op, ast.Lt) else v
                    env.set(left.id, (bound, False))
            left = comp


def _list_value_elts(value: ast.AST) -> Optional[list]:
    """Element ASTs of a list-valued expression: a literal, or the
    ``[spec] * n`` replication idiom."""
    if isinstance(value, (ast.List, ast.Tuple)):
        return list(value.elts)
    if isinstance(value, ast.BinOp) and isinstance(value.op, ast.Mult):
        for lst, n in ((value.left, value.right), (value.right, value.left)):
            if isinstance(lst, (ast.List, ast.Tuple)) \
                    and isinstance(n, ast.Constant) \
                    and isinstance(n.value, int):
                return list(lst.elts) * n.value
    return None


def collect_list_parts(name: str, call: ast.Call, func: ast.FunctionDef) -> Optional[list]:
    """Element ASTs of a list variable at ``call``'s site, or None.

    Understands the build-a-spec-list idiom::

        specs = [A, B]
        if cond:
            specs.append(C)
        specs += [D] * 2

    Conditional appends are *included* (superset — the conservative
    direction for a VMEM upper bound).
    """
    parts = None
    stop = call.lineno
    for node in scope_nodes(func):
        if getattr(node, "lineno", 0) >= stop:
            continue
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == name:
            parts = _list_value_elts(node.value)
        elif isinstance(node, ast.AugAssign) and isinstance(node.target, ast.Name) \
                and node.target.id == name and parts is not None:
            elts = _list_value_elts(node.value)
            parts = parts + elts if elts is not None else None
        elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("append", "extend") \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == name and parts is not None:
            if node.func.attr == "append" and len(node.args) == 1:
                parts.append(node.args[0])
            elif node.func.attr == "extend" and len(node.args) == 1:
                elts = _list_value_elts(node.args[0])
                parts = parts + elts if elts is not None else None
            else:
                parts = None
    return parts


def resolve_name(node: ast.AST, call: ast.Call, func: Optional[ast.AST]) -> ast.AST:
    """Follow a ``Name`` to its last straight-line assignment before
    ``call`` in ``func``'s scope; non-Names pass through unchanged."""
    if not isinstance(node, ast.Name) or func is None:
        return node
    value = node
    for stmt in scope_nodes(func):
        if getattr(stmt, "lineno", 0) >= call.lineno:
            continue
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and stmt.targets[0].id == node.id:
            value = stmt.value
    return value


#: dtype name → byte width, for VMEM footprint arithmetic.
DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool_": 1, "bool": 1,
    "float8_e4m3fn": 1, "float8_e5m2": 1,
}


def dtype_width(node: ast.AST, imports: ImportMap, default: int = 4) -> int:
    """Byte width of a dtype expression (``jnp.float32`` → 4); ``default``
    when the dtype is dynamic (``x.dtype``)."""
    resolved = imports.resolve(node)
    if resolved:
        return DTYPE_BYTES.get(resolved.rsplit(".", 1)[-1], default)
    return default
