import sys

from tools.palint.cli import main

sys.exit(main())
