"""palint core: rule registry, file loading, suppressions, the runner.

A rule is a class with a unique ``name``, a one-line ``summary``, and a
``check`` method yielding :class:`Finding` (a violation — fails the run)
and/or :class:`Report` (informational data surfaced in ``--json``, e.g.
per-``pallas_call`` VMEM estimates). Python rules get a parsed
:class:`PyModule`; data rules (``bench-schema``) get raw file bytes;
project rules run once against the repo root.

Per-line suppression::

    something_flagged()  # palint: disable=rule-name  -- why it is OK

suppresses findings of the named rule(s) on that physical line
(comma-separate several; ``disable=all`` silences every rule).
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional

if __package__ in (None, ""):  # pragma: no cover - direct script use
    import sys

    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    )

from tools.fsutil import repo_root, walk_files
from tools.palint.astutil import ImportMap

_SUPPRESS_RE = re.compile(
    r"#\s*palint:\s*disable=([A-Za-z0-9_\-]+(?:\s*,\s*[A-Za-z0-9_\-]+)*)"
)


@dataclasses.dataclass
class Finding:
    """One violation. ``path`` is repo-root-relative (posix separators)."""

    rule: str
    path: str
    line: int
    message: str
    col: int = 0
    extra: Optional[dict] = None

    def to_json(self) -> dict:
        d = {"rule": self.rule, "path": self.path, "line": self.line,
             "col": self.col, "message": self.message}
        if self.extra:
            d["extra"] = self.extra
        return d

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class Report:
    """Informational per-site data (never fails the run)."""

    rule: str
    path: str
    line: int
    data: dict

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "data": self.data}


class PyModule:
    """One parsed python file plus its suppression table."""

    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.imports = ImportMap(self.tree)
        self.suppressions: Dict[int, set] = {}
        for lineno, line in enumerate(source.splitlines(), 1):
            m = _SUPPRESS_RE.search(line)
            if m:
                self.suppressions[lineno] = {
                    r.strip() for r in m.group(1).split(",") if r.strip()
                }

    def is_suppressed(self, rule: str, line: int) -> bool:
        rules = self.suppressions.get(line, ())
        return rule in rules or "all" in rules


@dataclasses.dataclass
class Context:
    """Run-wide configuration handed to every rule."""

    root: str
    vmem_budget_bytes: int = 16 * 1024 * 1024
    assume_dim: int = 128


class Rule:
    """Base: AST rule over one python module."""

    name: str = ""
    summary: str = ""
    kind: str = "python"  # "python" | "data" | "project"

    def check(self, module: PyModule, ctx: Context) -> Iterable:
        raise NotImplementedError

    def check_data(self, path: str, rel: str, raw: bytes, ctx: Context) -> Iterable:
        raise NotImplementedError

    def check_project(self, ctx: Context) -> Iterable:
        raise NotImplementedError


_REGISTRY: Dict[str, Rule] = {}


def register(cls):
    """Class decorator adding a rule (by its ``name``) to the registry."""
    rule = cls()
    if not rule.name:
        raise ValueError(f"rule {cls.__name__} has no name")
    if rule.name in _REGISTRY:
        raise ValueError(f"duplicate rule name {rule.name!r}")
    _REGISTRY[rule.name] = rule
    return cls


def all_rules() -> List[Rule]:
    import tools.palint.rules  # noqa: F401  (registers on import)

    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


DEFAULT_PATHS = ("src", "tests", "examples", "benchmarks", "tools")


def _rel(path: str, root: str) -> str:
    try:
        rel = os.path.relpath(path, root)
    except ValueError:  # different drive (windows)
        rel = path
    if rel.startswith(".."):
        rel = os.path.basename(path)
    return rel.replace(os.sep, "/")


@dataclasses.dataclass
class Result:
    findings: List[Finding]
    reports: List[Report]
    n_files: int

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_json(self) -> dict:
        return {
            "version": 1,
            "ok": self.ok,
            "files_scanned": self.n_files,
            "findings": [f.to_json() for f in self.findings],
            "reports": [r.to_json() for r in self.reports],
        }


def run(
    paths: Optional[Iterable[str]] = None,
    *,
    root: Optional[str] = None,
    ctx: Optional[Context] = None,
) -> Result:
    """Run every registered rule over ``paths`` (files or directories,
    relative to ``root``). Defaults: :data:`DEFAULT_PATHS` plus the
    repo-root ``BENCH_*.json`` benchmark records."""
    root = os.path.abspath(root or repo_root())
    ctx = ctx or Context(root=root)
    ctx.root = root

    explicit = list(paths) if paths else None
    scan = explicit if explicit is not None else [
        p for p in DEFAULT_PATHS if os.path.exists(os.path.join(root, p))
    ]
    py_files = walk_files(scan, root=root, suffixes=(".py",))
    bench_files = walk_files(scan, root=root, patterns=("BENCH_*.json",))
    if explicit is None:
        bench_files += walk_files(
            sorted(
                f for f in os.listdir(root)
                if re.fullmatch(r"BENCH_.*\.json", f)
            ),
            root=root,
        )
    bench_files = sorted(dict.fromkeys(bench_files))

    rules = all_rules()
    findings: List[Finding] = []
    reports: List[Report] = []

    def emit(items, module: Optional[PyModule] = None):
        for item in items:
            if isinstance(item, Report):
                reports.append(item)
            elif module is not None and module.is_suppressed(item.rule, item.line):
                continue
            else:
                findings.append(item)

    for path in py_files:
        rel = _rel(path, root)
        try:
            source = open(path, encoding="utf-8").read()
            module = PyModule(path, rel, source)
        except (SyntaxError, UnicodeDecodeError, OSError) as e:
            lineno = getattr(e, "lineno", None) or 0
            findings.append(Finding(
                rule="parse-error", path=rel, line=lineno,
                message=f"cannot analyze: {e.__class__.__name__}: {e}",
            ))
            continue
        for rule in rules:
            if rule.kind == "python":
                emit(rule.check(module, ctx), module)

    for path in bench_files:
        rel = _rel(path, root)
        try:
            raw = open(path, "rb").read()
        except OSError as e:
            findings.append(Finding(
                rule="bench-schema", path=rel, line=0,
                message=f"unreadable: {e}",
            ))
            continue
        for rule in rules:
            if rule.kind == "data":
                emit(rule.check_data(path, rel, raw, ctx))

    for rule in rules:
        if rule.kind == "project":
            emit(rule.check_project(ctx))

    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    reports.sort(key=lambda r: (r.path, r.line, r.rule))
    return Result(findings, reports, n_files=len(py_files) + len(bench_files))
