"""palint — the PAC repo's project-invariant static analyzer.

AST-based rules encode the invariants the architecture depends on
(compat-surface confinement, models↛kernels layering, jit purity,
Pallas BlockSpec/VMEM sanity, collective axis-name binding, the
storage-form no-f32-round-trip contract, benchmark-record schema).

Run ``python -m tools.palint`` from the repo root; see
``docs/LINTING.md`` for the rule catalog and suppression syntax.
"""

from tools.palint.engine import (  # noqa: F401
    Context,
    Finding,
    Report,
    Result,
    all_rules,
    run,
)

__version__ = "1.0"
