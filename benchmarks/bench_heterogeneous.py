"""Paper Fig. 12 — heterogeneous edge environment (Env. B) comparison.

PAC+ vs its heterogeneity-oblivious predecessor (PAC) vs cost models of
Asteroid (HPP + full-parameter FT) and HetPipe (inter-group DP +
intra-group PP + full FT, higher comm). 1-epoch and 3-epoch totals
(epochs ≥2 use the activation cache in PAC+/PAC only).

``--executed`` adds an *executed* row next to the modelled ones: the
ragged Env.B plan (10 periods over 3 uneven stages) runs for real
through the 1F1B SPMD pipeline on fake host devices (subprocess — the
device count must be forced before JAX initialises), reporting measured
ms/step beside the plan's modelled ms/minibatch. Different silicon, same
Plan — the point is that the modelled numbers now have an execution
path that can contradict them.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np

from benchmarks.common import row
from repro.configs import get_arch
from repro.core.planner import (
    HybridParallelismPlanner,
    JETSON_NANO_H,
    JETSON_NANO_L,
    JETSON_TX2_H,
    JETSON_TX2_L,
    model_layer_costs,
)

ENV_B = [JETSON_NANO_H, JETSON_NANO_L, JETSON_TX2_H, JETSON_TX2_L]
STEPS_PER_EPOCH = 50


def _epoch_time(plan):
    return plan.minibatch_latency * STEPS_PER_EPOCH


def _hetpipe_like(costs, devs, mbs, M):
    """HetPipe: straight PP inside virtual workers + async DP across them —
    modelled as the PP plan plus 2× inter-stage comm (asymmetric links) and
    full-model parameter sync."""
    from repro.core.planner import plan_pure_pp

    pp = plan_pure_pp(costs, devs, mbs, M)
    if pp is None:
        return None
    sync = 2.0 * sum(c.param_bytes for c in costs) / min(d.bandwidth for d in devs)
    return pp.minibatch_latency * 1.35 + sync / STEPS_PER_EPOCH


_EXECUTED_CHILD = textwrap.dedent(
    """
    from repro.compat import force_host_device_count
    force_host_device_count(4)
    # the ONE definition of the executed-plan workload lives in the example;
    # this bench only harvests its timings
    from examples.plan_edge_cluster import execute_winning_plan
    r = execute_winning_plan(N_STEPS)
    print(f"EXEC modelled_ms={r['modelled_ms']:.3f} "
          f"executed_ms={r['executed_ms']:.1f} compile_ms={r['compile_ms']:.0f} "
          f"stages={r['stages']} ragged={int(r['ragged'])} "
          f"periods={'/'.join(map(str, r['periods']))}")
    """
)


def executed_rows(n_steps: int = 3) -> list:
    """Run the ragged Env.B plan for real (subprocess, 4 fake host devices;
    the workload is ``examples.plan_edge_cluster.execute_winning_plan``)
    and report executed-vs-modelled latency rows."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root, env.get("PYTHONPATH", "")) if p
    )
    env["JAX_PLATFORMS"] = "cpu"
    code = f"N_STEPS = {n_steps}\n" + _EXECUTED_CHILD
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=1200, cwd=root,
    )
    if out.returncode != 0:
        raise RuntimeError(f"executed-plan child failed:\n{out.stderr[-3000:]}")
    line = next(l for l in out.stdout.splitlines() if l.startswith("EXEC "))
    return [row("fig12_executed_plan", 0.0, line[5:].replace(" ", ";"))]


def main(arch="bart-large-pac", executed: bool = False) -> list:
    cfg = get_arch(arch)
    out = []
    rows = {}

    pac_costs = model_layer_costs(cfg, "pac", seq_len=128)
    cached_costs = model_layer_costs(cfg, "pac_cached", seq_len=128)
    full_costs = model_layer_costs(cfg, "full", seq_len=128)

    pacp = HybridParallelismPlanner(pac_costs, ENV_B, 8, 4).plan()
    pac_homo = HybridParallelismPlanner(pac_costs, ENV_B, 8, 4, heterogeneity_aware=False).plan()
    cachedp = HybridParallelismPlanner(cached_costs, ENV_B, 8, 4).plan()
    asteroid = HybridParallelismPlanner(full_costs, ENV_B, 8, 4).plan()
    hetpipe_mb = _hetpipe_like(full_costs, ENV_B, 8, 4)

    e_pac = _epoch_time(pacp)
    e_cached = _epoch_time(cachedp)
    rows["pac+"] = (e_pac, e_pac + 2 * e_cached)
    e_homo = _epoch_time(pac_homo)
    rows["pac_homo"] = (e_homo, e_homo + 2 * _epoch_time(
        HybridParallelismPlanner(cached_costs, ENV_B, 8, 4, heterogeneity_aware=False).plan()
    ))
    e_ast = _epoch_time(asteroid)
    rows["asteroid"] = (e_ast, 3 * e_ast)
    if hetpipe_mb is not None:
        e_het = hetpipe_mb * STEPS_PER_EPOCH
        rows["hetpipe"] = (e_het, 3 * e_het)

    for name, (e1, e3) in rows.items():
        out.append(row(
            f"fig12_{name}", 0.0, f"epoch1_s={e1:.1f};epochs3_s={e3:.1f}",
        ))
    s1_ast = rows["asteroid"][0] / rows["pac+"][0]
    s3_ast = rows["asteroid"][1] / rows["pac+"][1]
    s1_het = rows.get("hetpipe", (np.nan,) * 2)[0] / rows["pac+"][0]
    s3_het = rows.get("hetpipe", (np.nan,) * 2)[1] / rows["pac+"][1]
    het_gain = 1 - rows["pac+"][0] / rows["pac_homo"][0]
    out.append(row(
        "fig12_claim", 0.0,
        f"speedup_vs_asteroid_1ep={s1_ast:.1f}x_3ep={s3_ast:.1f}x;"
        f"vs_hetpipe_1ep={s1_het:.1f}x_3ep={s3_het:.1f}x;"
        f"het_aware_gain={het_gain:.1%};"
        f"claim=2.9-9.7x (1ep), 6.9-14.7x (3ep), ≤35% het gain;"
        f"holds={s3_ast > s1_ast and s1_ast > 1.0}",
    ))
    if executed:
        out.extend(executed_rows())
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--executed", action="store_true",
                    help="also run the ragged Env.B plan for real on fake "
                         "host devices (subprocess)")
    args = ap.parse_args()
    main(executed=args.executed)  # row() prints as it goes
