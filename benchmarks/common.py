"""Shared helpers for the paper-table benchmarks.

Benchmarks run REDUCED-scale models on CPU (1 device): wall-times are
indicative ratios (the paper's Jetson absolute numbers are reproduced by
the planner's analytic device profiles), FLOPs/memory come from the same
trip-count-aware HLO cost model the roofline uses.
"""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze_compiled


def timeit(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-time per call (seconds), after jit warmup."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def hlo_cost_of(fn: Callable, *args):
    """(flops, bytes) from the compiled module of fn(*args)."""
    compiled = jax.jit(fn).lower(*args).compile()
    c = analyze_compiled(compiled)
    return c.flops, c.bytes


def mem_stats_of(fn: Callable, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return compiled.memory_analysis()


def row(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line)
    return line


def make_batch(cfg, B, S, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    batch = {}
    if cfg.frontend is not None:
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model)) * 0.3
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    return batch
