"""Paper Fig. 18 — activation cache benefit vs number of epochs.

Measured: epoch wall-time with and without the cache on the reduced
model; derived: latency reduction as epochs grow (paper: 39% at 2 epochs
→ 71% at 10 for T5-Large; 26–71% overall).
"""

import functools

import jax
import numpy as np

from benchmarks.common import make_batch, row, timeit
from repro.configs import get_arch
from repro.core import steps
from repro.core.activation_cache import ActivationCache
from repro.core.parallel_adapters import init_adapter
from repro.data import DataPipeline, SyntheticPersonalCorpus
from repro.models import backbone as bb
from repro.optim import adamw_init

B, S = 8, 32


def main(arch="bart-large-pac") -> list:
    cfg = get_arch(arch).reduced()
    corpus = SyntheticPersonalCorpus(cfg.vocab, S + 1, 32, seed=4)
    pipe = DataPipeline(corpus, global_batch=B, shuffle=False)
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    ap = init_adapter(jax.random.PRNGKey(1), cfg, r=8)
    opt = adamw_init(ap)
    out = []

    step_full = jax.jit(functools.partial(steps.pac_train_step, cfg=cfg, r=8))
    step_cached = jax.jit(functools.partial(steps.pac_cached_train_step, cfg=cfg, r=8))

    # warmup compiles
    b0batch = next(iter(pipe.epoch(0)))
    _, _, _, (b0, taps, bf) = step_full(bp, ap, opt, {k: v for k, v in b0batch.items() if k != "seq_ids"})
    cached_proto = {"b0": b0, "taps": taps, "b_final": bf, "labels": b0batch["labels"]}
    step_cached(bp, ap, opt, cached_proto)

    t_epoch1 = timeit(
        lambda: [step_full(bp, ap, opt, {k: v for k, v in bt.items() if k != "seq_ids"})[0]
                 for bt in pipe.epoch(0)],
        iters=2,
    )
    t_epochN = timeit(
        lambda: [step_cached(bp, ap, opt, cached_proto)[0] for _ in range(pipe.steps_per_epoch())],
        iters=2,
    )
    out.append(row("fig18_epoch1_s", t_epoch1 * 1e6, f"epoch_time_s={t_epoch1:.3f}"))
    out.append(row("fig18_epochN_s", t_epochN * 1e6, f"epoch_time_s={t_epochN:.3f}"))

    for n_epochs in (2, 3, 5, 10):
        no_cache = n_epochs * t_epoch1
        with_cache = t_epoch1 + (n_epochs - 1) * t_epochN
        red = 1 - with_cache / no_cache
        out.append(row(
            f"fig18_epochs_{n_epochs}", 0.0,
            f"latency_reduction={red:.2%}",
        ))
    red10 = 1 - (t_epoch1 + 9 * t_epochN) / (10 * t_epoch1)
    red2 = 1 - (t_epoch1 + t_epochN) / (2 * t_epoch1)
    out.append(row(
        "fig18_claim", 0.0,
        f"reduction_grows_with_epochs={red10 > red2};red2={red2:.2%};red10={red10:.2%};"
        f"claim=26-71%, growing;holds={red10 > red2 and red10 > 0.25}",
    ))

    # the functional cache round-trip (paper Fig. 11 redistribution),
    # b_final folded into the budgeted entries (cache v2)
    cache = ActivationCache(budget_bytes=1 << 30)
    cache.put_batch(list(b0batch["seq_ids"]), b0, taps, bf)
    got = cache.get_batch(list(b0batch["seq_ids"]), with_final=True)
    assert got is not None and len(got) == 3
    out.append(row("fig11_cache_roundtrip", 0.0, f"entries={len(cache)};hits={cache.hits}"))
    return out


if __name__ == "__main__":
    main()
