"""Serving decode-step benchmark: ref vs Pallas paged attention, per KV
page policy — writes ``BENCH_decode_step.json``.

One continuous-batching decode step (`repro.serve.decode.
paged_pac_decode_step`: B requests × B adapters against the shared KV
page pool) is timed with ``kernel_impl="ref"`` (gather-then-dense
oracle) and ``"pallas"`` (page-walking kernel, in-VMEM INT8 dequant) for
each KV storage policy, and the per-token serving KV footprint is
recorded alongside (``kv_bytes_per_token`` — the number the paged INT8
cache exists to shrink). Off-TPU the Pallas column runs the interpreter
— a correctness/traffic datapoint, not a speed claim; the
``pallas_interpret_mode`` flag in the JSON says which it was.

    PYTHONPATH=src python -m benchmarks.bench_decode [--quick]
"""

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.configs import get_arch
from repro.core.parallel_adapters import (
    gather_adapters,
    init_adapter,
    stack_adapters,
)
from repro.core.quantization import quantize_tree
from repro.kernels.cached_step import _auto_interpret
from repro.models import backbone as bb
from repro.serve import paging
from repro.serve.decode import paged_pac_decode_step, paged_prefill


def main(arch="internlm2-1.8b", B=4, S=24, page=8, quick=False,
         out_json="BENCH_decode_step.json") -> list:
    cfg = get_arch(arch).reduced()
    backbone = quantize_tree(
        bb.init_backbone(jax.random.PRNGKey(0), cfg), bits=8, min_size=1024)
    # two distinct adapters shared across the batch — the multi-tenant shape
    bank = stack_adapters([
        init_adapter(jax.random.PRNGKey(1), cfg, r=8),
        init_adapter(jax.random.PRNGKey(2), cfg, r=8),
    ])
    abatch = gather_adapters(bank, jnp.arange(B) % 2)
    max_len = S + page  # headroom for the timed decode token
    max_pages = -(-max_len // page)
    n_pages = B * max_pages + 1
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    tok = jnp.full((B, 1), 7, jnp.int32)
    iters = 2 if quick else 5
    out, results = [], {}

    for policy in ("f32", "bf16", "int8"):
        pools = paging.init_pools(cfg, n_pages, page, B, policy)
        alloc = paging.PageAllocator(n_pages)
        table = paging.PageTable(alloc, page, max_pages)
        for i in range(B):
            table.open(i, S)
        bt0, lens0 = table.dense(range(B))
        _, pools, acache = paged_prefill(
            backbone, abatch, prompt, jnp.asarray(lens0), pools,
            jnp.asarray(bt0), cfg=cfg, max_len=max_len, r=8)
        for i in range(B):
            table.extend_to(i, S + 1)
        bt, lengths = table.dense(range(B))
        bt, lengths = jnp.asarray(bt), jnp.asarray(lengths)
        rec = {
            "kv_bytes_per_token": paging.kv_bytes_per_token(cfg, policy),
            "pool_mb": round(sum(
                t.size * t.dtype.itemsize for t in jax.tree.leaves(pools)
            ) / 2**20, 3),
        }
        logits = {}
        for impl in ("ref", "pallas"):
            step = jax.jit(functools.partial(
                paged_pac_decode_step, cfg=cfg, r=8, kernel_impl=impl))
            t = timeit(step, backbone, abatch, tok, pools, bt, lengths,
                       acache, iters=iters)
            logits[impl] = np.asarray(
                step(backbone, abatch, tok, pools, bt, lengths, acache)[0])
            rec[f"{impl}_ms"] = round(t * 1e3, 3)
            rec[f"{impl}_tokens_per_s"] = round(B / t, 2)
        rec["ratio_pallas_over_ref"] = round(rec["pallas_ms"] / rec["ref_ms"], 3)
        rec["logits_abs_diff"] = float(
            np.max(np.abs(logits["ref"] - logits["pallas"])))
        results[policy] = rec
        out.append(row(
            f"decode_step_{policy}", rec["pallas_ms"] * 1e3 / B,
            f"ref_ms={rec['ref_ms']};pallas_ms={rec['pallas_ms']};"
            f"kv_bytes_per_token={rec['kv_bytes_per_token']};"
            f"logits_diff={rec['logits_abs_diff']:.2e}",
        ))

    payload = {
        "arch": cfg.name, "batch": B, "seq": S, "page_size": page,
        "backend": jax.default_backend(),
        "pallas_interpret_mode": _auto_interpret(None),
        "policies": results,
    }
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"wrote {out_json}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=24)
    ap.add_argument("--page", type=int, default=8)
    ap.add_argument("--quick", action="store_true",
                    help="fewer timing iters (CI smoke)")
    a = ap.parse_args()
    main(arch=a.arch, B=a.batch, S=a.seq, page=a.page, quick=a.quick)
