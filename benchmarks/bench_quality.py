"""Paper Table VI — fine-tuned quality parity of Parallel Adapters.

Synthetic-personal-corpus analogue: fine-tune the reduced backbone on a
learnable sequence task with each technique for the same step budget and
compare final eval losses. Claim: PAC+ within noise of full/LoRA/Adapters
(paper: |Δ| ≤ 0.37 points).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.configs import get_arch
from repro.core import steps
from repro.core.init_methods import pruning_init
from repro.core.parallel_adapters import init_adapter
from repro.core.peft import init_houlsby, init_lora
from repro.data import SyntheticPersonalCorpus
from repro.models import backbone as bb
from repro.optim import adamw_init

STEPS = 60
B, S = 8, 32


def _eval_loss(logits_fn, batches):
    losses = []
    for b in batches:
        lg = logits_fn(b)
        losses.append(float(bb.cross_entropy(lg, b["labels"])))
    return float(np.mean(losses))


def main(arch="internlm2-1.8b", steps_budget=STEPS) -> list:
    cfg = get_arch(arch).reduced()
    corpus = SyntheticPersonalCorpus(cfg.vocab, S + 1, 64, seed=1)
    # train on samples 0..47, evaluate on the held-out 48..63 — otherwise
    # full FT memorizes the eval batch at this reduced scale and the
    # "quality parity" comparison measures memorization capacity instead
    train = [corpus.batch(np.arange(i * B, (i + 1) * B) % 48) for i in range(8)]
    evalb = [corpus.batch(np.arange(48, 48 + B)), corpus.batch(np.arange(56, 56 + B))]
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    out = []
    results = {}

    def run(name, params, step_fn, logits_fn, lr=3e-3):
        opt = adamw_init(params)
        jstep = jax.jit(step_fn)
        p = params
        for i in range(steps_budget):
            loss, p, opt = jstep(p, opt, train[i % len(train)])
        final = _eval_loss(lambda b: logits_fn(p, b), evalb)
        results[name] = final
        out.append(row(f"table6_quality_{name}", 0.0, f"eval_loss={final:.4f}"))
        return p

    # full
    run("full", bp,
        lambda p, o, b: steps.full_train_step(p, o, b, cfg=cfg, lr=1e-3)[:3],
        lambda p, b: bb.backbone_logits(p, cfg, b))
    # lora
    lp = init_lora(jax.random.PRNGKey(1), cfg)
    from repro.core import peft
    run("lora", lp,
        lambda p, o, b: steps.lora_train_step(bp, p, o, b, cfg=cfg)[:3],
        lambda p, b: peft.lora_logits(bp, p, cfg, b))
    # houlsby adapters
    hp = init_houlsby(jax.random.PRNGKey(2), cfg)
    run("adapters", hp,
        lambda p, o, b: steps.houlsby_train_step(bp, p, o, b, cfg=cfg)[:3],
        lambda p, b: peft.houlsby_logits(bp, p, cfg, b))
    # PAC+ (pruning init, as deployed)
    ap = pruning_init(jax.random.PRNGKey(3), bp, cfg, r=4)

    def pac_step(p, o, b):
        loss, p2, o2, _ = steps.pac_train_step(bp, p, o, b, cfg=cfg, r=4)
        return loss, p2, o2

    def pac_logits_fn(p, b):
        x, pos = bb.embed_inputs(bp, cfg, b)
        bf, taps = bb.backbone_forward(bp, cfg, b, collect_taps=True)
        from repro.core.parallel_adapters import pac_logits
        return pac_logits(bp, p, cfg, x, taps, bf, pos, r=4)

    run("pac", ap, pac_step, pac_logits_fn)

    base_mean = np.mean([results["full"], results["lora"], results["adapters"]])
    diff = results["pac"] - base_mean
    out.append(row(
        "table6_claim", 0.0,
        f"pac_minus_mean={diff:+.4f};claim=|Δ|small;holds={abs(diff) < 0.5}",
    ))
    return out


if __name__ == "__main__":
    main()
