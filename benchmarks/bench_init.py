"""Paper Fig. 14 — adapter weight-initialization strategies.

Random-Gaussian vs zero vs structural-pruning vs distillation init:
iterations to reach a target train loss. Claim: pruning/distillation
reach the target in ~25–35% fewer iterations.
"""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.compat import tree_map
from repro.configs import get_arch
from repro.core import steps
from repro.core.init_methods import distillation_init, pruning_init
from repro.core.parallel_adapters import init_adapter
from repro.data import SyntheticPersonalCorpus
from repro.models import backbone as bb
from repro.optim import adamw_init

B, S, MAX_STEPS, SEEDS = 8, 32, 150, 3


def _curve(bp, cfg, ap, train):
    opt = adamw_init(ap)

    @jax.jit
    def step(p, o, b):
        loss, p2, o2, _ = steps.pac_train_step(bp, p, o, b, cfg=cfg, r=4)
        return loss, p2, o2

    losses = []
    for i in range(MAX_STEPS):
        loss, ap, opt = step(ap, opt, train[i % len(train)])
        losses.append(float(loss))
    return losses


def _smooth(losses, w=8):
    c = np.convolve(losses, np.ones(w) / w, mode="valid")
    return c


def _steps_to(losses, target):
    for i, l in enumerate(_smooth(losses)):
        if l <= target:
            return i + 1
    return None


def main(arch="internlm2-1.8b") -> list:
    cfg = get_arch(arch).reduced()
    corpus = SyntheticPersonalCorpus(cfg.vocab, S + 1, 64, seed=3)
    train = [corpus.batch(np.arange(i * B, (i + 1) * B) % 64) for i in range(8)]
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    out = []

    # average smoothed curves over seeds — a single seed at this reduced
    # scale is too noisy to rank init strategies (paper Fig. 14 is
    # BART/T5-Large over ~600 iterations)
    curves = {k: [] for k in ("gaussian", "zero", "pruning", "distill")}
    for seed in range(SEEDS):
        key = jax.random.PRNGKey(10 + seed)
        inits = {
            "gaussian": init_adapter(key, cfg, r=4),
            "zero": tree_map(jnp.zeros_like, init_adapter(key, cfg, r=4)),
            "pruning": pruning_init(key, bp, cfg, r=4),
            "distill": distillation_init(key, bp, cfg, train[:2], r=4, steps=10),
        }
        for k, v in inits.items():
            curves[k].append(_curve(bp, cfg, v, train))
    mean_curves = {k: np.mean(np.array(v), axis=0) for k, v in curves.items()}
    # common target: the worst final smoothed loss among the non-zero
    # inits — every contender can reach it, so steps-to-target is defined
    finals = {k: _smooth(c)[-1] for k, c in mean_curves.items()}
    target = max(v for k, v in finals.items() if k != "zero")
    res = {}
    for k, c in mean_curves.items():
        n = _steps_to(c, target)
        res[k] = n
        out.append(row(
            f"fig14_init_{k}", 0.0,
            f"steps_to_target={n};final_loss={float(c[-1]):.4f}",
        ))
    big = MAX_STEPS * 10
    ok = min(res["pruning"] or big, res["distill"] or big) < (res["gaussian"] or big)
    out.append(row(
        "fig14_claim", 0.0,
        f"claim=pruning/distill converge faster than gaussian;"
        f"gaussian={res['gaussian']};pruning={res['pruning']};"
        f"distill={res['distill']};holds={ok}",
    ))
    return out


if __name__ == "__main__":
    main()
