"""Aggregate results/dryrun/*.json into the §Roofline markdown table."""

import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir="results/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs, mesh="16x16") -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 9))
    out = [
        f"### §Roofline-table (mesh {mesh}, per-chip terms, seconds)",
        "",
        "| arch | shape | tech | note | compute | memory | collective | bottleneck | useful % |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['technique']} | {r['note']} "
            f"| {r['t_compute']:.4f} | {r['t_memory']:.4f} | {r['t_collective']:.4f} "
            f"| **{r['bottleneck']}** | {100*r['useful_compute_ratio']:.1f} |"
        )
    return "\n".join(out)


def main() -> None:
    import sys

    recs = load(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    if not recs:
        print("roofline_table,0.0,no-dryrun-results-yet")
        return
    for mesh in ("16x16", "2x16x16"):
        t = table(recs, mesh)
        if t.count("\n") > 4:
            print(t)
            print()
    n_ok = len(recs)
    print(f"roofline_table,0.0,cases={n_ok}")


if __name__ == "__main__":
    main()
