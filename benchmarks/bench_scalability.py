"""Paper Figs. 16/17 — scalability of DP / PP / HP and device grouping.

Planner + 1F1B discrete-event simulation over 2..8 Jetson Nano-H devices,
three paper models, Parallel Adapters everywhere (the paper's setting for
this figure). Claims: DP OOMs on the larger models; HP throughput ≥ PP
(paper: +39.5–84.8%).
"""

import numpy as np

from benchmarks.common import row
from repro.configs import get_arch
from repro.core.pipeline import simulate_plan
from repro.core.planner import (
    HybridParallelismPlanner,
    JETSON_NANO_H,
    model_layer_costs,
    plan_pure_dp,
    plan_pure_pp,
)


def main() -> list:
    out = []
    gains = []
    for arch in ("t5-base-pac", "bart-large-pac", "t5-large-pac"):
        cfg = get_arch(arch)
        costs = model_layer_costs(cfg, "pac", seq_len=128)
        for n in (2, 4, 6, 8):
            devs = [JETSON_NANO_H] * n
            mbs = n  # batch size = device count (paper's setting)
            hp = HybridParallelismPlanner(costs, devs, mbs, 4).plan()
            dp = plan_pure_dp(costs, devs, mbs, 4)
            pp = plan_pure_pp(costs, devs, mbs, 4)
            thr = lambda p: (mbs * 4) / p.minibatch_latency if p else 0.0
            sim = simulate_plan(hp)
            gain = (thr(hp) / thr(pp) - 1) if pp else float("nan")
            if pp:
                gains.append(gain)
            grouping = "|".join(
                f"L{s.layer_start}-{s.layer_end}x{len(s.devices)}" for s in hp.stages
            )
            out.append(row(
                f"fig16_{arch}_n{n}", 0.0,
                f"hp_thr={thr(hp):.2f};dp_thr={'OOM' if dp is None else f'{thr(dp):.2f}'};"
                f"pp_thr={'OOM' if pp is None else f'{thr(pp):.2f}'};"
                f"hp_vs_pp={gain:+.1%};bubble={sim['bubble_fraction']:.2%};"
                f"grouping={grouping}",
            ))
    out.append(row(
        "fig16_claim", 0.0,
        f"hp_ge_pp_everywhere={all(g >= -1e-9 for g in gains)};max_gain={max(gains):.1%}",
    ))
    return out


if __name__ == "__main__":
    main()
