"""Paper Table VII + Fig. 15 — fine-tuning with a quantized backbone.

FP32/INT8/INT4 storage for the frozen backbone; PAC+ adapter stays FP32
(the paper's mixed-precision Fig. 8). Checks: quality degrades gracefully
with precision, memory drops ~4×/~8× on the backbone.
"""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.compat import tree_map
from repro.configs import get_arch
from repro.core import steps
from repro.core.init_methods import pruning_init
from repro.core.quantization import quantize_tree, tree_storage_bytes
from repro.data import SyntheticPersonalCorpus
from repro.models import backbone as bb
from repro.optim import adamw_init

B, S, STEPS = 8, 32, 50


def main(arch="internlm2-1.8b") -> list:
    cfg = get_arch(arch).reduced()
    corpus = SyntheticPersonalCorpus(cfg.vocab, S + 1, 64, seed=2)
    train = [corpus.batch(np.arange(i * B, (i + 1) * B) % 64) for i in range(8)]
    evalb = corpus.batch(np.arange(48, 48 + B))
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    f32_bytes = tree_storage_bytes(bp)
    out = []
    results = {}

    for precision in ("fp32", "bf16", "int8", "int4"):
        if precision == "fp32":
            bq = bp
        elif precision == "bf16":
            # paper Table VII's FP16 row; bf16 is the TPU-native half type
            bq = tree_map(
                lambda t: t.astype(jnp.bfloat16) if t.dtype == jnp.float32 else t, bp
            )
        else:
            bq = quantize_tree(bp, bits=int(precision[3:]), min_size=1024)
        ap = pruning_init(jax.random.PRNGKey(1), bp, cfg, r=4)
        opt = adamw_init(ap)

        @jax.jit
        def step(p, o, b, bq=bq):
            loss, p2, o2, _ = steps.pac_train_step(bq, p, o, b, cfg=cfg, r=4)
            return loss, p2, o2

        for i in range(STEPS):
            loss, ap, opt = step(ap, opt, train[i % len(train)])
        x, pos = bb.embed_inputs(bq, cfg, evalb)
        bf, taps = bb.backbone_forward(bq, cfg, evalb, collect_taps=True)
        from repro.core.parallel_adapters import pac_logits
        lg = pac_logits(bq, ap, cfg, x, taps, bf, pos, r=4)
        ev = float(bb.cross_entropy(lg, evalb["labels"]))
        results[precision] = ev
        mem = tree_storage_bytes(bq)
        out.append(row(
            f"table7_quant_{precision}", 0.0,
            f"eval_loss={ev:.4f};backbone_MB={mem/2**20:.1f};vs_fp32_mem={f32_bytes/mem:.2f}x",
        ))

    graceful = (
        results["bf16"] <= results["fp32"] + 0.3
        and results["int8"] <= results["fp32"] + 0.5
        and results["int4"] <= results["fp32"] + 1.0
    )
    out.append(row(
        "table7_claim", 0.0,
        f"fp32={results['fp32']:.3f};bf16={results['bf16']:.3f};"
        f"int8={results['int8']:.3f};int4={results['int4']:.3f};"
        f"claim=graceful_degradation;holds={graceful}",
    ))
    return out


if __name__ == "__main__":
    main()
