"""Paper Table I / Fig. 13b / Fig. 15 — memory footprint breakdown.

Weights / activations(+opt state) / gradients per technique, from the
analytic layer-cost model (the same accounting the paper's Table I uses)
plus compiled peak-temp measurements on the reduced model.
"""

import functools

import jax

from benchmarks.common import make_batch, mem_stats_of, row
from repro.configs import get_arch
from repro.core import steps
from repro.core.parallel_adapters import adapter_param_count, init_adapter
from repro.core.peft import init_lora
from repro.core.planner import model_layer_costs
from repro.models import backbone as bb
from repro.optim import adamw_init


def analytic_breakdown(cfg, technique, seq=128, batch=16, quant_bits=None):
    costs = model_layer_costs(cfg, technique, seq_len=seq, quant_bits=quant_bits)
    weights = sum(c.param_bytes for c in costs) + cfg.vocab * cfg.d_model * 4 * 2
    grads = sum(c.trainable_bytes for c in costs)
    # "Activations contain the intermediate results and optimizer states"
    # (Table I); the paper's T5 setup is Adafactor-like, so opt state ≈ 1×
    # trainable bytes, not Adam's 2×.
    acts = sum(c.resident_act_bytes for c in costs) * batch + grads
    return {"weights": weights, "activations": acts, "grads": grads,
            "total": weights + acts + grads}


def main() -> list:
    out = []
    cfg = get_arch("t5-large-pac")
    rows = {}
    for tech in ("full", "lora", "adapters", "pac", "pac_cached"):
        b = analytic_breakdown(cfg, tech)
        rows[tech] = b
        out.append(row(
            f"table1_memory_{tech}", 0.0,
            f"weights_GB={b['weights']/2**30:.2f};acts_GB={b['activations']/2**30:.2f};"
            f"grads_GB={b['grads']/2**30:.2f};total_GB={b['total']/2**30:.2f}",
        ))
    peft_save = 1 - min(rows["lora"]["total"], rows["adapters"]["total"]) / rows["full"]["total"]
    pac_save = 1 - rows["pac"]["total"] / rows["full"]["total"]
    cache_save = 1 - rows["pac_cached"]["total"] / rows["full"]["total"]
    out.append(row(
        "table1_claim", 0.0,
        f"peft_mem_saving={peft_save:.2%};pac={pac_save:.2%};pac_cached={cache_save:.2%};"
        f"claim=peft≈36%,cache≤88%;holds={0.15 < peft_save < 0.5 < pac_save < cache_save}",
    ))

    # Fig. 15: quantized backbone
    for bits in (8, 4):
        b = analytic_breakdown(cfg, "pac", quant_bits=bits)
        save = 1 - b["total"] / rows["full"]["total"]
        out.append(row(
            f"fig15_memory_pac_int{bits}", 0.0,
            f"total_GB={b['total']/2**30:.2f};saving_vs_full={save:.2%}",
        ))

    # measured peak temp on the reduced model (compiled)
    rcfg = get_arch("t5-base-pac").reduced()
    bp = bb.init_backbone(jax.random.PRNGKey(0), rcfg)
    batch = make_batch(rcfg, 4, 64)
    ms_full = mem_stats_of(
        functools.partial(steps.full_train_step, cfg=rcfg), bp, adamw_init(bp), batch
    )
    ap = init_adapter(jax.random.PRNGKey(1), rcfg, r=8)
    ms_pac = mem_stats_of(
        functools.partial(steps.pac_train_step, cfg=rcfg, r=8), bp, ap, adamw_init(ap), batch
    )
    ratio = ms_pac.temp_size_in_bytes / max(ms_full.temp_size_in_bytes, 1)
    out.append(row(
        "fig13b_measured_temp", 0.0,
        f"full_MB={ms_full.temp_size_in_bytes/2**20:.1f};"
        f"pac_MB={ms_pac.temp_size_in_bytes/2**20:.1f};pac_vs_full={ratio:.3f}",
    ))
    return out


if __name__ == "__main__":
    main()
