"""Paper Fig. 3 — FLOPs of fine-tuning techniques vs inference.

Claim under test: Adapters/LoRA reduce training FLOPs only ~30% vs full
fine-tuning (they still backprop through the backbone), while Parallel
Adapters cut the backward pass ~92% and the activation cache removes the
backbone forward entirely.
"""

import functools

import jax

from benchmarks.common import hlo_cost_of, make_batch, row
from repro.configs import get_arch
from repro.core import steps
from repro.core.parallel_adapters import init_adapter
from repro.core.peft import init_houlsby, init_lora
from repro.models import backbone as bb
from repro.optim import adamw_init


def main(arch="t5-base-pac") -> list:
    cfg = get_arch(arch).reduced()
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, B=4, S=64)
    out = []

    # inference reference
    f_inf, _ = hlo_cost_of(lambda p, b: bb.backbone_logits(p, cfg, b), bp, batch)

    # full FT
    opt_f = adamw_init(bp)
    f_full, _ = hlo_cost_of(
        functools.partial(steps.full_train_step, cfg=cfg), bp, opt_f, batch
    )
    # LoRA
    lp = init_lora(jax.random.PRNGKey(1), cfg)
    f_lora, _ = hlo_cost_of(
        functools.partial(steps.lora_train_step, cfg=cfg), bp, lp, adamw_init(lp), batch
    )
    # Houlsby adapters
    hp = init_houlsby(jax.random.PRNGKey(2), cfg)
    f_ad, _ = hlo_cost_of(
        functools.partial(steps.houlsby_train_step, cfg=cfg), bp, hp, adamw_init(hp), batch
    )
    # PAC+ (parallel adapters) and cached
    ap = init_adapter(jax.random.PRNGKey(3), cfg, r=8)
    f_pac, _ = hlo_cost_of(
        functools.partial(steps.pac_train_step, cfg=cfg, r=8), bp, ap, adamw_init(ap), batch
    )
    _, _, _, (b0, taps, bf) = steps.pac_train_step(bp, ap, adamw_init(ap), batch, cfg=cfg, r=8)
    cached = {"b0": b0, "taps": taps, "b_final": bf, "labels": batch["labels"]}
    f_cached, _ = hlo_cost_of(
        functools.partial(steps.pac_cached_train_step, cfg=cfg, r=8),
        bp, ap, adamw_init(ap), cached,
    )

    for name, f in [
        ("inference", f_inf), ("full", f_full), ("lora", f_lora),
        ("adapters", f_ad), ("pac", f_pac), ("pac_cached", f_cached),
    ]:
        out.append(row(f"fig3_flops_{name}", 0.0, f"GFLOP={f/1e9:.3f};vs_full={f/f_full:.3f}"))

    peft_saving = 1 - min(f_lora, f_ad) / f_full
    pac_saving = 1 - f_pac / f_full
    out.append(row(
        "fig3_claim", 0.0,
        f"peft_flop_saving={peft_saving:.2%};pac_flop_saving={pac_saving:.2%};"
        f"claim=peft≤~35% pac≫peft;holds={peft_saving < 0.45 and pac_saving > peft_saving}",
    ))
    return out


if __name__ == "__main__":
    main()
