"""Paper Table V / Fig. 13a — per-sample training time by technique.

Measured wall-time on the reduced model (CPU): the paper's claim is
relative (PAC+ cuts per-sample time 32–56% vs baselines without cache,
up to 96% with cache) — we check the same ratios.
"""

import functools

import jax

from benchmarks.common import make_batch, row, timeit
from repro.configs import get_arch
from repro.core import steps
from repro.core.parallel_adapters import init_adapter
from repro.core.peft import init_houlsby, init_lora
from repro.models import backbone as bb
from repro.optim import adamw_init


def main(arch="t5-base-pac") -> list:
    cfg = get_arch(arch).reduced()
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    B, S = 8, 64
    batch = make_batch(cfg, B, S)
    out = []

    t_full = timeit(
        jax.jit(functools.partial(steps.full_train_step, cfg=cfg)), bp, adamw_init(bp), batch
    )
    lp = init_lora(jax.random.PRNGKey(1), cfg)
    t_lora = timeit(
        jax.jit(functools.partial(steps.lora_train_step, cfg=cfg)), bp, lp, adamw_init(lp), batch
    )
    hp = init_houlsby(jax.random.PRNGKey(2), cfg)
    t_ad = timeit(
        jax.jit(functools.partial(steps.houlsby_train_step, cfg=cfg)), bp, hp, adamw_init(hp), batch
    )
    ap = init_adapter(jax.random.PRNGKey(3), cfg, r=8)
    t_pac = timeit(
        jax.jit(functools.partial(steps.pac_train_step, cfg=cfg, r=8)), bp, ap, adamw_init(ap), batch
    )
    _, _, _, (b0, taps, bf) = steps.pac_train_step(bp, ap, adamw_init(ap), batch, cfg=cfg, r=8)
    cached = {"b0": b0, "taps": taps, "b_final": bf, "labels": batch["labels"]}
    t_cached = timeit(
        jax.jit(functools.partial(steps.pac_cached_train_step, cfg=cfg, r=8)),
        bp, ap, adamw_init(ap), cached,
    )

    for name, t in [("full", t_full), ("lora", t_lora), ("adapters", t_ad),
                    ("pac", t_pac), ("pac_cached", t_cached)]:
        out.append(row(
            f"fig13a_step_time_{name}", t * 1e6 / B,
            f"per_sample_ms={t*1e3/B:.2f};speedup_vs_full={t_full/t:.2f}x",
        ))
    red = 1 - t_pac / min(t_full, t_lora, t_ad)
    red_c = 1 - t_cached / min(t_full, t_lora, t_ad)
    out.append(row(
        "fig13a_claim", 0.0,
        f"pac_time_saving={red:.2%};cached_saving={red_c:.2%};"
        f"claim=32-56% (96% cached);holds={red > 0.15 and red_c > red}",
    ))
    return out


if __name__ == "__main__":
    main()
