"""Paper Table V / Fig. 13a — per-sample training time by technique.

Measured wall-time on the reduced model (CPU): the paper's claim is
relative (PAC+ cuts per-sample time 32–56% vs baselines without cache,
up to 96% with cache) — we check the same ratios.

``--dp N --stages S`` switches to the distributed mode (Fig. 10/11): the
hybrid DP×PP epoch-1 step and the pure-DP cached step are timed on an
emulated (dp, stage) host-device mesh against the single-device step.
Run as ``python -m benchmarks.bench_step_time --dp 2 --stages 2`` (own
process: the device count locks at backend init).

``--kernels`` benchmarks the cached-epoch fast path: the ref (dense jnp)
vs Pallas (fused dequant×adapter + blockwise CE) cached step, per cache
compression policy, and writes ``BENCH_cached_step.json`` so the perf
trajectory has datapoints. Off-TPU the Pallas numbers are *interpreter
mode* — a correctness/traffic datapoint, not a speed claim; rerun on TPU
hardware for the real comparison.

``--epoch1-kernels`` does the same for the *epoch-1* step, now that the
OpSet dispatch (``repro.core.opset``) routes the frozen forward through
the quantized kernels: ref vs pallas stage timing (frozen forward with
tap emission, then the full PAC+ train step) on an INT8 backbone, plus a
(bm, bn, bk) block-size autotune sweep of ``quant_matmul`` on a
representative projection shape. Writes ``BENCH_epoch1_step.json``; the
``pallas_interpret_mode`` flag in the JSON says whether the Pallas
columns ran the interpreter (CPU CI) or the real TPU backend — never
read interpret-mode ratios as speed claims.
"""

import functools
import json

import jax
import jax.numpy as jnp

from benchmarks.common import make_batch, row, timeit
from repro.configs import get_arch
from repro.core import steps
from repro.core.parallel_adapters import init_adapter
from repro.core.peft import init_houlsby, init_lora
from repro.models import backbone as bb
from repro.optim import adamw_init


def main(arch="t5-base-pac") -> list:
    cfg = get_arch(arch).reduced()
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    B, S = 8, 64
    batch = make_batch(cfg, B, S)
    out = []

    t_full = timeit(
        jax.jit(functools.partial(steps.full_train_step, cfg=cfg)), bp, adamw_init(bp), batch
    )
    lp = init_lora(jax.random.PRNGKey(1), cfg)
    t_lora = timeit(
        jax.jit(functools.partial(steps.lora_train_step, cfg=cfg)), bp, lp, adamw_init(lp), batch
    )
    hp = init_houlsby(jax.random.PRNGKey(2), cfg)
    t_ad = timeit(
        jax.jit(functools.partial(steps.houlsby_train_step, cfg=cfg)), bp, hp, adamw_init(hp), batch
    )
    ap = init_adapter(jax.random.PRNGKey(3), cfg, r=8)
    t_pac = timeit(
        jax.jit(functools.partial(steps.pac_train_step, cfg=cfg, r=8)), bp, ap, adamw_init(ap), batch
    )
    _, _, _, (b0, taps, bf) = steps.pac_train_step(bp, ap, adamw_init(ap), batch, cfg=cfg, r=8)
    cached = {"b0": b0, "taps": taps, "b_final": bf, "labels": batch["labels"]}
    t_cached = timeit(
        jax.jit(functools.partial(steps.pac_cached_train_step, cfg=cfg, r=8)),
        bp, ap, adamw_init(ap), cached,
    )

    for name, t in [("full", t_full), ("lora", t_lora), ("adapters", t_ad),
                    ("pac", t_pac), ("pac_cached", t_cached)]:
        out.append(row(
            f"fig13a_step_time_{name}", t * 1e6 / B,
            f"per_sample_ms={t*1e3/B:.2f};speedup_vs_full={t_full/t:.2f}x",
        ))
    red = 1 - t_pac / min(t_full, t_lora, t_ad)
    red_c = 1 - t_cached / min(t_full, t_lora, t_ad)
    out.append(row(
        "fig13a_claim", 0.0,
        f"pac_time_saving={red:.2%};cached_saving={red_c:.2%};"
        f"claim=32-56% (96% cached);holds={red > 0.15 and red_c > red}",
    ))

    # Activation-cache v2: storage + cached-step time per compression
    # policy, with the decompress/reassemble path on the clock (what a
    # cached epoch actually pays per step without the prefetcher)
    from repro.core.activation_cache import ActivationCache

    opt_a = adamw_init(ap)
    stepN = jax.jit(functools.partial(steps.pac_cached_train_step, cfg=cfg, r=8))
    ids = list(range(B))
    for policy in ("f32", "bf16", "int8"):
        cache = ActivationCache(budget_bytes=1 << 30, compress=policy)
        cache.put_batch(ids, b0, taps, bf)

        def cached_from_cache():
            cb0, ctaps, cbf = cache.get_batch(ids, with_final=True, dtype=None)
            return stepN(bp, ap, opt_a, {
                "b0": jnp.asarray(cb0), "taps": jnp.asarray(ctaps),
                "b_final": jnp.asarray(cbf), "labels": batch["labels"],
            })

        t = timeit(cached_from_cache)
        out.append(row(
            f"cachev2_step_time_{policy}", t * 1e6 / B,
            f"cache_mb={cache.nbytes/2**20:.2f};"
            f"per_seq_kb={cache.nbytes/B/1024:.1f};cached_step_ms={t*1e3:.2f}",
        ))
    return out


def main_kernels(arch="t5-base-pac", B=8, S=64, out_json="BENCH_cached_step.json") -> list:
    """Cached-epoch step: ref vs Pallas kernels, per cache policy.

    Times the jitted ``pac_cached_train_step`` with ``kernel_impl="ref"``
    (host-decompressed f32 entries — the pre-kernel path) against
    ``kernel_impl="pallas"`` fed *storage-form* entries
    (``get_batch(compressed=True)``: int8 payload+scales / bf16), and
    records both plus the per-batch device-transfer bytes in
    ``out_json``. On CPU the Pallas columns run the interpreter —
    correctness-priced, not speed-priced (the JSON records the backend).
    """
    import jax

    from repro.core.activation_cache import ActivationCache
    from repro.kernels.cached_step import _auto_interpret

    cfg = get_arch(arch).reduced()
    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    ap = init_adapter(jax.random.PRNGKey(3), cfg, r=8)
    opt = adamw_init(ap)
    batch = make_batch(cfg, B, S)
    _, _, _, (b0, taps, bf) = steps.pac_train_step(bp, ap, opt, batch, cfg=cfg, r=8)
    ids = list(range(B))
    out, results = [], {}

    step_ref = jax.jit(functools.partial(
        steps.pac_cached_train_step, cfg=cfg, r=8, kernel_impl="ref"))
    step_pal = jax.jit(functools.partial(
        steps.pac_cached_train_step, cfg=cfg, r=8, kernel_impl="pallas"))

    def entry_bytes(tree):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))

    for policy in ("f32", "bf16", "int8"):
        cache = ActivationCache(budget_bytes=1 << 30, compress=policy)
        cache.put_batch(ids, b0, taps, bf)
        # dtype=None is the pre-kernel trainer path: bf16 ships compressed
        # and upcasts in-step, int8 dequantizes on the host to f32
        plain = cache.get_batch(ids, with_final=True, dtype=None)
        comp = cache.get_batch(ids, with_final=True, compressed=True)

        def as_cached(hit):
            cb0, ct, cbf = (jax.tree.map(jnp.asarray, h) for h in hit)
            return {"b0": cb0, "taps": ct, "b_final": cbf,
                    "labels": batch["labels"]}

        cached_ref, cached_pal = as_cached(plain), as_cached(comp)
        t_ref = timeit(step_ref, bp, ap, opt, cached_ref)
        t_pal = timeit(step_pal, bp, ap, opt, cached_pal)
        l_ref = float(step_ref(bp, ap, opt, cached_ref)[0])
        l_pal = float(step_pal(bp, ap, opt, cached_pal)[0])
        acts = {k: cached_pal[k] for k in ("b0", "taps", "b_final")}
        results[policy] = {
            "ref_ms": round(t_ref * 1e3, 3),
            "pallas_ms": round(t_pal * 1e3, 3),
            "ratio_pallas_over_ref": round(t_pal / t_ref, 3),
            "cache_mb": round(cache.nbytes / 2**20, 3),
            "h2d_bytes_per_batch": entry_bytes(acts),
            "loss_ref": l_ref,
            "loss_pallas": l_pal,
            "loss_abs_diff": abs(l_ref - l_pal),
        }
        out.append(row(
            f"cached_step_kernels_{policy}", t_pal * 1e6 / B,
            f"ref_ms={t_ref*1e3:.2f};pallas_ms={t_pal*1e3:.2f};"
            f"h2d_kb={entry_bytes(acts)/1024:.0f};"
            f"loss_diff={abs(l_ref-l_pal):.2e}",
        ))

    payload = {
        "arch": cfg.name, "batch": B, "seq": S,
        "backend": jax.default_backend(),
        "pallas_interpret_mode": _auto_interpret(None),
        "policies": results,
    }
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"wrote {out_json}")
    return out


def main_epoch1_kernels(arch="t5-base-pac", B=8, S=64,
                        out_json="BENCH_epoch1_step.json") -> list:
    """Epoch-1 step: ref vs pallas OpSet on an INT8 backbone, plus a
    quant_matmul block-size autotune sweep.

    Stage timing per impl: the frozen forward alone (embed + blocks +
    tap emission — what the OpSet dispatch governs) and the full PAC+
    train step (forward + adapter grads + update). The pallas leg emits
    int8 storage-form taps at the tap site; the ref leg is the dense
    oracle with f32 taps. The autotune sweep times ``quant_matmul`` over
    the (bm, bn, bk) grid on one representative projection tile and
    records the fastest block config. ``pallas_interpret_mode`` in the
    JSON marks interpreter-mode (off-TPU) numbers — correctness-priced,
    not speed-priced.
    """
    from repro.core.opset import get_opset
    from repro.core.quantization import quantize, quantize_tree
    from repro.kernels.cached_step import _auto_interpret
    from repro.kernels.quant_matmul import quant_matmul

    cfg = get_arch(arch).reduced()
    bp = quantize_tree(bb.init_backbone(jax.random.PRNGKey(0), cfg),
                       bits=8, min_size=1024)
    ap = init_adapter(jax.random.PRNGKey(3), cfg, r=8)
    opt = adamw_init(ap)
    batch = make_batch(cfg, B, S)
    interp = _auto_interpret(None)
    out, stages_r = [], {}

    for impl in ("ref", "pallas"):
        tap = "int8" if impl == "pallas" else "f32"

        def fwd(p, b, impl=impl, tap=tap):
            ops = get_opset(impl, tap)
            return bb.backbone_forward(p, cfg, b, collect_taps=True, ops=ops)

        t_fwd = timeit(jax.jit(fwd), bp, batch)
        step = jax.jit(functools.partial(
            steps.pac_train_step, cfg=cfg, r=8, kernel_impl=impl,
            tap_policy=tap))
        t_step = timeit(step, bp, ap, opt, batch)
        loss = float(step(bp, ap, opt, batch)[0])
        stages_r[impl] = {
            "frozen_forward_ms": round(t_fwd * 1e3, 3),
            "train_step_ms": round(t_step * 1e3, 3),
            "loss": loss,
            "tap_form": "int8 q+scale (storage form)" if tap == "int8" else "f32",
        }
        out.append(row(
            f"epoch1_kernels_{impl}", t_step * 1e6 / B,
            f"fwd_ms={t_fwd*1e3:.2f};step_ms={t_step*1e3:.2f};loss={loss:.4f}",
        ))

    # -- quant_matmul block-size autotune on one projection tile ----------
    # Padded shapes (the OpSet's pad rules make every real projection land
    # on these multiples): M = B*S tokens, K = d_model, N = one 128-block
    # fan-out. Kept small so interpreter mode stays tractable.
    M, K, N = 256, 256, 512
    x = jax.random.normal(jax.random.PRNGKey(7), (M, K))
    wq = quantize(jax.random.normal(jax.random.PRNGKey(8), (K, N)),
                  bits=8, block=128)
    sweep = []
    for bm in (64, 128, 256):
        for bn in (128, 256):
            for bk in (128, 256):
                if M % bm or N % bn or K % bk:
                    continue
                f = functools.partial(
                    quant_matmul, bits=8, bm=bm, bn=bn, bk=bk, interpret=interp)
                t = timeit(f, x, wq.q, wq.scale)
                sweep.append({"bm": bm, "bn": bn, "bk": bk,
                              "ms": round(t * 1e3, 3)})
    best = min(sweep, key=lambda s: s["ms"])
    out.append(row(
        "epoch1_autotune_quant_matmul", best["ms"] * 1e3,
        f"best=bm{best['bm']}xbn{best['bn']}xbk{best['bk']};"
        f"shape={M}x{K}x{N};configs={len(sweep)}",
    ))

    payload = {
        "arch": cfg.name, "batch": B, "seq": S,
        "backend": jax.default_backend(),
        "pallas_interpret_mode": interp,
        "epoch1": stages_r,
        "ratio_pallas_over_ref": round(
            stages_r["pallas"]["train_step_ms"] / stages_r["ref"]["train_step_ms"], 3),
        "loss_abs_diff": abs(stages_r["pallas"]["loss"] - stages_r["ref"]["loss"]),
        "autotune_quant_matmul": {
            "shape_mkn": [M, K, N], "bits": 8, "sweep": sweep, "best": best,
        },
    }
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"wrote {out_json}")
    return out


def main_distributed(arch="internlm2-1.8b", dp=2, stages=2, n_micro=None, B=8, S=64) -> list:
    """Hybrid DP×PP step time vs single device, measured through the
    runtime layer: one :class:`~repro.runtime.EdgeSession` owns the pool
    (fake host devices forced pre-backend), the mesh, the model state
    and both compiled distributed steps; the per-step
    :class:`~repro.runtime.StepEvent` wall clocks are the measurement —
    what an epoch-1 minibatch (staged forward + cache fill) and a cached
    pure-DP step actually pay. Run in its own process (the device count
    locks at backend init)."""
    import numpy as np

    from repro.runtime import EdgeSession, EpochRunner, RunSpec, StepEvent

    n_micro = n_micro or stages
    # epochs=2: epoch 0 times the hybrid step, epoch 1 the cached step;
    # 4 steps each = 1 compile + 3 timed (matches timeit's median-of-3)
    spec = RunSpec(
        arch=arch, reduced=True, epochs=2, steps_per_epoch=4, batch=B,
        seq=S, r=8, init="random", dp=dp, stages=stages, micro=n_micro)
    walls, out = {}, []
    with EdgeSession(spec) as s:
        for ev in EpochRunner(s).events():
            if isinstance(ev, StepEvent):
                walls.setdefault(ev.mode, []).append(ev.wall_s)
        # single-device reference on the same model state: the plain
        # PAC+ step jitted without the mesh (runs on one pool device)
        batch = make_batch(s.cfg, B, S)
        t_pac = timeit(
            jax.jit(functools.partial(steps.pac_train_step, cfg=s.cfg, r=8)),
            s.backbone, s.adapter, adamw_init(s.adapter), batch,
        )
    t_pipe = float(np.median(walls[f"hybrid dp{dp}xpp{stages}"][1:]))
    t_cached_dp = float(np.median(walls["cached pure-dp"][1:]))
    for name, t in [("pac_1dev", t_pac), (f"pac_hybrid_dp{dp}xpp{stages}", t_pipe),
                    (f"pac_cached_dp{dp}", t_cached_dp)]:
        out.append(row(
            f"fig10_dist_step_time_{name}", t * 1e6 / B,
            f"per_sample_ms={t*1e3/B:.2f};n_micro={n_micro}",
        ))
    return out


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None,
                   help="default: t5-base-pac (single device) / internlm2-1.8b (distributed)")
    p.add_argument("--dp", type=int, default=1)
    p.add_argument("--stages", type=int, default=1)
    p.add_argument("--micro", type=int, default=None)
    p.add_argument("--kernels", action="store_true",
                   help="benchmark the ref-vs-pallas cached step per cache "
                        "policy and write BENCH_cached_step.json")
    p.add_argument("--epoch1-kernels", action="store_true",
                   help="benchmark the ref-vs-pallas epoch-1 step (OpSet "
                        "dispatch, int8 backbone) + quant_matmul block "
                        "autotune and write BENCH_epoch1_step.json")
    p.add_argument("--out", default=None,
                   help="JSON output path for --kernels / --epoch1-kernels")
    a = p.parse_args()
    if a.epoch1_kernels:
        main_epoch1_kernels(a.arch or "t5-base-pac",
                            out_json=a.out or "BENCH_epoch1_step.json")
    elif a.kernels:
        main_kernels(a.arch or "t5-base-pac",
                     out_json=a.out or "BENCH_cached_step.json")
    elif a.dp * a.stages > 1:
        # the session forces the fake device pool before backend init
        main_distributed(a.arch or "internlm2-1.8b", a.dp, a.stages, a.micro)
    else:
        main(a.arch or "t5-base-pac")
