"""Benchmark harness — one module per paper table/figure.

Usage: ``PYTHONPATH=src python -m benchmarks.run [--only fig3,table1,...]``
Each function prints ``name,us_per_call,derived`` CSV rows.
"""

import argparse
import sys
import time
import traceback

from repro.compat import enable_compilation_cache

BENCHES = [
    ("fig3_flops", "benchmarks.bench_flops"),
    ("table1_memory", "benchmarks.bench_memory"),
    ("fig13a_step_time", "benchmarks.bench_step_time"),
    ("table6_quality", "benchmarks.bench_quality"),
    ("table7_quant", "benchmarks.bench_quant"),
    ("fig14_init", "benchmarks.bench_init"),
    ("fig18_cache", "benchmarks.bench_cache"),
    ("fig16_scalability", "benchmarks.bench_scalability"),
    ("fig12_heterogeneous", "benchmarks.bench_heterogeneous"),
    ("roofline", "benchmarks.roofline_table"),
    ("serve_decode", "benchmarks.bench_decode"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench name prefixes")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    # reruns of the same bench matrix hit the persistent compile cache
    enable_compilation_cache()

    failures = []
    print("name,us_per_call,derived")
    for name, module in BENCHES:
        if only and not any(name.startswith(o) for o in only):
            continue
        t0 = time.perf_counter()
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main()
            print(f"# {name} done in {time.perf_counter() - t0:.1f}s", file=sys.stderr)
        except Exception as e:
            failures.append((name, repr(e)))
            print(f"{name}_FAILED,0.0,{e!r}")
            traceback.print_exc()
    if failures:
        print(f"# {len(failures)} benchmark(s) failed", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
