"""Planner demo — paper Fig. 17: device grouping across models and pools —
plus plan-driven execution: the winning plan for a heterogeneous pool is
not just printed, it is *run*, end to end, on emulated edge devices.

The survey half is pure planning (Alg. 1 over the paper's Jetson
profiles, full-size models). The execution half plans a CPU-runnable
demo model at period granularity on the heterogeneous Env.B pool, saves
the winning (RAGGED) Plan, and replays it through the runtime layer: a
:class:`~repro.runtime.RunSpec` pointing at the plan file, executed by
an :class:`~repro.runtime.EdgeSession` — which derives the
:class:`StagePartition` (uneven layer boundaries and all), builds the
mesh from it, and trains a few real steps through the 1F1B pipeline.
Modelled vs executed latency are printed side by side.

    PYTHONPATH=src python examples/plan_edge_cluster.py [--quick] [--steps N]
"""

import argparse
import dataclasses
import os
import tempfile

POOL_SIZE = 4  # fake host devices for the execution half


def survey(archs=("t5-base-pac", "bart-large-pac", "t5-large-pac")):
    """The Fig. 17 sweep: hybrid vs pure-DP vs pure-PP across pools."""
    from repro.configs import get_arch
    from repro.core.pipeline import simulate_plan
    from repro.core.planner import (
        HybridParallelismPlanner,
        JETSON_NANO_H,
        JETSON_NANO_L,
        JETSON_TX2_H,
        JETSON_TX2_L,
        model_layer_costs,
        plan_pure_dp,
        plan_pure_pp,
    )

    pools = {
        "Env.A (4x nano-H)": [JETSON_NANO_H] * 4,
        "Env.B (het 4-dev)": [JETSON_NANO_H, JETSON_NANO_L, JETSON_TX2_H, JETSON_TX2_L],
        "8x nano-H": [JETSON_NANO_H] * 8,
    }

    for arch in archs:
        cfg = get_arch(arch)
        costs = model_layer_costs(cfg, "pac", seq_len=128)
        print(f"\n=== {arch} ({cfg.param_count()/1e9:.2f}B params), technique=PAC+ ===")
        for pool_name, devs in pools.items():
            plan = HybridParallelismPlanner(costs, devs, len(devs), 4).plan()
            sim = simulate_plan(plan)
            dp = plan_pure_dp(costs, devs, len(devs), 4)
            pp = plan_pure_pp(costs, devs, len(devs), 4)
            print(f"\n[{pool_name}] HP: {plan.minibatch_latency*1e3:.0f} ms/minibatch, "
                  f"bubble {sim['bubble_fraction']:.1%} | "
                  f"DP: {'OOM' if dp is None else f'{dp.minibatch_latency*1e3:.0f} ms'} | "
                  f"PP: {'OOM' if pp is None else f'{pp.minibatch_latency*1e3:.0f} ms'}")
            print(plan.describe())


PLANNED_MB = 4  # samples per micro-batch, both planned and executed
N_MICRO = 2


def build_demo_plan():
    """The 10-period demo model and its RAGGED Env.B plan (pure Python —
    safe before any JAX backend init). Also the workload
    ``benchmarks/bench_heterogeneous.py --executed`` measures."""
    from repro.configs.base import ArchConfig, LayerSpec, register
    from repro.core.planner import (
        HybridParallelismPlanner,
        JETSON_NANO_H,
        JETSON_NANO_L,
        JETSON_TX2_H,
        JETSON_TX2_L,
        period_costs,
    )

    # registered so a RunSpec can name it (the session replays the plan)
    cfg = register(ArchConfig(
        name="plan-demo-10p", family="dense", n_layers=10, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
        pattern=(LayerSpec(kind="attn"),), source="plan-execution demo",
    ))
    # Env.B speed ratios with memory budgets scaled to the demo model
    # (~6.8 MB): no single device can host all 10 periods, so Alg. 1 must
    # pipeline — and the heterogeneous speeds make the split RAGGED
    env_b = [
        dataclasses.replace(d, memory_bytes=3 * 2 ** 20)
        for d in (JETSON_NANO_H, JETSON_NANO_L, JETSON_TX2_H, JETSON_TX2_L)
    ]
    plan = HybridParallelismPlanner(
        period_costs(cfg, "pac", seq_len=32), env_b, PLANNED_MB, N_MICRO,
    ).plan(max_stages=3)
    return cfg, plan


def execute_winning_plan(n_steps: int = 3) -> dict:
    """Plan the demo model on Env.B, save the Plan, and *replay* it
    through the runtime layer (RunSpec → EdgeSession) for real.

    Returns {modelled_ms, executed_ms, compile_ms, stages, periods,
    ragged} so the heterogeneous benchmark can reuse this workload."""
    if n_steps < 1:
        raise ValueError(f"n_steps must be >= 1, got {n_steps}")

    from repro.core.pipeline import simulate_plan
    from repro.runtime import EdgeSession, EpochRunner, RunSpec, StepEvent

    cfg, plan = build_demo_plan()
    part = plan.stage_partition()
    sim = simulate_plan(plan)

    print(f"\n=== executing the winning Env.B plan for {cfg.name} "
          f"({cfg.n_periods} periods) ===")
    print(plan.describe())
    print(f"partition: boundaries={part.boundaries} "
          f"periods/stage={part.periods_per_stage} "
          f"{'uniform' if part.is_uniform else 'RAGGED (padded+masked stages)'}")

    # save → replay: the plan file is the contract the session executes
    # (the same round-trip the trainer's --save-plan / --plan do)
    fd, plan_path = tempfile.mkstemp(suffix=".json", prefix="env_b_plan_")
    os.close(fd)
    try:
        plan.save(plan_path)
        # execute the micro-batch size the plan was made for: mb == PLANNED_MB
        spec = RunSpec(
            arch=cfg.name, epochs=1, steps_per_epoch=n_steps + 1,
            batch=PLANNED_MB * N_MICRO, seq=32, r=8, lr=1e-3, init="random",
            plan=plan_path, pool=POOL_SIZE, micro=N_MICRO, use_cache=False,
        )
        times = []
        with EdgeSession(spec) as session:  # forces the fake pool pre-backend
            dp = session.exec_dp
            for rec in EpochRunner(session).events():
                if isinstance(rec, StepEvent):  # step 0 pays compilation
                    times.append(rec.wall_s)
                    print(f"  step {rec.index}: loss={rec.loss:.4f} "
                          f"wall={rec.wall_s*1e3:.0f}ms")
    finally:
        os.unlink(plan_path)
    print(f"modelled (Jetson Env.B): {sim['minibatch_time']*1e3:.1f} ms/minibatch, "
          f"bubble {sim['bubble_fraction']:.1%}")
    print(f"executed (CPU-emulated {dp}x{part.n_stages} mesh): "
          f"{min(times[1:])*1e3:.0f} ms/step best-of-{n_steps} "
          f"(different silicon — the point is the *same plan* drives both)")
    return {
        "modelled_ms": sim["minibatch_time"] * 1e3,
        "executed_ms": min(times[1:]) * 1e3,
        "compile_ms": times[0] * 1e3,
        "stages": part.n_stages,
        "periods": part.periods_per_stage,
        "ragged": not part.is_uniform,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="skip the full-size survey (CI smoke)")
    ap.add_argument("--steps", type=int, default=3,
                    help="real train steps for the executed plan")
    args = ap.parse_args()

    # the survey is pure planning; the session forces its own device
    # pool before the backend comes up when the execution half runs
    if not args.quick:
        survey()
    execute_winning_plan(args.steps)


if __name__ == "__main__":
    main()
