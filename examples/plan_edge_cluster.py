"""Planner demo — paper Fig. 17: device grouping across models and pools.

    PYTHONPATH=src python examples/plan_edge_cluster.py
"""

import sys

sys.path.insert(0, "src")

from repro.configs import get_arch
from repro.core.pipeline import simulate_plan
from repro.core.planner import (
    HybridParallelismPlanner,
    JETSON_NANO_H,
    JETSON_NANO_L,
    JETSON_TX2_H,
    JETSON_TX2_L,
    model_layer_costs,
    plan_pure_dp,
    plan_pure_pp,
)

POOLS = {
    "Env.A (4x nano-H)": [JETSON_NANO_H] * 4,
    "Env.B (het 4-dev)": [JETSON_NANO_H, JETSON_NANO_L, JETSON_TX2_H, JETSON_TX2_L],
    "8x nano-H": [JETSON_NANO_H] * 8,
}

for arch in ("t5-base-pac", "bart-large-pac", "t5-large-pac"):
    cfg = get_arch(arch)
    costs = model_layer_costs(cfg, "pac", seq_len=128)
    print(f"\n=== {arch} ({cfg.param_count()/1e9:.2f}B params), technique=PAC+ ===")
    for pool_name, devs in POOLS.items():
        plan = HybridParallelismPlanner(costs, devs, len(devs), 4).plan()
        sim = simulate_plan(plan)
        dp = plan_pure_dp(costs, devs, len(devs), 4)
        pp = plan_pure_pp(costs, devs, len(devs), 4)
        print(f"\n[{pool_name}] HP: {plan.minibatch_latency*1e3:.0f} ms/minibatch, "
              f"bubble {sim['bubble_fraction']:.1%} | "
              f"DP: {'OOM' if dp is None else f'{dp.minibatch_latency*1e3:.0f} ms'} | "
              f"PP: {'OOM' if pp is None else f'{pp.minibatch_latency*1e3:.0f} ms'}")
        print(plan.describe())
