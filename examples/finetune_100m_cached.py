"""End-to-end driver: fine-tune a ~100M-param decoder with PAC+ for a few
hundred steps, activation cache on — the paper's personal-LLM scenario.

Epoch 1 pays the backbone forward; epochs 2+ hit the cache and train the
side network only (≈50× cheaper per step at r=8). The run itself is a
:class:`~repro.runtime.RunSpec` executed by an
:class:`~repro.runtime.EdgeSession` (no more shelling into the trainer
CLI) — the custom architecture just has to be registered first.

    PYTHONPATH=src python examples/finetune_100m_cached.py \
        [--steps 300] [--small]   # --small: ~10M for a fast demo
"""

import argparse
import dataclasses

from repro.configs.base import ArchConfig, LayerSpec, register
from repro.runtime import ConsoleHook, EdgeSession, RunSpec

# a ~100M decoder (12L, d=768, ff=2048, vocab=16384)
PAC_DEMO_100M = register(
    ArchConfig(
        name="pac-demo-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=2048,
        vocab=16384,
        pattern=(LayerSpec(kind="attn"),),
        source="demo config (~100M params)",
    )
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300, help="total train steps")
    ap.add_argument("--small", action="store_true", help="~10M fast demo")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = PAC_DEMO_100M
    if args.small:
        cfg = register(dataclasses.replace(
            cfg, name="pac-demo-10m", n_layers=4, d_model=256, n_heads=4,
            n_kv_heads=4, head_dim=64, d_ff=1024, vocab=4096,
        ))
    print(f"model: {cfg.name}, {cfg.param_count()/1e6:.1f}M params")

    # steps 1..6 of the paper workflow (quantize → pruning-init → plan →
    # epoch-1 capture → cached epochs) are the session's lifecycle
    steps_per_epoch = 16
    spec = RunSpec(
        arch=cfg.name, epochs=max(2, args.steps // steps_per_epoch),
        steps_per_epoch=steps_per_epoch, batch=args.batch, seq=args.seq,
        quant=8, init="pruning",
    )
    EdgeSession(spec, log=print).run(hooks=(ConsoleHook(),))


if __name__ == "__main__":
    main()
