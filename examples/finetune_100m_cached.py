"""End-to-end driver: fine-tune a ~100M-param decoder with PAC+ for a few
hundred steps, activation cache on — the paper's personal-LLM scenario.

Epoch 1 pays the backbone forward; epochs 2+ hit the cache and train the
side network only (≈50× cheaper per step at r=8).

    PYTHONPATH=src python examples/finetune_100m_cached.py \
        [--steps 300] [--small]   # --small: ~10M for a fast demo
"""

import argparse
import dataclasses
import sys

from repro.configs.base import ArchConfig, LayerSpec, register

# a ~100M decoder (12L, d=768, ff=2048, vocab=16384)
PAC_DEMO_100M = register(
    ArchConfig(
        name="pac-demo-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=2048,
        vocab=16384,
        pattern=(LayerSpec(kind="attn"),),
        source="demo config (~100M params)",
    )
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300, help="total train steps")
    ap.add_argument("--small", action="store_true", help="~10M fast demo")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = PAC_DEMO_100M
    if args.small:
        cfg = register(dataclasses.replace(
            cfg, name="pac-demo-10m", n_layers=4, d_model=256, n_heads=4,
            n_kv_heads=4, head_dim=64, d_ff=1024, vocab=4096,
        ))
    print(f"model: {cfg.name}, {cfg.param_count()/1e6:.1f}M params")

    # steps 1..6 of the paper workflow live in the trainer CLI — reuse it
    from repro.launch import train as trainer

    steps_per_epoch = 16
    epochs = max(2, args.steps // steps_per_epoch)
    sys.argv = [
        "train", "--arch", cfg.name, "--epochs", str(epochs),
        "--steps-per-epoch", str(steps_per_epoch),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--quant", "8", "--init", "pruning",
    ]
    trainer.main()


if __name__ == "__main__":
    main()
