"""Serve a PAC+-personalised model: batched greedy decoding through the
frozen (quantized) backbone + fine-tuned side network.

    PYTHONPATH=src python examples/serve_personalized.py [arch] [n_tokens]
"""

import functools
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import steps
from repro.core.parallel_adapters import init_adapter, init_adapter_cache
from repro.core.quantization import quantize_tree
from repro.models import backbone as bb


def main(arch: str = "xlstm-125m", n_new: int = 24) -> None:
    cfg = get_arch(arch).reduced()
    backbone = quantize_tree(bb.init_backbone(jax.random.PRNGKey(0), cfg), bits=8, min_size=1024)
    adapter = init_adapter(jax.random.PRNGKey(1), cfg, r=8)

    B, MAXLEN = 4, 64
    cache = bb.init_cache(cfg, B, MAXLEN)
    acache = init_adapter_cache(cfg, B, MAXLEN, r=8)
    step = jax.jit(functools.partial(steps.pac_decode_step, cfg=cfg, r=8))

    prompt = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0, cfg.vocab)
    tok = prompt[:, :1]
    out_tokens = []
    t0 = time.time()
    for t in range(prompt.shape[1] + n_new):
        if cfg.frontend:
            inp = {"embeds": jnp.zeros((B, 1, cfg.d_model))}
        else:
            inp = {"tokens": tok}
        logits, cache, acache = step(backbone, adapter, inp, cache, acache, jnp.int32(t))
        if t + 1 < prompt.shape[1]:
            tok = prompt[:, t + 1 : t + 2]  # teacher-force the prompt
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out_tokens.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={B}: generated {gen.shape[1]} tokens/seq "
          f"in {dt:.2f}s ({B * gen.shape[1] / dt:.1f} tok/s)")
    print("sample:", gen[0][:16].tolist())


if __name__ == "__main__":
    main(
        sys.argv[1] if len(sys.argv) > 1 else "xlstm-125m",
        int(sys.argv[2]) if len(sys.argv) > 2 else 24,
    )
