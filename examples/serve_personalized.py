"""Serve PAC+-personalised models through the multi-tenant engine: one
frozen (quantized) backbone, one fine-tuned side network *per user*, all
requests sharing a paged INT8 KV pool with continuous batching
(`repro.serve.ServeEngine`).

Each submitted request names its adapter — one decode step serves the
whole batch with per-request adapters gathered from the resident bank.
Prompts are ingested by a single batched prefill (all-attention archs)
or the stepwise fallback (SSM/hybrid archs), never the old
token-by-token teacher-forcing loop; the engine's greedy output at f32
KV is checked byte-for-byte against that legacy loop below.

``--kernels pallas`` routes the frozen decode through the pallas OpSet
(`repro.core.opset`): quantized projections in `quant_matmul`, the paged
Pallas attention kernel walking the page tables. Off-TPU the kernels run
in interpreter mode: a correctness demo, not a speed claim.

    PYTHONPATH=src python examples/serve_personalized.py \
        [--arch internlm2-1.8b] [--tokens 24] [--kernels ref|pallas] \
        [--kv int8|bf16|f32] [--users 3]
"""

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import steps
from repro.core.parallel_adapters import init_adapter, init_adapter_cache
from repro.core.quantization import quantize_tree
from repro.models import backbone as bb
from repro.serve import ServeEngine

PROMPT_LEN = 8


def legacy_greedy_loop(backbone, adapter, cfg, prompt, n_new, max_len, kernels):
    """The pre-engine serving loop: every prompt token teacher-forced
    through `pac_decode_step`, one request per run — the byte-stability
    reference for the engine's f32-KV output."""
    cache = bb.init_cache(cfg, 1, max_len)
    acache = init_adapter_cache(cfg, 1, max_len, r=8)
    step = jax.jit(functools.partial(
        steps.pac_decode_step, cfg=cfg, r=8, kernel_impl=kernels))
    tok = jnp.asarray([[prompt[0]]], jnp.int32)
    out = []
    for t in range(len(prompt) + n_new - 1):
        logits, cache, acache = step(
            backbone, adapter, {"tokens": tok}, cache, acache, jnp.int32(t))
        if t + 1 < len(prompt):
            tok = jnp.asarray([[prompt[t + 1]]], jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(int(tok[0, 0]))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--tokens", type=int, default=24, help="tokens to generate")
    ap.add_argument("--kernels", default="ref", choices=["ref", "pallas"],
                    help="OpSet for the frozen backbone decode")
    ap.add_argument("--kv", default="int8", choices=["int8", "bf16", "f32"],
                    help="KV page storage policy")
    ap.add_argument("--users", type=int, default=3)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    backbone = quantize_tree(
        bb.init_backbone(jax.random.PRNGKey(0), cfg), bits=8, min_size=1024)
    adapters = {
        f"user{u}": init_adapter(jax.random.PRNGKey(1 + u), cfg, r=8)
        for u in range(args.users)
    }
    max_len = PROMPT_LEN + args.tokens
    engine_kw = dict(
        r=8, kernel_impl=args.kernels, page_size=8, max_len=max_len,
        max_batch=max(4, args.users),
    )
    engine = ServeEngine(backbone, cfg, adapters, kv_policy=args.kv, **engine_kw)

    prompts = jax.random.randint(
        jax.random.PRNGKey(2), (args.users, PROMPT_LEN), 0, cfg.vocab).tolist()
    t0 = time.perf_counter()
    handles = [
        engine.submit(prompts[u], f"user{u}", max_new_tokens=args.tokens)
        for u in range(args.users)
    ]
    engine.drain()
    dt = time.perf_counter() - t0
    results = [h.result() for h in handles]
    n_gen = sum(len(r) for r in results)
    print(f"arch={cfg.name} users={args.users} kernels={args.kernels} "
          f"kv={args.kv} prefill={engine.prefill_mode}: generated {n_gen} "
          f"tokens in {dt:.2f}s ({n_gen / dt:.1f} tok/s)")
    for u, r in enumerate(results):
        print(f"  user{u}: {r[:12]}")

    # byte-stability gate: the engine at f32 KV must reproduce the legacy
    # teacher-forcing loop's greedy tokens exactly, user by user
    eng_f32 = (engine if args.kv == "f32"
               else ServeEngine(backbone, cfg, adapters, kv_policy="f32", **engine_kw))
    if args.kv != "f32":
        hs = [eng_f32.submit(prompts[u], f"user{u}", max_new_tokens=args.tokens)
              for u in range(args.users)]
        eng_f32.drain()
        results_f32 = [h.result() for h in hs]
    else:
        results_f32 = results
    for u in range(args.users):
        legacy = legacy_greedy_loop(
            backbone, adapters[f"user{u}"], cfg, prompts[u], args.tokens,
            max_len, args.kernels)
        assert results_f32[u] == legacy, (
            f"user{u}: engine f32 output diverged from the legacy loop:\n"
            f"  engine: {results_f32[u]}\n  legacy: {legacy}")
    print(f"engine(f32 KV) == legacy teacher-forcing loop for all "
          f"{args.users} users: ok")


if __name__ == "__main__":
    main()
