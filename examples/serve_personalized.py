"""Serve a PAC+-personalised model: batched greedy decoding through the
frozen (quantized) backbone + fine-tuned side network.

``--kernels pallas`` routes the frozen decode through the pallas OpSet
(`repro.core.opset`): the QKV/MLP projections consume the still-quantized
INT8 weights via `quant_matmul` instead of dequantize-then-dense (the
side network and LM head stay on the ref ops — they are the trainable/fp
math). Off-TPU the kernels run in interpreter mode: a correctness demo,
not a speed claim.

    PYTHONPATH=src python examples/serve_personalized.py \
        [--arch xlstm-125m] [--tokens 24] [--kernels ref|pallas]
"""

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import steps
from repro.core.parallel_adapters import init_adapter, init_adapter_cache
from repro.core.quantization import quantize_tree
from repro.models import backbone as bb


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--tokens", type=int, default=24, help="tokens to generate")
    ap.add_argument("--kernels", default="ref", choices=["ref", "pallas"],
                    help="OpSet for the frozen backbone decode")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    backbone = quantize_tree(bb.init_backbone(jax.random.PRNGKey(0), cfg), bits=8, min_size=1024)
    adapter = init_adapter(jax.random.PRNGKey(1), cfg, r=8)

    B, MAXLEN = 4, 64
    cache = bb.init_cache(cfg, B, MAXLEN)
    acache = init_adapter_cache(cfg, B, MAXLEN, r=8)
    step = jax.jit(functools.partial(
        steps.pac_decode_step, cfg=cfg, r=8, kernel_impl=args.kernels))

    prompt = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0, cfg.vocab)
    tok = prompt[:, :1]
    out_tokens = []
    t0 = time.perf_counter()
    for t in range(prompt.shape[1] + args.tokens):
        if cfg.frontend:
            inp = {"embeds": jnp.zeros((B, 1, cfg.d_model))}
        else:
            inp = {"tokens": tok}
        logits, cache, acache = step(backbone, adapter, inp, cache, acache, jnp.int32(t))
        if t + 1 < prompt.shape[1]:
            tok = prompt[:, t + 1 : t + 2]  # teacher-force the prompt
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out_tokens.append(tok)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={B} kernels={args.kernels}: generated "
          f"{gen.shape[1]} tokens/seq in {dt:.2f}s ({B * gen.shape[1] / dt:.1f} tok/s)")
    print("sample:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
