"""Serve with the fully-quantized memory stack through the paged engine:
INT8 backbone weights (paper Eq. 1) + paged INT8 KV (beyond-paper,
EXPERIMENTS §Beyond-paper) vs the f32-paged baseline — prints weight /
KV-pool bytes and the per-token KV footprint, and verifies the greedy
streams agree.

Both legs run `repro.serve.ServeEngine` (one batched prefill + paged
continuous-batching decode; no adapters — the bare backbone). The f32
leg is additionally checked byte-for-byte against the legacy
token-by-token `decode_step` loop it replaced.

``--kernels pallas`` runs the decode through the pallas OpSet: quantized
projections in `quant_matmul`, the paged Pallas attention kernel
dequantizing INT8 pages in VMEM (interpret mode off-TPU).

    PYTHONPATH=src python examples/serve_quantized_kv.py \
        [--arch internlm2-1.8b] [--tokens 16] [--kernels ref|pallas]
"""

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import steps
from repro.core.quantization import quantize_tree, tree_storage_bytes
from repro.models import backbone as bb
from repro.serve import ServeEngine, kv_bytes_per_token

B = 4
PROMPT_LEN = 8


def _pool_bytes(pools):
    return sum(t.size * t.dtype.itemsize for t in jax.tree.leaves(pools))


def legacy_greedy_loop(params, cfg, prompt, n_new, max_len, kernels):
    """The pre-engine loop: prompt teacher-forced token-by-token through
    `decode_step` — the byte-stability reference for the f32 engine leg."""
    cache = bb.init_cache(cfg, 1, max_len)
    step = jax.jit(functools.partial(steps.decode_step, cfg=cfg, kernel_impl=kernels))
    tok = jnp.asarray([[prompt[0]]], jnp.int32)
    out = []
    for t in range(len(prompt) + n_new - 1):
        logits, cache = step(params, {"tokens": tok}, cache, jnp.int32(t))
        if t + 1 < len(prompt):
            tok = jnp.asarray([[prompt[t + 1]]], jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(int(tok[0, 0]))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--tokens", type=int, default=16, help="tokens to generate")
    ap.add_argument("--kernels", default="ref", choices=["ref", "pallas"],
                    help="OpSet for the backbone decode")
    args = ap.parse_args()
    n_new = args.tokens

    cfg = get_arch(args.arch).reduced()
    bp_f32 = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    bp_q = quantize_tree(bp_f32, bits=8, min_size=1024)
    max_len = PROMPT_LEN + n_new
    prompts = jax.random.randint(
        jax.random.PRNGKey(2), (B, PROMPT_LEN), 0, cfg.vocab).tolist()

    def run(params, kv_policy, kernels):
        eng = ServeEngine(
            params, cfg, kernel_impl=kernels, kv_policy=kv_policy,
            page_size=8, max_len=max_len, max_batch=B)
        t0 = time.perf_counter()
        handles = [eng.submit(p, max_new_tokens=n_new) for p in prompts]
        eng.drain()
        return [h.result() for h in handles], time.perf_counter() - t0, eng

    ref, t_f, eng_f = run(bp_f32, "f32", "ref")
    out, t_q, eng_q = run(bp_q, "int8", args.kernels)

    n_tok = sum(len(r) for r in ref)
    agree = sum(
        int(a == b) for ra, rb in zip(ref, out) for a, b in zip(ra, rb)
    ) / n_tok
    print(f"arch={cfg.name}  {n_new} tokens × batch {B}  kernels={args.kernels}  "
          f"prefill={eng_q.prefill_mode}")
    print(f"  weights: f32 {tree_storage_bytes(bp_f32)/2**20:.1f} MB -> int8 "
          f"{tree_storage_bytes(bp_q)/2**20:.1f} MB")
    print(f"  KV pool: f32 {_pool_bytes(eng_f.pools)/2**20:.2f} MB -> int8+scales "
          f"{_pool_bytes(eng_q.pools)/2**20:.2f} MB  "
          f"({kv_bytes_per_token(cfg, 'f32')} -> "
          f"{kv_bytes_per_token(cfg, 'int8')} KV bytes/token)")
    print(f"  wall: f32 {t_f:.2f}s, quantized {t_q:.2f}s (CPU; TPU target is "
          f"bandwidth-bound where the 4x byte cut pays)")
    print(f"  greedy-token agreement: {agree:.1%} (random weights -> near-"
          f"uniform logits; step flips compound autoregressively)")

    # byte-stability gate: the f32 engine leg must reproduce the legacy
    # token-by-token decode loop exactly
    for i, p in enumerate(prompts):
        legacy = legacy_greedy_loop(bp_f32, cfg, p, n_new, max_len, "ref")
        assert ref[i] == legacy, (
            f"request {i}: engine f32 output diverged from the legacy loop:\n"
            f"  engine: {ref[i]}\n  legacy: {legacy}")
    print("  engine(f32 KV) == legacy decode_step loop: ok")


if __name__ == "__main__":
    main()
