"""Serve with the fully-quantized memory stack: INT8 backbone weights
(paper Eq. 1) + INT8 KV cache (beyond-paper, EXPERIMENTS §Beyond-paper)
vs the f32 baseline — prints the cache/weight bytes and verifies the
generated tokens agree.

``--kernels pallas`` additionally runs the quantized leg's decode
through the pallas OpSet (still-quantized projections in `quant_matmul`;
interpret mode off-TPU).

    PYTHONPATH=src python examples/serve_quantized_kv.py \
        [--arch internlm2-1.8b] [--tokens 16] [--kernels ref|pallas]
"""

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import steps
from repro.core.quantization import quantize_tree, tree_storage_bytes
from repro.models import backbone as bb


def _cache_bytes(cache):
    return sum(t.size * t.dtype.itemsize for t in jax.tree.leaves(cache))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--tokens", type=int, default=16, help="tokens to generate")
    ap.add_argument("--kernels", default="ref", choices=["ref", "pallas"],
                    help="OpSet for the quantized leg's backbone decode")
    args = ap.parse_args()
    n_new = args.tokens

    cfg = get_arch(args.arch).reduced()
    bp_f32 = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    bp_q = quantize_tree(bp_f32, bits=8, min_size=1024)
    B, MAXLEN = 4, 48
    step_f = jax.jit(functools.partial(steps.decode_step, cfg=cfg))
    step_q = jax.jit(functools.partial(steps.decode_step, cfg=cfg, kernel_impl=args.kernels))

    def generate(step, params, cache):
        tok = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
        toks, last = [], None
        for t in range(n_new):
            inp = {"embeds": jnp.zeros((B, 1, cfg.d_model))} if cfg.frontend else {"tokens": tok}
            logits, cache = step(params, inp, cache, jnp.int32(t))
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            toks.append(tok)
            last = logits
        return jnp.concatenate(toks, 1), cache, last

    t0 = time.perf_counter()
    ref, c_f, lg_f = generate(step_f, bp_f32, bb.init_cache(cfg, B, MAXLEN))
    t_f = time.perf_counter() - t0

    t0 = time.perf_counter()
    out, c_q, lg_q = generate(step_q, bp_q, bb.init_cache(cfg, B, MAXLEN, kv_quant=8))
    t_q = time.perf_counter() - t0

    agree = float(jnp.mean((ref == out).astype(jnp.float32)))
    print(f"arch={cfg.name}  {n_new} tokens × batch {B}  kernels={args.kernels}")
    print(f"  weights: f32 {tree_storage_bytes(bp_f32)/2**20:.1f} MB -> int8 "
          f"{tree_storage_bytes(bp_q)/2**20:.1f} MB")
    print(f"  KV cache: f32 {_cache_bytes(c_f)/2**20:.1f} MB -> int8+scales "
          f"{_cache_bytes(c_q)/2**20:.1f} MB")
    print(f"  wall: f32 {t_f:.2f}s, quantized {t_q:.2f}s (CPU; TPU target is "
          f"bandwidth-bound where the 4x byte cut pays)")
    print(f"  greedy-token agreement: {agree:.1%} (random weights -> near-"
          f"uniform logits; step flips compound autoregressively)")

    # faithfulness check under teacher forcing (same tokens through both)
    forced = jax.random.randint(jax.random.PRNGKey(3), (B, n_new), 0, cfg.vocab)
    cf, cq = bb.init_cache(cfg, B, MAXLEN), bb.init_cache(cfg, B, MAXLEN, kv_quant=8)
    worst = 0.0
    for t in range(n_new):
        inp = ({"embeds": jnp.zeros((B, 1, cfg.d_model))} if cfg.frontend
               else {"tokens": forced[:, t : t + 1]})
        lf, cf = step_f(bp_f32, inp, cf, jnp.int32(t))
        lq, cq = step_q(bp_q, inp, cq, jnp.int32(t))
        worst = max(worst, float(jnp.max(jnp.abs(lq - lf))) / (float(jnp.max(jnp.abs(lf))) + 1e-6))
    print(f"  max relative logit deviation (teacher-forced, int8 W + int8 KV): {worst:.2%}")
    assert worst < 0.10, "quantized serving diverged from the f32 reference"
    print("ok")


if __name__ == "__main__":
    main()
