"""Serve with the fully-quantized memory stack: INT8 backbone weights
(paper Eq. 1) + INT8 KV cache (beyond-paper, EXPERIMENTS §Beyond-paper)
vs the f32 baseline — prints the cache/weight bytes and verifies the
generated tokens agree.

    PYTHONPATH=src python examples/serve_quantized_kv.py [arch] [n_tokens]
"""

import functools
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import steps
from repro.core.quantization import quantize_tree, tree_storage_bytes
from repro.models import backbone as bb


def _cache_bytes(cache):
    return sum(t.size * t.dtype.itemsize for t in jax.tree.leaves(cache))


def main(arch: str = "internlm2-1.8b", n_new: int = 16) -> None:
    cfg = get_arch(arch).reduced()
    bp_f32 = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    bp_q = quantize_tree(bp_f32, bits=8, min_size=1024)
    B, MAXLEN = 4, 48
    step = jax.jit(functools.partial(steps.decode_step, cfg=cfg))

    def generate(params, cache):
        tok = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
        toks, last = [], None
        for t in range(n_new):
            inp = {"embeds": jnp.zeros((B, 1, cfg.d_model))} if cfg.frontend else {"tokens": tok}
            logits, cache = step(params, inp, cache, jnp.int32(t))
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            toks.append(tok)
            last = logits
        return jnp.concatenate(toks, 1), cache, last

    t0 = time.time()
    ref, c_f, lg_f = generate(bp_f32, bb.init_cache(cfg, B, MAXLEN))
    t_f = time.time() - t0

    t0 = time.time()
    out, c_q, lg_q = generate(bp_q, bb.init_cache(cfg, B, MAXLEN, kv_quant=8))
    t_q = time.time() - t0

    agree = float(jnp.mean((ref == out).astype(jnp.float32)))
    print(f"arch={cfg.name}  {n_new} tokens × batch {B}")
    print(f"  weights: f32 {tree_storage_bytes(bp_f32)/2**20:.1f} MB -> int8 "
          f"{tree_storage_bytes(bp_q)/2**20:.1f} MB")
    print(f"  KV cache: f32 {_cache_bytes(c_f)/2**20:.1f} MB -> int8+scales "
          f"{_cache_bytes(c_q)/2**20:.1f} MB")
    print(f"  wall: f32 {t_f:.2f}s, quantized {t_q:.2f}s (CPU; TPU target is "
          f"bandwidth-bound where the 4x byte cut pays)")
    print(f"  greedy-token agreement: {agree:.1%} (random weights -> near-"
          f"uniform logits; step flips compound autoregressively)")

    # faithfulness check under teacher forcing (same tokens through both)
    forced = jax.random.randint(jax.random.PRNGKey(3), (B, n_new), 0, cfg.vocab)
    cf, cq = bb.init_cache(cfg, B, MAXLEN), bb.init_cache(cfg, B, MAXLEN, kv_quant=8)
    worst = 0.0
    for t in range(n_new):
        inp = ({"embeds": jnp.zeros((B, 1, cfg.d_model))} if cfg.frontend
               else {"tokens": forced[:, t : t + 1]})
        lf, cf = step(bp_f32, inp, cf, jnp.int32(t))
        lq, cq = step(bp_q, inp, cq, jnp.int32(t))
        worst = max(worst, float(jnp.max(jnp.abs(lq - lf))) / (float(jnp.max(jnp.abs(lf))) + 1e-6))
    print(f"  max relative logit deviation (teacher-forced, int8 W + int8 KV): {worst:.2%}")
    assert worst < 0.10, "quantized serving diverged from the f32 reference"
    print("ok")


if __name__ == "__main__":
    main(
        sys.argv[1] if len(sys.argv) > 1 else "internlm2-1.8b",
        int(sys.argv[2]) if len(sys.argv) > 2 else 16,
    )
