"""Hybrid DP×pipeline fine-tuning on an (emulated) edge pool.

The paper's Fig. 10/11 workflow end to end, on one machine: four fake
CPU host devices stand in for a pool of four Jetson-class edge boards
arranged as a 2×2 (dp, stage) mesh.

* epoch 1 — the frozen backbone forward is *staged* over the pipeline
  axis (1F1B micro-batching via ``pipeline_apply``); every stage emits
  its periods' taps, the adapter trains data-parallel with one tiny
  AllReduce of its grads per minibatch, and the activation cache fills.
* epoch ≥2 — the backbone never runs again: cached taps are re-batched
  (fresh shuffle each epoch) and the run drops to pure data parallelism.

Run:  PYTHONPATH=src python examples/hybrid_edge_training.py
"""

from repro.compat import force_host_device_count

DP, STAGES, N_MICRO = 2, 2, 2
force_host_device_count(DP * STAGES)  # before any JAX backend init

import functools  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.core import steps  # noqa: E402
from repro.core.activation_cache import ActivationCache  # noqa: E402
from repro.core.init_methods import pruning_init  # noqa: E402
from repro.core.planner import (  # noqa: E402
    HybridParallelismPlanner,
    JETSON_NANO_H,
    JETSON_TX2_H,
    model_layer_costs,
)
from repro.data import DataPipeline, SyntheticPersonalCorpus  # noqa: E402
from repro.launch import sharding as shard  # noqa: E402
from repro.launch.mesh import make_edge_mesh  # noqa: E402
from repro.models import backbone as bb  # noqa: E402
from repro.optim import adamw_init  # noqa: E402


def main():
    cfg = get_arch("internlm2-1.8b").reduced()
    B, S, EPOCHS = 4, 32, 3

    # offline plan for the (heterogeneous) pool — Alg. 1
    devices = [JETSON_TX2_H, JETSON_TX2_H, JETSON_NANO_H, JETSON_NANO_H]
    plan = HybridParallelismPlanner(
        model_layer_costs(cfg, "pac", seq_len=S), devices, B, N_MICRO
    ).plan(max_stages=STAGES)
    print(plan.describe())

    mesh = make_edge_mesh(DP, STAGES)
    print(f"executing on mesh {dict(mesh.shape)} with {plan.micro_batches} micro-batches")

    bp = bb.init_backbone(jax.random.PRNGKey(0), cfg)
    adapter = pruning_init(jax.random.PRNGKey(1), bp, cfg, r=8)
    opt = adamw_init(adapter)

    corpus = SyntheticPersonalCorpus(cfg.vocab, S + 1, 4 * B, seed=0)
    pipe = DataPipeline(corpus, global_batch=B, shuffle=True, seed=0)
    # bf16 entries: half the cache bytes, taps within bf16 tolerance
    cache = ActivationCache(budget_bytes=1 << 30, compress="bf16")

    step1 = jax.jit(functools.partial(
        steps.pipeline_pac_train_step, cfg=cfg, mesh=mesh,
        n_micro=plan.micro_batches, r=8, lr=3e-3))
    stepN = None

    for epoch in range(EPOCHS):
        t0, losses = time.time(), []
        for batch in pipe.epoch(epoch):  # fresh shuffle; cache keys per-seq
            ids = batch.pop("seq_ids")
            hit = cache.get_batch(ids, with_final=True)
            if hit is None:  # epoch-1: hybrid DP×PP through the pipeline
                loss, adapter, opt, (b0, taps, bf) = step1(bp, adapter, opt, batch)
                cache.put_batch(ids, b0, taps, bf)
            else:  # epoch≥2: pure DP from the cache
                b0, taps, bf = hit
                cached = {
                    "b0": jnp.asarray(b0), "taps": jnp.asarray(taps),
                    "b_final": jnp.asarray(bf),
                    "labels": batch["labels"],
                }
                if stepN is None:
                    stepN = jax.jit(
                        functools.partial(steps.pac_cached_train_step, cfg=cfg, r=8, lr=3e-3),
                        in_shardings=shard.cached_step_shardings(
                            bp, adapter, opt, cached, mesh))
                loss, adapter, opt = stepN(bp, adapter, opt, cached)
            losses.append(float(loss))
        mode = "hybrid dp×pp" if epoch == 0 else "cached pure-dp"
        print(f"epoch {epoch}: loss={np.mean(losses):.4f} "
              f"time={time.time()-t0:.1f}s ({mode})")


if __name__ == "__main__":
    main()
