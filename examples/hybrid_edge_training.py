"""Hybrid DP×pipeline fine-tuning on an (emulated) edge pool.

The paper's Fig. 10/11 workflow end to end, on one machine: four fake
CPU host devices stand in for a pool of four Jetson-class edge boards
arranged as a 2×2 (dp, stage) mesh.

* epoch 1 — the frozen backbone forward is *staged* over the pipeline
  axis (1F1B micro-batching via ``pipeline_apply``); every stage emits
  its periods' taps, the adapter trains data-parallel with one tiny
  AllReduce of its grads per minibatch, and the activation cache fills.
* epoch ≥2 — the backbone never runs again: cached taps are re-batched
  (fresh shuffle each epoch) and the run drops to pure data parallelism.

All of that wiring — forcing the fake device pool before the backend
comes up, the mesh, the cache, both compiled steps — is the runtime
layer's job now: this example is one :class:`~repro.runtime.RunSpec`
and an :class:`~repro.runtime.EdgeSession`. The offline Alg. 1 plan for
a *heterogeneous* pool is still printed first (pure planning, the same
report the session logs for its homogeneous emulated pool).

Run:  PYTHONPATH=src python examples/hybrid_edge_training.py
"""

from repro.runtime import ConsoleHook, EdgeSession, RunSpec

DP, STAGES, N_MICRO = 2, 2, 2


def main():
    # the run, as data: a 2×2 (dp, stage) mesh, bf16 cache entries
    # (half the bytes, taps within bf16 tolerance), 3 epochs of which
    # the last two train straight from the cache
    spec = RunSpec(
        arch="internlm2-1.8b", reduced=True, epochs=3, steps_per_epoch=4,
        batch=4, seq=32, r=8, lr=3e-3, init="pruning", seed=0,
        dp=DP, stages=STAGES, micro=N_MICRO,
        cache_compress="bf16", cache_budget_mb=1024,
    )

    # offline plan for a *heterogeneous* pool (Alg. 1) — report only;
    # the session below executes the CLI-pinned 2×2 mesh
    from repro.core.planner import (
        HybridParallelismPlanner,
        JETSON_NANO_H,
        JETSON_TX2_H,
        model_layer_costs,
    )

    cfg = spec.arch_config()
    devices = [JETSON_TX2_H, JETSON_TX2_H, JETSON_NANO_H, JETSON_NANO_H]
    plan = HybridParallelismPlanner(
        model_layer_costs(cfg, "pac", seq_len=spec.seq), devices,
        spec.batch, N_MICRO,
    ).plan(max_stages=STAGES)
    print(plan.describe())

    # the session owns the pool (fake host devices forced pre-backend),
    # mesh, cache, and both step variants; ConsoleHook prints the
    # classic per-epoch line (mode switches hybrid → cached pure-dp)
    EdgeSession(spec, log=print).run(hooks=(ConsoleHook(),))


if __name__ == "__main__":
    main()
