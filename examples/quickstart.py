"""Quickstart: fine-tune any assigned architecture with PAC+ in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py [arch]
"""

import functools
import sys

import jax

from repro.configs import get_arch, list_archs
from repro.core import steps
from repro.core.parallel_adapters import init_adapter
from repro.models import backbone as bb
from repro.optim import adamw_init


def main(arch: str = "gemma2-2b") -> None:
    print(f"available architectures: {list_archs()}")

    cfg = get_arch(arch).reduced()  # CPU-scale variant of the same family
    backbone = bb.init_backbone(jax.random.PRNGKey(0), cfg)  # frozen
    adapter = init_adapter(jax.random.PRNGKey(1), cfg, r=8)  # trainable side net
    opt = adamw_init(adapter)

    B, S = 4, 32
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab),
    }
    if cfg.frontend:  # audio/vlm: the stub frontend supplies embeddings
        batch["embeds"] = jax.random.normal(jax.random.PRNGKey(4), (B, S, cfg.d_model)) * 0.3
        del batch["tokens"]

    step = jax.jit(functools.partial(steps.pac_train_step, cfg=cfg, r=8))
    for i in range(10):
        loss, adapter, opt, _cache = step(backbone, adapter, opt, batch)
        print(f"step {i}: loss={float(loss):.4f}")
    print("done — backbone untouched, adapter fine-tuned.")


if __name__ == "__main__":
    main(*sys.argv[1:2])
