"""Quickstart: fine-tune any assigned architecture with PAC+ in a few lines.

The run is a :class:`~repro.runtime.RunSpec` executed by an
:class:`~repro.runtime.EdgeSession` — the same engine behind the trainer
CLI (quantize → init adapters → epoch-1 capture → cached epochs).

    PYTHONPATH=src python examples/quickstart.py [--arch gemma2-2b]
"""

import argparse

from repro.configs import list_archs
from repro.runtime import ConsoleHook, EdgeSession, RunSpec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma2-2b",
                    help=f"one of: {', '.join(list_archs())}")
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--steps-per-epoch", type=int, default=4)
    ap.add_argument("--kernels", default="ref", choices=["ref", "pallas"],
                    help="OpSet for the frozen path (pallas = quantized "
                         "kernels; interpret mode off-TPU)")
    args = ap.parse_args()

    spec = RunSpec(
        arch=args.arch, reduced=True,  # CPU-scale variant of the same family
        epochs=args.epochs, steps_per_epoch=args.steps_per_epoch,
        batch=4, seq=32, r=8, quant=8, kernels=args.kernels,
        cache_compress="int8" if args.kernels == "pallas" else "f32",
    )
    EdgeSession(spec, log=print).run(hooks=(ConsoleHook(),))
    print("done — backbone untouched, adapter fine-tuned.")


if __name__ == "__main__":
    main()
